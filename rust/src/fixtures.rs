//! Synthetic in-memory fixtures: a tiny zoo + platform model + profiles
//! that need no `artifacts/` directory on disk.
//!
//! Everything downstream of the profiler (scenarios, dispatch, sharding,
//! experiments) is exercisable from these fixtures alone, which is what
//! doc-examples, benches, and PJRT-free environments use. The task
//! models are stand-ins (2 subgraphs × 3 variants per task), but the
//! *structure* the scheduler cares about — heterogeneous per-task
//! latencies, dense/INT8/structured variant trade-offs, per-processor
//! scaling — matches the real artifact zoos.
//!
//! ```
//! use sparseloom::fixtures;
//! use sparseloom::scenario::{Scenario, Server};
//!
//! let (zoo, lm, profiles) = fixtures::tiny();
//! let server = Server::builder(&zoo, &lm, &profiles).build();
//! let scenario = Scenario::closed_loop(&fixtures::task_names(&zoo),
//!                                      fixtures::slos(&zoo, 0.5, 1e9))
//!     .with_queries(5);
//! assert_eq!(server.run(&scenario).unwrap().total_queries, 5);
//! ```

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::gbdt::GbdtParams;
use crate::profiler::{profile_task, ProfilerConfig, TaskProfile};
use crate::soc::{BaseLatencies, LatencyModel, Platform};
use crate::stitching::StitchSpace;
use crate::workload::Slo;
use crate::zoo::{
    DType, KernelPath, Precision, SubgraphWeights, TaskVariant, TaskZoo, TensorSpec,
    VariantSpec, VariantType, Zoo,
};

/// Subgraphs per fixture task (pipeline stages).
pub const SUBGRAPHS: usize = 2;

fn variant(
    name: &str,
    vtype: VariantType,
    sparsity: f64,
    kernel_path: KernelPath,
    accuracy: f64,
    bytes: u64,
) -> TaskVariant {
    TaskVariant {
        spec: VariantSpec {
            name: name.into(),
            vtype,
            sparsity,
            kernel_path,
            precision: Precision::Fp32,
        },
        accuracy,
        subgraphs: (0..SUBGRAPHS)
            .map(|_| SubgraphWeights {
                file: PathBuf::from("/dev/null"),
                bytes,
                params: vec![TensorSpec { dtype: DType::F32, shape: vec![4] }],
            })
            .collect(),
    }
}

fn synthetic_task(name: &str, top_accuracy: f64) -> TaskZoo {
    TaskZoo {
        name: name.into(),
        family: "synthetic".into(),
        input_dim: 8,
        iface: vec![8; SUBGRAPHS + 1],
        variants: vec![
            variant("dense", VariantType::Dense, 0.0, KernelPath::Dense, top_accuracy, 1000),
            variant(
                "int8",
                VariantType::Int8,
                0.0,
                KernelPath::Dense,
                top_accuracy - 0.05,
                400,
            ),
            variant(
                "struct50",
                VariantType::Structured,
                0.5,
                KernelPath::BlockSparse,
                top_accuracy - 0.15,
                600,
            ),
        ],
        hlo: BTreeMap::new(),
    }
}

/// Build a fixture from `(task name, top accuracy, base latency ms)`
/// triples: the zoo, a desktop latency model seeded with those base
/// latencies, and estimator-mode profiles with oracle truth attached.
pub fn build(specs: &[(&str, f64, f64)]) -> (Zoo, LatencyModel, BTreeMap<String, TaskProfile>) {
    let mut tasks = BTreeMap::new();
    let mut base = BaseLatencies::new();
    for &(name, accuracy, base_ms) in specs {
        tasks.insert(name.to_string(), synthetic_task(name, accuracy));
        for sg in 0..SUBGRAPHS {
            base.set(name, sg, KernelPath::Dense, base_ms);
            base.set(name, sg, KernelPath::BlockSparse, base_ms * 0.8);
        }
    }
    assemble(tasks, base)
}

/// Stitch-friendly fixture: like [`build`] but every task carries a
/// fourth, unstructured-sparse variant (`us90`, 90 % sparsity). On the
/// desktop platform's heterogeneous placement orders the fastest
/// composition is then a *mix* — `us90` on the CPU position (its
/// DeepSparse-style engine rewards masked weights) stitched with
/// `struct50` or `int8` on the GPU/NPU position — strictly faster than
/// every pure variant under any order in Ω. That is the regime the
/// online synthesis action (`PlannerConfig::synthesize`) exists to
/// exploit, so this fixture backs its integration, determinism, and
/// smoke coverage.
pub fn stitchable(
    specs: &[(&str, f64, f64)],
) -> (Zoo, LatencyModel, BTreeMap<String, TaskProfile>) {
    let mut tasks = BTreeMap::new();
    let mut base = BaseLatencies::new();
    for &(name, accuracy, base_ms) in specs {
        let mut tz = synthetic_task(name, accuracy);
        tz.variants.push(variant(
            "us90",
            VariantType::Unstructured,
            0.9,
            KernelPath::Masked,
            accuracy - 0.10,
            500,
        ));
        tasks.insert(name.to_string(), tz);
        for sg in 0..SUBGRAPHS {
            base.set(name, sg, KernelPath::Dense, base_ms);
            base.set(name, sg, KernelPath::BlockSparse, base_ms * 0.8);
            base.set(name, sg, KernelPath::Masked, base_ms * 0.9);
        }
    }
    assemble(tasks, base)
}

fn assemble(
    tasks: BTreeMap<String, TaskZoo>,
    base: BaseLatencies,
) -> (Zoo, LatencyModel, BTreeMap<String, TaskProfile>) {
    let zoo = Zoo {
        root: PathBuf::from("/nonexistent"),
        seed: 0,
        zoo_name: "fixture".into(),
        subgraphs: SUBGRAPHS,
        n_classes: 10,
        batch_sizes: vec![1, 256],
        probe_batch: 4,
        n_eval: 512,
        tasks,
    };
    let lm = LatencyModel::new(Platform::desktop(), base);
    let cfg = ProfilerConfig {
        train_samples: 6,
        gbdt: GbdtParams {
            n_trees: 120,
            max_depth: 3,
            eta: 0.2,
            min_leaf: 1,
            subsample: 1.0,
            seed: 1,
        },
        seed: 23,
    };
    let mut profiles = BTreeMap::new();
    for (name, tz) in &zoo.tasks {
        let space = StitchSpace::for_task(tz);
        // Oracle: mean of the parent-variant accuracies per position.
        let oracle: Vec<f64> = space
            .iter()
            .map(|c| {
                c.0.iter().map(|&i| tz.variants[i].accuracy).sum::<f64>()
                    / SUBGRAPHS as f64
            })
            .collect();
        profiles.insert(name.clone(), profile_task(tz, &lm, &oracle, &cfg, true));
    }
    (zoo, lm, profiles)
}

/// One-task fixture (task `"tiny"`, ~10 ms base latency per subgraph).
pub fn tiny() -> (Zoo, LatencyModel, BTreeMap<String, TaskProfile>) {
    build(&[("tiny", 0.90, 10.0)])
}

/// Three heterogeneous tasks (`alpha`/`beta`/`gamma` at 8/12/16 ms base
/// latency) — enough structure for sharding and fairness scenarios.
pub fn trio() -> (Zoo, LatencyModel, BTreeMap<String, TaskProfile>) {
    build(&[("alpha", 0.92, 8.0), ("beta", 0.88, 12.0), ("gamma", 0.85, 16.0)])
}

/// Four heterogeneous tasks — the backlog fixture of the replan and
/// steal/warm-migration studies: `alpha`/`beta`/`delta` are pinned
/// together on one shard (the saturating partition) while `gamma`
/// idles on the other.
pub fn quartet() -> (Zoo, LatencyModel, BTreeMap<String, TaskProfile>) {
    build(&[
        ("alpha", 0.92, 8.0),
        ("beta", 0.88, 12.0),
        ("delta", 0.90, 10.0),
        ("gamma", 0.85, 16.0),
    ])
}

/// Fleet-scale fixture: `n_tasks` deterministic heterogeneous tasks
/// plus a hash [`Sharding`](crate::scenario::Sharding) over `n_shards`
/// shards — the substrate of `sparseloom bench` and the threaded-drive
/// tests. Task `fleet00`, `fleet01`, … get accuracies cycling over
/// {0.92, 0.88, 0.90, 0.85} and base latencies cycling over
/// {8, 12, 10, 16} ms, the same spread as [`quartet`], so the planner
/// sees real heterogeneity at any fleet size. Names are zero-padded so
/// zoo (BTreeMap) order equals declaration order up to 100 tasks.
pub fn fleet(
    n_shards: usize,
    n_tasks: usize,
) -> (
    Zoo,
    LatencyModel,
    BTreeMap<String, TaskProfile>,
    crate::scenario::Sharding,
) {
    let accs = [0.92, 0.88, 0.90, 0.85];
    let lats = [8.0, 12.0, 10.0, 16.0];
    let names: Vec<String> = (0..n_tasks.max(1))
        .map(|i| format!("fleet{i:02}"))
        .collect();
    let specs: Vec<(&str, f64, f64)> = names
        .iter()
        .enumerate()
        .map(|(i, n)| (n.as_str(), accs[i % accs.len()], lats[i % lats.len()]))
        .collect();
    let (zoo, lm, profiles) = build(&specs);
    (zoo, lm, profiles, crate::scenario::Sharding::hash(n_shards.max(1)))
}

/// A uniform SLO map over every task of a fixture zoo.
pub fn slos(zoo: &Zoo, min_accuracy: f64, max_latency_ms: f64) -> BTreeMap<String, Slo> {
    zoo.tasks
        .keys()
        .map(|name| (name.clone(), Slo { min_accuracy, max_latency_ms }))
        .collect()
}

/// Task names in zoo (BTreeMap) order.
pub fn task_names(zoo: &Zoo) -> Vec<String> {
    zoo.tasks.keys().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_fixture_scales_and_is_deterministic() {
        let (zoo, _lm, profiles, sharding) = fleet(4, 6);
        assert_eq!(zoo.tasks.len(), 6);
        assert_eq!(profiles.len(), 6);
        assert_eq!(sharding.shards, 4);
        // Zero-padded names keep map order == declaration order.
        assert_eq!(
            task_names(&zoo),
            vec!["fleet00", "fleet01", "fleet02", "fleet03", "fleet04", "fleet05"]
        );
        // Every shard index the hash produces is in range.
        for t in task_names(&zoo) {
            assert!(sharding.shard_of(&t) < 4);
        }
        // Degenerate sizes clamp instead of panicking.
        let (zoo1, _, _, sh1) = fleet(0, 0);
        assert_eq!(zoo1.tasks.len(), 1);
        assert_eq!(sh1.shards, 1);
    }

    #[test]
    fn stitchable_mix_beats_every_pure_under_every_order() {
        // The property the online synthesis action needs from this
        // fixture: under EVERY placement order in Ω, some stitched mix
        // undercuts the best pure variant by more than the 5 % commit
        // margin (us90 on the CPU position, struct50/int8 elsewhere).
        let (zoo, lm, profiles) = stitchable(&[("mix", 0.92, 20.0)]);
        assert_eq!(zoo.task("mix").unwrap().variants.len(), 4);
        let p = &profiles["mix"];
        let orders = crate::workload::placement_orders(&lm.platform, SUBGRAPHS);
        for order in &orders {
            let best_pure = (0..p.space.n_variants)
                .filter_map(|i| {
                    p.latency_est(&p.space.composition(p.space.pure_index(i)), order)
                })
                .fold(f64::INFINITY, f64::min);
            let best_any = (0..p.space.len())
                .filter_map(|k| p.latency_est(&p.space.composition(k), order))
                .fold(f64::INFINITY, f64::min);
            assert!(
                best_any < 0.95 * best_pure,
                "{order:?}: best mix {best_any} ms must undercut best pure {best_pure} ms by >5%"
            );
        }
    }

    #[test]
    fn fixtures_profile_without_artifacts() {
        let (zoo, lm, profiles) = trio();
        assert_eq!(zoo.tasks.len(), 3);
        assert_eq!(profiles.len(), 3);
        for (name, p) in &profiles {
            assert_eq!(p.space.len(), 9, "{name}: 3 variants × 2 subgraphs");
            assert!(p.acc_truth.is_some());
        }
        // Heterogeneous base latencies survive into the latency model.
        let a = lm
            .subgraph_ms(zoo.task("alpha").unwrap(), 0, 0, crate::soc::Processor::Cpu)
            .unwrap();
        let g = lm
            .subgraph_ms(zoo.task("gamma").unwrap(), 0, 0, crate::soc::Processor::Cpu)
            .unwrap();
        assert!(g > a, "gamma ({g} ms) must be slower than alpha ({a} ms)");
        assert_eq!(slos(&zoo, 0.5, 40.0).len(), 3);
        assert_eq!(task_names(&zoo), vec!["alpha", "beta", "gamma"]);
    }
}
