"""L1 correctness: every Pallas kernel path vs its pure-jnp oracle.

Hypothesis sweeps shapes (multiples of 8 so tiles divide evenly — the
models only ever use such dims), sparsity levels, and block-size
overrides. ``assert_allclose`` against :mod:`compile.kernels.ref` is the
core correctness signal for the L1 layer.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels import sparse_matmul as sm

DIMS = st.sampled_from([8, 16, 24, 32, 48, 64, 96, 128, 192, 256])
SMALL_DIMS = st.sampled_from([8, 16, 32, 64])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)

RTOL = 2e-5
ATOL = 2e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(m=SMALL_DIMS, k=DIMS, n=DIMS, seed=SEEDS)
def test_dense_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    got = sm.matmul(x, w, b)
    want = ref.matmul_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), RTOL, ATOL)


@settings(max_examples=25, deadline=None)
@given(m=SMALL_DIMS, k=DIMS, n=DIMS, seed=SEEDS,
       sparsity=st.floats(min_value=0.0, max_value=1.0))
def test_masked_matmul_matches_ref(m, k, n, seed, sparsity):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    mask = jnp.asarray((rng.random((k, n)) >= sparsity).astype(np.float32))
    got = sm.masked_matmul(x, w, mask, b)
    want = ref.masked_matmul_ref(x, w, mask, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), RTOL, ATOL)


@settings(max_examples=25, deadline=None)
@given(m=SMALL_DIMS, k=DIMS, n=DIMS, seed=SEEDS,
       sparsity=st.floats(min_value=0.0, max_value=0.95))
def test_block_sparse_matmul_matches_ref(m, k, n, seed, sparsity):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    keep = jnp.asarray((rng.random(k) >= sparsity).astype(np.float32))
    got = sm.block_sparse_matmul(x, w, keep, b)
    want = ref.block_sparse_matmul_ref(x, w, keep, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), RTOL, ATOL)


def test_block_sparse_all_pruned_tile_is_skipped():
    """A fully-pruned K-tile contributes exactly zero (the skip branch)."""
    rng = np.random.default_rng(0)
    x, w, b = _rand(rng, 8, 256), _rand(rng, 256, 32), _rand(rng, 32)
    keep = np.ones(256, np.float32)
    keep[:128] = 0.0  # first whole 128-tile dead
    got = sm.block_sparse_matmul(x, w, jnp.asarray(keep), b, bk=128)
    want = ref.block_sparse_matmul_ref(x, w, jnp.asarray(keep), b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), RTOL, ATOL)


@settings(max_examples=25, deadline=None)
@given(m=SMALL_DIMS, k=DIMS, n=DIMS, seed=SEEDS)
def test_quant_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    wq, scale = ref.fake_quant_weights_ref(w)
    got = sm.quant_matmul(x, wq, scale, b)
    want = ref.quant_matmul_ref(x, wq, scale, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), RTOL, ATOL)


@pytest.mark.parametrize("bm,bk,bn", [(8, 32, 32), (16, 64, 16), (8, 128, 64)])
def test_block_shape_overrides_are_equivalent(bm, bk, bn):
    """Tiling is a schedule, not semantics: any divisor tiling agrees."""
    rng = np.random.default_rng(3)
    x, w, b = _rand(rng, 16, 128), _rand(rng, 128, 64), _rand(rng, 64)
    base = np.asarray(sm.matmul(x, w, b))
    tiled = np.asarray(sm.matmul(x, w, b, bm=bm, bk=bk, bn=bn))
    np.testing.assert_allclose(tiled, base, RTOL, ATOL)


def test_quant_error_bounded():
    """INT8 fake-quant error stays within the per-channel step bound."""
    rng = np.random.default_rng(11)
    w = _rand(rng, 64, 32)
    wq, scale = ref.fake_quant_weights_ref(w)
    err = np.abs(np.asarray(wq, np.float32) * np.asarray(scale)[None, :]
                 - np.asarray(w))
    assert (err <= 0.5 * np.asarray(scale)[None, :] + 1e-7).all()


def test_block_helper_divides():
    for dim in (8, 24, 128, 192, 256, 1000, 13):
        b = sm._block(dim)
        assert dim % b == 0 and 1 <= b <= max(dim, 1)
