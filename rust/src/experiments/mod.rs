//! Experiment runners: one per table/figure of the paper's evaluation.
//!
//! Every runner prints the same rows/series the paper reports (shape
//! reproduction — who wins, by roughly what factor, where crossovers
//! fall; see DESIGN.md §5). Run via `sparseloom exp <id>` or
//! `sparseloom exp all`; EXPERIMENTS.md records paper-vs-measured.

pub mod endtoend;
pub mod estimators;
pub mod modules;
pub mod motivation;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::json::{self, Json};
use crate::profiler::{profile_zoo, ProfilerConfig, TaskProfile};
use crate::runtime::Runtime;
use crate::soc::{BaseLatencies, LatencyModel, Platform};
use crate::zoo::{KernelPath, Zoo};

/// Shared experiment context: per-platform artifact zoos + measured
/// base latencies. Intel platforms (desktop/laptop) use the intel zoo in
/// `<artifacts>/`; orin uses the jetson zoo in `<artifacts>/jetson/`
/// when present (paper Table 5 ships different zoos per vendor).
pub struct Ctx {
    /// The intel/default zoo (also the one pinned desktop-only
    /// experiments use directly).
    pub zoo: Zoo,
    pub base: BaseLatencies,
    /// The jetson zoo for orin, when exported.
    pub jetson: Option<(Zoo, BaseLatencies)>,
    /// Whether `base` came from real PJRT measurements (vs HLO flops).
    pub measured: bool,
}

impl Ctx {
    /// Load artifacts and base latencies. Measurement policy:
    /// 1. `<artifacts>/base_latencies.json` cache if present;
    /// 2. else measure every (task, sg, path) through PJRT (median of
    ///    `iters`) and write the cache;
    /// 3. `synthetic=true` skips PJRT and derives latencies from HLO
    ///    flops (useful for PJRT-free environments / quick benches).
    pub fn load(artifacts: &str, synthetic: bool) -> Result<Ctx> {
        let (zoo, base, measured) = load_one(Path::new(artifacts), synthetic)?;
        let jetson_dir = Path::new(artifacts).join("jetson");
        let jetson = if jetson_dir.join("manifest.json").exists() {
            let (z, b, _) = load_one(&jetson_dir, synthetic)?;
            Some((z, b))
        } else {
            None
        };
        Ok(Ctx { zoo, base, jetson, measured })
    }

    /// The zoo serving a platform (orin → jetson zoo when available).
    pub fn zoo_for(&self, platform: &Platform) -> &Zoo {
        if platform.name == "orin" {
            if let Some((z, _)) = &self.jetson {
                return z;
            }
        }
        &self.zoo
    }

    pub fn lm(&self, platform: Platform) -> LatencyModel {
        let base = if platform.name == "orin" {
            self.jetson
                .as_ref()
                .map(|(_, b)| b.clone())
                .unwrap_or_else(|| self.base.clone())
        } else {
            self.base.clone()
        };
        LatencyModel::new(platform, base)
    }

    pub fn profiles(
        &self,
        lm: &LatencyModel,
        cfg: &ProfilerConfig,
    ) -> Result<BTreeMap<String, TaskProfile>> {
        profile_zoo(self.zoo_for(&lm.platform), lm, cfg, true)
    }
}

fn load_one(dir: &Path, synthetic: bool) -> Result<(Zoo, BaseLatencies, bool)> {
    let zoo = Zoo::load(dir)?;
    if synthetic {
        let base = BaseLatencies::from_flops(&zoo, 5.0);
        return Ok((zoo, base, false));
    }
    let cache = dir.join("base_latencies.json");
    if cache.exists() {
        let base = read_base_cache(&cache)?;
        return Ok((zoo, base, true));
    }
    eprintln!("[ctx] measuring base latencies through PJRT ({})…", dir.display());
    let rt = Runtime::new()?;
    let base = measure_base_latencies(&zoo, &rt, 30)?;
    write_base_cache(&cache, &base, &zoo)?;
    Ok((zoo, base, true))
}

/// Measure all (task, sg, kernel-path) batch-1 latencies through PJRT.
pub fn measure_base_latencies(zoo: &Zoo, rt: &Runtime, iters: usize) -> Result<BaseLatencies> {
    let mut base = BaseLatencies::new();
    for (tname, tz) in &zoo.tasks {
        let paths: Vec<KernelPath> = {
            let mut v: Vec<KernelPath> =
                tz.variants.iter().map(|x| x.spec.kernel_path).collect();
            v.sort();
            v.dedup();
            v
        };
        for sg in 0..zoo.subgraphs {
            for &path in &paths {
                let ms = rt.measure_subgraph_ms(zoo, tname, sg, path, iters)?;
                base.set(tname, sg, path, ms);
            }
        }
    }
    Ok(base)
}

fn write_base_cache(path: &Path, base: &BaseLatencies, zoo: &Zoo) -> Result<()> {
    let mut entries = Vec::new();
    for (tname, tz) in &zoo.tasks {
        let mut paths: Vec<KernelPath> =
            tz.variants.iter().map(|x| x.spec.kernel_path).collect();
        paths.sort();
        paths.dedup();
        for sg in 0..zoo.subgraphs {
            for &p in &paths {
                if let Ok(ms) = base.get(tname, sg, p) {
                    entries.push(Json::obj(vec![
                        ("task", Json::Str(tname.clone())),
                        ("sg", Json::Num(sg as f64)),
                        ("path", Json::Str(p.name().to_string())),
                        ("ms", Json::Num(ms)),
                    ]));
                }
            }
        }
    }
    std::fs::write(path, Json::arr(entries).to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

fn read_base_cache(path: &Path) -> Result<BaseLatencies> {
    let text = std::fs::read_to_string(path)?;
    let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut base = BaseLatencies::new();
    for e in v.as_arr().context("cache array")? {
        base.set(
            e.req("task")?.as_str().context("task")?,
            e.req("sg")?.as_usize().context("sg")?,
            KernelPath::parse(e.req("path")?.as_str().context("path")?)?,
            e.req("ms")?.as_f64().context("ms")?,
        );
    }
    Ok(base)
}

/// All experiment ids: the paper's figures/tables in paper order, plus
/// the beyond-the-paper `backlog` dispatch study.
pub const ALL: &[&str] = &[
    "fig3", "fig4", "table1", "table2", "fig5", "fig7", "fig8", "fig9",
    "fig10", "fig11", "fig12", "fig13", "fig14", "fig15", "fig16",
    "table5", "overhead", "ablate", "backlog",
];

/// Dispatch one experiment by id; returns the printed report.
pub fn run(ctx: &Ctx, id: &str) -> Result<String> {
    let out = match id {
        "fig3" => motivation::fig3(ctx)?,
        "fig4" => motivation::fig4(ctx)?,
        "table2" => motivation::table2(ctx)?,
        "fig5" => motivation::fig5(ctx)?,
        "table1" => estimators::table1()?,
        "fig7" => estimators::fig7(ctx)?,
        "fig8" => estimators::fig8()?,
        "fig12" => estimators::fig12(ctx)?,
        "fig9" => modules::fig9(ctx)?,
        "fig13" => modules::fig13(ctx)?,
        "fig14" => modules::fig14(ctx)?,
        "table5" => modules::table5(ctx)?,
        "overhead" => modules::overhead(ctx)?,
        "ablate" => modules::ablate(ctx)?,
        "fig10" => endtoend::fig10(ctx)?,
        "fig11" => endtoend::fig11(ctx)?,
        "fig15" => endtoend::fig15(ctx)?,
        "fig16" => endtoend::fig16(ctx)?,
        "backlog" => endtoend::backlog(ctx)?,
        other => anyhow::bail!("unknown experiment {other:?}; ids: {ALL:?}"),
    };
    Ok(out)
}
