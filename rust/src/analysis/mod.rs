//! `sparselint` — static analysis & invariant verification for
//! scenarios, plans, and stitched variants (DESIGN.md §Static analysis).
//!
//! The stack has five interacting config surfaces (arrivals, admission,
//! dispatch, sharding, planner) plus the combinatorial V^S stitched
//! space; this module rejects bad configurations *before* a replay
//! starts instead of panicking mid-run. Four pass groups:
//!
//! 1. **Scenario well-formedness** ([`scenario::lint_scenario`], codes
//!    `SL-SCN-*`): duplicate tasks, phases missing SLOs, universe ⊉
//!    schedule, nonpositive rates/horizons, admission parameter ranges,
//!    sharding maps naming unknown tasks or out-of-range shards,
//!    `max_batch == 0` footguns.
//! 2. **Cross-layer consistency** (same entry point, codes `SL-XLY-*`):
//!    `predictive` without a positive `horizon_ms`, `steal`/
//!    `warm_migrate` with `shards < 2`, replan knobs on a single-server
//!    run.
//! 3. **Plan/stitch feasibility against a zoo**
//!    ([`feasibility::lint_feasibility`], codes `SL-FEA-*`): every
//!    selection's composition index in-bounds for V^S, interface
//!    alignment across subgraph positions, per-task budgets summing
//!    within the shard pool, preload sets that fit.
//! 4. **Dynamic invariant verification** ([`invariants`], codes
//!    `SL-INV-*`): replay a session's `RequestOutcome` stream and check
//!    per-task FIFO, ready-floor monotonicity, budget conservation, and
//!    NaN-free metrics.
//!
//! Every diagnostic carries a stable reason code, a severity, a
//! location, and a message; a [`Report`] renders as aligned text or
//! JSON. Error-level checks are enforced fail-fast at `Session` open
//! and `ShardedServer::build`; the full pass set runs from
//! `sparseloom lint <scenario.json>`, and `serve --verify` runs the
//! invariant verifier over the finished run.

pub mod feasibility;
pub mod invariants;
pub mod scenario;

pub use feasibility::lint_feasibility;
pub use scenario::lint_scenario;
pub use scenario::trace_mode_gate;

use anyhow::{bail, Result};

use crate::json::Json;

/// Diagnostic severity. `Error` diagnostics make `lint` exit nonzero
/// and are enforced fail-fast at session open / sharded build; `Warn`
/// flags configurations that run but almost certainly do not mean what
/// they say; `Info` is advisory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl Severity {
    /// Fixed-width label used in text rendering and JSON.
    pub fn label(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
            Severity::Info => "info",
        }
    }
}

/// One finding: a stable reason code, severity, a location within the
/// analyzed object (`"schedule[1]"`, `"task \"beta\""`, `"shard 2"`),
/// and a human message.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Stable reason code (`SL-SCN-001` …). Codes are append-only: a
    /// retired check's code is never reused.
    pub code: &'static str,
    pub severity: Severity,
    /// Where in the scenario/plan/event stream the finding anchors.
    pub at: String,
    pub message: String,
}

impl Diagnostic {
    pub fn error(code: &'static str, at: impl Into<String>, message: impl Into<String>) -> Self {
        Self { code, severity: Severity::Error, at: at.into(), message: message.into() }
    }

    pub fn warn(code: &'static str, at: impl Into<String>, message: impl Into<String>) -> Self {
        Self { code, severity: Severity::Warn, at: at.into(), message: message.into() }
    }

    pub fn info(code: &'static str, at: impl Into<String>, message: impl Into<String>) -> Self {
        Self { code, severity: Severity::Info, at: at.into(), message: message.into() }
    }

    /// One text line: `error SL-SCN-004 [schedule[1]] message`.
    pub fn render(&self) -> String {
        format!(
            "{:<5} {} [{}] {}",
            self.severity.label(),
            self.code,
            self.at,
            self.message
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::Str(self.code.to_string())),
            ("severity", Json::Str(self.severity.label().to_string())),
            ("at", Json::Str(self.at.clone())),
            ("message", Json::Str(self.message.clone())),
        ])
    }
}

/// An ordered collection of diagnostics from one or more passes.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, d: Diagnostic) {
        self.diagnostics.push(d);
    }

    /// Fold another pass's findings into this report.
    pub fn merge(&mut self, other: Report) {
        self.diagnostics.extend(other.diagnostics);
    }

    pub fn is_empty(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn errors(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warnings(&self) -> usize {
        self.count(Severity::Warn)
    }

    pub fn notes(&self) -> usize {
        self.count(Severity::Info)
    }

    fn count(&self, sev: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == sev).count()
    }

    pub fn has_errors(&self) -> bool {
        self.errors() > 0
    }

    /// Summary line: `2 error(s), 1 warning(s), 0 note(s)`.
    pub fn summary(&self) -> String {
        format!(
            "{} error(s), {} warning(s), {} note(s)",
            self.errors(),
            self.warnings(),
            self.notes()
        )
    }

    /// Full text rendering: one line per diagnostic (most severe
    /// first, original order within a severity), then the summary.
    pub fn render_text(&self) -> String {
        let mut lines: Vec<String> = Vec::with_capacity(self.diagnostics.len() + 1);
        for sev in [Severity::Error, Severity::Warn, Severity::Info] {
            for d in &self.diagnostics {
                if d.severity == sev {
                    lines.push(d.render());
                }
            }
        }
        lines.push(self.summary());
        lines.join("\n")
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("errors", Json::Num(self.errors() as f64)),
            ("warnings", Json::Num(self.warnings() as f64)),
            ("notes", Json::Num(self.notes() as f64)),
            (
                "diagnostics",
                Json::arr(self.diagnostics.iter().map(Diagnostic::to_json)),
            ),
        ])
    }

    /// Fail-fast gate: `Err` listing every Error-level diagnostic when
    /// any exist (the `Session` open / `ShardedServer::build` contract),
    /// `Ok` otherwise — warnings never block.
    pub fn fail_on_errors(&self, what: &str) -> Result<()> {
        if !self.has_errors() {
            return Ok(());
        }
        let lines: Vec<String> = self
            .diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(Diagnostic::render)
            .collect();
        bail!("{what} rejected by sparselint:\n{}", lines.join("\n"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        let mut r = Report::new();
        r.push(Diagnostic::warn("SL-SCN-010", "dispatch", "max_batch == 0 behaves as 1"));
        r.push(Diagnostic::error("SL-SCN-002", "tasks[1]", "duplicate task \"a\""));
        r.push(Diagnostic::info("SL-XLY-007", "planner", "batch_aware at max_batch 1"));
        r
    }

    #[test]
    fn counts_and_gate() {
        let r = sample();
        assert_eq!(r.errors(), 1);
        assert_eq!(r.warnings(), 1);
        assert_eq!(r.notes(), 1);
        assert!(r.has_errors());
        let err = r.fail_on_errors("scenario").unwrap_err().to_string();
        assert!(err.contains("SL-SCN-002"), "{err}");
        assert!(!err.contains("SL-SCN-010"), "warnings must not block: {err}");
        let clean = Report::new();
        assert!(clean.fail_on_errors("scenario").is_ok());
    }

    #[test]
    fn text_orders_by_severity_and_summarizes() {
        let text = sample().render_text();
        let first = text.lines().next().unwrap();
        assert!(first.starts_with("error"), "{text}");
        assert!(text.ends_with("1 error(s), 1 warning(s), 1 note(s)"), "{text}");
    }

    #[test]
    fn json_shape_is_stable() {
        let j = sample().to_json();
        assert_eq!(j.req("errors").unwrap().as_usize(), Some(1));
        let ds = j.req("diagnostics").unwrap().as_arr().unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds[1].req("code").unwrap().as_str(), Some("SL-SCN-002"));
        assert_eq!(ds[1].req("severity").unwrap().as_str(), Some("error"));
    }
}
