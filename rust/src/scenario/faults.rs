//! Deterministic fault & degradation lab (DESIGN.md §Fault lab).
//!
//! A [`FaultProfile`] is a declarative overlay on a [`super::Scenario`]
//! describing *unhealthy* conditions: per-shard crash/recover windows
//! (a crashed shard swallows the work queued or arriving during the
//! window and rejoins with a cold or warm pool), slow-shard degradation
//! ramps (a latency multiplier that rises over `ramp_ms`), a DVFS-style
//! thermal throttle curve driven by each processor's accumulated busy
//! time on the simulated SoC clock, and cross-shard link costs that
//! make steal/warm-migrate adoption pay a topology-dependent transfer
//! price.
//!
//! Every fault is a pure function of *virtual time* (window bounds,
//! ramp positions, busy-time thresholds) — the lab adds no randomness
//! of its own, so a scenario with a fault profile replays bit-identical
//! under its arrival seed, which is what `tests/determinism.rs` pins.
//!
//! The profile also carries declarative [`Expect`] clauses ("task X
//! completes ≥ N despite shard 1 crashing") checked after a run via
//! [`FaultProfile::check_expects`]; failures surface as `SL-EXP-*`
//! error diagnostics, so `serve` on a scenario with expectations is
//! itself a recovery test.

use anyhow::{bail, Context, Result};

use crate::analysis::{Diagnostic, Report};
use crate::json::Json;
use crate::metrics::ShardedReport;

/// How a crashed shard's memory pool comes back at the end of the
/// window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejoinMode {
    /// The pool is wiped: every resident task pays compile + load again
    /// on its first post-rejoin batch.
    Cold,
    /// The pool survives (e.g. the crash was a transient stall, not a
    /// power cycle): service resumes at the window end with warm state.
    Warm,
}

impl RejoinMode {
    fn tag(self) -> &'static str {
        match self {
            RejoinMode::Cold => "cold",
            RejoinMode::Warm => "warm",
        }
    }
}

/// One crash/recover window on one shard. While `start_ms <= t <
/// end_ms` the shard serves nothing: queries arriving during the
/// window — and queries still queued when it opens — are lost (unless
/// an online path redirects them to a live shard first).
#[derive(Clone, Debug, PartialEq)]
pub struct CrashWindow {
    pub shard: usize,
    pub start_ms: f64,
    pub end_ms: f64,
    pub rejoin: RejoinMode,
}

impl CrashWindow {
    /// Is the shard down at virtual time `t`?
    pub fn active_at(&self, t: f64) -> bool {
        t >= self.start_ms && t < self.end_ms
    }

    /// Does the window swallow a query that arrived at `arrival_ms` and
    /// would start no earlier than `ready_ms`? Covers both queries
    /// arriving mid-window and queries queued when the window opens.
    pub fn swallows(&self, arrival_ms: f64, ready_ms: f64) -> bool {
        arrival_ms < self.end_ms && arrival_ms.max(ready_ms) >= self.start_ms
    }
}

/// A slow-shard degradation ramp: service times on the shard are
/// multiplied by a factor that ramps linearly from 1 at `start_ms` to
/// `factor` at `start_ms + ramp_ms` and stays there. Overlapping ramps
/// multiply.
#[derive(Clone, Debug, PartialEq)]
pub struct Degradation {
    pub shard: usize,
    pub start_ms: f64,
    pub ramp_ms: f64,
    pub factor: f64,
}

impl Degradation {
    /// The multiplier this ramp contributes at virtual time `t`.
    pub fn factor_at(&self, t: f64) -> f64 {
        let progress = if self.ramp_ms > 0.0 {
            ((t - self.start_ms) / self.ramp_ms).clamp(0.0, 1.0)
        } else if t >= self.start_ms {
            1.0
        } else {
            0.0
        };
        1.0 + (self.factor - 1.0) * progress
    }
}

/// One step of a DVFS-style throttle curve: once a processor's
/// accumulated busy time reaches `busy_ms`, its service times are
/// multiplied by `factor` (the thermal governor has dropped the clock).
#[derive(Clone, Debug, PartialEq)]
pub struct ThrottleStep {
    pub busy_ms: f64,
    pub factor: f64,
}

/// A busy-time → slowdown step function applied per processor on the
/// simulated SoC clock. Steps must be sorted by `busy_ms`; the factor
/// before the first step is 1.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ThrottleCurve {
    pub steps: Vec<ThrottleStep>,
}

impl ThrottleCurve {
    /// The slowdown factor in effect after `busy_ms` of accumulated
    /// work (1.0 before the first step).
    pub fn factor_at(&self, busy_ms: f64) -> f64 {
        let mut f = 1.0;
        for s in &self.steps {
            if busy_ms >= s.busy_ms {
                f = s.factor;
            } else {
                break;
            }
        }
        f
    }

    /// The curve as plain `(busy_ms, factor)` pairs — the form
    /// [`crate::soc::SocSim::set_throttle`] takes, keeping `soc`
    /// independent of this module.
    pub fn as_steps(&self) -> Vec<(f64, f64)> {
        self.steps.iter().map(|s| (s.busy_ms, s.factor)).collect()
    }
}

/// Cross-shard transfer costs: `transfer_ms[from][to]` is the virtual
/// latency a steal/warm-migrate adoption pays to move task state from
/// shard `from` to shard `to`. Must be square, symmetric, with a zero
/// diagonal (linted as `SL-SCN-016`).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct LinkMatrix {
    pub transfer_ms: Vec<Vec<f64>>,
}

impl LinkMatrix {
    /// Transfer cost from shard `from` to shard `to` (0 when the matrix
    /// does not cover the pair).
    pub fn cost(&self, from: usize, to: usize) -> f64 {
        self.transfer_ms
            .get(from)
            .and_then(|row| row.get(to))
            .copied()
            .unwrap_or(0.0)
    }

    /// Smallest off-diagonal cost, if any transfer is possible.
    pub fn min_transfer_ms(&self) -> Option<f64> {
        let mut best = f64::INFINITY;
        let mut any = false;
        for (i, row) in self.transfer_ms.iter().enumerate() {
            for (j, &ms) in row.iter().enumerate() {
                if i != j {
                    any = true;
                    best = best.min(ms);
                }
            }
        }
        if any {
            Some(best)
        } else {
            None
        }
    }
}

/// A declarative post-run assertion on a fault scenario — the lab's
/// test vocabulary. Checked by [`FaultProfile::check_expects`]; each
/// failed clause is an `SL-EXP-*` error diagnostic.
#[derive(Clone, Debug, PartialEq)]
pub enum Expect {
    /// At least `at_least` requests complete (non-dropped) — for one
    /// task when `task` is set, across the whole run otherwise.
    MinCompleted { task: Option<String>, at_least: usize },
    /// At most `at_most` requests are dropped across the run.
    MaxDropped { at_most: usize },
    /// The aggregate SLO violation rate stays at or under `at_most`.
    MaxViolationRate { at_most: f64 },
    /// Every crash window on `shard` recovers — first post-rejoin
    /// completion — within `ms` of the window end.
    RecoveryWithin { shard: usize, ms: f64 },
}

/// The declarative fault overlay on a scenario. `Default` is the empty
/// profile: no crashes, no degradation, no throttle, no link costs —
/// and the runtime takes the exact pre-fault-lab code paths, so legacy
/// scenarios replay bit-identically.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultProfile {
    pub crashes: Vec<CrashWindow>,
    pub degradations: Vec<Degradation>,
    pub throttle: Option<ThrottleCurve>,
    pub links: Option<LinkMatrix>,
    pub expects: Vec<Expect>,
}

impl FaultProfile {
    /// True when the profile injects nothing and asserts nothing.
    pub fn is_default(&self) -> bool {
        self.crashes.is_empty()
            && self.degradations.is_empty()
            && self.throttle.is_none()
            && self.links.is_none()
            && self.expects.is_empty()
    }

    /// The profile as seen from inside shard `shard`'s own session:
    /// crash windows and degradations for that shard re-indexed to
    /// shard 0, the throttle curve kept (it is per processor, not per
    /// shard), link costs and expectations dropped (both are
    /// cross-shard concerns handled by `ShardedServer`).
    pub fn for_shard(&self, shard: usize) -> FaultProfile {
        FaultProfile {
            crashes: self
                .crashes
                .iter()
                .filter(|w| w.shard == shard)
                .map(|w| CrashWindow { shard: 0, ..w.clone() })
                .collect(),
            degradations: self
                .degradations
                .iter()
                .filter(|d| d.shard == shard)
                .map(|d| Degradation { shard: 0, ..d.clone() })
                .collect(),
            throttle: self.throttle.clone(),
            links: None,
            expects: Vec::new(),
        }
    }

    /// Is shard `shard` inside one of its crash windows at time `t`?
    pub fn down_at(&self, shard: usize, t: f64) -> bool {
        self.crashes
            .iter()
            .any(|w| w.shard == shard && w.active_at(t))
    }

    /// Would a query on `shard` with this (arrival, ready-floor) pair
    /// be swallowed by one of the shard's crash windows?
    pub fn swallowed_by(&self, shard: usize, arrival_ms: f64, ready_ms: f64) -> bool {
        self.crashes
            .iter()
            .any(|w| w.shard == shard && w.swallows(arrival_ms, ready_ms))
    }

    /// The combined degradation multiplier on `shard` at time `t`
    /// (exactly 1.0 when no ramp touches the shard).
    pub fn degradation_factor(&self, shard: usize, t: f64) -> f64 {
        let mut f = 1.0;
        for d in &self.degradations {
            if d.shard == shard {
                f *= d.factor_at(t);
            }
        }
        f
    }

    /// Largest shard index any fault entry names (for the sharding
    /// cross-check lint).
    pub fn max_shard_named(&self) -> Option<usize> {
        let crash = self.crashes.iter().map(|w| w.shard);
        let degr = self.degradations.iter().map(|d| d.shard);
        let exp = self.expects.iter().filter_map(|e| match e {
            Expect::RecoveryWithin { shard, .. } => Some(*shard),
            _ => None,
        });
        crash.chain(degr).chain(exp).max()
    }

    // ---- post-run assertions -------------------------------------------

    /// Check every [`Expect`] clause against a finished sharded run.
    /// Failures are `SL-EXP-*` error diagnostics; an empty report means
    /// every expectation held.
    pub fn check_expects(&self, report: &ShardedReport) -> Report {
        let mut r = Report::new();
        for (i, e) in self.expects.iter().enumerate() {
            let at = format!("expects[{i}]");
            match e {
                Expect::MinCompleted { task, at_least } => {
                    // Judged on the per-task outcome counters, not the
                    // event log, so the clause also works in streaming
                    // mode (`ServeOpts::record_events` off). A task's
                    // outcome may be split across shard fragments
                    // (steal/migration); each query completes exactly
                    // once globally, so summing fragments is exact.
                    let done = match task {
                        Some(t) => report
                            .aggregate
                            .outcomes
                            .iter()
                            .filter(|o| &o.task == t)
                            .map(|o| o.queries_completed)
                            .sum::<usize>(),
                        None => report.aggregate.total_queries,
                    };
                    if done < *at_least {
                        let scope = match task {
                            Some(t) => format!("task {t:?}"),
                            None => "run".to_string(),
                        };
                        r.push(Diagnostic::error(
                            "SL-EXP-001",
                            at,
                            format!("{scope} completed {done} request(s), expected >= {at_least}"),
                        ));
                    }
                }
                Expect::MaxDropped { at_most } => {
                    let dropped = report.aggregate.total_dropped;
                    if dropped > *at_most {
                        r.push(Diagnostic::error(
                            "SL-EXP-002",
                            at,
                            format!("run dropped {dropped} request(s), expected <= {at_most}"),
                        ));
                    }
                }
                Expect::MaxViolationRate { at_most } => {
                    let rate = report.aggregate.violation_rate();
                    if rate > *at_most {
                        r.push(Diagnostic::error(
                            "SL-EXP-003",
                            at,
                            format!("violation rate {rate:.3}, expected <= {at_most}"),
                        ));
                    }
                }
                Expect::RecoveryWithin { shard, ms } => {
                    let windows =
                        self.crashes.iter().filter(|w| w.shard == *shard).count();
                    let recs: &[f64] = report
                        .per_shard
                        .get(*shard)
                        .map(|s| s.recoveries.as_slice())
                        .unwrap_or(&[]);
                    if recs.len() < windows {
                        r.push(Diagnostic::error(
                            "SL-EXP-004",
                            at,
                            format!(
                                "shard {shard} recovered from {} of {windows} crash \
                                 window(s) (no post-rejoin completion observed)",
                                recs.len()
                            ),
                        ));
                    } else if let Some(worst) =
                        recs.iter().copied().reduce(f64::max)
                    {
                        if worst > *ms {
                            r.push(Diagnostic::error(
                                "SL-EXP-004",
                                at,
                                format!(
                                    "shard {shard} worst recovery latency {worst:.1} ms, \
                                     expected <= {ms}"
                                ),
                            ));
                        }
                    }
                }
            }
        }
        r
    }

    // ---- JSON ----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if !self.crashes.is_empty() {
            fields.push((
                "crashes",
                Json::arr(self.crashes.iter().map(|w| {
                    Json::obj(vec![
                        ("shard", Json::Num(w.shard as f64)),
                        ("start_ms", Json::Num(w.start_ms)),
                        ("end_ms", Json::Num(w.end_ms)),
                        ("rejoin", Json::Str(w.rejoin.tag().into())),
                    ])
                })),
            ));
        }
        if !self.degradations.is_empty() {
            fields.push((
                "degradations",
                Json::arr(self.degradations.iter().map(|d| {
                    Json::obj(vec![
                        ("shard", Json::Num(d.shard as f64)),
                        ("start_ms", Json::Num(d.start_ms)),
                        ("ramp_ms", Json::Num(d.ramp_ms)),
                        ("factor", Json::Num(d.factor)),
                    ])
                })),
            ));
        }
        if let Some(curve) = &self.throttle {
            fields.push((
                "throttle",
                Json::obj(vec![(
                    "steps",
                    Json::arr(curve.steps.iter().map(|s| {
                        Json::obj(vec![
                            ("busy_ms", Json::Num(s.busy_ms)),
                            ("factor", Json::Num(s.factor)),
                        ])
                    })),
                )]),
            ));
        }
        if let Some(links) = &self.links {
            fields.push((
                "links",
                Json::obj(vec![(
                    "transfer_ms",
                    Json::arr(
                        links
                            .transfer_ms
                            .iter()
                            .map(|row| Json::arr(row.iter().map(|&ms| Json::Num(ms)))),
                    ),
                )]),
            ));
        }
        if !self.expects.is_empty() {
            fields.push((
                "expects",
                Json::arr(self.expects.iter().map(|e| match e {
                    Expect::MinCompleted { task, at_least } => {
                        let mut f = vec![("kind", Json::Str("min_completed".into()))];
                        if let Some(t) = task {
                            f.push(("task", Json::Str(t.clone())));
                        }
                        f.push(("at_least", Json::Num(*at_least as f64)));
                        Json::obj(f)
                    }
                    Expect::MaxDropped { at_most } => Json::obj(vec![
                        ("kind", Json::Str("max_dropped".into())),
                        ("at_most", Json::Num(*at_most as f64)),
                    ]),
                    Expect::MaxViolationRate { at_most } => Json::obj(vec![
                        ("kind", Json::Str("max_violation_rate".into())),
                        ("at_most", Json::Num(*at_most)),
                    ]),
                    Expect::RecoveryWithin { shard, ms } => Json::obj(vec![
                        ("kind", Json::Str("recovery_within".into())),
                        ("shard", Json::Num(*shard as f64)),
                        ("ms", Json::Num(*ms)),
                    ]),
                })),
            ));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<FaultProfile> {
        let crashes = match v.get("crashes") {
            None => Vec::new(),
            Some(ws) => ws
                .as_arr()
                .context("faults.crashes must be an array")?
                .iter()
                .map(|w| {
                    let rejoin = match w.get("rejoin").and_then(|r| r.as_str()) {
                        None | Some("cold") => RejoinMode::Cold,
                        Some("warm") => RejoinMode::Warm,
                        Some(other) => bail!("unknown rejoin mode {other:?}"),
                    };
                    Ok(CrashWindow {
                        shard: w.req("shard")?.as_usize().context("crash.shard")?,
                        start_ms: w
                            .req("start_ms")?
                            .as_f64()
                            .context("crash.start_ms")?,
                        end_ms: w.req("end_ms")?.as_f64().context("crash.end_ms")?,
                        rejoin,
                    })
                })
                .collect::<Result<_>>()?,
        };
        let degradations = match v.get("degradations") {
            None => Vec::new(),
            Some(ds) => ds
                .as_arr()
                .context("faults.degradations must be an array")?
                .iter()
                .map(|d| {
                    Ok(Degradation {
                        shard: d.req("shard")?.as_usize().context("degradation.shard")?,
                        start_ms: d
                            .req("start_ms")?
                            .as_f64()
                            .context("degradation.start_ms")?,
                        ramp_ms: d
                            .req("ramp_ms")?
                            .as_f64()
                            .context("degradation.ramp_ms")?,
                        factor: d
                            .req("factor")?
                            .as_f64()
                            .context("degradation.factor")?,
                    })
                })
                .collect::<Result<_>>()?,
        };
        let throttle = match v.get("throttle") {
            None => None,
            Some(t) => {
                let steps = t
                    .req("steps")?
                    .as_arr()
                    .context("faults.throttle.steps must be an array")?
                    .iter()
                    .map(|s| {
                        Ok(ThrottleStep {
                            busy_ms: s
                                .req("busy_ms")?
                                .as_f64()
                                .context("throttle.busy_ms")?,
                            factor: s
                                .req("factor")?
                                .as_f64()
                                .context("throttle.factor")?,
                        })
                    })
                    .collect::<Result<_>>()?;
                Some(ThrottleCurve { steps })
            }
        };
        let links = match v.get("links") {
            None => None,
            Some(l) => {
                let transfer_ms = l
                    .req("transfer_ms")?
                    .as_arr()
                    .context("faults.links.transfer_ms must be an array")?
                    .iter()
                    .map(|row| {
                        row.as_arr()
                            .context("links.transfer_ms rows must be arrays")?
                            .iter()
                            .map(|ms| {
                                ms.as_f64().context("links.transfer_ms entries")
                            })
                            .collect::<Result<Vec<f64>>>()
                    })
                    .collect::<Result<_>>()?;
                Some(LinkMatrix { transfer_ms })
            }
        };
        let expects = match v.get("expects") {
            None => Vec::new(),
            Some(es) => es
                .as_arr()
                .context("faults.expects must be an array")?
                .iter()
                .map(|e| {
                    let kind = e.req("kind")?.as_str().context("expect.kind")?;
                    Ok(match kind {
                        "min_completed" => Expect::MinCompleted {
                            task: e
                                .get("task")
                                .and_then(|t| t.as_str())
                                .map(|t| t.to_string()),
                            at_least: e
                                .req("at_least")?
                                .as_usize()
                                .context("expect.at_least")?,
                        },
                        "max_dropped" => Expect::MaxDropped {
                            at_most: e
                                .req("at_most")?
                                .as_usize()
                                .context("expect.at_most")?,
                        },
                        "max_violation_rate" => Expect::MaxViolationRate {
                            at_most: e
                                .req("at_most")?
                                .as_f64()
                                .context("expect.at_most")?,
                        },
                        "recovery_within" => Expect::RecoveryWithin {
                            shard: e.req("shard")?.as_usize().context("expect.shard")?,
                            ms: e.req("ms")?.as_f64().context("expect.ms")?,
                        },
                        other => bail!("unknown expect kind {other:?}"),
                    })
                })
                .collect::<Result<_>>()?,
        };
        Ok(FaultProfile { crashes, degradations, throttle, links, expects })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> FaultProfile {
        FaultProfile {
            crashes: vec![CrashWindow {
                shard: 1,
                start_ms: 500.0,
                end_ms: 1_200.0,
                rejoin: RejoinMode::Warm,
            }],
            degradations: vec![Degradation {
                shard: 0,
                start_ms: 100.0,
                ramp_ms: 400.0,
                factor: 3.0,
            }],
            throttle: Some(ThrottleCurve {
                steps: vec![
                    ThrottleStep { busy_ms: 200.0, factor: 1.5 },
                    ThrottleStep { busy_ms: 800.0, factor: 2.0 },
                ],
            }),
            links: Some(LinkMatrix {
                transfer_ms: vec![vec![0.0, 4.0], vec![4.0, 0.0]],
            }),
            expects: vec![
                Expect::MinCompleted { task: Some("gamma".into()), at_least: 5 },
                Expect::MaxViolationRate { at_most: 0.9 },
            ],
        }
    }

    #[test]
    fn default_profile_is_inert() {
        let p = FaultProfile::default();
        assert!(p.is_default());
        assert!(!p.down_at(0, 100.0));
        assert!(!p.swallowed_by(0, 10.0, 20.0));
        assert_eq!(p.degradation_factor(0, 1_000.0).to_bits(), 1.0f64.to_bits());
        assert_eq!(p.max_shard_named(), None);
    }

    #[test]
    fn crash_window_swallow_semantics() {
        let w = CrashWindow {
            shard: 0,
            start_ms: 100.0,
            end_ms: 200.0,
            rejoin: RejoinMode::Cold,
        };
        // Arrives mid-window.
        assert!(w.swallows(150.0, 150.0));
        // Arrived earlier but still queued when the window opened.
        assert!(w.swallows(50.0, 120.0));
        // Served before the crash.
        assert!(!w.swallows(50.0, 60.0));
        // Arrives after rejoin.
        assert!(!w.swallows(250.0, 250.0));
        assert!(w.active_at(100.0) && w.active_at(199.9));
        assert!(!w.active_at(200.0));
    }

    #[test]
    fn degradation_ramps_linearly_and_saturates() {
        let d = Degradation { shard: 0, start_ms: 100.0, ramp_ms: 200.0, factor: 3.0 };
        assert_eq!(d.factor_at(0.0), 1.0);
        assert_eq!(d.factor_at(100.0), 1.0);
        assert!((d.factor_at(200.0) - 2.0).abs() < 1e-12);
        assert_eq!(d.factor_at(300.0), 3.0);
        assert_eq!(d.factor_at(10_000.0), 3.0);
        // Zero-ramp degrades as a step.
        let step = Degradation { shard: 0, start_ms: 50.0, ramp_ms: 0.0, factor: 2.0 };
        assert_eq!(step.factor_at(49.0), 1.0);
        assert_eq!(step.factor_at(50.0), 2.0);
    }

    #[test]
    fn throttle_curve_is_a_step_function() {
        let c = ThrottleCurve {
            steps: vec![
                ThrottleStep { busy_ms: 100.0, factor: 1.5 },
                ThrottleStep { busy_ms: 400.0, factor: 2.5 },
            ],
        };
        assert_eq!(c.factor_at(0.0), 1.0);
        assert_eq!(c.factor_at(99.9), 1.0);
        assert_eq!(c.factor_at(100.0), 1.5);
        assert_eq!(c.factor_at(399.9), 1.5);
        assert_eq!(c.factor_at(400.0), 2.5);
        assert_eq!(c.as_steps(), vec![(100.0, 1.5), (400.0, 2.5)]);
    }

    #[test]
    fn for_shard_reindexes_and_drops_cross_shard_concerns() {
        let p = sample();
        let s1 = p.for_shard(1);
        assert_eq!(s1.crashes.len(), 1);
        assert_eq!(s1.crashes[0].shard, 0, "re-indexed to the session's view");
        assert!(s1.degradations.is_empty());
        assert!(s1.throttle.is_some(), "throttle is per processor, kept");
        assert!(s1.links.is_none() && s1.expects.is_empty());
        let s0 = p.for_shard(0);
        assert!(s0.crashes.is_empty());
        assert_eq!(s0.degradations.len(), 1);
        assert_eq!(p.max_shard_named(), Some(1));
    }

    #[test]
    fn link_matrix_costs_and_min_transfer() {
        let links = LinkMatrix {
            transfer_ms: vec![vec![0.0, 7.0], vec![3.0, 0.0]],
        };
        assert_eq!(links.cost(0, 1), 7.0);
        assert_eq!(links.cost(1, 0), 3.0);
        assert_eq!(links.cost(5, 0), 0.0, "out-of-range pairs cost nothing");
        assert_eq!(links.min_transfer_ms(), Some(3.0));
        assert_eq!(LinkMatrix::default().min_transfer_ms(), None);
    }

    #[test]
    fn json_round_trip_preserves_every_field() {
        let p = sample();
        let text = p.to_json().to_string_pretty();
        let back = FaultProfile::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, p);
        // The empty profile round-trips through an empty object.
        let empty = FaultProfile::default();
        let text = empty.to_json().to_string_pretty();
        let back = FaultProfile::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert!(back.is_default());
    }

    #[test]
    fn from_json_rejects_unknown_kinds() {
        let bad = crate::json::parse(
            r#"{"crashes": [{"shard": 0, "start_ms": 1, "end_ms": 2, "rejoin": "hot"}]}"#,
        )
        .unwrap();
        assert!(FaultProfile::from_json(&bad).is_err());
        let bad = crate::json::parse(r#"{"expects": [{"kind": "teleport"}]}"#).unwrap();
        assert!(FaultProfile::from_json(&bad).is_err());
    }
}
