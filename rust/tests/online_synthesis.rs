//! Online stitched-variant synthesis acceptance (the `--synthesize`
//! planner action): on a bursty, over-budget fleet fixture the
//! synthesizing provider must strictly reduce SLO violations versus
//! the enumerated-only planner, complete no fewer queries, and leave
//! a `TR-CTL-SYNTH` audit trail.
//!
//! Regime under test (see `fixtures::stitchable`): every task's SLO
//! latency bound sits between the best *stitched mix* and the best
//! *pure* variant at the live batch-1 operating point, while
//! batch-aware planning at `batch_hint = 4` projects every composition
//! over the bound — Θ is empty at plan time, so the enumerated path
//! serves the best-effort pure fallback and misses on every query.
//! Only the pressure-triggered synthesis search can find and commit
//! the cheaper mix (us90 on the CPU position, struct50 on the GPU),
//! flipping post-commit queries under the bound.

use std::collections::BTreeMap;

use sparseloom::coordinator::ServeOpts;
use sparseloom::fixtures;
use sparseloom::metrics::ShardedReport;
use sparseloom::profiler::TaskProfile;
use sparseloom::scenario::{
    Admission, PlannerConfig, Scenario, ShardedServer, Sharding,
};
use sparseloom::soc::{LatencyModel, Processor};
use sparseloom::trace;
use sparseloom::zoo::Zoo;

/// Sits between the best mix (≈13.72 ms) and the best pure
/// (≈15.89 ms) on the forced C-G order at 20 ms base latency.
const BOUND_MS: f64 = 14.8;

fn fleet_fixture() -> (Zoo, LatencyModel, BTreeMap<String, TaskProfile>, Sharding) {
    let (zoo, lm, profiles) = fixtures::stitchable(&[
        ("cam0", 0.92, 20.0),
        ("cam1", 0.90, 20.0),
        ("lidar", 0.88, 20.0),
        ("radar", 0.91, 20.0),
    ]);
    let map: BTreeMap<String, usize> =
        [("cam0", 0), ("cam1", 0), ("lidar", 1), ("radar", 1)]
            .into_iter()
            .map(|(t, s)| (t.to_string(), s))
            .collect();
    (zoo, lm, profiles, Sharding::explicit(map, 2))
}

fn bursty_scenario(zoo: &Zoo, sharding: Sharding, synthesize: bool) -> Scenario {
    let tasks = fixtures::task_names(zoo);
    let slos = fixtures::slos(zoo, 0.25, BOUND_MS);
    Scenario::bursty(&tasks, slos, 2.0, 80.0, 500.0, 3000.0)
        .with_name("online-synthesis")
        .with_admission(Admission::Always)
        .with_sharding(sharding)
        .with_planner(PlannerConfig {
            batch_aware: true,
            saturation_slack: 1.5,
            synthesize,
            ..PlannerConfig::default()
        })
        .with_seed(7)
}

fn serve_opts() -> ServeOpts {
    ServeOpts {
        // Plan at the dispatch operating point: ests × (1 + 0.32·3)
        // clear the bound for every composition, so Θ is empty and the
        // enumerated plan degrades to the best-effort pure fallback.
        batch_hint: 4.0,
        // Over-budget pool: the greedy preload fills >95 % of the
        // budgeted share, so the synthesis pool-pressure trigger is hot
        // from the first served batch.
        memory_budget_frac: 0.6,
        // Isolate the synthesis action: no feedback switching in
        // either arm.
        feedback_switching: false,
        // Pin the committed order so the mix-vs-pure margins are the
        // ones this test's bound was sized for.
        force_order: Some(vec![Processor::Cpu, Processor::Gpu]),
        trace: true,
        ..ServeOpts::default()
    }
}

fn run_arm(synthesize: bool) -> ShardedReport {
    let (zoo, lm, profiles, sharding) = fleet_fixture();
    let sc = bursty_scenario(&zoo, sharding.clone(), synthesize);
    let server = ShardedServer::build(&zoo, &lm, &profiles, serve_opts(), sharding)
        .expect("build sharded server");
    server.run(&sc).expect("run scenario")
}

#[test]
fn synthesize_strictly_reduces_slo_violations_on_bursty_overbudget_fleet() {
    let base = run_arm(false);
    let synth = run_arm(true);

    // Same arrivals, admit-always: no fewer completions, nothing dropped.
    assert_eq!(base.aggregate.total_dropped, 0);
    assert_eq!(synth.aggregate.total_dropped, 0);
    assert_eq!(
        synth.aggregate.total_queries, base.aggregate.total_queries,
        "synthesis must not lose completions"
    );
    assert!(base.aggregate.total_queries > 0);

    // The enumerated-only arm is pinned to the pure fallback, which
    // sits above the bound: every query misses.
    assert_eq!(
        base.aggregate.slo_miss_count, base.aggregate.total_queries,
        "enumerated-only arm should miss on every query (pure fallback > bound)"
    );
    assert_eq!(base.synths, 0, "synthesis must not fire when disabled");

    // The synthesizing arm commits mixes and strictly reduces misses.
    assert!(synth.synths >= 1, "no synthesized switch committed");
    assert!(
        synth.aggregate.slo_miss_count < base.aggregate.slo_miss_count,
        "synthesis must strictly reduce SLO misses ({} vs {})",
        synth.aggregate.slo_miss_count,
        base.aggregate.slo_miss_count
    );

    // Audit trail: TR-CTL-SYNTH events in the canonical trace of the
    // synthesizing arm only.
    let synth_jsonl = trace::to_jsonl(&synth.canonical_trace());
    assert!(
        synth_jsonl.contains(trace::TR_CTL_SYNTH),
        "synthesizing run left no TR-CTL-SYNTH audit events"
    );
    let base_jsonl = trace::to_jsonl(&base.canonical_trace());
    assert!(
        !base_jsonl.contains(trace::TR_CTL_SYNTH),
        "enumerated-only run must not emit TR-CTL-SYNTH"
    );
}

#[test]
fn synthesize_alone_routes_to_the_online_drive_even_single_shard() {
    // `--synthesize` without replan/steal must still reach the online
    // drive (where the synthesis action lives) — including on a single
    // shard, where replan/steal would be meaningless.
    let (zoo, lm, profiles) = fixtures::stitchable(&[("solo", 0.92, 20.0)]);
    let sharding = Sharding::hash(1);
    let tasks = fixtures::task_names(&zoo);
    let slos = fixtures::slos(&zoo, 0.25, BOUND_MS);
    let sc = Scenario::bursty(&tasks, slos, 2.0, 80.0, 500.0, 2000.0)
        .with_admission(Admission::Always)
        .with_sharding(sharding.clone())
        .with_planner(PlannerConfig {
            batch_aware: true,
            saturation_slack: 1.5,
            synthesize: true,
            ..PlannerConfig::default()
        })
        .with_seed(11);
    let server = ShardedServer::build(&zoo, &lm, &profiles, serve_opts(), sharding)
        .expect("build single-shard server");
    let report = server.run(&sc).expect("run single-shard scenario");
    assert!(
        report.synths >= 1,
        "single-shard --synthesize run never synthesized (static-drive routing?)"
    );
    assert!(report.aggregate.slo_miss_count < report.aggregate.total_queries);
}
