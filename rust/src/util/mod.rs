//! Shared utilities: deterministic RNG, statistics, permutations.

pub mod perm;
pub mod rng;
pub mod stats;

pub use perm::{factorial, permutations};
pub use rng::Rng;

/// Format a byte count human-readably (for memory reports).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in ms with adaptive precision.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 1000.0 {
        format!("{:.2} s", ms / 1000.0)
    } else if ms >= 1.0 {
        format!("{ms:.2} ms")
    } else {
        format!("{:.1} µs", ms * 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(fmt_ms(1500.0), "1.50 s");
        assert_eq!(fmt_ms(12.345), "12.35 ms");
        assert_eq!(fmt_ms(0.5), "500.0 µs");
    }
}
