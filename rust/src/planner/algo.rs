//! Batch-aware Algorithm 1 with a pruned candidate walk.
//!
//! This is the canonical implementation of the paper's Sparsity-Aware
//! Optimizer (§3.3); `crate::optimizer` keeps only the plan types it
//! returns (the old free-function shims there are gone — use
//! [`CostModel::unit`] for the batch-1 behavior). The math notes live
//! in DESIGN.md §"Algorithm 1".
//!
//! Two prunes speed up the |Ω| × V^S hot loop without changing its
//! result (asserted by `pruned_feasible_set_matches_reference`):
//!
//! * **Order-level**: an order whose per-position latency *minima*
//!   already exceed the SLO bound cannot make any candidate feasible
//!   and is dropped from the scan entirely.
//! * **Candidate-level**: the accuracy digit is order-independent, so a
//!   failed accuracy check skips the whole per-order latency scan; the
//!   per-order partial latency sum aborts as soon as it crosses the
//!   bound, and the order scan short-circuits on the first feasible
//!   order.

use std::collections::BTreeMap;

use crate::optimizer::{CandidateSet, Plan, Selection};
use crate::profiler::TaskProfile;
use crate::soc::Processor;
use crate::workload::Slo;

use super::cost::CostModel;

/// Lower bound on any candidate's latency under `order`: the sum over
/// positions of the fastest supported variant there. `None` when some
/// position supports no variant at all on its assigned processor.
fn order_lower_bound(p: &TaskProfile, order: &[Processor]) -> Option<f64> {
    let mut total = 0.0;
    for (j, proc) in order.iter().enumerate() {
        let mut best = f64::INFINITY;
        for cell in &p.sg_lat[j] {
            if let Some(ms) = cell[proc.idx()] {
                if ms < best {
                    best = ms;
                }
            }
        }
        if !best.is_finite() {
            return None;
        }
        total += best;
    }
    Some(total)
}

/// Early-exit Eq. 5: is the additive latency of `digits` under `order`
/// within `bound`? Aborts the digit walk as soon as the partial sum
/// crosses the bound or a position is unsupported.
fn within_bound(
    p: &TaskProfile,
    digits: &[usize],
    order: &[Processor],
    bound: f64,
) -> bool {
    let mut total = 0.0;
    for (j, (&vi, proc)) in digits.iter().zip(order).enumerate() {
        match p.sg_lat[j][vi][proc.idx()] {
            Some(ms) => {
                total += ms;
                if total > bound {
                    return false;
                }
            }
            None => return false,
        }
    }
    true
}

/// Step 1 of Algorithm 1 (pruned, batch-aware): compute Θᵗ — the
/// stitched indices whose estimated accuracy meets the SLO and whose
/// batch-scaled latency fits the bound under at least one order in Ω.
pub fn feasible_set(
    cost: &CostModel,
    profile: &TaskProfile,
    slo: &Slo,
    orders: &[Vec<Processor>],
) -> CandidateSet {
    let v = profile.space.n_variants;
    let s = profile.space.n_subgraphs;
    // The batch factor scales every candidate equally, so it folds into
    // the latency bound once instead of into every partial sum.
    let bound = slo.max_latency_ms / cost.batch_factor(&profile.task);
    let live: Vec<&[Processor]> = orders
        .iter()
        .map(|o| o.as_slice())
        .filter(|o| order_lower_bound(profile, o).map(|lb| lb <= bound).unwrap_or(false))
        .collect();
    let mut indices = Vec::new();
    if live.is_empty() {
        return CandidateSet { indices };
    }
    let mut digits = vec![0usize; s];
    for k in 0..profile.space.len() {
        if profile.accuracy(k) >= slo.min_accuracy
            && live.iter().any(|o| within_bound(profile, &digits, o, bound))
        {
            indices.push(k);
        }
        // increment base-V odometer (little-endian on the last digit)
        for j in (0..s).rev() {
            digits[j] += 1;
            if digits[j] < v {
                break;
            }
            digits[j] = 0;
        }
    }
    CandidateSet { indices }
}

/// Algorithm 1, complete (batch-aware): joint placement-order + variant
/// selection. Equivalent to [`optimize_weighted`] with no weights.
///
/// Planning is driven by the SLO map: tasks with an SLO but no profile
/// are skipped, and profiles without an SLO are left unplanned — shard
/// sub-scenarios hand the planner exactly this shape (their schedules
/// are filtered to the shard's partition while the profile map stays
/// global).
pub fn optimize(
    cost: &CostModel,
    profiles: &BTreeMap<String, TaskProfile>,
    slos: &BTreeMap<String, Slo>,
    orders: &[Vec<Processor>],
) -> Plan {
    optimize_weighted(cost, profiles, slos, orders, &BTreeMap::new())
}

/// [`optimize`] with per-task arrival weights: step 2's objective
/// becomes the *weighted* mean best latency, so tasks expected to see
/// more traffic (the `PlanContext::arrival_hint`) pull the shared
/// placement order toward their optimum. Missing weights default to
/// 1.0; an empty map reproduces the paper's unweighted objective.
pub fn optimize_weighted(
    cost: &CostModel,
    profiles: &BTreeMap<String, TaskProfile>,
    slos: &BTreeMap<String, Slo>,
    orders: &[Vec<Processor>],
    weights: &BTreeMap<String, f64>,
) -> Plan {
    assert!(!orders.is_empty(), "empty order set Ω");

    let planned: Vec<(&String, &TaskProfile, &Slo)> = slos
        .iter()
        .filter_map(|(name, slo)| profiles.get(name).map(|p| (name, p, slo)))
        .collect();

    // Step 1: Θᵗ per planned task.
    let theta: BTreeMap<&str, CandidateSet> = planned
        .iter()
        .map(|&(name, p, slo)| (name.as_str(), feasible_set(cost, p, slo, orders)))
        .collect();

    // Step 2: pick p⃗* minimizing the (weighted) mean best latency.
    let mut best: Option<(f64, usize)> = None;
    for (oi, order) in orders.iter().enumerate() {
        let mut sum = 0.0;
        let mut weight_sum = 0.0;
        for &(name, p, _) in &planned {
            let cands = &theta[name.as_str()];
            let mut task_best = f64::INFINITY;
            for &k in &cands.indices {
                let comp = p.space.composition(k);
                if let Some(l) = cost.latency(p, &comp, order) {
                    if l < task_best {
                        task_best = l;
                    }
                }
            }
            if task_best.is_finite() {
                let w = weights.get(name.as_str()).copied().unwrap_or(1.0).max(0.0);
                sum += w * task_best;
                weight_sum += w;
            }
        }
        if weight_sum <= 0.0 {
            continue;
        }
        let mean = sum / weight_sum;
        if best.map(|(b, _)| mean < b).unwrap_or(true) {
            best = Some((mean, oi));
        }
    }
    let (mean_latency_ms, oi) = best.unwrap_or((f64::INFINITY, 0));
    let order = orders[oi].clone();

    // Step 3: final per-task selection under p⃗*.
    let mut selections = BTreeMap::new();
    for &(name, p, _) in &planned {
        let cands = &theta[name.as_str()];
        let mut choice: Option<Selection> = None;
        for &k in &cands.indices {
            let comp = p.space.composition(k);
            if let Some(l) = cost.latency(p, &comp, &order) {
                if choice.map(|c| l < c.latency_ms).unwrap_or(true) {
                    choice = Some(Selection {
                        stitched_index: k,
                        latency_ms: l,
                        accuracy: p.accuracy(k),
                    });
                }
            }
        }
        selections.insert(name.clone(), choice);
    }

    Plan { order, selections, mean_latency_ms }
}

/// Restricted Algorithm 1 for the no-stitching baselines: only pure
/// compositions are considered (classic adaptive-variant selection).
pub fn optimize_pure_only(
    cost: &CostModel,
    profiles: &BTreeMap<String, TaskProfile>,
    slos: &BTreeMap<String, Slo>,
    orders: &[Vec<Processor>],
) -> Plan {
    let restricted: BTreeMap<String, TaskProfile> = profiles
        .iter()
        .map(|(name, p)| {
            let mut r = p.clone();
            // Suppress all non-pure variants by zeroing their accuracy
            // (they will fail any positive accuracy SLO) — latency table
            // untouched so pure entries behave identically.
            for k in 0..r.space.len() {
                if !r.space.composition(k).is_pure() {
                    r.acc_pred[k] = -1.0;
                }
            }
            (name.clone(), r)
        })
        .collect();
    optimize(cost, &restricted, slos, orders)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixtures;
    use crate::soc::LatencyModel;

    fn setup() -> (BTreeMap<String, TaskProfile>, LatencyModel, Vec<Vec<Processor>>) {
        let (zoo, lm, profiles) = fixtures::trio();
        let orders =
            crate::workload::placement_orders(&lm.platform, zoo.subgraphs);
        (profiles, lm, orders)
    }

    /// The unpruned reference walk (the pre-planner `feasible_set`).
    fn reference_feasible_set(
        cost: &CostModel,
        p: &TaskProfile,
        slo: &Slo,
        orders: &[Vec<Processor>],
    ) -> Vec<usize> {
        let mut out = Vec::new();
        for k in 0..p.space.len() {
            if p.accuracy(k) < slo.min_accuracy {
                continue;
            }
            let comp = p.space.composition(k);
            let ok = orders.iter().any(|o| {
                cost.latency(p, &comp, o)
                    .map(|l| l <= slo.max_latency_ms)
                    .unwrap_or(false)
            });
            if ok {
                out.push(k);
            }
        }
        out
    }

    #[test]
    fn pruned_feasible_set_matches_reference() {
        let (profiles, lm, orders) = setup();
        // Sweep bounds from impossible to lax; the pruned walk must
        // agree with the naive reference at every point, batch-aware
        // included.
        for hint in [1.0, 3.0] {
            let cost = CostModel::batch_aware(&lm, hint);
            for p in profiles.values() {
                for acc in [0.0, 0.8, 0.95] {
                    for lat in [0.001, 5.0, 12.0, 30.0, 1e9] {
                        let slo = Slo { min_accuracy: acc, max_latency_ms: lat };
                        let pruned = feasible_set(&cost, p, &slo, &orders);
                        let naive = reference_feasible_set(&cost, p, &slo, &orders);
                        assert_eq!(
                            pruned.indices, naive,
                            "{} acc={acc} lat={lat} hint={hint}",
                            p.task
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn batch_hint_only_shrinks_feasible_sets() {
        let (profiles, lm, orders) = setup();
        let p = &profiles["alpha"];
        let slo = Slo { min_accuracy: 0.5, max_latency_ms: 20.0 };
        let unit = feasible_set(&CostModel::unit(), p, &slo, &orders);
        let batched =
            feasible_set(&CostModel::batch_aware(&lm, 4.0), p, &slo, &orders);
        assert!(batched.len() <= unit.len());
        // A batched-feasible candidate is always batch-1 feasible.
        for k in &batched.indices {
            assert!(unit.indices.contains(k));
        }
    }

    #[test]
    fn optimize_skips_tasks_without_slos() {
        // Shard sub-scenarios plan with a filtered SLO map over the full
        // profile map; the planner must plan exactly the SLO'd tasks.
        let (profiles, _lm, orders) = setup();
        let slos = BTreeMap::from([(
            "beta".to_string(),
            Slo { min_accuracy: 0.5, max_latency_ms: 1e9 },
        )]);
        let plan = optimize(&CostModel::unit(), &profiles, &slos, &orders);
        assert_eq!(plan.selections.len(), 1);
        assert!(plan.selections["beta"].is_some());
        assert!(orders.contains(&plan.order));
    }

    // --- unit-cost behavioral pins ------------------------------------
    // Folded in from the removed `optimizer::{feasible_set, optimize,
    // optimize_pure_only}` shims: the same assertions, stated directly
    // against the canonical implementation at `CostModel::unit()`.

    fn tiny_setup() -> BTreeMap<String, TaskProfile> {
        use crate::soc::{BaseLatencies, LatencyModel, Platform};
        use crate::zoo::KernelPath;
        let tz = crate::soc::latency::tests::tiny_taskzoo();
        let mut b = BaseLatencies::new();
        for sg in 0..2 {
            b.set("tiny", sg, KernelPath::Dense, 10.0);
            b.set("tiny", sg, KernelPath::BlockSparse, 8.0);
        }
        let lm = LatencyModel::new(Platform::desktop(), b);
        let space = crate::stitching::StitchSpace::for_task(&tz);
        let oracle: Vec<f64> = space
            .iter()
            .map(|c| c.0.iter().map(|&i| tz.variants[i].accuracy).sum::<f64>() / 2.0)
            .collect();
        let cfg = crate::profiler::ProfilerConfig {
            train_samples: 4,
            gbdt: crate::gbdt::GbdtParams {
                n_trees: 200,
                max_depth: 3,
                eta: 0.2,
                min_leaf: 1,
                subsample: 1.0,
                seed: 1,
            },
            seed: 23,
        };
        let p = crate::profiler::profile_task(&tz, &lm, &oracle, &cfg, true);
        BTreeMap::from([("tiny".to_string(), p)])
    }

    fn orders2() -> Vec<Vec<Processor>> {
        use Processor::*;
        vec![vec![Cpu, Gpu], vec![Gpu, Cpu], vec![Gpu, Npu], vec![Npu, Gpu]]
    }

    #[test]
    fn feasible_set_respects_both_constraints() {
        let profiles = tiny_setup();
        let p = &profiles["tiny"];
        let unit = CostModel::unit();
        let lax = Slo { min_accuracy: 0.0, max_latency_ms: 1e9 };
        assert_eq!(feasible_set(&unit, p, &lax, &orders2()).len(), p.space.len());
        let impossible = Slo { min_accuracy: 2.0, max_latency_ms: 1e9 };
        assert!(feasible_set(&unit, p, &impossible, &orders2()).is_empty());
        let tight_lat = Slo { min_accuracy: 0.0, max_latency_ms: 0.0001 };
        assert!(feasible_set(&unit, p, &tight_lat, &orders2()).is_empty());
    }

    #[test]
    fn optimizer_picks_feasible_and_order_in_omega() {
        let profiles = tiny_setup();
        let slos = BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.6, max_latency_ms: 100.0 },
        )]);
        let orders = orders2();
        let plan = optimize(&CostModel::unit(), &profiles, &slos, &orders);
        assert!(orders.contains(&plan.order));
        let sel = plan.selections["tiny"].expect("feasible");
        assert!(sel.accuracy >= 0.6);
        assert!(sel.latency_ms <= 100.0);
        assert_eq!(plan.infeasible_tasks(), 0);
    }

    #[test]
    fn optimizer_reports_infeasible() {
        let profiles = tiny_setup();
        let slos = BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.99, max_latency_ms: 0.001 },
        )]);
        let plan = optimize(&CostModel::unit(), &profiles, &slos, &orders2());
        assert_eq!(plan.infeasible_tasks(), 1);
    }

    #[test]
    fn chosen_variant_is_latency_minimal_under_order() {
        let profiles = tiny_setup();
        let slos = BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.0, max_latency_ms: 1e9 },
        )]);
        let plan = optimize(&CostModel::unit(), &profiles, &slos, &orders2());
        let p = &profiles["tiny"];
        let sel = plan.selections["tiny"].unwrap();
        for k in 0..p.space.len() {
            if let Some(l) = p.latency_est(&p.space.composition(k), &plan.order) {
                assert!(sel.latency_ms <= l + 1e-12);
            }
        }
    }

    #[test]
    fn pure_only_selects_pure() {
        let profiles = tiny_setup();
        let slos = BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.5, max_latency_ms: 1e9 },
        )]);
        let plan = optimize_pure_only(&CostModel::unit(), &profiles, &slos, &orders2());
        let p = &profiles["tiny"];
        let sel = plan.selections["tiny"].unwrap();
        assert!(p.space.composition(sel.stitched_index).is_pure());
    }

    #[test]
    fn stitching_beats_pure_under_tight_slo() {
        // The paper's core claim (Fig. 3): stitched variants satisfy
        // SLOs that pure variants cannot. Construct an SLO between the
        // pure variants' (acc, lat) points.
        let profiles = tiny_setup();
        let p = &profiles["tiny"];
        // accuracy above struct50's 0.7 but latency below what pure
        // dense can reach on the fastest order:
        let pure_dense_lat = {
            let comp = p.space.composition(p.space.pure_index(0));
            orders2()
                .iter()
                .filter_map(|o| p.latency_est(&comp, o))
                .fold(f64::INFINITY, f64::min)
        };
        let slo = Slo { min_accuracy: 0.75, max_latency_ms: pure_dense_lat * 0.98 };
        let slos = BTreeMap::from([("tiny".to_string(), slo)]);
        let unit = CostModel::unit();
        let stitched = optimize(&unit, &profiles, &slos, &orders2());
        let pure = optimize_pure_only(&unit, &profiles, &slos, &orders2());
        assert!(pure.infeasible_tasks() >= stitched.infeasible_tasks());
    }

    #[test]
    fn arrival_weights_can_steer_the_order() {
        let (profiles, _lm, orders) = setup();
        let slos: BTreeMap<String, Slo> = profiles
            .keys()
            .map(|n| (n.clone(), Slo { min_accuracy: 0.0, max_latency_ms: 1e9 }))
            .collect();
        let cost = CostModel::unit();
        // Degenerate all-weight-on-one-task objective: the joint order
        // must be at least as good for that task as the unweighted one.
        let heavy = BTreeMap::from([("gamma".to_string(), 1e6)]);
        let weighted = optimize_weighted(&cost, &profiles, &slos, &orders, &heavy);
        let solo_slos = BTreeMap::from([("gamma".to_string(), slos["gamma"])]);
        let solo = optimize(&cost, &profiles, &solo_slos, &orders);
        let gamma_best = |plan: &Plan| plan.selections["gamma"].unwrap().latency_ms;
        // Tolerance: the residual unit weights can shift the weighted
        // argmin by at most (Σ other latencies)/1e6 ≈ microseconds.
        assert!(gamma_best(&weighted) <= gamma_best(&solo) + 1e-3);
    }
}
