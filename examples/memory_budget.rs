//! Memory-budget walk (Fig. 14's mechanism, inspectable): shrink the
//! preload budget and watch the Hot-Subgraph Preloader triage — which
//! subgraphs stay hot, how coverage decays, and what it costs in
//! violations and switch latency.
//!
//! ```text
//! cargo run --release --example memory_budget [-- <platform>]
//! ```

use std::collections::BTreeMap;

use sparseloom::experiments::Ctx;
use sparseloom::metrics::render_table;
use sparseloom::planner::memory;
use sparseloom::preloader::{coverage, full_preload_bytes, Hotness};
use sparseloom::profiler::ProfilerConfig;
use sparseloom::scenario::{Scenario, Server};
use sparseloom::soc::Platform;
use sparseloom::util::fmt_bytes;
use sparseloom::workload::{placement_orders, slo_grid, Slo, TaskRanges};

fn main() -> anyhow::Result<()> {
    let platform_name = std::env::args().nth(1).unwrap_or_else(|| "desktop".into());
    let platform = Platform::by_name(&platform_name)?;
    let ctx = Ctx::load("artifacts", false)?;
    let lm = ctx.lm(platform.clone());
    let zoo = ctx.zoo_for(&platform);
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
    let orders = placement_orders(&platform, zoo.subgraphs);

    // SLO universe Ψ = the 25-config grid per task.
    let mut grids: BTreeMap<String, Vec<Slo>> = BTreeMap::new();
    let mut universe = Vec::new();
    for (name, _) in &profiles {
        let g = slo_grid(&TaskRanges::measure(zoo.task(name)?, &lm));
        universe.extend(g.iter().copied());
        grids.insert(name.clone(), g);
    }

    // Hotness per task + full-preload reference.
    let pairs: Vec<_> = profiles
        .iter()
        .map(|(name, p)| (zoo.task(name).unwrap(), Hotness::compute(p, &universe, &orders)))
        .collect();
    let refs: Vec<_> = pairs.iter().map(|(tz, h)| (*tz, h)).collect();
    let task_zoos: Vec<_> = pairs.iter().map(|(tz, _)| *tz).collect();
    let full = full_preload_bytes(&task_zoos);
    println!("full preloading on {}: {}\n", platform.name, fmt_bytes(full));

    let arrival: Vec<String> = profiles.keys().cloned().collect();
    let mut rows = Vec::new();
    for frac in [0.1, 0.15, 0.25, 0.4, 0.55, 0.75, 1.0] {
        let budget = (full as f64 * frac) as u64;
        let plan = memory::preload(&refs, budget);
        // Mean feasible-config coverage over tasks.
        let mut cov = 0.0;
        for (name, p) in &profiles {
            cov += coverage(p, &plan, &grids[name], &orders).covered_configs;
        }
        cov /= profiles.len() as f64;

        // Serve the mid-grid config and accumulate violations + switch cost.
        let slos: BTreeMap<String, Slo> =
            grids.iter().map(|(n, g)| (n.clone(), g[12])).collect();
        let server = Server::builder(zoo, &lm, &profiles)
            .memory_budget_frac(frac)
            .build();
        let prepared = server.prepare(&slos, &universe)?;
        let switch_ms: f64 = prepared.switch_penalty_ms.values().sum();
        let scenario = Scenario::closed_loop(&arrival, slos.clone())
            .with_universe(universe.clone());
        let report = server.run(&scenario)?;

        rows.push(vec![
            format!("{:.0} %", frac * 100.0),
            fmt_bytes(plan.total_bytes),
            format!("{}", plan.blobs.len()),
            format!("{:.0} %", 100.0 * cov),
            format!("{:.2}", switch_ms),
            format!("{:.0} %", 100.0 * report.violation_rate()),
        ]);
    }
    println!("{}", render_table(
        &["budget", "preloaded", "blobs", "coverage", "switch ms", "violation"],
        &rows,
    ));
    Ok(())
}
