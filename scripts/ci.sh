#!/usr/bin/env bash
# Tiered CI entry point. Run from anywhere; operates on the repo root.
#
#   CI_TIER=1  → tier 1 only: cargo build --release + cargo test -q
#                (the ROADMAP tier-1 gate; `make check` runs this)
#   CI_TIER=2  → tier 2 only: benches, rustdoc, clippy, fmt, and the
#                hermetic CLI smoke stage — serve/backlog runs, the
#                sparselint stage (lint every shipped scenario, exercise
#                the corrupt-input path, and a serve --verify replay),
#                and the trace stage (serve --trace in both formats,
#                explain attribution, the --json report paths, and the
#                traced-vs-untraced overhead gate riding bench --gate).
#                Assumes nothing is prebuilt; the smoke stage builds the
#                release binary itself.
#   unset      → both tiers, tier 1 first so its failures surface fast
set -euo pipefail

cd "$(dirname "$0")/.."

TIER="${CI_TIER:-all}"

tier1() {
    echo "== [tier 1] cargo build --release =="
    cargo build --release

    echo "== [tier 1] cargo test -q =="
    cargo test -q
}

tier2() {
    # Bench targets are plain main()s (harness = false): running them
    # under `cargo test` compile-checks every bench and executes it once
    # — each falls back to the synthetic fixture zoo (or exits cleanly)
    # when artifacts/ is absent, so this stays fast and hermetic.
    echo "== [tier 2] cargo test -q --benches =="
    cargo test -q --benches

    # Rustdoc must stay warning-free (broken intra-doc links, bad code
    # fences); doc-examples themselves run as doc-tests under tier 1.
    echo "== [tier 2] cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

    # Lints across every target (tests, benches, examples). clippy is
    # optional in minimal toolchains; when installed, warnings are errors.
    if cargo clippy --version >/dev/null 2>&1; then
        echo "== [tier 2] cargo clippy --all-targets (-D warnings) =="
        cargo clippy --all-targets --quiet -- -D warnings
    else
        echo "== [tier 2] cargo clippy skipped (clippy not installed) =="
    fi

    # rustfmt is optional in minimal toolchains; tolerate its absence but
    # fail on real formatting drift when it is installed.
    if cargo fmt --version >/dev/null 2>&1; then
        echo "== [tier 2] cargo fmt --check =="
        cargo fmt --all -- --check
    else
        echo "== [tier 2] cargo fmt --check skipped (rustfmt not installed) =="
    fi

    smoke
}

# Hermetic CLI smoke: the serving CLI and the backlog study must run
# end-to-end on the in-memory fixture zoo (no artifacts/), with the
# online flags exercised and non-empty report output — so CLI flags
# cannot rot unnoticed between releases.
smoke() {
    echo "== [tier 2] CLI smoke (fixture zoo, hermetic) =="
    cargo build --release
    local bin=target/release/sparseloom
    local out

    out="$("$bin" serve --fixture --scenario bursty --rate-qps 20 \
        --burst-qps 120 --period-ms 400 --horizon-ms 1500 \
        --admission predictive --shards 2 --max-batch 4 --steal --replan)"
    printf '%s\n' "$out"
    if ! grep -q "violation rate" <<<"$out"; then
        echo "CLI smoke FAILED: serve produced no summary line" >&2
        exit 1
    fi
    if ! grep -q "scenario: bursty" <<<"$out"; then
        echo "CLI smoke FAILED: serve produced no scenario header" >&2
        exit 1
    fi

    out="$("$bin" exp backlog --fixture --horizon-ms 1500)"
    printf '%s\n' "$out"
    # Match the arm's table row, not the report title (which would
    # pass vacuously even if the arm itself disappeared).
    if ! grep -q "batch<=4, predictive" <<<"$out"; then
        echo "CLI smoke FAILED: exp backlog missing the predictive arm" >&2
        exit 1
    fi
    if ! grep -q "Backlog" <<<"$out"; then
        echo "CLI smoke FAILED: exp backlog produced no report" >&2
        exit 1
    fi

    lint_smoke "$bin"
    trace_smoke "$bin"
    bench_smoke "$bin"
}

# Tracing smoke: a traced fault-lab serve must write a replayable JSONL
# trace (byte-determinism is pinned by tests/determinism.rs; this stage
# pins the CLI plumbing), export valid Chrome trace-event JSON, and
# `explain` must attribute the run's SLO violations and drops to
# nonzero cause buckets. Also exercises the machine-readable report
# path (`serve --json`).
trace_smoke() {
    local bin="$1"
    local out jsonl chrome
    echo "== [tier 2] trace smoke (serve --trace, explain, serve --json) =="
    jsonl="$(mktemp)"
    chrome="$(mktemp)"

    out="$("$bin" serve --fixture --scenario-file examples/scenarios/crash_recover.json \
        --verify --trace "$jsonl")"
    printf '%s\n' "$out"
    if ! grep -Eq "wrote [1-9][0-9]* trace event" <<<"$out"; then
        echo "trace smoke FAILED: serve --trace wrote no trace events" >&2
        rm -f "$jsonl" "$chrome"
        exit 1
    fi
    if ! grep -q "invariants OK" <<<"$out"; then
        echo "trace smoke FAILED: traced run failed the invariant replay" >&2
        rm -f "$jsonl" "$chrome"
        exit 1
    fi

    out="$("$bin" serve --fixture --scenario-file examples/scenarios/crash_recover.json \
        --verify --trace "$chrome" --trace-format chrome)"
    printf '%s\n' "$out"

    out="$("$bin" explain "$chrome")"
    printf '%s\n' "$out"
    if ! grep -q "chrome trace OK" <<<"$out"; then
        echo "trace smoke FAILED: Chrome export did not validate" >&2
        rm -f "$jsonl" "$chrome"
        exit 1
    fi

    out="$("$bin" explain "$jsonl")"
    printf '%s\n' "$out"
    if ! grep -q "SLO-violation attribution" <<<"$out"; then
        echo "trace smoke FAILED: explain produced no attribution report" >&2
        rm -f "$jsonl" "$chrome"
        exit 1
    fi
    if ! grep -Eq "buckets: .*[1-9]" <<<"$out"; then
        echo "trace smoke FAILED: explain attributed nothing on the fault-lab run" >&2
        rm -f "$jsonl" "$chrome"
        exit 1
    fi
    rm -f "$jsonl" "$chrome"

    out="$("$bin" serve --fixture --scenario bursty --rate-qps 20 --burst-qps 120 \
        --period-ms 400 --horizon-ms 1500 --shards 2 --max-batch 4 --json)"
    if ! grep -q '"total_queries"' <<<"$out"; then
        echo "trace smoke FAILED: serve --json emitted no structured report" >&2
        exit 1
    fi
    out="$("$bin" exp backlog --fixture --horizon-ms 1500 --json)"
    if ! grep -q '"arms"' <<<"$out"; then
        echo "trace smoke FAILED: exp backlog --json emitted no arms array" >&2
        exit 1
    fi
}

# Fleet bench smoke + throughput regression gate: `sparseloom bench`
# must sweep the fleet fixture, write its JSON record, keep retention
# O(1) (no request events with streaming metrics), and clear the
# committed speedup floors in benchmarks/BENCH_fleet.baseline.json.
# Small sizes keep this fast; the floors are conservative (see the
# baseline's note field) so slower CI machines do not flake.
bench_smoke() {
    local bin="$1"
    local out tmp
    echo "== [tier 2] sparseloom bench (fleet sweep + regression gate) =="
    tmp="$(mktemp)"
    if ! out="$("$bin" bench --tasks 8 --rate-qps 30 --horizon-ms 1200 \
        --shards 1,4 --iters 2 --out "$tmp" \
        --gate benchmarks/BENCH_fleet.baseline.json)"; then
        printf '%s\n' "$out"
        echo "bench smoke FAILED: bench exited nonzero (gate regression?)" >&2
        rm -f "$tmp"
        exit 1
    fi
    printf '%s\n' "$out"
    if ! grep -q "throughput gate OK" <<<"$out"; then
        echo "bench smoke FAILED: regression gate did not report OK" >&2
        rm -f "$tmp"
        exit 1
    fi
    if ! grep -q "trace overhead gate OK" <<<"$out"; then
        echo "bench smoke FAILED: trace overhead gate did not report OK" >&2
        rm -f "$tmp"
        exit 1
    fi
    if ! grep -q '"speedup_vs_single"' "$tmp"; then
        echo "bench smoke FAILED: bench JSON has no speedup record" >&2
        rm -f "$tmp"
        exit 1
    fi
    if grep -q '"events_retained": [1-9]' "$tmp"; then
        echo "bench smoke FAILED: streaming bench run retained request events" >&2
        rm -f "$tmp"
        exit 1
    fi
    rm -f "$tmp"
}

# sparselint stage: every checked-in example scenario must lint clean
# (Error diagnostics exit nonzero), a deliberately corrupt file must
# produce diagnostics without crashing, and a verified serve must
# replay its run through the SL-INV-* invariant checks.
lint_smoke() {
    local bin="$1"
    local out

    echo "== [tier 2] sparseloom lint over examples/scenarios =="
    out="$("$bin" lint examples/scenarios/*.json --fixture)"
    printf '%s\n' "$out"
    if ! grep -q "lint OK" <<<"$out"; then
        echo "lint smoke FAILED: shipped scenarios no longer lint clean" >&2
        exit 1
    fi

    # Error diagnostics must flip the exit code — and a file that is
    # not even JSON must yield a diagnostic, never a crash.
    local corrupt
    corrupt="$(mktemp)"
    printf '{ "tasks": ["alpha", "alpha"], broken' >"$corrupt"
    if out="$("$bin" lint "$corrupt" --fixture 2>&1)"; then
        echo "lint smoke FAILED: corrupt scenario exited zero" >&2
        rm -f "$corrupt"
        exit 1
    fi
    printf '%s\n' "$out"
    rm -f "$corrupt"
    if ! grep -q "SL-SCN-000" <<<"$out"; then
        echo "lint smoke FAILED: corrupt scenario produced no diagnostic" >&2
        exit 1
    fi

    echo "== [tier 2] serve --fixture --verify (invariant replay) =="
    out="$("$bin" serve --fixture --scenario-file examples/scenarios/bursty_sharded.json \
        --verify)"
    printf '%s\n' "$out"
    if ! grep -q "invariants OK" <<<"$out"; then
        echo "lint smoke FAILED: serve --verify did not confirm run invariants" >&2
        exit 1
    fi

    # Online-synthesis smoke: the shipped synthesis scenario must lint
    # clean (covered by the glob above), serve end-to-end through the
    # invariant replay, and the `--synthesize` CLI flag itself must
    # compose with the other online flags.
    echo "== [tier 2] online synthesis smoke (--synthesize, --verify) =="
    out="$("$bin" serve --fixture --scenario-file examples/scenarios/online_synthesis.json \
        --verify)"
    printf '%s\n' "$out"
    if ! grep -q "invariants OK" <<<"$out"; then
        echo "lint smoke FAILED: synthesis serve --verify did not confirm run invariants" >&2
        exit 1
    fi
    out="$("$bin" serve --fixture --scenario bursty --rate-qps 20 --burst-qps 120 \
        --period-ms 400 --horizon-ms 1500 --shards 2 --max-batch 4 --synthesize --verify)"
    printf '%s\n' "$out"
    if ! grep -q "invariants OK" <<<"$out"; then
        echo "lint smoke FAILED: serve --synthesize did not confirm run invariants" >&2
        exit 1
    fi

    # Fault-lab smoke: a crash/recover scenario must replay through the
    # invariant verifier AND have its declarative expect clauses checked
    # (SL-EXP-* failures exit nonzero, so a silently-broken recovery
    # path fails CI here).
    echo "== [tier 2] fault-lab smoke (crash_recover, --verify + expects) =="
    out="$("$bin" serve --fixture --scenario-file examples/scenarios/crash_recover.json \
        --verify)"
    printf '%s\n' "$out"
    if ! grep -q "invariants OK" <<<"$out"; then
        echo "lint smoke FAILED: fault-lab serve --verify did not confirm run invariants" >&2
        exit 1
    fi
    if ! grep -q "expectations OK" <<<"$out"; then
        echo "lint smoke FAILED: fault-lab run did not check its expect clauses" >&2
        exit 1
    fi
}

case "$TIER" in
    1) tier1 ;;
    2) tier2 ;;
    all) tier1; tier2 ;;
    *)
        echo "unknown CI_TIER=${TIER} (want 1, 2, or unset for both)" >&2
        exit 2
        ;;
esac

echo "CI OK (tier: ${TIER})"
