"""AOT export: lower every (task, subgraph, kernel-path) to HLO text and
serialize every (task, variant, subgraph) weight blob + eval data +
manifest.json.

This is the *only* python entrypoint on the build path (``make
artifacts``); the rust binary is self-contained afterwards.

Key layout decision: variants of a subgraph share shapes — they differ
only in which kernel path executes their GEMMs — so we export **one HLO
per (task, subgraph, kernel-path, batch)** with weights as *parameters*,
and store per-variant weights as binary blobs the rust runtime feeds as
PJRT literals. `V^S` stitched variants therefore run from `S·paths` HLOs
plus `V·S` weight blobs per task, which is exactly the paper's memory
story (subgraphs, not whole variants, are the loadable unit).

Interchange is HLO **text** (not serialized protos): jax ≥ 0.5 emits
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Artifacts:

    artifacts/
      manifest.json
      hlo/<task>/sg<j>/<path>_b<batch>.hlo.txt
      weights/<task>/<variant>/sg<j>.bin
      data/<task>_eval.bin          X f32-LE then y u32-LE
      probes/<task>.bin             probe X + per-variant expected logits
      oracle/<task>.bin             f32-LE accuracies of all V^S stitched
                                    variants (index k = ((i1*V)+i2)*V+i3)
"""

from __future__ import annotations

import argparse
import json
import os
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import compress, model as M, train

BATCH_SIZES = (1, 256)  # serve + accuracy-eval batch shapes
PROBE_BATCH = 4
MANIFEST_VERSION = 3
SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format).

    ``return_tuple=False``: each subgraph has exactly one output, so the
    root stays a plain array — the rust runtime can chain stage outputs
    as device buffers (``execute_b``) without host round-trips or tuple
    unwrapping.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _dtype_tag(a) -> str:
    return {"float32": "f32", "int8": "i8"}[str(a.dtype)]


def _param_specs(flat):
    return [{"dtype": _dtype_tag(a), "shape": list(a.shape)} for a in flat]


def _write_blob(path: str, flat) -> int:
    """Concatenate tensors (C-order, LE) into one blob; return #bytes."""
    with open(path, "wb") as f:
        for a in flat:
            f.write(np.asarray(a).tobytes())
    return os.path.getsize(path)


def _variant_paths_for(zoo):
    """Kernel paths actually used by a zoo (fp16 rides the dense path)."""
    return sorted({spec.kernel_path for spec in zoo})


def export_task_hlos(task: str, paths, out_dir: str, variants_by_path,
                     manifest_task: dict):
    """Lower each (subgraph, kernel-path, batch) of ``task`` to HLO text."""
    spec = M.TASKS[task]
    manifest_task["hlo"] = {}
    for j in range(M.SUBGRAPHS):
        sg_dir = os.path.join(out_dir, "hlo", task, f"sg{j}")
        os.makedirs(sg_dir, exist_ok=True)
        din = spec.iface[j]
        for path in paths:
            # Shapes are variant-independent within a path; use any
            # representative variant's params as the lowering template.
            rep = variants_by_path[path]
            flat = M.flatten_params(rep[j])
            for batch in BATCH_SIZES:
                x_spec = jax.ShapeDtypeStruct((batch, din), jnp.float32)
                p_specs = [
                    jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat
                ]

                def fn(x, *params, _j=j, _path=path):
                    sg = M.unflatten_like(rep[_j], params)
                    return M.forward_subgraph(
                        task, _j, x, sg, path=_path, use_kernel=True
                    )

                lowered = jax.jit(fn).lower(x_spec, *p_specs)
                text = to_hlo_text(lowered)
                fname = f"{path}_b{batch}.hlo.txt"
                with open(os.path.join(sg_dir, fname), "w") as f:
                    f.write(text)
                cost = lowered.cost_analysis() or {}
                key = f"sg{j}/{path}/b{batch}"
                manifest_task["hlo"][key] = {
                    "file": f"hlo/{task}/sg{j}/{fname}",
                    "flops": float(cost.get("flops", 0.0)),
                    "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                    "params": _param_specs(flat),
                    "input_dim": din,
                    "output_dim": spec.iface[j + 1],
                }


def stitched_oracle_accuracies(task: str, variant_params, y_eval, x_eval):
    """Exact accuracies of ALL V^S stitched variants, computed stage-wise.

    Stage-wise evaluation needs V + V² + V³ subgraph passes instead of
    S·V³ — the same observation that makes the paper's estimator training
    set cheap to label. Uses the pure-jnp forward (kernel equivalence is
    covered by python/tests/test_model.py).
    """
    V = len(variant_params)
    fwd = {}  # (j, path) -> jitted fn

    def run(j, x, vp, path):
        if (j, path) not in fwd:
            fwd[(j, path)] = jax.jit(
                lambda x, flat, _j=j, _p=path, _tpl=vp[j]: M.forward_subgraph(
                    task, _j, x, M.unflatten_like(_tpl, flat), path=_p,
                    use_kernel=False,
                )
            )
        return fwd[(j, path)](x, tuple(M.flatten_params(vp[j])))

    outs1 = [run(0, x_eval, vp, path) for vp, path in variant_params]
    accs = np.zeros(V * V * V, np.float32)
    for i1 in range(V):
        outs2 = [
            run(1, outs1[i1], vp, path) for vp, path in variant_params
        ]
        for i2 in range(V):
            for i3, (vp, path) in enumerate(variant_params):
                logits = run(2, outs2[i2], vp, path)
                pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
                acc = float(jnp.mean((pred == y_eval).astype(jnp.float32)))
                accs[(i1 * V + i2) * V + i3] = acc
    return accs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--steps", type=int, default=240,
                    help="base-model training steps")
    ap.add_argument("--zoo", default="intel", choices=sorted(compress.ZOOS),
                    help="which Table-5 zoo to export weights for")
    ap.add_argument("--tasks", default=",".join(M.TASK_NAMES))
    args = ap.parse_args()

    t0 = time.time()
    out = args.out
    for sub in ("hlo", "weights", "data", "probes", "oracle"):
        os.makedirs(os.path.join(out, sub), exist_ok=True)

    zoo = compress.ZOOS[args.zoo]()
    tasks = args.tasks.split(",")

    manifest = {
        "version": MANIFEST_VERSION,
        "seed": args.seed,
        "zoo_name": args.zoo,
        "subgraphs": M.SUBGRAPHS,
        "n_classes": M.N_CLASSES,
        "batch_sizes": list(BATCH_SIZES),
        "probe_batch": PROBE_BATCH,
        "n_eval": train.N_EVAL,
        "stitched_index": "k = ((i1*V)+i2)*V+i3 over zoo order",
        "variants": [
            {
                "name": s.name, "vtype": s.vtype, "sparsity": s.sparsity,
                "kernel_path": s.kernel_path, "precision": s.precision,
            }
            for s in zoo
        ],
        "tasks": {},
    }

    for task in tasks:
        print(f"[aot] {task}: training base model ({args.steps} steps)")
        base = train.train_base_model(task, args.seed, steps=args.steps)
        spec = M.TASKS[task]
        mt = {
            "family": spec.family,
            "input_dim": spec.input_dim,
            "iface": list(spec.iface),
            "variants": {},
        }

        # --- compress into the zoo; record accuracy + weight blobs ---
        variant_params = []  # [(params, kernel_path)] in zoo order
        by_path = {}
        for vs in zoo:
            params = compress.compress_model(base, vs)
            variant_params.append((params, vs.kernel_path))
            by_path.setdefault(vs.kernel_path, params)
            acc = train.eval_accuracy(
                task, params, path=vs.kernel_path, seed=args.seed
            )
            vdir = os.path.join(out, "weights", task, vs.name)
            os.makedirs(vdir, exist_ok=True)
            sgs = []
            for j in range(M.SUBGRAPHS):
                flat = M.flatten_params(params[j])
                nbytes = _write_blob(os.path.join(vdir, f"sg{j}.bin"), flat)
                sgs.append({
                    "file": f"weights/{task}/{vs.name}/sg{j}.bin",
                    "bytes": nbytes,
                    "params": _param_specs(flat),
                })
            mt["variants"][vs.name] = {"accuracy": acc, "subgraphs": sgs}
            print(f"[aot]   {vs.name:9s} acc={acc:.3f}")

        # --- HLO per (sg, path, batch) ---
        export_task_hlos(task, _variant_paths_for(zoo), out, by_path, mt)

        # --- eval dataset ---
        x_eval, y_eval = train.make_dataset(
            task, train.N_EVAL, args.seed, "eval"
        )
        with open(os.path.join(out, "data", f"{task}_eval.bin"), "wb") as f:
            f.write(np.asarray(x_eval, np.float32).tobytes())
            f.write(np.asarray(y_eval, np.uint32).tobytes())

        # --- probes: fixed input + per-variant expected logits ---
        probe_rng = np.random.default_rng(
            zlib.crc32(f"probe/{task}".encode()) % (2**31)
        )
        x_probe = probe_rng.standard_normal(
            (PROBE_BATCH, spec.input_dim)
        ).astype(np.float32)
        with open(os.path.join(out, "probes", f"{task}.bin"), "wb") as f:
            f.write(x_probe.tobytes())
            for params, path in variant_params:
                logits = M.forward(
                    task, jnp.asarray(x_probe), params, path=path,
                    use_kernel=False,
                )
                f.write(np.asarray(logits, np.float32).tobytes())

        # --- exact stitched-variant oracle accuracies ---
        print(f"[aot]   stitched oracle ({len(zoo)**M.SUBGRAPHS} variants)")
        accs = stitched_oracle_accuracies(
            task, variant_params, y_eval, x_eval
        )
        with open(os.path.join(out, "oracle", f"{task}.bin"), "wb") as f:
            f.write(accs.tobytes())

        manifest["tasks"][task] = mt

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time() - t0:.1f}s → {out}/manifest.json")


if __name__ == "__main__":
    main()
