//! Deterministic pseudo-random number generation.
//!
//! Offline substrate for the `rand` crate: SplitMix64 for seeding and
//! xoshiro256++ as the workhorse generator. Everything in the simulator,
//! workload generators, and GBDT subsampling draws from here, so runs are
//! reproducible from a single seed.

/// SplitMix64 — used to expand one `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-period PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream for a named sub-component.
    pub fn fork(&mut self, label: &str) -> Rng {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Rng::new(h ^ self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (n > 0), via Lemire's method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival sampling).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues reachable");
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork("a");
        let mut b = root.fork("b");
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn exponential_mean_close_to_inverse_rate() {
        let mut r = Rng::new(13);
        let n = 40_000;
        let m = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((m - 0.25).abs() < 0.01, "mean {m}");
    }
}
