//! Model stitching (paper §3.1): the V^S stitched-variant space.
//!
//! A stitched variant `ṽ^{t,k}` is a composition `(i₁, …, i_S)` — at
//! subgraph position j it reuses subgraph `s_j^{t,i_j}` of original
//! variant i_j (Eq. 1). Because every variant of a task shares the
//! layer-aligned interface shapes, any composition is shape-safe; no
//! retraining, no new weights — the stitched space is purely
//! combinatorial over existing subgraphs.
//!
//! The canonical index is the base-V big-endian digit encoding
//! `k = ((i₁·V)+i₂)·V+i₃` (S=3 shown; general below), matching the
//! python oracle exporter (`aot.py`).

use crate::zoo::{TaskZoo, VariantType};

/// A stitched variant: which original variant supplies each subgraph.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Composition(pub Vec<usize>);

impl Composition {
    /// Decode from the canonical base-V index.
    pub fn from_index(k: usize, v: usize, s: usize) -> Composition {
        assert!(v > 0 && s > 0);
        let mut digits = vec![0usize; s];
        let mut rem = k;
        for j in (0..s).rev() {
            digits[j] = rem % v;
            rem /= v;
        }
        assert_eq!(rem, 0, "index {k} out of range for V={v}, S={s}");
        Composition(digits)
    }

    /// Encode to the canonical base-V index.
    pub fn to_index(&self, v: usize) -> usize {
        self.0.iter().fold(0, |acc, &d| {
            debug_assert!(d < v);
            acc * v + d
        })
    }

    /// Is this a pure (non-stitched) variant — all subgraphs from one i?
    pub fn is_pure(&self) -> bool {
        self.0.windows(2).all(|w| w[0] == w[1])
    }

    pub fn subgraphs(&self) -> usize {
        self.0.len()
    }

    /// Paper-style label like "P-Q-D" from the zoo's variant types.
    pub fn label(&self, zoo: &TaskZoo) -> String {
        self.0
            .iter()
            .map(|&i| zoo.variants[i].spec.vtype.tag().to_string())
            .collect::<Vec<_>>()
            .join("-")
    }

    /// Long label like "unstr80-int8-dense".
    pub fn name(&self, zoo: &TaskZoo) -> String {
        self.0
            .iter()
            .map(|&i| zoo.variants[i].spec.name.clone())
            .collect::<Vec<_>>()
            .join("-")
    }
}

/// The stitched-variant space of one task.
#[derive(Clone, Copy, Debug)]
pub struct StitchSpace {
    /// V — original variants per task.
    pub n_variants: usize,
    /// S — subgraph positions.
    pub n_subgraphs: usize,
}

impl StitchSpace {
    pub fn new(n_variants: usize, n_subgraphs: usize) -> Self {
        assert!(n_variants > 0 && n_subgraphs > 0);
        Self { n_variants, n_subgraphs }
    }

    pub fn for_task(zoo: &TaskZoo) -> Self {
        Self::new(zoo.n_variants(), zoo.iface.len() - 1)
    }

    /// |space| = V^S.
    pub fn len(&self) -> usize {
        self.n_variants.pow(self.n_subgraphs as u32)
    }

    pub fn is_empty(&self) -> bool {
        false // V ≥ 1 and S ≥ 1 always yield at least one composition
    }

    pub fn composition(&self, k: usize) -> Composition {
        Composition::from_index(k, self.n_variants, self.n_subgraphs)
    }

    pub fn index(&self, c: &Composition) -> usize {
        assert_eq!(c.subgraphs(), self.n_subgraphs);
        c.to_index(self.n_variants)
    }

    /// Index of the pure composition of original variant i.
    pub fn pure_index(&self, i: usize) -> usize {
        self.index(&Composition(vec![i; self.n_subgraphs]))
    }

    /// Iterate all V^S compositions in canonical order.
    pub fn iter(&self) -> impl Iterator<Item = Composition> + '_ {
        (0..self.len()).map(move |k| self.composition(k))
    }

    /// How many compositions contain original-variant subgraph (j, i)?
    /// (V^{S-1} — each other position free; used by hotness sanity tests.)
    pub fn occurrences_per_subgraph(&self) -> usize {
        self.n_variants.pow(self.n_subgraphs as u32 - 1)
    }
}

/// Mixing profile of a composition over variant *types* — e.g. how many
/// subgraph positions come from pruned vs quantized vs dense variants.
/// Feeds the accuracy estimator's feature vector.
pub fn type_histogram(c: &Composition, zoo: &TaskZoo) -> [usize; 5] {
    let mut h = [0usize; 5];
    for &i in &c.0 {
        let idx = match zoo.variants[i].spec.vtype {
            VariantType::Dense => 0,
            VariantType::Fp16 => 1,
            VariantType::Int8 => 2,
            VariantType::Unstructured => 3,
            VariantType::Structured => 4,
        };
        h[idx] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip_exhaustive() {
        let sp = StitchSpace::new(10, 3);
        assert_eq!(sp.len(), 1000);
        for k in 0..sp.len() {
            let c = sp.composition(k);
            assert_eq!(sp.index(&c), k);
        }
    }

    #[test]
    fn canonical_order_matches_python_oracle() {
        // aot.py: k = ((i1*V)+i2)*V+i3
        let sp = StitchSpace::new(10, 3);
        let c = Composition(vec![3, 1, 4]);
        assert_eq!(sp.index(&c), (3 * 10 + 1) * 10 + 4);
        assert_eq!(sp.composition(314), c);
    }

    #[test]
    fn pure_detection() {
        assert!(Composition(vec![2, 2, 2]).is_pure());
        assert!(!Composition(vec![2, 2, 3]).is_pure());
        assert!(Composition(vec![5]).is_pure());
    }

    #[test]
    fn pure_index_diagonal() {
        let sp = StitchSpace::new(10, 3);
        assert_eq!(sp.pure_index(0), 0);
        assert_eq!(sp.pure_index(7), (7 * 10 + 7) * 10 + 7);
    }

    #[test]
    fn iterator_covers_space_once() {
        let sp = StitchSpace::new(3, 2);
        let all: Vec<_> = sp.iter().collect();
        assert_eq!(all.len(), 9);
        let uniq: std::collections::HashSet<_> = all.iter().cloned().collect();
        assert_eq!(uniq.len(), 9);
    }

    #[test]
    fn occurrences_per_subgraph_formula() {
        assert_eq!(StitchSpace::new(10, 3).occurrences_per_subgraph(), 100);
        assert_eq!(StitchSpace::new(4, 2).occurrences_per_subgraph(), 4);
    }

    #[test]
    #[should_panic]
    fn out_of_range_index_panics() {
        Composition::from_index(1000, 10, 3);
    }
}
