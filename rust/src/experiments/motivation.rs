//! Motivation-section experiments: Fig. 3 (stitching vs no stitching),
//! Fig. 4 (accuracy–latency space), Table 2 (placement orders),
//! Fig. 5 (switch-latency and memory breakdowns).

use anyhow::Result;

use super::Ctx;
use crate::metrics::render_table;
use crate::planner::{algo, CostModel};
use crate::profiler::{profile_task_exhaustive, TaskProfile};
use crate::runtime::Runtime;
use crate::soc::{order_label, Platform};
use crate::stitching::Composition;

use crate::workload::{placement_orders, slo_ladder, Slo, TaskRanges};

/// Exhaustive (oracle-accuracy) profiles for all tasks on a platform —
/// motivation experiments judge feasibility on ground truth.
fn truth_profiles(ctx: &Ctx, platform: Platform) -> Result<Vec<TaskProfile>> {
    let lm = ctx.lm(platform);
    ctx.zoo
        .tasks
        .values()
        .map(|tz| {
            let oracle = ctx.zoo.load_oracle(&tz.name)?;
            Ok(profile_task_exhaustive(tz, &lm, &oracle))
        })
        .collect()
}

/// Fig. 3: average SLO violation rate with vs without stitching across
/// the C1–C8 strictness ladder (desktop platform, all tasks).
pub fn fig3(ctx: &Ctx) -> Result<String> {
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let profiles = truth_profiles(ctx, platform.clone())?;
    let orders = placement_orders(&platform, ctx.zoo.subgraphs);

    let mut rows = Vec::new();
    let mut max_reduction = 0.0f64;
    for c in 0..8 {
        let mut viol_with = 0usize;
        let mut viol_without = 0usize;
        let mut n = 0usize;
        for p in &profiles {
            let tz = ctx.zoo.task(&p.task)?;
            let ladder = slo_ladder(&TaskRanges::measure(tz, &lm));
            let slo = ladder[c];
            n += 1;
            let theta = algo::feasible_set(&CostModel::unit(), p, &slo, &orders);
            if theta.is_empty() {
                viol_with += 1;
            }
            let any_pure = theta
                .indices
                .iter()
                .any(|&k| p.space.composition(k).is_pure());
            if !any_pure {
                viol_without += 1;
            }
        }
        let vw = 100.0 * viol_with as f64 / n as f64;
        let vo = 100.0 * viol_without as f64 / n as f64;
        max_reduction = max_reduction.max(vo - vw);
        rows.push(vec![
            format!("C{}", c + 1),
            format!("{vo:.1}"),
            format!("{vw:.1}"),
            format!("{:.1}", vo - vw),
        ]);
    }
    let mut out = String::from(
        "Fig. 3 — SLO violation rate (%) with vs without model stitching\n\
         (desktop; C1 laxest → C8 strictest; paper: up to 63 pp reduction,\n\
         100% without stitching at C8)\n\n",
    );
    out.push_str(&render_table(
        &["config", "no-stitch %", "stitch %", "reduction pp"],
        &rows,
    ));
    out.push_str(&format!("\nmax reduction: {max_reduction:.1} pp\n"));
    Ok(out)
}

/// Fig. 4: the stitched accuracy–latency space vs the original zoo
/// (imgcls, desktop), histogram + Pareto frontier + the 4 %/5 % stats.
pub fn fig4(ctx: &Ctx) -> Result<String> {
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let task = "imgcls";
    let tz = ctx.zoo.task(task)?;
    let oracle = ctx.zoo.load_oracle(task)?;
    let p = profile_task_exhaustive(tz, &lm, &oracle);
    let orders = placement_orders(&platform, ctx.zoo.subgraphs);

    // Best-order true latency + oracle accuracy per stitched variant.
    let mut pts: Vec<(f64, f64, bool)> = Vec::new(); // (lat, acc, is_pure)
    for k in 0..p.space.len() {
        let comp = p.space.composition(k);
        let lat = orders
            .iter()
            .filter_map(|o| p.latency_true(&comp, o))
            .fold(f64::INFINITY, f64::min);
        if lat.is_finite() {
            pts.push((lat, oracle[k], comp.is_pure()));
        }
    }
    let pure: Vec<&(f64, f64, bool)> = pts.iter().filter(|x| x.2).collect();
    let best_pure_acc = pure.iter().map(|x| x.1).fold(f64::NEG_INFINITY, f64::max);
    let best_pure_lat = pure.iter().map(|x| x.0).fold(f64::INFINITY, f64::min);
    let n_stitched = pts.iter().filter(|x| !x.2).count();
    let above_acc = pts
        .iter()
        .filter(|x| !x.2 && x.1 > best_pure_acc + 1e-9)
        .count();
    let below_lat = pts
        .iter()
        .filter(|x| !x.2 && x.0 < best_pure_lat - 1e-9)
        .count();

    // Pareto frontier over all points (min latency, max accuracy).
    let mut sorted: Vec<(f64, f64, bool)> = pts.clone();
    sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut pareto = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for &(lat, acc, is_pure) in &sorted {
        if acc > best_acc {
            best_acc = acc;
            pareto.push((lat, acc, is_pure));
        }
    }
    let pareto_stitched = pareto.iter().filter(|x| !x.2).count();

    // 10×10 density histogram (text rendering of the paper's heatmap).
    let (lat_lo, lat_hi) = (
        pts.iter().map(|x| x.0).fold(f64::INFINITY, f64::min),
        pts.iter().map(|x| x.0).fold(f64::NEG_INFINITY, f64::max),
    );
    let (acc_lo, acc_hi) = (
        pts.iter().map(|x| x.1).fold(f64::INFINITY, f64::min),
        pts.iter().map(|x| x.1).fold(f64::NEG_INFINITY, f64::max),
    );
    let mut grid = [[0usize; 10]; 10];
    for &(lat, acc, _) in &pts {
        let i = (((acc - acc_lo) / (acc_hi - acc_lo + 1e-12)) * 9.999) as usize;
        let j = (((lat - lat_lo) / (lat_hi - lat_lo + 1e-12)) * 9.999) as usize;
        grid[i][j] += 1;
    }
    let mut hist = String::new();
    for i in (0..10).rev() {
        hist.push_str(&format!("acc {:5.2} | ", acc_lo + (acc_hi - acc_lo) * (i as f64 + 0.5) / 10.0));
        for j in 0..10 {
            hist.push_str(&format!("{:>4}", grid[i][j]));
        }
        hist.push('\n');
    }
    hist.push_str(&format!(
        "            lat {:.2}..{:.2} ms →\n",
        lat_lo, lat_hi
    ));

    Ok(format!(
        "Fig. 4 — accuracy–latency space, task {task} (desktop)\n\n\
         {hist}\n\
         original variants: {} | stitched: {n_stitched}\n\
         Pareto frontier size: {} ({} stitched, {} pure)\n\
         stitched above best original accuracy: {above_acc} ({:.1} %)   [paper: 4 %]\n\
         stitched below best original latency:  {below_lat} ({:.1} %)   [paper: 5 %]\n",
        pure.len(),
        pareto.len(),
        pareto_stitched,
        pareto.len() - pareto_stitched,
        100.0 * above_acc as f64 / n_stitched as f64,
        100.0 * below_lat as f64 / n_stitched as f64,
    ))
}

/// Table 2: latency of six stitched ResNet-stand-in variants under all
/// six desktop placement orders; the best order varies per variant and
/// N-G-C is consistently suboptimal.
pub fn table2(ctx: &Ctx) -> Result<String> {
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let task = "imgcls";
    let tz = ctx.zoo.task(task)?;
    let oracle = ctx.zoo.load_oracle(task)?;
    let p = profile_task_exhaustive(tz, &lm, &oracle);
    let orders = placement_orders(&platform, ctx.zoo.subgraphs);

    // The paper's six variants over {P: pruned, Q: int8, D: dense}.
    let vi = |name: &str| tz.variant_by_name(name).unwrap().0;
    let (d, q, pu, ps) = (vi("dense"), vi("int8"), vi("unstr80"), vi("struct50"));
    let variants: Vec<(&str, Composition)> = vec![
        ("P-Q-P", Composition(vec![pu, q, ps])),
        ("P-P-Q", Composition(vec![pu, ps, q])),
        ("D-D-P", Composition(vec![d, d, pu])),
        ("D-P-Q", Composition(vec![d, pu, q])),
        ("Q-P-D", Composition(vec![q, ps, d])),
        ("P-D-Q", Composition(vec![ps, d, q])),
    ];

    let mut rows = Vec::new();
    let mut best_orders = Vec::new();
    for order in &orders {
        let mut row = vec![order_label(order)];
        for (_, comp) in &variants {
            match p.latency_true(comp, order) {
                Some(l) => row.push(format!("{l:.3}")),
                None => row.push("n/s".into()),
            }
        }
        rows.push(row);
    }
    for (_, comp) in &variants {
        let mut best = (f64::INFINITY, String::new());
        for order in &orders {
            if let Some(l) = p.latency_true(comp, order) {
                if l < best.0 {
                    best = (l, order_label(order));
                }
            }
        }
        best_orders.push(best.1);
    }
    let mut headers = vec!["order"];
    let names: Vec<&str> = variants.iter().map(|(n, _)| *n).collect();
    headers.extend(names.iter());
    let mut best_row = vec!["Best".to_string()];
    best_row.extend(best_orders.iter().cloned());
    rows.push(best_row);

    let unique_best: std::collections::HashSet<&String> = best_orders.iter().collect();
    Ok(format!(
        "Table 2 — stitched-variant latency (ms) per placement order\n\
         (task {task}, desktop; P=pruned, Q=int8, D=dense)\n\n{}\n\
         distinct best orders: {} of {} variants  [paper: best order varies]\n\
         N-G-C optimal for: {} variants            [paper: never]\n",
        render_table(&headers, &rows),
        unique_best.len(),
        variants.len(),
        best_orders.iter().filter(|b| b.as_str() == "N-G-C").count(),
    ))
}

/// Fig. 5: (a) compile/load/inference breakdown of adding a variant;
/// (b) runtime memory breakdown. Uses real PJRT costs for (a)'s
/// measured column plus the platform model's projection.
pub fn fig5(ctx: &Ctx) -> Result<String> {
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let task = "imgcls";
    let tz = ctx.zoo.task(task)?;

    // Real PJRT: compile + weight-upload + inference of one variant.
    let rt = Runtime::new()?;
    let vi = tz.variant_by_name("dense").unwrap().0;
    let mut compile_ms = 0.0;
    let mut load_ms = 0.0;
    for sg in 0..ctx.zoo.subgraphs {
        let path = tz.variants[vi].spec.kernel_path;
        let exe = rt.executable(&ctx.zoo, task, sg, path, 1)?;
        compile_ms += exe.compile_ms;
        let (_, l) = rt.weight_buffers(&ctx.zoo, task, vi, sg)?;
        load_ms += l;
    }
    let mut infer_ms = 0.0;
    for sg in 0..ctx.zoo.subgraphs {
        infer_ms += rt.measure_subgraph_ms(
            &ctx.zoo, task, sg, tz.variants[vi].spec.kernel_path, 10,
        )?;
    }

    // Platform model projection (per-MiB coefficients × real bytes).
    let bytes = tz.variants[vi].total_bytes();
    let proc = crate::soc::Processor::Gpu;
    let m_compile = lm.compile_ms(bytes, proc);
    let m_load = lm.load_ms(bytes, proc);
    let m_infer: f64 = (0..ctx.zoo.subgraphs)
        .filter_map(|j| lm.subgraph_ms(tz, vi, j, proc))
        .sum();

    // Memory breakdown: prepared pool state under full preloading.
    let cfg = crate::profiler::ProfilerConfig::default();
    let profiles = ctx.profiles(&lm, &cfg)?;
    let server = crate::scenario::Server::builder(&ctx.zoo, &lm, &profiles).build();
    let mut slos = std::collections::BTreeMap::new();
    for (name, _) in &profiles {
        let tr = TaskRanges::measure(ctx.zoo.task(name)?, &lm);
        slos.insert(
            name.clone(),
            Slo { min_accuracy: tr.acc_min, max_latency_ms: tr.lat_max_ms },
        );
    }
    let universe: Vec<Slo> = slos.values().copied().collect();
    let prepared = server.prepare(&slos, &universe)?;
    let mut pool = prepared.pool.clone();
    pool.other_bytes = 64 * 1024 * 1024; // engine + activations overhead
    let b = pool.breakdown();

    Ok(format!(
        "Fig. 5a — latency breakdown of adding one variant ({task}/dense)\n\n\
         measured PJRT (this host):  compile {compile_ms:.1} ms | weight-upload {load_ms:.2} ms | inference {infer_ms:.3} ms\n\
         platform model (desktop GPU): compile {m_compile:.1} ms | load {m_load:.1} ms | inference {m_infer:.3} ms\n\
         model compile/infer ratio: {:.1}x   [paper: 23.7x]\n\
         model load/infer ratio:    {:.1}x   [paper: 3x]\n\
         compile+load share of switch: {:.1} %  [paper: up to 96.4 %]\n\n\
         Fig. 5b — runtime memory breakdown (full preloading)\n\n\
         active variants:    {}\n\
         preloaded variants: {}\n\
         other (runtime):    {}\n\
         total:              {}\n",
        m_compile / m_infer.max(1e-9),
        m_load / m_infer.max(1e-9),
        100.0 * (m_compile + m_load) / (m_compile + m_load + m_infer),
        crate::util::fmt_bytes(b.active_bytes),
        crate::util::fmt_bytes(b.preloaded_bytes),
        crate::util::fmt_bytes(b.other_bytes),
        crate::util::fmt_bytes(b.total()),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_reduction_positive_on_real_artifacts() {
        let Ok(ctx) = Ctx::load("artifacts", true) else { return };
        let out = fig3(&ctx).unwrap();
        assert!(out.contains("C8"));
    }

    #[test]
    fn stats_helpers_available() {
        assert_eq!(crate::util::stats::mean(&[2.0, 4.0]), 3.0);
    }
}
