//! Short-horizon load forecasting: time-aware Holt (double-EWMA)
//! trend fitting plus a burst detector on rate acceleration.
//!
//! The telemetry estimators of the base module are *trailing*: the
//! EWMA and the sliding window both describe traffic that has already
//! arrived. Everything predictive in the control plane — shedding
//! before deadline slack is exhausted (`Admission::Predictive`),
//! migrating before a shard actually saturates (the forecast replan
//! trigger), projecting SLO violation rates — needs the *next* `H` ms,
//! which is this module's job. Two building blocks:
//!
//! * [`TrendTracker`] — a time-aware Holt filter over an arbitrary
//!   scalar series (windowed rate, shard backlog): level
//!   `ℓ ← α·x + (1−α)·(ℓ + b·Δt)` and trend
//!   `b ← β·(ℓ' − ℓ)/Δt + (1−β)·b`, with the trend kept per
//!   millisecond so irregular sample spacing (samples land on arrival
//!   timestamps, gated to ≥ `sample_ms` apart) projects correctly:
//!   `x̂(t + H) = ℓ + b·(t − t_last + H)`.
//! * [`RateForecaster`] — a sliding arrival window feeding a
//!   [`TrendTracker`] with windowed-rate samples, plus a burst
//!   detector: a sample whose acceleration `(x_k − x_{k−1})/Δt`
//!   exceeds [`ForecastConfig::burst_accel_qps_per_s`] *and* sits
//!   above [`ForecastConfig::burst_ratio`] × the fitted level flags a
//!   burst, and the projection then floors at the raw windowed rate —
//!   the Holt level deliberately lags a square-wave edge, the raw
//!   window does not.
//!
//! Everything is deterministic in the observed timestamps (no wall
//! clock, no randomness), which the determinism integration test
//! relies on. Cold starts are total: zero or one sample projects the
//! last observation (or 0.0), never NaN.
//!
//! ```
//! use sparseloom::telemetry::forecast::RateForecaster;
//!
//! let mut f = RateForecaster::default();
//! for i in 0..200 {
//!     f.observe(10.0 * i as f64); // steady 100 qps
//! }
//! let p = f.projected_qps(2_000.0, 500.0);
//! // Two seconds in, the Holt transient still overshoots a little.
//! assert!((p - 100.0).abs() / 100.0 < 0.35, "{p}");
//! ```

use std::collections::VecDeque;

/// Knobs for the Holt fit and the burst detector. The defaults favor a
/// responsive fit (the forecaster exists to catch bursts the trailing
/// EWMA smooths over): level gain 0.3, trend gain 0.15, one rate
/// sample per 100 ms of virtual time over a 1 s window.
#[derive(Clone, Debug, PartialEq)]
pub struct ForecastConfig {
    /// Holt level smoothing gain α (0 < α ≤ 1).
    pub alpha: f64,
    /// Holt trend smoothing gain β (0 < β ≤ 1).
    pub beta: f64,
    /// Sliding-window length (virtual ms) for the rate samples.
    pub window_ms: f64,
    /// Minimum spacing (virtual ms) between Holt samples.
    pub sample_ms: f64,
    /// Burst threshold on rate acceleration between consecutive
    /// samples (qps per second).
    pub burst_accel_qps_per_s: f64,
    /// A bursting sample must also exceed this multiple of the fitted
    /// level (keeps steady-state Poisson noise from flagging).
    pub burst_ratio: f64,
}

impl Default for ForecastConfig {
    fn default() -> Self {
        Self {
            alpha: 0.3,
            beta: 0.15,
            window_ms: 1_000.0,
            sample_ms: 100.0,
            burst_accel_qps_per_s: 50.0,
            burst_ratio: 1.5,
        }
    }
}

/// A time-aware Holt (double-EWMA) filter over one scalar series.
/// Feed it `(now_ms, value)` observations in non-decreasing time order
/// and read `level + trend × horizon` projections back. Samples closer
/// than `sample_ms` to the previous one are ignored, so callers may
/// observe on every event.
#[derive(Clone, Debug)]
pub struct TrendTracker {
    alpha: f64,
    beta: f64,
    sample_ms: f64,
    level: f64,
    trend_per_ms: f64,
    last_sample_ms: f64,
    samples: u64,
}

impl Default for TrendTracker {
    fn default() -> Self {
        // Backlog-style defaults: same gains as the rate fit, sampled
        // at up to 20 Hz of virtual time.
        Self::new(0.3, 0.15, 50.0)
    }
}

impl TrendTracker {
    pub fn new(alpha: f64, beta: f64, sample_ms: f64) -> TrendTracker {
        TrendTracker {
            alpha: alpha.clamp(1e-6, 1.0),
            beta: beta.clamp(1e-6, 1.0),
            sample_ms: sample_ms.max(1e-9),
            level: 0.0,
            trend_per_ms: 0.0,
            last_sample_ms: 0.0,
            samples: 0,
        }
    }

    /// Ingest one observation. Observations must be fed in
    /// non-decreasing time order; ones closer than `sample_ms` to the
    /// last accepted sample are dropped. Returns whether the
    /// observation was accepted as a sample.
    pub fn observe(&mut self, now_ms: f64, value: f64) -> bool {
        if !now_ms.is_finite() || !value.is_finite() {
            return false;
        }
        if self.samples == 0 {
            self.level = value;
            self.last_sample_ms = now_ms;
            self.samples = 1;
            return true;
        }
        let dt = now_ms - self.last_sample_ms;
        if dt < self.sample_ms {
            return false;
        }
        let predicted = self.level + self.trend_per_ms * dt;
        let new_level = self.alpha * value + (1.0 - self.alpha) * predicted;
        self.trend_per_ms = self.beta * ((new_level - self.level) / dt)
            + (1.0 - self.beta) * self.trend_per_ms;
        self.level = new_level;
        self.last_sample_ms = now_ms;
        self.samples += 1;
        true
    }

    /// Projection `horizon_ms` past `now_ms`, clamped at 0 (rates and
    /// backlogs are non-negative). 0.0 before any sample.
    pub fn forecast(&self, now_ms: f64, horizon_ms: f64) -> f64 {
        if self.samples == 0 {
            return 0.0;
        }
        let ahead = (now_ms - self.last_sample_ms).max(0.0) + horizon_ms.max(0.0);
        (self.level + self.trend_per_ms * ahead).max(0.0)
    }

    /// The projected *increase* over the next `horizon_ms`: positive
    /// trend × horizon, 0 when the series is flat or falling — the
    /// growth term predictive admission adds to the observed backlog.
    pub fn projected_growth(&self, horizon_ms: f64) -> f64 {
        self.trend_per_ms.max(0.0) * horizon_ms.max(0.0)
    }

    /// Fitted level (0.0 before any sample).
    pub fn level(&self) -> f64 {
        if self.samples == 0 { 0.0 } else { self.level }
    }

    /// Fitted trend, in value units per millisecond.
    pub fn trend_per_ms(&self) -> f64 {
        self.trend_per_ms
    }

    /// Accepted samples so far.
    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Timestamp of the last accepted sample (0.0 before any).
    pub fn last_sample_ms(&self) -> f64 {
        self.last_sample_ms
    }
}

/// Per-task arrival-rate forecaster: sliding window → rate samples →
/// [`TrendTracker`], plus the burst flag. Feed every arrival (of one
/// task, non-decreasing times) through [`RateForecaster::observe`].
#[derive(Clone, Debug)]
pub struct RateForecaster {
    cfg: ForecastConfig,
    window: VecDeque<f64>,
    holt: TrendTracker,
    last_rate: f64,
    burst: bool,
}

impl Default for RateForecaster {
    fn default() -> Self {
        Self::new(ForecastConfig::default())
    }
}

impl RateForecaster {
    pub fn new(cfg: ForecastConfig) -> RateForecaster {
        let holt = TrendTracker::new(cfg.alpha, cfg.beta, cfg.sample_ms);
        RateForecaster { cfg, window: VecDeque::new(), holt, last_rate: 0.0, burst: false }
    }

    pub fn config(&self) -> &ForecastConfig {
        &self.cfg
    }

    /// Ingest one arrival timestamp (non-decreasing per task).
    pub fn observe(&mut self, arrival_ms: f64) {
        if !arrival_ms.is_finite() {
            return;
        }
        let w = self.cfg.window_ms.max(1e-9);
        self.window.push_back(arrival_ms);
        while self
            .window
            .front()
            .map(|&t| t + w < arrival_ms)
            .unwrap_or(false)
        {
            self.window.pop_front();
        }
        if self.holt.samples() == 0 {
            let x = self.window_rate_qps(arrival_ms);
            self.holt.observe(arrival_ms, x);
            self.last_rate = x;
            return;
        }
        let dt = arrival_ms - self.holt.last_sample_ms();
        if dt < self.cfg.sample_ms.max(1e-9) {
            return;
        }
        let x = self.window_rate_qps(arrival_ms);
        let accel_qps_per_s = (x - self.last_rate) / dt * 1_000.0;
        self.burst = accel_qps_per_s > self.cfg.burst_accel_qps_per_s
            && x > self.cfg.burst_ratio * self.holt.level().max(1e-9);
        self.holt.observe(arrival_ms, x);
        self.last_rate = x;
    }

    /// Raw windowed arrival rate at `now_ms` (same convention as
    /// `Telemetry::window_rate_qps`: arrivals in the trailing window
    /// over the full window length).
    pub fn window_rate_qps(&self, now_ms: f64) -> f64 {
        let w = self.cfg.window_ms.max(1e-9);
        let n = self
            .window
            .iter()
            .filter(|&&t| t + w >= now_ms && t <= now_ms)
            .count();
        1_000.0 * n as f64 / w
    }

    /// Projected arrival rate (qps) `horizon_ms` past `now_ms`. During
    /// a detected burst the projection floors at the *current* raw
    /// windowed rate (the Holt level lags a square-wave edge; the raw
    /// window does not, and it self-decays once arrivals stop). 0.0
    /// before any observation, never negative, never NaN.
    ///
    /// Sampling is arrival-driven, so a task that goes silent would
    /// otherwise keep (and linearly extrapolate) its last fitted burst
    /// forever: once nothing has been observed for a full window, the
    /// fit is declared stale and the projection falls back to the raw
    /// windowed rate at `now_ms` — which empties with `now` and reads
    /// ~0 for an idle task, exactly like the trailing estimators.
    pub fn projected_qps(&self, now_ms: f64, horizon_ms: f64) -> f64 {
        if self.holt.samples() == 0 {
            return 0.0;
        }
        if now_ms - self.holt.last_sample_ms() > self.cfg.window_ms.max(1e-9) {
            return self.window_rate_qps(now_ms);
        }
        let mut p = self.holt.forecast(now_ms, horizon_ms);
        if self.burst {
            p = p.max(self.window_rate_qps(now_ms));
        }
        p.max(0.0)
    }

    /// Forecast load relative to the fitted current level:
    /// `projected / level`, 1.0 before any observation. The SLO
    /// forecast scales the observed violation share by this factor.
    pub fn load_factor(&self, now_ms: f64, horizon_ms: f64) -> f64 {
        if self.holt.samples() == 0 {
            return 1.0;
        }
        let f = self.projected_qps(now_ms, horizon_ms) / self.holt.level().max(1e-9);
        if f.is_finite() { f.max(0.0) } else { 1.0 }
    }

    /// Whether the latest sample flagged a burst (rate acceleration
    /// above threshold and above the fitted level).
    pub fn is_burst(&self) -> bool {
        self.burst
    }

    /// Fitted rate level (qps).
    pub fn level_qps(&self) -> f64 {
        self.holt.level()
    }

    /// Fitted rate trend (qps per ms).
    pub fn trend_qps_per_ms(&self) -> f64 {
        self.holt.trend_per_ms()
    }

    /// Accepted Holt samples so far.
    pub fn samples(&self) -> u64 {
        self.holt.samples()
    }
}

/// Clamp a forecast probability into [0, 1]; non-finite inputs map to
/// 0 (a broken estimate must read "no signal", never poison a report).
pub fn clamp01(x: f64) -> f64 {
    if x.is_finite() {
        x.clamp(0.0, 1.0)
    } else {
        0.0
    }
}

/// Projected violation rate: the observed violation share scaled by
/// the forecast load factor, clamped into [0, 1]. First-order model:
/// violations under this serving engine come from batch growth and
/// queue pressure, both of which scale with offered load over the
/// horizon.
pub fn project_violation_rate(observed_miss_rate: f64, load_factor: f64) -> f64 {
    clamp01(observed_miss_rate * load_factor)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Arrivals at a fixed `qps` over [0, horizon_ms).
    fn steady(f: &mut RateForecaster, qps: f64, from_ms: f64, to_ms: f64) {
        let gap = 1_000.0 / qps;
        let mut t = from_ms;
        while t < to_ms {
            f.observe(t);
            t += gap;
        }
    }

    #[test]
    fn empty_forecaster_is_total_and_zero() {
        let f = RateForecaster::default();
        assert_eq!(f.projected_qps(0.0, 500.0), 0.0);
        assert_eq!(f.projected_qps(1e9, 0.0), 0.0);
        assert_eq!(f.window_rate_qps(123.0), 0.0);
        assert_eq!(f.load_factor(0.0, 500.0), 1.0);
        assert!(!f.is_burst());
        assert_eq!(f.samples(), 0);
        let t = TrendTracker::default();
        assert_eq!(t.forecast(0.0, 1_000.0), 0.0);
        assert_eq!(t.projected_growth(1_000.0), 0.0);
        assert_eq!(t.level(), 0.0);
    }

    #[test]
    fn single_sample_cold_start_never_nans_or_panics() {
        let mut f = RateForecaster::default();
        f.observe(42.0);
        assert_eq!(f.samples(), 1);
        for (now, h) in [(42.0, 0.0), (42.0, 500.0), (1e6, 1e6), (0.0, 0.0)] {
            let p = f.projected_qps(now, h);
            assert!(p.is_finite() && p >= 0.0, "({now}, {h}) → {p}");
            let lf = f.load_factor(now, h);
            assert!(lf.is_finite() && lf >= 0.0, "({now}, {h}) → {lf}");
        }
        let mut t = TrendTracker::default();
        t.observe(10.0, 7.5);
        assert_eq!(t.samples(), 1);
        assert!((t.level() - 7.5).abs() < 1e-12);
        assert_eq!(t.trend_per_ms(), 0.0, "one sample has no trend");
        assert!(t.forecast(10.0, 1_000.0).is_finite());
        // Non-finite observations are rejected, not absorbed.
        t.observe(20.0, f64::NAN);
        assert!(t.level().is_finite());
        f.observe(f64::INFINITY);
        assert!(f.projected_qps(50.0, 100.0).is_finite());
    }

    #[test]
    fn constant_rate_has_no_trend() {
        let mut f = RateForecaster::default();
        steady(&mut f, 100.0, 0.0, 6_000.0);
        // Trend decays to ~0 once the window is saturated.
        assert!(
            f.trend_qps_per_ms().abs() < 0.02,
            "constant rate must fit a flat trend: {}",
            f.trend_qps_per_ms()
        );
        let p = f.projected_qps(6_000.0, 500.0);
        assert!((p - 100.0).abs() / 100.0 < 0.15, "projection ≈ rate: {p}");
        // The load factor sits near 1 on a flat series.
        let lf = f.load_factor(6_000.0, 500.0);
        assert!((lf - 1.0).abs() < 0.15, "{lf}");
        let mut t = TrendTracker::default();
        for i in 0..50 {
            t.observe(100.0 * i as f64, 40.0);
        }
        assert!(t.trend_per_ms().abs() < 1e-9);
        assert!((t.forecast(5_000.0, 1_000.0) - 40.0).abs() < 1e-6);
        assert_eq!(t.projected_growth(1_000.0), 0.0);
    }

    #[test]
    fn linear_ramp_projects_ahead_within_tolerance() {
        // Rate ramps 20 → 220 qps over 4 s (slope 0.05 qps/ms); the
        // projection 1 s ahead must land near the extrapolated 270 qps
        // and strictly above the current windowed rate.
        let mut f = RateForecaster::default();
        let mut t = 0.0;
        while t < 4_000.0 {
            f.observe(t);
            let rate = 20.0 + 0.05 * t;
            t += 1_000.0 / rate;
        }
        assert!(
            f.trend_qps_per_ms() > 0.02,
            "ramp must fit a positive trend: {}",
            f.trend_qps_per_ms()
        );
        let now_rate = f.window_rate_qps(4_000.0);
        let p = f.projected_qps(4_000.0, 1_000.0);
        assert!(p > now_rate, "projection must lead the ramp: {p} vs {now_rate}");
        let true_future = 270.0;
        assert!(
            (p - true_future).abs() / true_future < 0.35,
            "projection {p} vs extrapolated {true_future}"
        );
        // The same ramp through a bare TrendTracker is exact: linear
        // series, time-aware updates ⇒ trend converges to the slope.
        let mut tt = TrendTracker::default();
        for i in 0..60 {
            let now = 100.0 * i as f64;
            tt.observe(now, 5.0 + 0.2 * now);
        }
        assert!((tt.trend_per_ms() - 0.2).abs() < 0.02, "{}", tt.trend_per_ms());
        let last = 100.0 * 59.0;
        let proj = tt.forecast(last, 500.0);
        let truth = 5.0 + 0.2 * (last + 500.0);
        assert!((proj - truth).abs() / truth < 0.1, "{proj} vs {truth}");
        assert!(tt.projected_growth(500.0) > 50.0);
    }

    #[test]
    fn burst_detector_fires_on_acceleration_only() {
        let mut f = RateForecaster::default();
        // Long steady 10 qps prefix: no burst.
        steady(&mut f, 10.0, 0.0, 5_000.0);
        assert!(!f.is_burst(), "steady traffic must not flag");
        let level_before = f.level_qps();
        // Square-wave edge to 200 qps.
        steady(&mut f, 200.0, 5_000.0, 5_600.0);
        assert!(f.is_burst(), "a 20× rate edge must flag a burst");
        // During the burst the projection floors at the raw windowed
        // rate, far above the lagging Holt level.
        let p = f.projected_qps(5_600.0, 200.0);
        assert!(
            p > 2.0 * level_before,
            "burst projection {p} must leave the old level {level_before} behind"
        );
        // Back to steady: the flag clears once acceleration stops.
        steady(&mut f, 200.0, 5_600.0, 9_000.0);
        assert!(!f.is_burst(), "sustained rate is the new normal, not a burst");
    }

    #[test]
    fn silent_task_projection_decays_instead_of_extrapolating() {
        // A task that bursts and then goes silent gets no further
        // samples; the projection must not keep extrapolating the
        // burst fit forever. Once a full window has passed with no
        // arrivals, the stale fit yields to the raw window — which is
        // empty — so the projection reads ~0, like the trailing rates.
        let mut f = RateForecaster::default();
        steady(&mut f, 10.0, 0.0, 3_000.0);
        steady(&mut f, 200.0, 3_000.0, 3_600.0);
        let during = f.projected_qps(3_600.0, 250.0);
        assert!(during > 50.0, "the burst itself must project hot: {during}");
        // 5 s of silence (>> the 1 s window): projection decays to 0.
        let after = f.projected_qps(8_600.0, 250.0);
        assert_eq!(after, 0.0, "an idle task must not project load");
        assert!(f.load_factor(8_600.0, 250.0) < 0.1);
    }

    #[test]
    fn trend_tracker_ignores_subsample_spacing() {
        let mut t = TrendTracker::new(0.5, 0.5, 100.0);
        assert!(t.observe(0.0, 1.0));
        assert!(!t.observe(1.0, 1e9), "closer than sample_ms: dropped");
        assert!(!t.observe(99.9, 1e9));
        assert_eq!(t.samples(), 1);
        assert!((t.level() - 1.0).abs() < 1e-12);
        assert!(t.observe(100.0, 2.0));
        assert_eq!(t.samples(), 2);
    }

    #[test]
    fn violation_projection_clamps() {
        assert_eq!(project_violation_rate(0.0, 5.0), 0.0);
        assert_eq!(project_violation_rate(0.5, 1.0), 0.5);
        assert_eq!(project_violation_rate(0.8, 3.0), 1.0, "clamped at 1");
        assert_eq!(project_violation_rate(f64::NAN, 1.0), 0.0);
        assert_eq!(project_violation_rate(0.5, f64::INFINITY), 0.0);
        assert_eq!(clamp01(-0.2), 0.0);
    }
}
