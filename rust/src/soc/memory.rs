//! Unified-memory pool accounting for loaded subgraph weights.
//!
//! On the paper's SoCs all processors share one memory space (§5.4), so
//! a single pool tracks which (task, variant, subgraph) weight blobs are
//! resident. The preloader (Alg. 2) fills it up-front under a budget;
//! the coordinator charges load latency for misses at switch time.

use std::collections::BTreeMap;

/// Identity of one loadable unit: subgraph j of variant i of a task.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlobId {
    pub task: String,
    pub variant: usize,
    pub subgraph: usize,
}

impl BlobId {
    pub fn new(task: &str, variant: usize, subgraph: usize) -> Self {
        Self { task: task.to_string(), variant, subgraph }
    }
}

/// Accounting summary (paper Fig. 5b's memory breakdown).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MemoryBreakdown {
    pub active_bytes: u64,
    pub preloaded_bytes: u64,
    pub other_bytes: u64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> u64 {
        self.active_bytes + self.preloaded_bytes + self.other_bytes
    }
}

/// The unified weight pool.
#[derive(Clone, Debug)]
pub struct MemoryPool {
    capacity: u64,
    resident: BTreeMap<BlobId, u64>,
    /// Blobs currently used by the active (selected) variants.
    active: BTreeMap<BlobId, bool>,
    /// Fixed overhead (runtime, activations, engine state).
    pub other_bytes: u64,
    /// Counters.
    pub loads: u64,
    pub evictions: u64,
    pub hits: u64,
    pub misses: u64,
}

impl MemoryPool {
    pub fn new(capacity: u64) -> Self {
        Self {
            capacity,
            resident: BTreeMap::new(),
            active: BTreeMap::new(),
            other_bytes: 0,
            loads: 0,
            evictions: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.resident.values().sum::<u64>() + self.other_bytes
    }

    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used())
    }

    pub fn contains(&self, id: &BlobId) -> bool {
        self.resident.contains_key(id)
    }

    pub fn n_resident(&self) -> usize {
        self.resident.len()
    }

    /// Load a blob; returns false (and loads nothing) if it won't fit.
    pub fn load(&mut self, id: BlobId, bytes: u64) -> bool {
        if self.resident.contains_key(&id) {
            return true;
        }
        if self.used() + bytes > self.capacity {
            return false;
        }
        self.resident.insert(id, bytes);
        self.loads += 1;
        true
    }

    /// Evict a blob; returns bytes freed.
    pub fn evict(&mut self, id: &BlobId) -> u64 {
        let freed = self.resident.remove(id).unwrap_or(0);
        if freed > 0 {
            self.evictions += 1;
            self.active.remove(id);
        }
        freed
    }

    /// Evict non-active blobs (smallest first) until `bytes` fit.
    /// Returns true on success.
    pub fn make_room(&mut self, bytes: u64) -> bool {
        if self.used() + bytes <= self.capacity {
            return true;
        }
        let mut victims: Vec<(BlobId, u64)> = self
            .resident
            .iter()
            .filter(|(id, _)| !self.active.get(id).copied().unwrap_or(false))
            .map(|(id, &b)| (id.clone(), b))
            .collect();
        victims.sort_by_key(|(_, b)| *b);
        for (id, _) in victims {
            if self.used() + bytes <= self.capacity {
                break;
            }
            self.evict(&id);
        }
        self.used() + bytes <= self.capacity
    }

    /// Record a lookup: hit if resident. Returns whether it was a hit.
    pub fn touch(&mut self, id: &BlobId) -> bool {
        if self.resident.contains_key(id) {
            self.hits += 1;
            true
        } else {
            self.misses += 1;
            false
        }
    }

    /// Resident blobs belonging to `task`, with their sizes — the
    /// source set of a warm migration (the migrant's pool contents
    /// travel with it instead of recompiling on the target shard).
    pub fn task_blobs(&self, task: &str) -> Vec<(BlobId, u64)> {
        self.resident
            .iter()
            .filter(|(id, _)| id.task == task)
            .map(|(id, &bytes)| (id.clone(), bytes))
            .collect()
    }

    pub fn set_active(&mut self, id: &BlobId, active: bool) {
        if self.resident.contains_key(id) {
            self.active.insert(id.clone(), active);
        }
    }

    pub fn clear_active(&mut self) {
        self.active.clear();
    }

    pub fn breakdown(&self) -> MemoryBreakdown {
        let mut active = 0u64;
        let mut preloaded = 0u64;
        for (id, &bytes) in &self.resident {
            if self.active.get(id).copied().unwrap_or(false) {
                active += bytes;
            } else {
                preloaded += bytes;
            }
        }
        MemoryBreakdown {
            active_bytes: active,
            preloaded_bytes: preloaded,
            other_bytes: self.other_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(v: usize, sg: usize) -> BlobId {
        BlobId::new("t", v, sg)
    }

    #[test]
    fn load_respects_capacity() {
        let mut pool = MemoryPool::new(100);
        assert!(pool.load(id(0, 0), 60));
        assert!(!pool.load(id(1, 0), 60));
        assert!(pool.load(id(1, 1), 40));
        assert_eq!(pool.used(), 100);
        assert_eq!(pool.free(), 0);
    }

    #[test]
    fn double_load_is_idempotent() {
        let mut pool = MemoryPool::new(100);
        assert!(pool.load(id(0, 0), 60));
        assert!(pool.load(id(0, 0), 60));
        assert_eq!(pool.used(), 60);
        assert_eq!(pool.loads, 1);
    }

    #[test]
    fn evict_frees() {
        let mut pool = MemoryPool::new(100);
        pool.load(id(0, 0), 70);
        assert_eq!(pool.evict(&id(0, 0)), 70);
        assert_eq!(pool.used(), 0);
        assert!(pool.load(id(1, 0), 100));
    }

    #[test]
    fn make_room_spares_active() {
        let mut pool = MemoryPool::new(100);
        pool.load(id(0, 0), 50);
        pool.load(id(1, 0), 40);
        pool.set_active(&id(0, 0), true);
        assert!(pool.make_room(50));
        assert!(pool.contains(&id(0, 0)), "active blob survives");
        assert!(!pool.contains(&id(1, 0)), "idle blob evicted");
    }

    #[test]
    fn make_room_fails_when_active_pins_everything() {
        let mut pool = MemoryPool::new(100);
        pool.load(id(0, 0), 90);
        pool.set_active(&id(0, 0), true);
        assert!(!pool.make_room(50));
    }

    #[test]
    fn task_blobs_filters_by_task() {
        let mut pool = MemoryPool::new(1000);
        pool.load(BlobId::new("a", 0, 0), 10);
        pool.load(BlobId::new("a", 0, 1), 20);
        pool.load(BlobId::new("b", 1, 0), 30);
        let mut a = pool.task_blobs("a");
        a.sort();
        assert_eq!(
            a,
            vec![(BlobId::new("a", 0, 0), 10), (BlobId::new("a", 0, 1), 20)]
        );
        assert_eq!(pool.task_blobs("c"), Vec::new());
    }

    #[test]
    fn hit_miss_accounting() {
        let mut pool = MemoryPool::new(100);
        pool.load(id(0, 0), 10);
        assert!(pool.touch(&id(0, 0)));
        assert!(!pool.touch(&id(9, 9)));
        assert_eq!((pool.hits, pool.misses), (1, 1));
    }

    #[test]
    fn breakdown_splits_active_and_preloaded() {
        let mut pool = MemoryPool::new(1000);
        pool.other_bytes = 5;
        pool.load(id(0, 0), 100);
        pool.load(id(1, 0), 200);
        pool.set_active(&id(0, 0), true);
        let b = pool.breakdown();
        assert_eq!(b.active_bytes, 100);
        assert_eq!(b.preloaded_bytes, 200);
        assert_eq!(b.total(), 305);
    }
}
