//! The six baseline policy classes of §5.1 plus SparseLoom itself.
//!
//! Two axes (paper "Baseline design"):
//!
//! * **Variant selection** — SV-AO (single accuracy-optimal variant),
//!   SV-LO (single latency-optimal variant), AV (adaptive pure-variant
//!   selection per SLO). SparseLoom adds the stitched space.
//! * **Partitioning** — P (subgraphs pipelined across processors, fixed
//!   N-G-C-style order as in Band/Hetero²Pipe) vs NP (whole variant on a
//!   single processor).
//!
//! Every policy reduces to "given profiles + SLOs, produce a `Plan`",
//! which the coordinator then executes identically — so the comparison
//! isolates exactly the paper's two axes plus stitching.

use std::collections::BTreeMap;

use crate::optimizer::{Plan, Selection};
use crate::planner::{algo, CostModel};
use crate::profiler::TaskProfile;
use crate::soc::{Platform, Processor};
use crate::workload::{placement_orders, Slo};

/// Which multi-DNN policy plans the serving run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Policy {
    /// Single variant, accuracy-optimal, partitioned (Pipe-it/RT-mDL class).
    SvAoP,
    /// Single variant, accuracy-optimal, non-partitioned.
    SvAoNp,
    /// Single variant, latency-optimal, partitioned (Band/Hetero²Pipe class).
    SvLoP,
    /// Single variant, latency-optimal, non-partitioned.
    SvLoNp,
    /// Adaptive pure-variant selection, partitioned (Tango/NestDNN class).
    AvP,
    /// Adaptive pure-variant selection, non-partitioned.
    AvNp,
    /// This paper: stitched variants + joint placement optimization.
    SparseLoom,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Self::SvAoP => "SV-AO-P",
            Self::SvAoNp => "SV-AO-NP",
            Self::SvLoP => "SV-LO-P",
            Self::SvLoNp => "SV-LO-NP",
            Self::AvP => "AV-P",
            Self::AvNp => "AV-NP",
            Self::SparseLoom => "SparseLoom",
        }
    }

    pub fn all() -> [Policy; 7] {
        [
            Self::SvAoP,
            Self::SvAoNp,
            Self::SvLoP,
            Self::SvLoNp,
            Self::AvP,
            Self::AvNp,
            Self::SparseLoom,
        ]
    }

    pub fn baselines() -> [Policy; 6] {
        [Self::SvAoP, Self::SvAoNp, Self::SvLoP, Self::SvLoNp, Self::AvP, Self::AvNp]
    }

    pub fn is_partitioned(&self) -> bool {
        matches!(self, Self::SvAoP | Self::SvLoP | Self::AvP | Self::SparseLoom)
    }

    pub fn parse(s: &str) -> Option<Policy> {
        Policy::all().into_iter().find(|p| p.name().eq_ignore_ascii_case(s))
    }
}

/// The fixed placement order existing partitioned systems adopt
/// (paper §2.2: "the widely adopted NPU-GPU-CPU (N-G-C) placement
/// order"), cyclically extended when P < S (Orin).
pub fn fixed_ngc_order(platform: &Platform, s: usize) -> Vec<Processor> {
    let has = |p| platform.processor_list().contains(&p);
    let mut pref = Vec::new();
    for p in [Processor::Npu, Processor::Gpu, Processor::Cpu] {
        if has(p) {
            pref.push(p);
        }
    }
    let mut order = Vec::with_capacity(s);
    for j in 0..s {
        order.push(pref[j % pref.len()]);
    }
    order
}

/// "Non-partitioned" pseudo-orders: the whole variant runs on ONE
/// processor, so the order is that processor repeated at every position.
pub fn np_order(proc: Processor, s: usize) -> Vec<Processor> {
    vec![proc; s]
}

/// The single processor NP systems schedule on. The paper's Class-1
/// systems (Pipe-it, Pantheon, REEF) are task-level schedulers on ONE
/// processor — conventionally the GPU.
pub fn np_processor(platform: &Platform) -> Processor {
    if platform.processor_list().contains(&Processor::Gpu) {
        Processor::Gpu
    } else {
        platform.processor_list()[0]
    }
}

/// Plan for a policy. `task_proc` assigns each task a processor for NP
/// policies (round-robin by task index, the common multi-DNN practice).
///
/// `cost` is the planner's cost model: SparseLoom plans through it
/// (batch-aware when the serving layer expects coalescing); the
/// baselines stay batch-naive — the systems they model plan at batch 1.
pub fn plan(
    policy: Policy,
    profiles: &BTreeMap<String, TaskProfile>,
    slos: &BTreeMap<String, Slo>,
    platform: &Platform,
    cost: &CostModel,
) -> Plan {
    let s = profiles
        .values()
        .next()
        .map(|p| p.space.n_subgraphs)
        .unwrap_or(3);
    match policy {
        Policy::SparseLoom => {
            let orders = placement_orders(platform, s);
            algo::optimize(cost, profiles, slos, &orders)
        }
        Policy::AvP => {
            // Adaptive pure variants, but the *fixed* N-G-C order —
            // these systems don't co-optimize placement.
            let orders = vec![fixed_ngc_order(platform, s)];
            algo::optimize_pure_only(&CostModel::unit(), profiles, slos, &orders)
        }
        Policy::AvNp => np_plans(profiles, slos, platform, s, true),
        Policy::SvAoP | Policy::SvLoP => {
            let order = fixed_ngc_order(platform, s);
            let mut selections = BTreeMap::new();
            let mut lat_sum = 0.0;
            let mut n = 0usize;
            for (name, p) in profiles {
                let sel = single_variant(p, &order, policy == Policy::SvAoP);
                if let Some(sel) = sel {
                    lat_sum += sel.latency_ms;
                    n += 1;
                }
                selections.insert(name.clone(), sel);
            }
            Plan {
                order,
                selections,
                mean_latency_ms: if n > 0 { lat_sum / n as f64 } else { f64::INFINITY },
            }
        }
        Policy::SvAoNp | Policy::SvLoNp => {
            np_single_plans(profiles, platform, s, policy == Policy::SvAoNp)
        }
    }
}

/// SV selection: accuracy-optimal (dense-est) or latency-optimal pure
/// variant — the variant is fixed per task, SLO-independent.
fn single_variant(p: &TaskProfile, order: &[Processor], accuracy_opt: bool) -> Option<Selection> {
    let mut best: Option<Selection> = None;
    for i in 0..p.space.n_variants {
        let k = p.space.pure_index(i);
        let comp = p.space.composition(k);
        let Some(lat) = p.latency_est(&comp, order) else { continue };
        let acc = p.accuracy(k);
        let better = match (&best, accuracy_opt) {
            (None, _) => true,
            (Some(b), true) => acc > b.accuracy + 1e-12
                || (acc >= b.accuracy - 1e-12 && lat < b.latency_ms),
            (Some(b), false) => lat < b.latency_ms,
        };
        if better {
            best = Some(Selection { stitched_index: k, latency_ms: lat, accuracy: acc });
        }
    }
    best
}

/// NP plans with adaptive selection: per task, pick the processor
/// (round-robin) and the pure variant meeting the SLO with min latency.
fn np_plans(
    profiles: &BTreeMap<String, TaskProfile>,
    slos: &BTreeMap<String, Slo>,
    platform: &Platform,
    s: usize,
    adaptive: bool,
) -> Plan {
    let proc = np_processor(platform);
    // NP systems profile under co-execution (all T tasks concurrent on
    // the one processor) — their feasibility checks see the slowdown.
    let coexec = 1.0 + platform.coexec_slowdown * (profiles.len().saturating_sub(1)) as f64;
    let mut selections = BTreeMap::new();
    let mut lat_sum = 0.0;
    let mut n = 0usize;
    for (name, p) in profiles.iter() {
        // SLO-driven like the planner: profiles without an SLO entry
        // (shard-filtered maps) are left unplanned instead of panicking.
        let Some(slo) = slos.get(name) else { continue };
        let order = np_order(proc, s);
        let mut best: Option<Selection> = None;
        for i in 0..p.space.n_variants {
            let k = p.space.pure_index(i);
            let comp = p.space.composition(k);
            let Some(lat) = p.latency_est(&comp, &order).map(|l| l * coexec) else { continue };
            let acc = p.accuracy(k);
            if adaptive && (acc < slo.min_accuracy || lat > slo.max_latency_ms) {
                continue;
            }
            if best.map(|b| lat < b.latency_ms).unwrap_or(true) {
                best = Some(Selection { stitched_index: k, latency_ms: lat, accuracy: acc });
            }
        }
        if let Some(b) = best {
            lat_sum += b.latency_ms;
            n += 1;
        }
        selections.insert(name.clone(), best);
    }
    Plan {
        order: np_order(proc, s),
        selections,
        mean_latency_ms: if n > 0 { lat_sum / n as f64 } else { f64::INFINITY },
    }
}

/// NP plans with a fixed single variant (SV-AO-NP / SV-LO-NP).
fn np_single_plans(
    profiles: &BTreeMap<String, TaskProfile>,
    platform: &Platform,
    s: usize,
    accuracy_opt: bool,
) -> Plan {
    let proc = np_processor(platform);
    let coexec = 1.0 + platform.coexec_slowdown * (profiles.len().saturating_sub(1)) as f64;
    let mut selections = BTreeMap::new();
    let mut lat_sum = 0.0;
    let mut n = 0usize;
    for (name, p) in profiles.iter() {
        let order = np_order(proc, s);
        let sel = single_variant(p, &order, accuracy_opt)
            .map(|sel| Selection { latency_ms: sel.latency_ms * coexec, ..sel });
        if let Some(sel) = sel {
            lat_sum += sel.latency_ms;
            n += 1;
        }
        selections.insert(name.clone(), sel);
    }
    Plan {
        order: np_order(proc, s),
        selections,
        mean_latency_ms: if n > 0 { lat_sum / n as f64 } else { f64::INFINITY },
    }
}

/// The per-task processor assignment used by NP policies (all tasks on
/// the single NP processor) — the coordinator needs it to place
/// whole-variant executions.
pub fn np_task_processor(
    profiles: &BTreeMap<String, TaskProfile>,
    platform: &Platform,
) -> BTreeMap<String, Processor> {
    let proc = np_processor(platform);
    profiles.keys().map(|name| (name.clone(), proc)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::{profile_task, ProfilerConfig};
    use crate::soc::latency::tests::tiny_taskzoo;
    use crate::soc::{BaseLatencies, LatencyModel, Platform};
    use crate::stitching::StitchSpace;
    use crate::zoo::KernelPath;

    fn setup() -> (BTreeMap<String, TaskProfile>, Platform) {
        let tz = tiny_taskzoo();
        let mut b = BaseLatencies::new();
        for sg in 0..2 {
            b.set("tiny", sg, KernelPath::Dense, 10.0);
            b.set("tiny", sg, KernelPath::BlockSparse, 8.0);
        }
        let plat = Platform::desktop();
        let lm = LatencyModel::new(plat.clone(), b);
        let space = StitchSpace::for_task(&tz);
        let oracle: Vec<f64> = space
            .iter()
            .map(|c| c.0.iter().map(|&i| tz.variants[i].accuracy).sum::<f64>() / 2.0)
            .collect();
        let cfg = ProfilerConfig {
            train_samples: 4,
            gbdt: crate::gbdt::GbdtParams {
                n_trees: 200,
                max_depth: 3,
                eta: 0.2,
                min_leaf: 1,
                subsample: 1.0,
                seed: 1,
            },
            seed: 23,
        };
        let p = profile_task(&tz, &lm, &oracle, &cfg, true);
        (BTreeMap::from([("tiny".to_string(), p)]), plat)
    }

    fn slos() -> BTreeMap<String, Slo> {
        BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.6, max_latency_ms: 1e9 },
        )])
    }

    #[test]
    fn names_cover_paper_grid() {
        let names: Vec<&str> = Policy::all().iter().map(|p| p.name()).collect();
        for want in ["SV-AO-P", "SV-AO-NP", "SV-LO-P", "SV-LO-NP", "AV-P", "AV-NP", "SparseLoom"] {
            assert!(names.contains(&want), "{want}");
        }
        assert_eq!(Policy::parse("av-np"), Some(Policy::AvNp));
        assert_eq!(Policy::parse("bogus"), None);
    }

    #[test]
    fn fixed_order_is_ngc_on_intel_and_gc_on_orin() {
        use Processor::*;
        assert_eq!(fixed_ngc_order(&Platform::desktop(), 3), vec![Npu, Gpu, Cpu]);
        assert_eq!(fixed_ngc_order(&Platform::orin(), 3), vec![Gpu, Cpu, Gpu]);
    }

    #[test]
    fn sv_ao_picks_max_accuracy() {
        let (profiles, plat) = setup();
        let plan = plan(Policy::SvAoP, &profiles, &slos(), &plat, &CostModel::unit());
        let sel = plan.selections["tiny"].unwrap();
        assert!((sel.accuracy - 0.9).abs() < 0.05, "dense is accuracy-optimal");
    }

    #[test]
    fn sv_lo_picks_min_latency() {
        let (profiles, plat) = setup();
        let plan = plan(Policy::SvLoP, &profiles, &slos(), &plat, &CostModel::unit());
        let p = &profiles["tiny"];
        let sel = plan.selections["tiny"].unwrap();
        let order = fixed_ngc_order(&plat, 2);
        for i in 0..p.space.n_variants {
            let comp = p.space.composition(p.space.pure_index(i));
            if let Some(l) = p.latency_est(&comp, &order) {
                assert!(sel.latency_ms <= l + 1e-12);
            }
        }
    }

    #[test]
    fn sv_policies_ignore_slo() {
        let (profiles, plat) = setup();
        let strict = BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.99, max_latency_ms: 0.001 },
        )]);
        let a = plan(Policy::SvAoP, &profiles, &slos(), &plat, &CostModel::unit());
        let b = plan(Policy::SvAoP, &profiles, &strict, &plat, &CostModel::unit());
        assert_eq!(
            a.selections["tiny"].unwrap().stitched_index,
            b.selections["tiny"].unwrap().stitched_index
        );
    }

    #[test]
    fn av_np_respects_slo() {
        let (profiles, plat) = setup();
        let strict = BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 2.0, max_latency_ms: 1e9 },
        )]);
        let p = plan(Policy::AvNp, &profiles, &strict, &plat, &CostModel::unit());
        assert!(p.selections["tiny"].is_none(), "infeasible must be None");
    }

    #[test]
    fn partitioned_policies_use_multiple_processors() {
        let (profiles, plat) = setup();
        let p = plan(Policy::SparseLoom, &profiles, &slos(), &plat, &CostModel::unit());
        let unique: std::collections::HashSet<_> = p.order.iter().collect();
        assert!(unique.len() > 1, "pipelined across processors");
        let np = plan(Policy::SvAoNp, &profiles, &slos(), &plat, &CostModel::unit());
        let unique_np: std::collections::HashSet<_> = np.order.iter().collect();
        assert_eq!(unique_np.len(), 1, "NP runs on one processor");
    }

    #[test]
    fn all_policies_select_only_pure_except_sparseloom() {
        let (profiles, plat) = setup();
        let p = &profiles["tiny"];
        for policy in Policy::baselines() {
            let pl = plan(policy, &profiles, &slos(), &plat, &CostModel::unit());
            if let Some(sel) = pl.selections["tiny"] {
                assert!(
                    p.space.composition(sel.stitched_index).is_pure(),
                    "{} must not stitch",
                    policy.name()
                );
            }
        }
    }
}
