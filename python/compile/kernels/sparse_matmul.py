"""L1 Pallas kernels: sparsity-aware tiled matmul family.

The paper's compute hot-spot is sparse/quantized GEMM on heterogeneous
edge accelerators (OpenVINO sparse path, TensorRT INT8). Re-thought for
TPU idioms (see DESIGN.md §Hardware-Adaptation):

* MXU-shaped tiles — blocks default to 128×128 (the systolic array edge),
  clipped to the actual dims for the tiny edge models.
* HBM↔VMEM schedule expressed with ``BlockSpec`` over a (M/bm, N/bn, K/bk)
  grid; the K axis is innermost so the f32 accumulator tile stays resident
  in VMEM across the whole reduction (single HBM write per output tile).
* Sparsity in VMEM — unstructured pruning applies the {0,1} mask on the
  weight tile *after* the load (zero-masking semantics, same as the
  paper's Intel zoos); structured pruning keeps a per-input-channel keep
  vector and *skips whole K-tiles* whose channels are all pruned
  (block-sparse ≙ channel pruning), saving both MXU issue slots and the
  HBM→VMEM weight transfer for that tile.
* INT8 — weights live in HBM as int8 (4× smaller transfers) and are
  dequantized per-tile in VMEM with a per-output-channel scale, feeding
  the MXU in f32 on this CPU build (bf16 on real TPU).

All kernels are lowered with ``interpret=True``: real-TPU pallas lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute. Analytic
VMEM/MXU estimates for the real-TPU schedule live in :mod:`roofline`.

Correctness oracle: :mod:`ref` (pure jnp), swept by hypothesis in
``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic-array edge; tiles bigger than this gain nothing.
MXU_DIM = 128


def _block(dim: int, want: int = MXU_DIM) -> int:
    """Largest divisor of ``dim`` that is ≤ ``want``.

    Keeps every grid block full-size (no partial tiles), which interpret
    mode and the VMEM schedule both like. Edge-model dims are multiples of
    8, so this lands on 128/64/32-style tiles in practice.
    """
    if dim <= want:
        return dim
    for cand in range(want, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _grid_for(m: int, k: int, n: int, bm=None, bk=None, bn=None):
    bm = bm or _block(m)
    bk = bk or _block(k)
    bn = bn or _block(n)
    return (m // bm, n // bn, k // bk), bm, bk, bn


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int):
    """Dense tile kernel: o[i,j] = sum_k x[i,k] @ w[k,j] + b[j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += acc

    @pl.when(k == nk - 1)
    def _bias():
        o_ref[...] += b_ref[...].astype(jnp.float32)[None, :]


def _masked_kernel(x_ref, w_ref, m_ref, b_ref, o_ref, *, nk: int):
    """Unstructured-sparse tile kernel: mask applied in VMEM post-load."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...].astype(jnp.float32) * m_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _bias():
        o_ref[...] += b_ref[...].astype(jnp.float32)[None, :]


def _block_sparse_kernel(x_ref, w_ref, keep_ref, b_ref, o_ref, *, nk: int):
    """Structured-sparse tile kernel: skip K-tiles with no live channel.

    ``keep_ref`` holds the {0,1} keep flags for this K-tile's input
    channels. If the whole tile is pruned the MXU work is skipped
    entirely — this is where structured pruning buys latency on real
    hardware (the HBM→VMEM weight DMA for the tile is also elided by the
    pipeline when the predicate is static).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    keep = keep_ref[...].astype(jnp.float32)

    @pl.when(jnp.sum(keep) > 0)
    def _compute():
        w = w_ref[...].astype(jnp.float32) * keep[:, None]
        o_ref[...] += jnp.dot(
            x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _bias():
        o_ref[...] += b_ref[...].astype(jnp.float32)[None, :]


def _quant_kernel(x_ref, wq_ref, s_ref, b_ref, o_ref, *, nk: int):
    """Full-INT8 tile kernel: activations dynamically quantized per row
    *within the K-tile*, int8×int8 contraction on the MXU, dequantized
    into the f32 accumulator. Matches `ref.quant_matmul_ref` when the
    K dimension fits one tile — `quant_matmul` defaults bk = K for
    exactly this reason; per-tile scales (bk < K) are still a valid
    dynamic-quant scheme but differ numerically from the oracle.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xf = x_ref[...].astype(jnp.float32)
    sx = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / 127.0
    sx = jnp.where(sx > 0, sx, 1.0)
    xq = jnp.clip(jnp.round(xf / sx), -127.0, 127.0)
    acc = jnp.dot(xq, wq_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    o_ref[...] += acc * sx * s_ref[...].astype(jnp.float32)[None, :]

    @pl.when(k == nk - 1)
    def _bias():
        o_ref[...] += b_ref[...].astype(jnp.float32)[None, :]


def _call(kernel, m, k, n, in_specs, args, bm=None, bk=None, bn=None):
    grid, bm, bk, bn = _grid_for(m, k, n, bm, bk, bn)
    return pl.pallas_call(
        functools.partial(kernel, nk=grid[2]),
        grid=grid,
        in_specs=in_specs(bm, bk, bn),
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(*args)


def matmul(x, w, b, *, bm=None, bk=None, bn=None):
    """Dense ``x @ w + b`` (f32 accumulate). Shapes: (M,K),(K,N),(N,)."""
    m, k = x.shape
    _, n = w.shape

    def specs(bm, bk, bn):
        return [
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ]

    return _call(_matmul_kernel, m, k, n, specs, (x, w, b), bm, bk, bn)


def masked_matmul(x, w, mask, b, *, bm=None, bk=None, bn=None):
    """Unstructured-pruned ``x @ (w*mask) + b``; mask is {0,1}, shape of w."""
    m, k = x.shape
    _, n = w.shape

    def specs(bm, bk, bn):
        return [
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ]

    return _call(_masked_kernel, m, k, n, specs, (x, w, mask, b), bm, bk, bn)


def block_sparse_matmul(x, w, row_keep, b, *, bm=None, bk=None, bn=None):
    """Structured-pruned matmul; ``row_keep`` is a {0,1} K-vector."""
    m, k = x.shape
    _, n = w.shape

    def specs(bm, bk, bn):
        return [
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk,), lambda i, j, kk: (kk,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ]

    return _call(
        _block_sparse_kernel, m, k, n, specs, (x, w, row_keep, b), bm, bk, bn
    )


def quant_matmul(x, wq, scale, b, *, bm=None, bk=None, bn=None):
    """Full-INT8 matmul: dynamic per-row activation quantization + int8
    weights, ``≈ x @ (wq*scale) + b``. ``wq`` int8, ``scale`` (N,) f32.

    The K axis stays in ONE tile by default so the per-row activation
    scale is computed over the full row — bit-exact with
    ``ref.quant_matmul_ref``. (Edge-model K ≤ 256 keeps the tile well
    inside VMEM; see roofline.py.)"""
    m, k = x.shape
    _, n = wq.shape
    if bk is None:
        bk = k

    def specs(bm, bk, bn):
        return [
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ]

    return _call(_quant_kernel, m, k, n, specs, (x, wq, scale, b), bm, bk, bn)
