//! Individual-module experiments: Fig. 9 (hotness), Fig. 13 (placement
//! orders vs throughput), Fig. 14 (memory budget vs violations),
//! Table 5 (zoo composition), §5.4 inter-processor overhead.

use std::collections::BTreeMap;

use anyhow::Result;

use super::Ctx;

use crate::coordinator::ServeOpts;
use crate::metrics::{render_table, Aggregate};
use crate::preloader::Hotness;
use crate::profiler::ProfilerConfig;
use crate::scenario::{Scenario, Server};
use crate::soc::{order_label, Platform};
use crate::util::{stats, Rng};
use crate::workload::{
    arrival_combinations, placement_orders, slo_grid, Slo, TaskRanges,
};

/// Build the per-task SLO grids and a few sampled multi-task SLO
/// assignments (grid configs applied to all tasks jointly).
pub fn task_slos(
    ctx: &Ctx,
    lm: &crate::soc::LatencyModel,
) -> Result<(BTreeMap<String, Vec<Slo>>, Vec<Slo>)> {
    let mut grids = BTreeMap::new();
    let mut universe = Vec::new();
    for (name, tz) in &ctx.zoo_for(&lm.platform).tasks {
        let g = slo_grid(&TaskRanges::measure(tz, lm));
        universe.extend(g.iter().copied());
        grids.insert(name.clone(), g);
    }
    Ok((grids, universe))
}

/// Joint SLO assignment i: each task takes the i-th config of its grid.
pub fn joint_slo(
    grids: &BTreeMap<String, Vec<Slo>>,
    i: usize,
) -> BTreeMap<String, Slo> {
    grids
        .iter()
        .map(|(name, g)| (name.clone(), g[i % g.len()]))
        .collect()
}

/// Fig. 9: hotness scores of all subgraphs at the third position.
pub fn fig9(ctx: &Ctx) -> Result<String> {
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let cfg = ProfilerConfig::default();
    let profiles = ctx.profiles(&lm, &cfg)?;
    let orders = placement_orders(&platform, ctx.zoo.subgraphs);

    let task = "imgcls";
    let tz = ctx.zoo.task(task)?;
    let grid = slo_grid(&TaskRanges::measure(tz, &lm));
    let h = Hotness::compute(&profiles[task], &grid, &orders);

    let pos = ctx.zoo.subgraphs - 1; // third position (j = 3 in the paper)
    let ranked = h.ranked_at(pos);
    let mut rows = Vec::new();
    for (i, score) in &ranked {
        rows.push(vec![
            tz.variants[*i].spec.name.clone(),
            format!("{score:.3}"),
        ]);
    }
    let top4: f64 = ranked.iter().take(4).map(|(_, s)| s).sum();
    let total: f64 = ranked.iter().map(|(_, s)| s).sum();
    Ok(format!(
        "Fig. 9 — hotness of subgraphs at position {} (task {task}, desktop, |Ψ|={})\n\n{}\n\
         top-4 share of total hotness: {:.1} %  [paper: top four dominant]\n",
        pos + 1,
        grid.len(),
        render_table(&["subgraph (variant)", "hotness"], &rows),
        100.0 * top4 / total.max(1e-12),
    ))
}

/// Fig. 13: throughput under each processor placement order, per SoC.
pub fn fig13(ctx: &Ctx) -> Result<String> {
    let mut out = String::from(
        "Fig. 13 — inference throughput (queries/s) by placement order\n\n",
    );
    let cfg = ProfilerConfig::default();
    for platform in Platform::all() {
        let lm = ctx.lm(platform.clone());
        let profiles = ctx.profiles(&lm, &cfg)?;
        let zoo = ctx.zoo_for(&platform);
        let (grids, universe) = task_slos(ctx, &lm)?;
        let tasks: Vec<String> = profiles.keys().cloned().collect();
        let orders = placement_orders(&platform, ctx.zoo.subgraphs);

        let mut rows = Vec::new();
        let mut best = (String::new(), 0.0f64);
        let mut worst = f64::INFINITY;
        for order in &orders {
            let mut agg = Aggregate::default();
            // A lax joint SLO (index 4: loosest latency row of the grid)
            // so throughput reflects placement, not infeasibility.
            let slos = joint_slo(&grids, 4);
            let server = Server::builder(zoo, &lm, &profiles)
                .force_order(order.clone())
                .feedback_switching(false)
                .build();
            for arrival in arrival_combinations(&tasks).into_iter().take(6) {
                let sc = Scenario::closed_loop(&arrival, slos.clone())
                    .with_universe(universe.clone());
                agg.push(&server.run(&sc)?);
            }
            let tput = agg.mean_throughput();
            rows.push(vec![order_label(order), format!("{tput:.1}")]);
            if tput > best.1 {
                best = (order_label(order), tput);
            }
            worst = worst.min(tput);
        }
        out.push_str(&format!("--- {} ---\n", platform.name));
        out.push_str(&render_table(&["order", "throughput"], &rows));
        out.push_str(&format!(
            "best: {} ({:.1}); spread {:.2}x  [paper: up to 2x, best differs per SoC]\n\n",
            best.0,
            best.1,
            best.1 / worst.max(1e-9),
        ));
    }
    Ok(out)
}

/// Fig. 14: SLO violation rate vs memory budget (fraction of full
/// preloading), per SoC.
pub fn fig14(ctx: &Ctx) -> Result<String> {
    let mut out = String::from(
        "Fig. 14 — SLO violation (%) vs memory budget (fraction of full preload)\n\n",
    );
    let cfg = ProfilerConfig::default();
    let budgets = [0.01, 0.02, 0.03, 0.05, 0.10, 0.25, 0.55, 1.0];
    for platform in Platform::all() {
        let lm = ctx.lm(platform.clone());
        let profiles = ctx.profiles(&lm, &cfg)?;
        let zoo = ctx.zoo_for(&platform);
        let (grids, _universe) = task_slos(ctx, &lm)?;
        let _ = &grids;
        let tasks: Vec<String> = profiles.keys().cloned().collect();
        let mut rng = Rng::new(99);
        let mut arrivals = arrival_combinations(&tasks);
        rng.shuffle(&mut arrivals);
        arrivals.truncate(4);

        let mut rows = Vec::new();
        let mut full_viol = 0.0;
        let mut results = Vec::new();
        // Runtime-rescheduling scenario (§3.4): the SLO configuration
        // changes every phase (25 closed-loop queries); the budgeted
        // pool persists across phases, so misses pay compile+load
        // latency.
        // The walk alternates strict ladder configs (C3–C8, where the
        // feasible sets Θ are small and budget pressure binds) — lax
        // grid configs have |Θ| in the hundreds and any budget serves
        // them from the hot set, as §3.4's hotness argument predicts.
        let ladders: BTreeMap<String, Vec<Slo>> = ctx
            .zoo_for(&platform)
            .tasks
            .iter()
            .map(|(name, tz)| {
                (name.clone(),
                 crate::workload::slo_ladder(&TaskRanges::measure(tz, &lm)))
            })
            .collect();
        let mut cfg_walk: Vec<usize> = (2..8).chain(2..8).collect();
        Rng::new(3).shuffle(&mut cfg_walk);
        let configs: Vec<BTreeMap<String, Slo>> = cfg_walk
            .iter()
            .map(|&i| {
                ladders
                    .iter()
                    .map(|(n, l)| (n.clone(), l[i]))
                    .collect()
            })
            .collect();
        let universe: Vec<Slo> = ladders.values().flatten().copied().collect();
        for &b in &budgets {
            let mut agg = Aggregate::default();
            let server = Server::builder(zoo, &lm, &profiles)
                .memory_budget_frac(b)
                .build();
            for arrival in &arrivals {
                // The SLO schedule IS the scenario: one phase per
                // config, persistent pool across phases.
                let sc = Scenario::closed_loop(arrival, configs[0].clone())
                    .with_queries(25)
                    .with_schedule(configs.clone())
                    .with_universe(universe.clone());
                for r in server.run_schedule(&sc)? {
                    agg.push(&r);
                }
            }
            let v = agg.mean_violation_pct();
            if (b - 1.0).abs() < 1e-9 {
                full_viol = v;
            }
            results.push((b, v));
            rows.push(vec![format!("{:.0} %", 100.0 * b), format!("{v:.1}")]);
        }
        // Min budget within 2.7 pp of full preloading (paper's criterion).
        let min_budget = results
            .iter()
            .find(|(_, v)| *v <= full_viol + 2.7)
            .map(|(b, _)| *b)
            .unwrap_or(1.0);
        out.push_str(&format!("--- {} ---\n", platform.name));
        out.push_str(&render_table(&["budget", "violation %"], &rows));
        out.push_str(&format!(
            "min budget within 2.7 pp of full preloading: {:.0} % → memory saved {:.0} %\n\
             [paper: 25/20/40 % savings on desktop/laptop/orin; ≤2.7 pp at 55 % budget]\n\n",
            100.0 * min_budget,
            100.0 * (1.0 - min_budget),
        ));
    }
    Ok(out)
}

/// Table 5: the sparse variant zoo actually exported in the artifacts.
pub fn table5(ctx: &Ctx) -> Result<String> {
    let mut rows = Vec::new();
    let first_task = ctx.zoo.tasks.values().next().unwrap();
    for v in &first_task.variants {
        rows.push(vec![
            v.spec.name.clone(),
            v.spec.vtype.name().to_string(),
            format!("{:.0} %", 100.0 * v.spec.sparsity),
            format!("{:?}", v.spec.precision).to_lowercase(),
            v.spec.kernel_path.name().to_string(),
        ]);
    }
    Ok(format!(
        "Table 5 — sparse model zoo ({} zoo, {} variants/task, {} tasks)\n\n{}",
        ctx.zoo.zoo_name,
        ctx.zoo.n_variants(),
        ctx.zoo.tasks.len(),
        render_table(
            &["variant", "type", "sparsity", "precision", "kernel path"],
            &rows,
        ),
    ))
}

/// §5.4: inter-processor execution overhead — the gap between the
/// additive latency estimate and the hop-charged ground truth.
pub fn overhead(ctx: &Ctx) -> Result<String> {
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let cfg = ProfilerConfig::default();
    let profiles = ctx.profiles(&lm, &cfg)?;
    let orders = placement_orders(&platform, ctx.zoo.subgraphs);
    let mut rng = Rng::new(5);
    let mut fracs = Vec::new();
    for p in profiles.values() {
        for _ in 0..200 {
            let k = rng.below(p.space.len());
            let comp = p.space.composition(k);
            let order = rng.choose(&orders);
            if let (Some(e), Some(t)) = (p.latency_est(&comp, order), p.latency_true(&comp, order)) {
                fracs.push(100.0 * (t - e) / t);
            }
        }
    }
    Ok(format!(
        "§5.4 — inter-processor execution overhead\n\n\
         mean overhead: {:.2} % of end-to-end latency (p95 {:.2} %)\n\
         [paper: ≈ 5 %, unified-memory SoCs]\n",
        stats::mean(&fracs),
        stats::percentile(&fracs, 95.0),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn joint_slo_indexing_wraps() {
        let mut grids = BTreeMap::new();
        grids.insert(
            "a".to_string(),
            vec![
                Slo { min_accuracy: 0.1, max_latency_ms: 1.0 },
                Slo { min_accuracy: 0.2, max_latency_ms: 2.0 },
            ],
        );
        let j = joint_slo(&grids, 3);
        assert!((j["a"].min_accuracy - 0.2).abs() < 1e-12);
    }
}


/// Ablation: which of SparseLoom's design choices buys what (DESIGN.md
/// §5 ablation benches). Each row disables exactly one mechanism on the
/// desktop profile and reports violation rate + throughput over the
/// 25-config grid.
pub fn ablate(ctx: &Ctx) -> Result<String> {
    use crate::baselines::{fixed_ngc_order, Policy};
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
    let zoo = ctx.zoo_for(&platform);
    let (grids, universe) = task_slos(ctx, &lm)?;
    let tasks: Vec<String> = profiles.keys().cloned().collect();
    let mut rng = Rng::new(17);
    let mut arrivals = arrival_combinations(&tasks);
    rng.shuffle(&mut arrivals);
    arrivals.truncate(4);

    let base = ServeOpts { policy: Policy::SparseLoom, ..Default::default() };
    let variants: Vec<(&str, ServeOpts)> = vec![
        ("full SparseLoom", base.clone()),
        ("− verified selection", ServeOpts { verify_selection: false, ..base.clone() }),
        ("− feedback switching", ServeOpts { feedback_switching: false, ..base.clone() }),
        ("− placement opt (fixed N-G-C)", ServeOpts {
            force_order: Some(fixed_ngc_order(&platform, ctx.zoo.subgraphs)),
            ..base.clone()
        }),
        ("− stitching (AV-P)", ServeOpts { policy: Policy::AvP, ..base.clone() }),
        ("15 % memory budget", ServeOpts { memory_budget_frac: 0.15, ..base.clone() }),
    ];

    let n_cfg = grids.values().next().map(|g| g.len()).unwrap_or(0);
    let mut rows = Vec::new();
    for (name, opts) in &variants {
        let server = Server::builder(zoo, &lm, &profiles).opts(opts.clone()).build();
        let mut agg = Aggregate::default();
        for i in 0..n_cfg {
            let slos = joint_slo(&grids, i);
            for arrival in &arrivals {
                let sc = Scenario::closed_loop(arrival, slos.clone())
                    .with_universe(universe.clone());
                agg.push(&server.run(&sc)?);
            }
        }
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", agg.mean_violation_pct()),
            format!("{:.0}", agg.mean_throughput()),
        ]);
    }
    Ok(format!(
        "Ablation — SparseLoom design choices (desktop, 25-config grid)\n\n{}",
        render_table(&["configuration", "violation %", "throughput q/s"], &rows),
    ))
}
