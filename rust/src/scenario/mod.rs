//! Scenario-first serving API.
//!
//! A [`Scenario`] is a *typed workload specification*: which tasks run,
//! how their queries arrive (closed loop, Poisson open loop, bursty
//! open loop, or a replayed trace), which SLO configuration(s) apply —
//! a multi-entry schedule reproduces the paper's §3.4 runtime-
//! rescheduling sequences — and what happens under overload (admission
//! control). A [`Server`] (see [`server`]) owns the profiles, latency
//! model, memory pool, and optional PJRT runtime, and executes
//! scenarios via `Server::run(&Scenario) -> RunReport`, emitting one
//! [`crate::metrics::RequestOutcome`] event per query.
//!
//! The paper's evaluation protocol (100 queries × batch 1 per task,
//! closed loop) is just `Scenario::closed_loop(...)`; everything the
//! paper never measured — open-loop throughput, overload, bursty
//! traffic — is the same API with a different [`Arrival`].
//!
//! Under backlog, the [`dispatch`] subsystem takes over: a
//! [`Dispatcher`] coalesces same-task queries into batches
//! ([`Dispatch`]), a [`ShardedServer`] partitions tasks across several
//! independent servers ([`Sharding`]), and [`Admission::Fair`] keeps one
//! bursty task from starving the rest.
//!
//! Scenarios serialize to JSON (`to_json`/`from_json`, `save`/`load`)
//! so the CLI can run workloads from files. See DESIGN.md §Scenario.
//!
//! The full walkthrough — builder → scenario → run → report — needs no
//! artifacts on disk thanks to [`crate::fixtures`]:
//!
//! ```
//! use sparseloom::fixtures;
//! use sparseloom::scenario::{Admission, Scenario, Server};
//!
//! let (zoo, lm, profiles) = fixtures::tiny();
//!
//! // 1. Build a server (planning engine + memory pool + plan cache).
//! let server = Server::builder(&zoo, &lm, &profiles)
//!     .memory_budget_frac(1.0)
//!     .build();
//!
//! // 2. Describe the workload as a typed scenario.
//! let scenario = Scenario::closed_loop(&fixtures::task_names(&zoo),
//!                                      fixtures::slos(&zoo, 0.5, 1e9))
//!     .with_queries(10)
//!     .with_admission(Admission::Always);
//!
//! // 3. Run it and read the report.
//! let report = server.run(&scenario).unwrap();
//! assert_eq!(report.total_queries, 10);
//! assert_eq!(report.violation_rate(), 0.0);
//!
//! // Scenarios round-trip through JSON for file-driven serving.
//! let json = scenario.to_json().to_string_pretty();
//! let back = Scenario::from_json(&sparseloom::json::parse(&json).unwrap()).unwrap();
//! assert_eq!(back.tasks, scenario.tasks);
//! ```

pub mod dispatch;
pub mod faults;
pub mod config;
pub mod server;

pub use config::{ServeConfig, Workload};
pub use dispatch::{Dispatch, Dispatcher, ShardAssignment, ShardedServer, Sharding};
pub use faults::{
    CrashWindow, Degradation, Expect, FaultProfile, LinkMatrix, RejoinMode, ThrottleCurve,
    ThrottleStep,
};
pub use server::{Server, ServerBuilder, Session};

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::{self, Json};
use crate::util::Rng;
use crate::workload::{bursty_stream, closed_loop_stream, poisson_stream, Query, Slo};

/// How queries arrive during one scenario phase.
#[derive(Clone, Debug)]
pub enum Arrival {
    /// The paper's protocol: each task issues `queries` back-to-back
    /// requests (the next issues when the previous completes); task at
    /// slot k starts at `k × stagger_ms`.
    ClosedLoop { queries: usize, stagger_ms: f64 },
    /// Open loop: each task receives Poisson arrivals at `rate_qps`
    /// for `horizon_ms` of virtual time, regardless of completions.
    PoissonOpenLoop { rate_qps: f64, horizon_ms: f64 },
    /// Open loop with a square-wave rate: each `period_ms` spends its
    /// first half at `base_qps` and its second half at `burst_qps`.
    Bursty {
        base_qps: f64,
        burst_qps: f64,
        period_ms: f64,
        horizon_ms: f64,
    },
    /// Replay an explicit query trace (e.g. recorded production
    /// arrivals). Queries must belong to the scenario's tasks.
    Trace(Vec<Query>),
}

/// Overload policy: what to do with a query whose task is already
/// backed up when it arrives. Closed-loop scenarios are self-clocking —
/// a query only exists once its predecessor completes — so their
/// backlog is always zero and every policy admits everything there.
#[derive(Clone, Debug, PartialEq)]
pub enum Admission {
    /// Admit everything (queues grow without bound under overload).
    Always,
    /// Drop a query when more than `max_queued` earlier queries of the
    /// same task are still waiting or executing.
    QueueCap { max_queued: usize },
    /// Drop a query whose queueing delay already exceeds
    /// `slack × max_latency_ms` of its task's SLO — it cannot possibly
    /// be worth serving.
    Deadline { slack: f64 },
    /// Weighted-fair deadline admission. Every task keeps the plain
    /// [`Admission::Deadline`] budget (`slack × max_latency_ms`), and is
    /// *additionally* admitted while its per-weight backlog is strictly
    /// under a margin of the **other** tasks' per-weight backlog
    /// (`backlog/w < 0.75 × Σ_others backlog / Σ_others w`). A heavy
    /// task whose standing backlog dwarfs the rest is shed at its
    /// deadline budget exactly as under `Deadline`; a latency-critical
    /// task (tight SLO ⇒ tiny deadline budget) riding out a short burst
    /// stays admitted as long as its backlog remains small next to the
    /// heavy tasks' — plain `Deadline` would shed its burst tail even
    /// though it is nowhere near its fair share of the system. With no
    /// other tasks, or under perfectly symmetric load, the share clause
    /// never fires and `Fair` behaves exactly like `Deadline`.
    Fair {
        /// Deadline slack, as in [`Admission::Deadline`].
        slack: f64,
        /// Per-task fair-share weights; tasks not listed weigh 1.0, so
        /// an empty map means an equal split.
        weights: BTreeMap<String, f64>,
    },
    /// Predictive admission (forecast-driven shedding). Every policy
    /// above is *reactive*: it sheds only once the observed backlog has
    /// already blown the budget. `Predictive` sheds on the *projected*
    /// queueing delay instead: each task fits a Holt trend over its own
    /// backlog series (`telemetry::forecast::TrendTracker`), and a
    /// query is dropped when `backlog + max(0, trend) × horizon_ms`
    /// exceeds `headroom × max_latency_ms` — during a building burst
    /// the growth term is positive, so shedding starts *before* the
    /// backlog itself crosses the budget. On a flat or draining queue
    /// the growth term is zero and `Predictive{headroom: s}` admits
    /// exactly like `Deadline{slack: s}`; a query facing an empty queue
    /// is always admitted (shedding it could not relieve anything, and
    /// closed loops stay lossless). See DESIGN.md §Forecasting.
    Predictive {
        /// Forecast horizon (virtual ms) the backlog trend is
        /// projected over.
        horizon_ms: f64,
        /// Budget multiplier on the task's SLO latency bound (the
        /// predictive counterpart of the deadline `slack`).
        headroom: f64,
    },
}

impl Admission {
    /// Short human label printed in CLI report headers, matching the
    /// JSON `kind` tags — so saved scenario files and printed reports
    /// agree on the policy in effect.
    pub fn label(&self) -> String {
        match self {
            Admission::Always => "always".into(),
            Admission::QueueCap { max_queued } => format!("queue_cap:{max_queued}"),
            Admission::Deadline { slack } => format!("deadline:{slack}"),
            Admission::Fair { slack, .. } => format!("fair:{slack}"),
            Admission::Predictive { horizon_ms, headroom } => {
                format!("predictive:{headroom}:{horizon_ms}")
            }
        }
    }
}

/// Planner knobs: batch-aware Algorithm 1, online re-planning, and the
/// telemetry-driven steal/warm-migration paths.
///
/// The default is the PR 2 regime — batch-1 planning, frozen at
/// startup. `replan` turns on the `ShardedServer` online path: when a
/// shard's total backlog crosses `saturation_slack ×` the mean SLO
/// latency bound of its tasks, `planner::Planner::replan` migrates the
/// hottest still-queued task (Eq. 7 mass × observed arrival rate) to
/// the least-loaded shard (at most `max_migrations` per phase, per-task
/// FIFO preserved). `steal` adds query-granularity work stealing on the
/// same saturation signal: an underloaded shard serves waiting batches
/// of a saturated shard's tasks (warm shards preferred; per-task FIFO
/// preserved by cross-shard ready floors). `warm_migrate` makes both
/// adoption paths carry the migrant's resident pool entries to the
/// target — a cross-shard load instead of a cold compile+load.
/// `predictive` switches both online triggers (steal and replan) from
/// the observed shard backlog to `max(observed, forecast)` — the
/// Holt-projected backlog `horizon_ms` ahead — so migration and
/// stealing start while the burst is still building (the observed
/// crossing is the degenerate horizon-0 forecast, so a predictive run
/// never reacts *later* than a reactive one); the replan
/// `ShardObservation::arrival_qps` then carries projected rather than
/// trailing rates.
#[derive(Clone, Debug, PartialEq)]
pub struct PlannerConfig {
    /// Plan at the dispatch batch operating point instead of batch 1
    /// (callers set `ServeOpts::batch_hint` from `Dispatch::max_batch`).
    pub batch_aware: bool,
    /// Enable online re-planning (bounded shard migration).
    pub replan: bool,
    /// Enable telemetry-driven query-granularity work stealing.
    pub steal: bool,
    /// Carry a migrant's pool contents across shards (skip the cold
    /// compile) on migration and steal adoption.
    pub warm_migrate: bool,
    /// Trigger the online paths on *forecast* shard backlog (never
    /// later than the observed trigger) and feed projected arrival
    /// rates into replanning.
    pub predictive: bool,
    /// Forecast horizon (virtual ms) for the predictive triggers.
    pub horizon_ms: f64,
    /// Saturation threshold multiplier on the shard's mean SLO latency.
    pub saturation_slack: f64,
    /// Bounded re-sharding: at most this many migrations per phase.
    pub max_migrations: usize,
    /// Epoch length (virtual ms) for the threaded online drive. `0.0`
    /// (the default) keeps the classic per-batch sequential drive;
    /// any positive value switches `ShardedServer::run_online` to the
    /// epoch-barrier protocol: shards run one epoch window each on
    /// their own OS thread, then meet at a lockstep barrier where the
    /// coordinator merges telemetry, steals, redirects and replans.
    /// Results are deterministic and independent of thread scheduling.
    pub epoch_ms: f64,
    /// Online variant synthesis: when a shard's backlog crosses its
    /// saturation threshold (or its pool runs hot), the planner's
    /// synthesizing `VariantProvider` searches the stitch space for a
    /// cheaper composition at the live batch operating point and
    /// switches the task to it (emitting `TR-CTL-SYNTH`). Off by
    /// default; the enumerated planner is untouched when unset.
    pub synthesize: bool,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self {
            batch_aware: false,
            replan: false,
            steal: false,
            warm_migrate: false,
            predictive: false,
            horizon_ms: 250.0,
            saturation_slack: 4.0,
            max_migrations: 1,
            epoch_ms: 0.0,
            synthesize: false,
        }
    }
}

impl PlannerConfig {
    /// Batch-aware planning + online re-planning, default thresholds.
    pub fn replanning() -> Self {
        Self { batch_aware: true, replan: true, ..Self::default() }
    }

    /// Batch-aware planning + work stealing, no whole-task re-planning.
    pub fn stealing() -> Self {
        Self { batch_aware: true, steal: true, ..Self::default() }
    }

    /// The full online stack: batch-aware planning, re-planning, work
    /// stealing, and warm migration.
    pub fn online() -> Self {
        Self {
            batch_aware: true,
            replan: true,
            steal: true,
            warm_migrate: true,
            ..Self::default()
        }
    }

    /// Builder: enable warm migration on top of any base config.
    pub fn with_warm_migration(mut self) -> Self {
        self.warm_migrate = true;
        self
    }

    /// The predictive stack: the full online config with both triggers
    /// switched to forecast backlog and projected arrival hints.
    pub fn predictive() -> Self {
        Self { predictive: true, ..Self::online() }
    }
}

/// A typed serving scenario: tasks + arrival process + SLO schedule +
/// admission policy. Construct with the `closed_loop` / `poisson` /
/// `bursty` / `trace` constructors and refine with the `with_*`
/// builder methods.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Human-readable label (reports, JSON files).
    pub name: String,
    /// Task arrival order (closed loop) / task set (open loop). Entries
    /// must be unique, and every entry must have a profile on the
    /// server and an SLO per phase (checked when a session opens).
    pub tasks: Vec<String>,
    pub arrival: Arrival,
    /// One entry per phase. Multi-entry schedules re-plan between
    /// phases over a persistent memory pool (§3.4 / Fig. 14): newly
    /// needed subgraphs pay compile+load on the spot.
    pub schedule: Vec<BTreeMap<String, Slo>>,
    /// The SLO universe Ψ the hotness-based preloader optimizes for.
    /// Empty ⇒ derived from `schedule`.
    pub universe: Vec<Slo>,
    pub admission: Admission,
    /// Adaptive batching under backlog (identity dispatch by default:
    /// every query is placed alone).
    pub dispatch: Dispatch,
    /// Multi-server sharding (a single server by default). This is the
    /// scenario's *declared* deployment: the CLI (and any caller)
    /// builds a [`ShardedServer`] from it. Routing itself follows the
    /// server's build-time [`Sharding`] — pass this field to
    /// `ShardedServer::build` (as the CLI does) so the file and the run
    /// agree. A plain `Server::run` serves the whole task set on one
    /// simulated SoC regardless.
    pub sharding: Sharding,
    /// Planner knobs: batch-aware Algorithm 1 + online re-planning
    /// (identity planner config by default — PR 2 behavior).
    pub planner: PlannerConfig,
    /// Declarative fault & degradation overlay (crash windows, slow
    /// ramps, thermal throttling, link costs, `expect` clauses). The
    /// default empty profile injects nothing — legacy scenarios replay
    /// bit-identically. See [`faults`].
    pub faults: FaultProfile,
    /// Seed for the open-loop arrival generators (deterministic replay).
    pub seed: u64,
}

impl Scenario {
    fn base(
        name: &str,
        tasks: &[String],
        slos: BTreeMap<String, Slo>,
        arrival: Arrival,
    ) -> Scenario {
        Scenario {
            name: name.to_string(),
            tasks: tasks.to_vec(),
            arrival,
            schedule: vec![slos],
            universe: Vec::new(),
            admission: Admission::Always,
            dispatch: Dispatch::default(),
            sharding: Sharding::default(),
            planner: PlannerConfig::default(),
            faults: FaultProfile::default(),
            seed: 0,
        }
    }

    /// The paper's closed-loop protocol: 100 queries × batch 1 per
    /// task, no stagger. Override with [`Scenario::with_queries`] /
    /// [`Scenario::with_stagger_ms`].
    pub fn closed_loop(tasks: &[String], slos: BTreeMap<String, Slo>) -> Scenario {
        Self::base(
            "closed-loop",
            tasks,
            slos,
            Arrival::ClosedLoop { queries: 100, stagger_ms: 0.0 },
        )
    }

    /// Poisson open-loop traffic at `rate_qps` per task for `horizon_ms`.
    pub fn poisson(
        tasks: &[String],
        slos: BTreeMap<String, Slo>,
        rate_qps: f64,
        horizon_ms: f64,
    ) -> Scenario {
        Self::base(
            "poisson",
            tasks,
            slos,
            Arrival::PoissonOpenLoop { rate_qps, horizon_ms },
        )
    }

    /// Bursty open-loop traffic (square-wave rate) per task.
    pub fn bursty(
        tasks: &[String],
        slos: BTreeMap<String, Slo>,
        base_qps: f64,
        burst_qps: f64,
        period_ms: f64,
        horizon_ms: f64,
    ) -> Scenario {
        Self::base(
            "bursty",
            tasks,
            slos,
            Arrival::Bursty { base_qps, burst_qps, period_ms, horizon_ms },
        )
    }

    /// Replay an explicit trace.
    pub fn trace(tasks: &[String], slos: BTreeMap<String, Slo>, queries: Vec<Query>) -> Scenario {
        Self::base("trace", tasks, slos, Arrival::Trace(queries))
    }

    // ---- builder refinements -------------------------------------------

    pub fn with_name(mut self, name: &str) -> Scenario {
        self.name = name.to_string();
        self
    }

    /// Closed-loop query count per task (ignored for open loops).
    pub fn with_queries(mut self, n: usize) -> Scenario {
        if let Arrival::ClosedLoop { queries, .. } = &mut self.arrival {
            *queries = n;
        }
        self
    }

    /// Closed-loop per-slot start stagger (ignored for open loops).
    pub fn with_stagger_ms(mut self, ms: f64) -> Scenario {
        if let Arrival::ClosedLoop { stagger_ms, .. } = &mut self.arrival {
            *stagger_ms = ms;
        }
        self
    }

    /// Replace the whole SLO schedule (one entry per phase) — the
    /// runtime-rescheduling scenario of §3.4.
    pub fn with_schedule(mut self, schedule: Vec<BTreeMap<String, Slo>>) -> Scenario {
        self.schedule = schedule;
        self
    }

    /// Set the preloader's SLO universe Ψ explicitly.
    pub fn with_universe(mut self, universe: Vec<Slo>) -> Scenario {
        self.universe = universe;
        self
    }

    pub fn with_admission(mut self, admission: Admission) -> Scenario {
        self.admission = admission;
        self
    }

    /// Configure adaptive batching under backlog (see [`Dispatch`]).
    pub fn with_dispatch(mut self, dispatch: Dispatch) -> Scenario {
        self.dispatch = dispatch;
        self
    }

    /// Configure multi-server sharding (see [`Sharding`]).
    pub fn with_sharding(mut self, sharding: Sharding) -> Scenario {
        self.sharding = sharding;
        self
    }

    /// Configure the planner (see [`PlannerConfig`]).
    pub fn with_planner(mut self, planner: PlannerConfig) -> Scenario {
        self.planner = planner;
        self
    }

    /// Overlay a fault & degradation profile (see [`faults`]).
    pub fn with_faults(mut self, faults: FaultProfile) -> Scenario {
        self.faults = faults;
        self
    }

    /// Replace the task set / arrival order, keeping everything else —
    /// [`ShardedServer`] uses this (together with a filtered
    /// [`Scenario::with_schedule`]) to restrict a scenario to one
    /// shard's partition. Schedule entries for absent tasks don't break
    /// a session, but they do participate in planning/preloading —
    /// filter them too when that matters.
    pub fn with_tasks(mut self, tasks: &[String]) -> Scenario {
        self.tasks = tasks.to_vec();
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Scenario {
        self.seed = seed;
        self
    }

    // ---- derived views --------------------------------------------------

    /// The SLO universe Ψ: explicit if set, else every SLO appearing in
    /// the schedule.
    pub fn slo_universe(&self) -> Vec<Slo> {
        if !self.universe.is_empty() {
            return self.universe.clone();
        }
        self.schedule
            .iter()
            .flat_map(|cfg| cfg.values().copied())
            .collect()
    }

    /// Number of phases (schedule entries).
    pub fn phases(&self) -> usize {
        self.schedule.len()
    }

    /// Generate the query stream for one phase. Open-loop streams are
    /// deterministic in (`seed`, `phase`); closed-loop and trace
    /// streams are phase-independent.
    pub fn stream(&self, phase: usize) -> Vec<Query> {
        let mut rng = Rng::new(
            self.seed ^ (phase as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        match &self.arrival {
            Arrival::ClosedLoop { queries, stagger_ms } => {
                closed_loop_stream(&self.tasks, *queries, *stagger_ms)
            }
            Arrival::PoissonOpenLoop { rate_qps, horizon_ms } => {
                poisson_stream(&self.tasks, *rate_qps, *horizon_ms, &mut rng)
            }
            Arrival::Bursty { base_qps, burst_qps, period_ms, horizon_ms } => {
                bursty_stream(
                    &self.tasks,
                    *base_qps,
                    *burst_qps,
                    *period_ms,
                    *horizon_ms,
                    &mut rng,
                )
            }
            Arrival::Trace(queries) => queries.clone(),
        }
    }

    // ---- JSON ----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let arrival = match &self.arrival {
            Arrival::ClosedLoop { queries, stagger_ms } => Json::obj(vec![
                ("kind", Json::Str("closed_loop".into())),
                ("queries", Json::Num(*queries as f64)),
                ("stagger_ms", Json::Num(*stagger_ms)),
            ]),
            Arrival::PoissonOpenLoop { rate_qps, horizon_ms } => Json::obj(vec![
                ("kind", Json::Str("poisson".into())),
                ("rate_qps", Json::Num(*rate_qps)),
                ("horizon_ms", Json::Num(*horizon_ms)),
            ]),
            Arrival::Bursty { base_qps, burst_qps, period_ms, horizon_ms } => Json::obj(vec![
                ("kind", Json::Str("bursty".into())),
                ("base_qps", Json::Num(*base_qps)),
                ("burst_qps", Json::Num(*burst_qps)),
                ("period_ms", Json::Num(*period_ms)),
                ("horizon_ms", Json::Num(*horizon_ms)),
            ]),
            Arrival::Trace(queries) => Json::obj(vec![
                ("kind", Json::Str("trace".into())),
                (
                    "queries",
                    Json::arr(queries.iter().map(|q| {
                        Json::obj(vec![
                            ("task", Json::Str(q.task.clone())),
                            ("arrival_ms", Json::Num(q.arrival_ms)),
                            // u64 ids go through strings: JSON numbers
                            // are f64 and corrupt values above 2^53.
                            ("id", Json::Str(q.id.to_string())),
                        ])
                    })),
                ),
            ]),
        };
        let admission = match &self.admission {
            Admission::Always => Json::obj(vec![("kind", Json::Str("always".into()))]),
            Admission::QueueCap { max_queued } => Json::obj(vec![
                ("kind", Json::Str("queue_cap".into())),
                ("max_queued", Json::Num(*max_queued as f64)),
            ]),
            Admission::Deadline { slack } => Json::obj(vec![
                ("kind", Json::Str("deadline".into())),
                ("slack", Json::Num(*slack)),
            ]),
            Admission::Fair { slack, weights } => Json::obj(vec![
                ("kind", Json::Str("fair".into())),
                ("slack", Json::Num(*slack)),
                (
                    "weights",
                    Json::Obj(
                        weights
                            .iter()
                            .map(|(task, w)| (task.clone(), Json::Num(*w)))
                            .collect(),
                    ),
                ),
            ]),
            Admission::Predictive { horizon_ms, headroom } => Json::obj(vec![
                ("kind", Json::Str("predictive".into())),
                ("horizon_ms", Json::Num(*horizon_ms)),
                ("headroom", Json::Num(*headroom)),
            ]),
        };
        let assignment = match &self.sharding.assignment {
            ShardAssignment::Hash => Json::obj(vec![("kind", Json::Str("hash".into()))]),
            ShardAssignment::Explicit(map) => Json::obj(vec![
                ("kind", Json::Str("explicit".into())),
                (
                    "map",
                    Json::Obj(
                        map.iter()
                            .map(|(task, shard)| (task.clone(), Json::Num(*shard as f64)))
                            .collect(),
                    ),
                ),
            ]),
        };
        let mut fields: Vec<(&str, Json)> = vec![
            ("name", Json::Str(self.name.clone())),
            // u64 seeds go through strings: JSON numbers are f64 and
            // corrupt values above 2^53, breaking deterministic replay.
            ("seed", Json::Str(self.seed.to_string())),
            (
                "tasks",
                Json::arr(self.tasks.iter().map(|t| Json::Str(t.clone()))),
            ),
            ("arrival", arrival),
            ("admission", admission),
            (
                "dispatch",
                Json::obj(vec![
                    ("max_batch", Json::Num(self.dispatch.max_batch as f64)),
                    ("min_queue", Json::Num(self.dispatch.min_queue as f64)),
                ]),
            ),
            (
                "sharding",
                Json::obj(vec![
                    ("shards", Json::Num(self.sharding.shards as f64)),
                    ("assignment", assignment),
                ]),
            ),
            (
                "planner",
                Json::obj(vec![
                    ("batch_aware", Json::Bool(self.planner.batch_aware)),
                    ("replan", Json::Bool(self.planner.replan)),
                    ("steal", Json::Bool(self.planner.steal)),
                    ("warm_migrate", Json::Bool(self.planner.warm_migrate)),
                    ("predictive", Json::Bool(self.planner.predictive)),
                    ("horizon_ms", Json::Num(self.planner.horizon_ms)),
                    (
                        "saturation_slack",
                        Json::Num(self.planner.saturation_slack),
                    ),
                    (
                        "max_migrations",
                        Json::Num(self.planner.max_migrations as f64),
                    ),
                    ("epoch_ms", Json::Num(self.planner.epoch_ms)),
                    ("synthesize", Json::Bool(self.planner.synthesize)),
                ]),
            ),
            (
                "schedule",
                Json::arr(self.schedule.iter().map(|cfg| {
                    Json::Obj(
                        cfg.iter()
                            .map(|(task, slo)| (task.clone(), slo_to_json(slo)))
                            .collect(),
                    )
                })),
            ),
            (
                "universe",
                Json::arr(self.universe.iter().map(slo_to_json)),
            ),
        ];
        // The fault overlay is omitted when empty so pre-fault-lab
        // files and their round-tripped forms stay byte-stable.
        if !self.faults.is_default() {
            fields.push(("faults", self.faults.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<Scenario> {
        let name = v
            .get("name")
            .and_then(|n| n.as_str())
            .unwrap_or("scenario")
            .to_string();
        let seed = match v.get("seed") {
            None => 0,
            Some(s) => u64_from_json(s).context("seed")?,
        };
        let tasks: Vec<String> = v
            .req("tasks")?
            .as_arr()
            .context("tasks must be an array")?
            .iter()
            .map(|t| {
                t.as_str()
                    .map(|s| s.to_string())
                    .context("task names must be strings")
            })
            .collect::<Result<_>>()?;

        let a = v.req("arrival")?;
        let kind = a.req("kind")?.as_str().context("arrival.kind")?;
        let f = |key: &str| -> Result<f64> {
            a.req(key)?
                .as_f64()
                .with_context(|| format!("arrival.{key} must be a number"))
        };
        let arrival = match kind {
            "closed_loop" => Arrival::ClosedLoop {
                queries: a.req("queries")?.as_usize().context("arrival.queries")?,
                stagger_ms: a
                    .get("stagger_ms")
                    .and_then(|x| x.as_f64())
                    .unwrap_or(0.0),
            },
            "poisson" => Arrival::PoissonOpenLoop {
                rate_qps: f("rate_qps")?,
                horizon_ms: f("horizon_ms")?,
            },
            "bursty" => Arrival::Bursty {
                base_qps: f("base_qps")?,
                burst_qps: f("burst_qps")?,
                period_ms: f("period_ms")?,
                horizon_ms: f("horizon_ms")?,
            },
            "trace" => {
                let qs = a
                    .req("queries")?
                    .as_arr()
                    .context("trace queries must be an array")?
                    .iter()
                    .map(|q| {
                        Ok(Query {
                            task: q
                                .req("task")?
                                .as_str()
                                .context("query.task")?
                                .to_string(),
                            arrival_ms: q
                                .req("arrival_ms")?
                                .as_f64()
                                .context("query.arrival_ms")?,
                            id: u64_from_json(q.req("id")?).context("query.id")?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Arrival::Trace(qs)
            }
            other => bail!("unknown arrival kind {other:?}"),
        };

        let admission = match v.get("admission") {
            None => Admission::Always,
            Some(adm) => match adm.req("kind")?.as_str().context("admission.kind")? {
                "always" | "none" => Admission::Always,
                "queue_cap" => Admission::QueueCap {
                    max_queued: adm
                        .req("max_queued")?
                        .as_usize()
                        .context("admission.max_queued")?,
                },
                "deadline" => Admission::Deadline {
                    slack: adm.req("slack")?.as_f64().context("admission.slack")?,
                },
                "fair" => {
                    let weights = match adm.get("weights") {
                        None => BTreeMap::new(),
                        Some(w) => w
                            .as_obj()
                            .context("admission.weights must be an object")?
                            .iter()
                            .map(|(task, v)| {
                                Ok((
                                    task.clone(),
                                    v.as_f64().with_context(|| {
                                        format!("admission.weights.{task} must be a number")
                                    })?,
                                ))
                            })
                            .collect::<Result<BTreeMap<_, _>>>()?,
                    };
                    Admission::Fair {
                        slack: adm.req("slack")?.as_f64().context("admission.slack")?,
                        weights,
                    }
                }
                "predictive" => Admission::Predictive {
                    horizon_ms: adm
                        .req("horizon_ms")?
                        .as_f64()
                        .context("admission.horizon_ms")?,
                    headroom: adm
                        .req("headroom")?
                        .as_f64()
                        .context("admission.headroom")?,
                },
                other => bail!("unknown admission kind {other:?}"),
            },
        };

        let dispatch = match v.get("dispatch") {
            None => Dispatch::default(),
            Some(d) => Dispatch {
                max_batch: d
                    .req("max_batch")?
                    .as_usize()
                    .context("dispatch.max_batch")?,
                min_queue: match d.get("min_queue") {
                    None => Dispatch::default().min_queue,
                    Some(x) => x.as_usize().context("dispatch.min_queue")?,
                },
            },
        };

        let sharding = match v.get("sharding") {
            None => Sharding::default(),
            Some(s) => {
                let shards = s.req("shards")?.as_usize().context("sharding.shards")?;
                let assignment = match s.get("assignment") {
                    None => ShardAssignment::Hash,
                    Some(a) => match a
                        .req("kind")?
                        .as_str()
                        .context("sharding.assignment.kind")?
                    {
                        "hash" => ShardAssignment::Hash,
                        "explicit" => ShardAssignment::Explicit(
                            a.req("map")?
                                .as_obj()
                                .context("sharding.assignment.map must be an object")?
                                .iter()
                                .map(|(task, v)| {
                                    Ok((
                                        task.clone(),
                                        v.as_usize().with_context(|| {
                                            format!("shard index for task {task:?}")
                                        })?,
                                    ))
                                })
                                .collect::<Result<BTreeMap<_, _>>>()?,
                        ),
                        other => bail!("unknown shard assignment kind {other:?}"),
                    },
                };
                Sharding { shards, assignment }
            }
        };

        let planner = match v.get("planner") {
            None => PlannerConfig::default(),
            Some(p) => {
                let d = PlannerConfig::default();
                PlannerConfig {
                    batch_aware: match p.get("batch_aware") {
                        None => d.batch_aware,
                        Some(x) => x.as_bool().context("planner.batch_aware")?,
                    },
                    replan: match p.get("replan") {
                        None => d.replan,
                        Some(x) => x.as_bool().context("planner.replan")?,
                    },
                    steal: match p.get("steal") {
                        None => d.steal,
                        Some(x) => x.as_bool().context("planner.steal")?,
                    },
                    warm_migrate: match p.get("warm_migrate") {
                        None => d.warm_migrate,
                        Some(x) => x.as_bool().context("planner.warm_migrate")?,
                    },
                    predictive: match p.get("predictive") {
                        None => d.predictive,
                        Some(x) => x.as_bool().context("planner.predictive")?,
                    },
                    horizon_ms: match p.get("horizon_ms") {
                        None => d.horizon_ms,
                        Some(x) => x.as_f64().context("planner.horizon_ms")?,
                    },
                    saturation_slack: match p.get("saturation_slack") {
                        None => d.saturation_slack,
                        Some(x) => x.as_f64().context("planner.saturation_slack")?,
                    },
                    max_migrations: match p.get("max_migrations") {
                        None => d.max_migrations,
                        Some(x) => x.as_usize().context("planner.max_migrations")?,
                    },
                    epoch_ms: match p.get("epoch_ms") {
                        None => d.epoch_ms,
                        Some(x) => x.as_f64().context("planner.epoch_ms")?,
                    },
                    synthesize: match p.get("synthesize") {
                        None => d.synthesize,
                        Some(x) => x.as_bool().context("planner.synthesize")?,
                    },
                }
            }
        };

        let schedule: Vec<BTreeMap<String, Slo>> = v
            .req("schedule")?
            .as_arr()
            .context("schedule must be an array")?
            .iter()
            .map(|cfg| {
                let obj = cfg.as_obj().context("schedule entries must be objects")?;
                obj.iter()
                    .map(|(task, slo)| Ok((task.clone(), slo_from_json(slo)?)))
                    .collect::<Result<BTreeMap<_, _>>>()
            })
            .collect::<Result<_>>()?;
        if schedule.is_empty() {
            bail!("scenario {name:?} has an empty SLO schedule");
        }

        let universe = match v.get("universe") {
            None => Vec::new(),
            Some(u) => u
                .as_arr()
                .context("universe must be an array")?
                .iter()
                .map(slo_from_json)
                .collect::<Result<_>>()?,
        };

        // Back-compat: files written before the fault lab carry no
        // `faults` key and parse to the inert empty profile.
        let faults = match v.get("faults") {
            None => FaultProfile::default(),
            Some(f) => FaultProfile::from_json(f).context("faults")?,
        };

        Ok(Scenario {
            name,
            tasks,
            arrival,
            schedule,
            universe,
            admission,
            dispatch,
            sharding,
            planner,
            faults,
            seed,
        })
    }

    /// Write the scenario as pretty JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing scenario {}", path.display()))?;
        Ok(())
    }

    /// Load a scenario from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> Result<Scenario> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {}", path.display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing scenario {}: {e}", path.display()))?;
        Self::from_json(&v)
    }
}

/// Read a u64 stored as either a JSON string (lossless, how we write
/// it) or a plain number (hand-written files; exact below 2^53).
fn u64_from_json(v: &Json) -> Result<u64> {
    if let Some(s) = v.as_str() {
        return s
            .parse()
            .with_context(|| format!("not an unsigned integer: {s:?}"));
    }
    v.as_u64().context("expected an unsigned integer")
}

fn slo_to_json(slo: &Slo) -> Json {
    Json::obj(vec![
        ("min_accuracy", Json::Num(slo.min_accuracy)),
        ("max_latency_ms", Json::Num(slo.max_latency_ms)),
    ])
}

fn slo_from_json(v: &Json) -> Result<Slo> {
    Ok(Slo {
        min_accuracy: v
            .req("min_accuracy")?
            .as_f64()
            .context("slo.min_accuracy")?,
        max_latency_ms: v
            .req("max_latency_ms")?
            .as_f64()
            .context("slo.max_latency_ms")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slos() -> BTreeMap<String, Slo> {
        BTreeMap::from([
            (
                "a".to_string(),
                Slo { min_accuracy: 0.8, max_latency_ms: 40.0 },
            ),
            (
                "b".to_string(),
                Slo { min_accuracy: 0.9, max_latency_ms: 25.0 },
            ),
        ])
    }

    fn tasks() -> Vec<String> {
        vec!["a".to_string(), "b".to_string()]
    }

    #[test]
    fn closed_loop_defaults_match_paper_protocol() {
        let sc = Scenario::closed_loop(&tasks(), slos());
        let qs = sc.stream(0);
        assert_eq!(qs.len(), 200, "100 queries × 2 tasks");
        assert!(qs.iter().all(|q| q.arrival_ms == 0.0));
        assert_eq!(sc.phases(), 1);
        assert_eq!(sc.slo_universe().len(), 2);
    }

    #[test]
    fn open_loop_stream_deterministic_per_phase() {
        let sc = Scenario::poisson(&tasks(), slos(), 50.0, 2_000.0).with_seed(9);
        let a = sc.stream(0);
        let b = sc.stream(0);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        // Different phases draw from different streams.
        let c = sc.stream(1);
        assert!(
            a.len() != c.len()
                || a.iter().zip(&c).any(|(x, y)| x.arrival_ms != y.arrival_ms)
        );
    }

    #[test]
    fn schedule_builder_makes_phases() {
        let sc = Scenario::closed_loop(&tasks(), slos())
            .with_queries(10)
            .with_schedule(vec![slos(), slos(), slos()]);
        assert_eq!(sc.phases(), 3);
        assert_eq!(sc.stream(2).len(), 20);
        // Universe derived from every phase entry.
        assert_eq!(sc.slo_universe().len(), 6);
    }

    #[test]
    fn json_round_trip_all_arrivals() {
        let cases = vec![
            Scenario::closed_loop(&tasks(), slos())
                .with_queries(7)
                .with_stagger_ms(1.5),
            Scenario::poisson(&tasks(), slos(), 20.0, 5_000.0)
                // Above 2^53: must survive JSON exactly (string-encoded).
                .with_seed(u64::MAX - 1)
                .with_admission(Admission::QueueCap { max_queued: 8 }),
            Scenario::bursty(&tasks(), slos(), 5.0, 80.0, 1_000.0, 4_000.0)
                .with_admission(Admission::Deadline { slack: 3.0 }),
            // The dispatch/sharding/fair-admission/planner block, with
            // the largest representable seed (string-encoded through
            // JSON).
            Scenario::bursty(&tasks(), slos(), 10.0, 120.0, 500.0, 3_000.0)
                .with_seed(u64::MAX)
                .with_admission(Admission::Fair {
                    slack: 1.5,
                    weights: BTreeMap::from([("a".to_string(), 2.0)]),
                })
                .with_dispatch(Dispatch { max_batch: 4, min_queue: 3 })
                .with_sharding(Sharding {
                    shards: 2,
                    assignment: ShardAssignment::Explicit(BTreeMap::from([
                        ("a".to_string(), 0),
                        ("b".to_string(), 1),
                    ])),
                })
                .with_planner(PlannerConfig {
                    batch_aware: true,
                    replan: true,
                    steal: true,
                    warm_migrate: true,
                    predictive: true,
                    horizon_ms: 125.0,
                    saturation_slack: 2.5,
                    max_migrations: 3,
                    epoch_ms: 25.0,
                    synthesize: true,
                }),
            Scenario::bursty(&tasks(), slos(), 8.0, 90.0, 400.0, 2_500.0)
                .with_admission(Admission::Predictive {
                    horizon_ms: 200.0,
                    headroom: 1.25,
                })
                .with_planner(PlannerConfig::predictive()),
            Scenario::poisson(&tasks(), slos(), 15.0, 2_000.0)
                // 2^53 + 1: the first u64 a JSON f64 cannot represent —
                // must survive exactly via the string encoding.
                .with_seed((1u64 << 53) + 1)
                .with_admission(Admission::Fair { slack: 2.0, weights: BTreeMap::new() })
                .with_sharding(Sharding::hash(3)),
            Scenario::trace(
                &tasks(),
                slos(),
                vec![
                    Query { task: "a".into(), arrival_ms: 0.5, id: 0 },
                    Query { task: "b".into(), arrival_ms: 1.5, id: 1 },
                ],
            )
            .with_universe(vec![Slo { min_accuracy: 0.7, max_latency_ms: 99.0 }]),
            // The full fault-lab overlay: crash window, degradation
            // ramp, throttle curve, link matrix, and expect clauses.
            Scenario::bursty(&tasks(), slos(), 6.0, 100.0, 400.0, 3_000.0)
                .with_seed(23)
                .with_sharding(Sharding::hash(2))
                .with_planner(PlannerConfig::online())
                .with_faults(FaultProfile {
                    crashes: vec![CrashWindow {
                        shard: 1,
                        start_ms: 800.0,
                        end_ms: 1_400.0,
                        rejoin: RejoinMode::Warm,
                    }],
                    degradations: vec![Degradation {
                        shard: 0,
                        start_ms: 200.0,
                        ramp_ms: 600.0,
                        factor: 2.5,
                    }],
                    throttle: Some(ThrottleCurve {
                        steps: vec![ThrottleStep { busy_ms: 500.0, factor: 1.8 }],
                    }),
                    links: Some(LinkMatrix {
                        transfer_ms: vec![vec![0.0, 6.0], vec![6.0, 0.0]],
                    }),
                    expects: vec![
                        Expect::MinCompleted { task: None, at_least: 1 },
                        Expect::RecoveryWithin { shard: 1, ms: 500.0 },
                    ],
                }),
        ];
        for sc in cases {
            let text = sc.to_json().to_string_pretty();
            let back = Scenario::from_json(&crate::json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.name, sc.name);
            assert_eq!(back.tasks, sc.tasks);
            assert_eq!(back.seed, sc.seed);
            assert_eq!(back.admission, sc.admission);
            assert_eq!(back.dispatch, sc.dispatch);
            assert_eq!(back.sharding, sc.sharding);
            assert_eq!(back.planner, sc.planner);
            assert_eq!(back.faults, sc.faults);
            assert_eq!(back.schedule, sc.schedule);
            assert_eq!(back.universe.len(), sc.universe.len());
            // Streams replay identically through the round trip.
            let a = sc.stream(0);
            let b = back.stream(0);
            assert_eq!(a.len(), b.len(), "{}", sc.name);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.task, y.task);
                assert!((x.arrival_ms - y.arrival_ms).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn legacy_json_defaults_dispatch_and_sharding() {
        // Files written before the dispatch subsystem existed carry no
        // `dispatch`/`sharding` keys: they must parse to the identity
        // configuration (no batching, one shard).
        let legacy = crate::json::parse(
            r#"{"tasks": ["a"], "arrival": {"kind": "poisson", "rate_qps": 5, "horizon_ms": 100},
                "schedule": [{"a": {"min_accuracy": 0.5, "max_latency_ms": 50}}]}"#,
        )
        .unwrap();
        let sc = Scenario::from_json(&legacy).unwrap();
        assert_eq!(sc.dispatch, Dispatch::default());
        assert_eq!(sc.sharding, Sharding::default());
        assert_eq!(sc.planner, PlannerConfig::default());
        assert_eq!(sc.dispatch.max_batch, 1, "default must not batch");
        assert_eq!(sc.sharding.shards, 1, "default must not shard");
        assert!(!sc.planner.replan, "default must not replan");
        assert!(!sc.planner.steal, "default must not steal");
        assert!(!sc.planner.warm_migrate, "default must not warm-migrate");
        assert!(!sc.planner.predictive, "default must not forecast");
        assert!(sc.faults.is_default(), "default must inject no faults");
    }

    #[test]
    fn admission_labels_match_json_kinds() {
        assert_eq!(Admission::Always.label(), "always");
        assert_eq!(Admission::QueueCap { max_queued: 4 }.label(), "queue_cap:4");
        assert_eq!(Admission::Deadline { slack: 2.0 }.label(), "deadline:2");
        assert_eq!(
            Admission::Fair { slack: 1.5, weights: BTreeMap::new() }.label(),
            "fair:1.5"
        );
        assert_eq!(
            Admission::Predictive { horizon_ms: 250.0, headroom: 1.5 }.label(),
            "predictive:1.5:250"
        );
    }

    #[test]
    fn predictive_planner_config_builds_on_online() {
        let pc = PlannerConfig::predictive();
        assert!(pc.predictive && pc.replan && pc.steal && pc.warm_migrate);
        assert!(pc.batch_aware);
        assert!(pc.horizon_ms > 0.0);
        assert!(!PlannerConfig::online().predictive);
    }

    #[test]
    fn from_json_rejects_garbage() {
        let bad = crate::json::parse(r#"{"tasks": ["a"], "arrival": {"kind": "warp"}, "schedule": []}"#)
            .unwrap();
        assert!(Scenario::from_json(&bad).is_err());
    }
}
