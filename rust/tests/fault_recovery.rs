//! Fault-lab recovery properties (the scenario lab's acceptance tests):
//!
//! 1. Under a shard crash, the online stack (work stealing + warm
//!    migration) completes strictly more requests than the static
//!    no-adaptation baseline, which can only swallow the dead shard's
//!    arrivals.
//! 2. Under a ramped degradation, predictive admission sheds its first
//!    query strictly earlier than reactive deadline admission on the
//!    identical (same-seed) arrival stream — the forecast term lowers
//!    the effective threshold while backlog is growing.
//! 3. Per-task FIFO (drops excluded) survives crash, redirect, and
//!    recovery: in id order, starts and completions stay monotone even
//!    when consecutive queries ran on different shards.
//!
//! Every run is replayed through the `SL-INV-*` invariant verifier —
//! the fault lab may bend throughput, never the serving contract.
//! Runs entirely on the synthetic fixture zoo (no artifacts needed).

use std::collections::BTreeMap;

use sparseloom::analysis::invariants;
use sparseloom::coordinator::ServeOpts;
use sparseloom::fixtures;
use sparseloom::metrics::{RunReport, ShardedReport};
use sparseloom::scenario::{
    Admission, CrashWindow, Degradation, Dispatch, FaultProfile, PlannerConfig,
    RejoinMode, Scenario, Server, ShardedServer, Sharding,
};

/// The skewed two-shard split used across the online-path studies:
/// three tasks flood shard 0, gamma idles on shard 1.
fn skewed_sharding() -> Sharding {
    Sharding::explicit(
        BTreeMap::from([
            ("alpha".to_string(), 0),
            ("beta".to_string(), 0),
            ("delta".to_string(), 0),
            ("gamma".to_string(), 1),
        ]),
        2,
    )
}

fn verify(report: &ShardedReport) {
    let inv = invariants::verify_sharded(report);
    assert!(inv.is_empty(), "{}", inv.render_text());
}

/// Bursty quartet stream with a mid-run crash of the loaded shard.
fn crash_scenario(rejoin: RejoinMode) -> Scenario {
    let (zoo, _lm, _profiles) = fixtures::quartet();
    let tasks = fixtures::task_names(&zoo);
    let slo_map = fixtures::slos(&zoo, 0.5, 60.0);
    Scenario::bursty(&tasks, slo_map, 4.0, 100.0, 500.0, 4_000.0)
        .with_seed(11)
        .with_dispatch(Dispatch::batched(4))
        .with_sharding(skewed_sharding())
        .with_faults(FaultProfile {
            crashes: vec![CrashWindow {
                shard: 0,
                start_ms: 1_000.0,
                end_ms: 2_500.0,
                rejoin,
            }],
            ..FaultProfile::default()
        })
}

#[test]
fn steal_and_warm_migration_beat_no_adaptation_under_a_crash() {
    let (zoo, lm, profiles) = fixtures::quartet();
    let base = crash_scenario(RejoinMode::Warm);

    // No-adaptation baseline: the static path has nowhere to send the
    // dead shard's arrivals, so it swallows them.
    let static_report =
        ShardedServer::build(&zoo, &lm, &profiles, ServeOpts::default(), base.sharding.clone())
            .unwrap()
            .run(&base)
            .unwrap();
    verify(&static_report);
    assert!(
        static_report.aggregate.total_dropped > 0,
        "the crash must actually cost the no-adaptation baseline"
    );
    assert!(
        static_report.aggregate.downtime_ms > 0.0,
        "the crash window must be accounted as downtime"
    );

    // Adaptive arm: the crash redirect adopts the dead shard's tasks on
    // the survivor (warm when the pool contents can be carried over).
    let adaptive_sc = base
        .clone()
        .with_planner(PlannerConfig { max_migrations: 2, ..PlannerConfig::online() });
    let opts = ServeOpts { batch_hint: 4.0, ..Default::default() };
    let adaptive =
        ShardedServer::build(&zoo, &lm, &profiles, opts, adaptive_sc.sharding.clone())
            .unwrap()
            .run(&adaptive_sc)
            .unwrap();
    verify(&adaptive);
    assert!(adaptive.steals > 0, "the crash redirect must actually reroute work");
    assert!(
        adaptive.aggregate.total_queries > static_report.aggregate.total_queries,
        "steal + warm migration must complete strictly more than no adaptation: \
         {} vs {}",
        adaptive.aggregate.total_queries,
        static_report.aggregate.total_queries
    );
}

#[test]
fn predictive_admission_sheds_earlier_than_reactive_under_a_ramp() {
    // One task, steady Poisson arrivals, and a slow 2x degradation
    // ramp: service time crosses the inter-arrival gap mid-ramp and
    // backlog then grows by a few ms per query — smooth enough that the
    // forecast term moves the shed point by whole queries.
    let (zoo, lm, profiles) = fixtures::tiny();
    let tasks = fixtures::task_names(&zoo);
    let slo_map = fixtures::slos(&zoo, 0.5, 60.0);
    let base = Scenario::poisson(&tasks, slo_map, 40.0, 3_000.0)
        .with_seed(5)
        .with_faults(FaultProfile {
            degradations: vec![Degradation {
                shard: 0,
                start_ms: 0.0,
                ramp_ms: 1_000.0,
                factor: 2.0,
            }],
            ..FaultProfile::default()
        });
    let run = |sc: &Scenario| -> RunReport {
        let report = Server::builder(&zoo, &lm, &profiles).build().run(sc).unwrap();
        let inv = invariants::verify_report(&report);
        assert!(inv.is_empty(), "{}", inv.render_text());
        report
    };
    let reactive = run(&base.clone().with_admission(Admission::Deadline { slack: 2.0 }));
    let predictive = run(&base
        .clone()
        .with_admission(Admission::Predictive { horizon_ms: 100.0, headroom: 2.0 }));

    let first_drop = |r: &RunReport| -> f64 {
        r.requests
            .iter()
            .filter(|q| q.dropped)
            .map(|q| q.arrival_ms)
            .fold(f64::INFINITY, f64::min)
    };
    let t_reactive = first_drop(&reactive);
    let t_predictive = first_drop(&predictive);
    assert!(t_reactive.is_finite(), "the ramp must overload the reactive arm");
    assert!(t_predictive.is_finite(), "the ramp must overload the predictive arm");
    assert!(
        t_predictive < t_reactive,
        "predictive admission must shed before reactive on the same stream: \
         first drop at {t_predictive} ms vs {t_reactive} ms"
    );
}

#[test]
fn per_task_fifo_holds_across_crash_and_recovery() {
    // Cold rejoin: the recovering shard additionally rebuilds its pool,
    // the harshest ordering stress (redirects during the window, a
    // compile-penalty backlog after it).
    let (zoo, lm, profiles) = fixtures::quartet();
    let sc = crash_scenario(RejoinMode::Cold)
        .with_planner(PlannerConfig { max_migrations: 2, ..PlannerConfig::online() });
    let opts = ServeOpts { batch_hint: 4.0, ..Default::default() };
    let report = ShardedServer::build(&zoo, &lm, &profiles, opts, sc.sharding.clone())
        .unwrap()
        .run(&sc)
        .unwrap();
    verify(&report);
    assert!(
        !report.aggregate.recoveries.is_empty(),
        "the rejoined shard must record a recovery latency"
    );
    for task in ["alpha", "beta", "delta", "gamma"] {
        let mut reqs: Vec<_> = report
            .aggregate
            .requests
            .iter()
            .filter(|r| r.task == task && !r.dropped)
            .collect();
        reqs.sort_by_key(|r| r.id);
        for w in reqs.windows(2) {
            assert!(
                w[1].start_ms >= w[0].start_ms - 1e-9,
                "{task}: query {} started at {} ms, before query {}'s start at {} ms",
                w[1].id,
                w[1].start_ms,
                w[0].id,
                w[0].start_ms
            );
            assert!(
                w[1].finish_ms >= w[0].finish_ms - 1e-9,
                "{task}: query {} finished before query {}",
                w[1].id,
                w[0].id
            );
        }
    }
}
