//! SLO-strictness sweep: walk the C1–C8 ladder (Fig. 3's axis) on one
//! platform and watch how SparseLoom's selections shift from accurate/
//! slow compositions toward fast stitched mixes — and where the space
//! runs out (violations).
//!
//! ```text
//! cargo run --release --example slo_sweep [-- <platform>]
//! ```

use std::collections::BTreeMap;

use sparseloom::baselines::Policy;
use sparseloom::experiments::Ctx;
use sparseloom::metrics::render_table;
use sparseloom::profiler::ProfilerConfig;
use sparseloom::scenario::{Scenario, Server};
use sparseloom::soc::{order_label, Platform};
use sparseloom::workload::{slo_ladder, Slo, TaskRanges};

fn main() -> anyhow::Result<()> {
    let platform_name = std::env::args().nth(1).unwrap_or_else(|| "desktop".into());
    let platform = Platform::by_name(&platform_name)?;
    let ctx = Ctx::load("artifacts", false)?;
    let lm = ctx.lm(platform.clone());
    let zoo = ctx.zoo_for(&platform);
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
    let server = Server::builder(zoo, &lm, &profiles)
        .policy(Policy::SparseLoom)
        .build();

    let mut ladders: BTreeMap<String, Vec<Slo>> = BTreeMap::new();
    let mut universe = Vec::new();
    for (name, _) in &profiles {
        let l = slo_ladder(&TaskRanges::measure(zoo.task(name)?, &lm));
        universe.extend(l.iter().copied());
        ladders.insert(name.clone(), l);
    }
    let arrival: Vec<String> = profiles.keys().cloned().collect();

    println!("SLO ladder sweep on {} (C1 laxest → C8 strictest)\n", platform.name);
    let mut rows = Vec::new();
    for c in 0..8 {
        let slos: BTreeMap<String, Slo> =
            ladders.iter().map(|(n, l)| (n.clone(), l[c])).collect();
        let prepared = server.prepare(&slos, &universe)?;
        let scenario = Scenario::closed_loop(&arrival, slos.clone())
            .with_universe(universe.clone());
        let report = server.run(&scenario)?;

        let mut selections = Vec::new();
        let mut stitched = 0usize;
        for (name, sel) in &prepared.selections {
            match sel {
                Some(sel) => {
                    let p = &profiles[name];
                    let comp = p.space.composition(sel.stitched_index);
                    if !comp.is_pure() {
                        stitched += 1;
                    }
                    selections.push(comp.label(zoo.task(name)?));
                }
                None => selections.push("—".into()),
            }
        }
        rows.push(vec![
            format!("C{}", c + 1),
            order_label(&prepared.order),
            selections.join(" "),
            format!("{stitched}/4"),
            format!("{:.0}", 100.0 * report.violation_rate()),
            format!("{:.0}", report.throughput_qps()),
        ]);
    }
    println!("{}", render_table(
        &["cfg", "p*", "compositions (per task)", "stitched", "viol %", "q/s"],
        &rows,
    ));
    println!("legend: D=dense H=fp16 Q=int8 P=pruned; — = no feasible variant");
    Ok(())
}
