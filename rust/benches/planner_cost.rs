//! Planner cost-model microbench: the pruned `feasible_set` walk vs a
//! naive reference on the |Ω| × V^S hot loop, plus batch-aware
//! Algorithm 1 end-to-end. Artifact-free (synthetic fixture zoo), so it
//! always runs.
//!
//! Run: `cargo bench --bench planner_cost` (also via `make bench`)

use std::collections::BTreeMap;

use sparseloom::benchkit::Bench;
use sparseloom::fixtures;
use sparseloom::planner::provider::SynthesizingProvider;
use sparseloom::planner::{algo, CostModel, PressureSignal, VariantProvider, VariantQuery};
use sparseloom::profiler::TaskProfile;
use sparseloom::soc::Processor;
use sparseloom::workload::{placement_orders, Slo};

/// The pre-prune reference walk: full |Ω| latency scan per candidate.
fn naive_feasible_set(
    cost: &CostModel,
    p: &TaskProfile,
    slo: &Slo,
    orders: &[Vec<Processor>],
) -> usize {
    let mut n = 0usize;
    for k in 0..p.space.len() {
        if p.accuracy(k) < slo.min_accuracy {
            continue;
        }
        let comp = p.space.composition(k);
        let ok = orders.iter().any(|o| {
            cost.latency(p, &comp, o)
                .map(|l| l <= slo.max_latency_ms)
                .unwrap_or(false)
        });
        if ok {
            n += 1;
        }
    }
    n
}

fn main() {
    let (zoo, lm, profiles) = fixtures::trio();
    let orders = placement_orders(&lm.platform, zoo.subgraphs);
    let p = &profiles["beta"];
    let unit = CostModel::unit();
    let batched = CostModel::batch_aware(&lm, 4.0);
    // A tight-but-satisfiable bound: the regime where the order-level
    // and partial-sum prunes actually cut work.
    let tight = Slo { min_accuracy: 0.6, max_latency_ms: 9.0 };
    let lax = Slo { min_accuracy: 0.0, max_latency_ms: 1e9 };
    let slos: BTreeMap<String, Slo> = profiles
        .keys()
        .map(|n| (n.clone(), Slo { min_accuracy: 0.5, max_latency_ms: 30.0 }))
        .collect();

    println!("\n== planner cost (synthetic trio fixture) ==\n");
    Bench::header();
    let mut b = Bench::new();

    b.case("feasible_set naive, tight SLO", || {
        naive_feasible_set(&unit, p, &tight, &orders)
    });
    b.case("feasible_set pruned, tight SLO", || {
        algo::feasible_set(&unit, p, &tight, &orders).len()
    });
    b.case("feasible_set naive, lax SLO", || {
        naive_feasible_set(&unit, p, &lax, &orders)
    });
    b.case("feasible_set pruned, lax SLO", || {
        algo::feasible_set(&unit, p, &lax, &orders).len()
    });
    b.case("feasible_set pruned, batch-aware", || {
        algo::feasible_set(&batched, p, &tight, &orders).len()
    });
    b.case("optimize batch-1, 3 tasks", || {
        algo::optimize(&unit, &profiles, &slos, &orders).mean_latency_ms
    });
    b.case("optimize batch-aware, 3 tasks", || {
        algo::optimize(&batched, &profiles, &slos, &orders).mean_latency_ms
    });

    // Synthesis-scored candidates: the best-first stitch-space search
    // the online `--synthesize` action runs under pressure, cold
    // (cache cleared every iteration) and warm (pure cache hit).
    let provider = SynthesizingProvider::new(&zoo, &lm, &profiles, orders.clone());
    let query = VariantQuery {
        task: "beta".to_string(),
        slo: Slo { min_accuracy: 0.6, max_latency_ms: 30.0 },
        feasible_orders: Vec::new(),
        commit_order: None,
        batch: 4.0,
        pool_share: u64::MAX,
        phase: 0,
        pressure: Some(PressureSignal {
            forecast_ms: 50.0,
            threshold_ms: 10.0,
            pool_utilization: 0.5,
        }),
    };
    b.case("synthesize cold (search)", || {
        provider.invalidate();
        provider.provide(&query).map(|d| d.stats.evaluated).unwrap_or(0)
    });
    provider.invalidate();
    let cold = provider.provide(&query).expect("feasible under a lax share");
    assert!(cold.stats.evaluated > 0, "search must score candidates");
    b.case("synthesize warm (cache hit)", || {
        provider.provide(&query).map(|d| d.stats.cache_hit as usize).unwrap_or(0)
    });

    // Sanity: the prune must not change the result.
    for (cost, name) in [(&unit, "unit"), (&batched, "batched")] {
        for slo in [tight, lax] {
            let pruned = algo::feasible_set(cost, p, &slo, &orders).len();
            let naive = naive_feasible_set(cost, p, &slo, &orders);
            assert_eq!(pruned, naive, "prune changed the result ({name})");
        }
    }
    println!("\nprune equivalence OK");
}
