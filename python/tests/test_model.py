"""L2 correctness: model structure, pallas/jnp path agreement, stitching.

The central invariants:

1. The pallas-kernel forward equals the pure-jnp forward (per subgraph
   and end-to-end) for every kernel path — this is what licenses training
   and oracle evaluation on the jnp path while exporting the pallas path.
2. Chained subgraph execution equals the monolithic forward — the
   property that makes runtime stitching (executing sg HLOs back-to-back)
   semantically identical to running one whole model.
3. Subgraph interfaces are variant-invariant (layer-aligned), the
   paper's operational-scope requirement for stitching.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import compress, model as M

RTOL, ATOL = 2e-4, 2e-4


def _probe(task, batch=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((batch, M.TASKS[task].input_dim)).astype(np.float32)
    )


@pytest.fixture(scope="module")
def base_params():
    return {task: M.init_params(task) for task in M.TASK_NAMES}


@pytest.mark.parametrize("task", M.TASK_NAMES)
def test_forward_shapes(task, base_params):
    x = _probe(task, batch=3)
    y = M.forward(task, x, base_params[task])
    assert y.shape == (3, M.N_CLASSES)


@pytest.mark.parametrize("task", M.TASK_NAMES)
def test_subgraph_interfaces_match_spec(task, base_params):
    """Boundary activation widths equal TaskSpec.iface for every variant."""
    spec = M.TASKS[task]
    for vs in (compress.intel_zoo()[0], compress.intel_zoo()[9]):
        params = compress.compress_model(base_params[task], vs)
        x = _probe(task)
        for j in range(M.SUBGRAPHS):
            assert x.shape[1] == spec.iface[j]
            x = M.forward_subgraph(task, j, x, params[j], path=vs.kernel_path)
        assert x.shape[1] == spec.iface[M.SUBGRAPHS]


@pytest.mark.parametrize("task", M.TASK_NAMES)
@pytest.mark.parametrize("vidx", [0, 1, 4, 8])
def test_pallas_path_matches_jnp_path(task, vidx, base_params):
    """Invariant 1: kernel forward == oracle forward, all kernel paths."""
    vs = compress.intel_zoo()[vidx]
    params = compress.compress_model(base_params[task], vs)
    x = _probe(task)
    jnp_out = M.forward(task, x, params, path=vs.kernel_path, use_kernel=False)
    pal_out = M.forward(task, x, params, path=vs.kernel_path, use_kernel=True)
    np.testing.assert_allclose(
        np.asarray(pal_out), np.asarray(jnp_out), RTOL, ATOL
    )


@pytest.mark.parametrize("task", M.TASK_NAMES)
def test_chained_subgraphs_equal_monolithic(task, base_params):
    """Invariant 2: the runtime's chained execution model is exact."""
    params = base_params[task]
    x = _probe(task, batch=4)
    mono = M.forward(task, x, params)
    h = x
    for j in range(M.SUBGRAPHS):
        h = M.forward_subgraph(task, j, h, params[j])
    np.testing.assert_allclose(np.asarray(h), np.asarray(mono), RTOL, ATOL)


@pytest.mark.parametrize("task", M.TASK_NAMES)
def test_stitched_chain_runs_and_differs(task, base_params):
    """A mixed-variant chain runs shape-safe and is a genuinely new fn."""
    zoo = compress.intel_zoo()
    v = [compress.compress_model(base_params[task], zoo[i]) for i in (0, 4, 9)]
    paths = [zoo[i].kernel_path for i in (0, 4, 9)]
    x = _probe(task, batch=4)
    h = x
    for j, (params, path) in enumerate(zip(v, paths)):
        h = M.forward_subgraph(task, j, h, params[j], path=path)
    assert h.shape == (4, M.N_CLASSES)
    dense = M.forward(task, x, v[0][0:3], path="dense")
    # The stitched output is not identical to pure-dense (it mixes
    # pruned/quantized subgraphs) but stays finite and class-shaped.
    assert np.isfinite(np.asarray(h)).all()
    assert not np.allclose(np.asarray(h), np.asarray(dense))


@pytest.mark.parametrize("task", M.TASK_NAMES)
def test_flatten_unflatten_roundtrip(task, base_params):
    params = base_params[task]
    for j in range(M.SUBGRAPHS):
        flat = M.flatten_params(params[j])
        rebuilt = M.unflatten_like(params[j], flat)
        flat2 = M.flatten_params(rebuilt)
        assert len(flat) == len(flat2)
        for a, b in zip(flat, flat2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flatten_order_is_deterministic(base_params):
    a = [t.shape for t in M.flatten_params(base_params["imgcls"][0])]
    b = [t.shape for t in M.flatten_params(M.init_params("imgcls")[0])]
    assert a == b
