//! Benchmark harness (offline substrate for `criterion`).
//!
//! `cargo bench` targets are plain `main` functions (harness = false);
//! this module supplies warmup, adaptive iteration counts, and robust
//! statistics (median / p95 / MAD) plus aligned report printing.

use std::time::Instant;

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

impl Measurement {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// A benchmark group with shared config.
pub struct Bench {
    /// Target wall time per case (controls iteration count).
    pub target_ms: f64,
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            target_ms: 300.0,
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Self { target_ms: 80.0, warmup_iters: 1, min_iters: 5, ..Default::default() }
    }

    /// Time `f`, printing and recording the measurement. The closure's
    /// return value is black-boxed to keep the optimizer honest.
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        // Pilot run to size the iteration count.
        let t0 = Instant::now();
        black_box(f());
        let pilot_ns = t0.elapsed().as_nanos().max(1) as f64;
        let iters = ((self.target_ms * 1e6 / pilot_ns) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            iters,
            median_ns: stats::median(&samples),
            mean_ns: stats::mean(&samples),
            p95_ns: stats::percentile(&samples, 95.0),
        };
        println!("{}", m.line());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    pub fn header() {
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            "benchmark", "median", "mean", "p95"
        );
        println!("{}", "-".repeat(86));
    }
}

/// Best-of-`iters` wall-clock timing for throughput sweeps: runs `f`
/// `iters.max(1)` times and returns (best wall milliseconds, last
/// result). Complements [`Bench::case`] where the caller needs the
/// closure's output and a fixed, deterministic repetition count —
/// `sparseloom bench` times whole fleet runs through this.
pub fn time_best_of<T>(iters: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    let mut best_ms = f64::INFINITY;
    let mut out = None;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        let r = black_box(f());
        best_ms = best_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        out = Some(r);
    }
    (best_ms, out.expect("iters >= 1"))
}

/// Optimizer barrier (stable-Rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench { target_ms: 5.0, warmup_iters: 1, min_iters: 5, max_iters: 50, results: vec![] };
        let m = b.case("spin", || (0..1000).sum::<u64>());
        assert!(m.median_ns > 0.0);
        assert!(m.iters >= 5);
    }

    #[test]
    fn best_of_returns_last_result_and_finite_wall() {
        let mut n = 0;
        let (ms, last) = time_best_of(3, || {
            n += 1;
            n
        });
        assert_eq!(last, 3);
        assert!(ms.is_finite() && ms >= 0.0);
    }

    #[test]
    fn formatting_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2_500.0), "2.500 µs");
        assert_eq!(fmt_ns(3_000_000.0), "3.000 ms");
        assert_eq!(fmt_ns(1.5e9), "1.500 s");
    }
}
