//! The dispatch subsystem: adaptive batching under backlog and
//! multi-server sharding.
//!
//! `scenario::Server` places one query at a time on one simulated SoC,
//! which is exactly right for the paper's closed-loop protocol and
//! degrades exactly where it shouldn't under bursty open-loop traffic:
//! backlog piles up per task while every stage still pays full
//! single-query occupancy, and every task contends for one set of
//! processors. This module adds the two scale mechanisms ROADMAP names:
//!
//! * **Adaptive batching** — a [`Dispatcher`] sits between the arrival
//!   stream and [`Session::submit`]. When a task's queue exceeds
//!   [`Dispatch::min_queue`], it coalesces up to [`Dispatch::max_batch`]
//!   consecutive same-task queries into one
//!   [`Session::submit_batch`] call: one placement decision, one booking
//!   per stage at the batch-aware occupancy
//!   (`LatencyModel::batch_factor`), which drains backlog strictly
//!   faster than dispatching queries alone. Batches are FIFO prefixes of
//!   the task queue, so requests are never reordered within a task.
//! * **Sharding** — a [`ShardedServer`] partitions the task set across N
//!   independent [`Server`]s ([`Sharding`]: hash or explicit map), each
//!   with its own planning cache, memory pool, and simulated SoC.
//!   Arrival streams are generated once per scenario (identical per-task
//!   arrivals to the unsharded run) and routed per query; the result is
//!   one `RunReport` per shard plus a cross-shard aggregate
//!   ([`crate::metrics::ShardedReport`]).
//!
//! Cross-task *admission fairness* rides along in
//! [`Admission::Fair`](super::Admission::Fair), judged per shard inside
//! the session.
//!
//! On top of the static paths sits the **online drive**
//! (`PlannerConfig::{replan, steal, warm_migrate}`): all shards run
//! through one interleaved simulated-time loop whose every
//! [`crate::metrics::RequestOutcome`] feeds a
//! [`crate::telemetry::Telemetry`] handle. Telemetry's backlog and
//! arrival-rate estimates drive whole-task migration
//! (`Planner::replan`), query-granularity work stealing (an
//! underloaded shard serves a saturated shard's waiting batches), and
//! warm migration (a migrant's pool contents travel with it — a
//! cross-shard load instead of a cold compile+load). See DESIGN.md
//! §Telemetry for the protocols and the FIFO-preservation argument.
//!
//! ```
//! use sparseloom::coordinator::ServeOpts;
//! use sparseloom::fixtures;
//! use sparseloom::scenario::{Dispatch, Scenario, ShardedServer, Sharding};
//!
//! let (zoo, lm, profiles) = fixtures::trio();
//! let scenario = Scenario::bursty(&fixtures::task_names(&zoo),
//!                                 fixtures::slos(&zoo, 0.5, 1e9),
//!                                 5.0, 60.0, 500.0, 2_000.0)
//!     .with_seed(7)
//!     .with_dispatch(Dispatch::batched(4))
//!     .with_sharding(Sharding::hash(2));
//!
//! let sharded = ShardedServer::build(&zoo, &lm, &profiles,
//!                                    ServeOpts::default(),
//!                                    scenario.sharding.clone()).unwrap();
//! let report = sharded.run(&scenario).unwrap();
//! assert_eq!(report.per_shard.len(), 2);
//! // Every arrival is accounted for: completed + dropped = events.
//! assert_eq!(report.aggregate.total_queries + report.aggregate.total_dropped,
//!            report.aggregate.requests.len());
//! ```

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use anyhow::{bail, Result};

use crate::coordinator::ServeOpts;
use crate::metrics::{RequestOutcome, RunReport, ShardedReport};
use crate::planner::{
    Planner, PressureSignal, ShardObservation, ShardPlan, SparsityAwarePlanner,
};
use crate::profiler::TaskProfile;
use crate::soc::{LatencyModel, Processor};
use crate::telemetry::Telemetry;
use crate::trace::{self, TraceEvent};
use crate::workload::{shard_of_task, Query, Slo};
use crate::zoo::Zoo;

use super::faults::FaultProfile;
use super::server::{Server, Session};
use super::{Arrival, Scenario};

/// Commit margin for an online synthesis switch: the candidate's
/// estimated latency must undercut the incumbent's by at least this
/// factor (hysteresis against estimate noise and switch thrash).
const SYNTH_MARGIN: f64 = 0.95;

/// Pool-utilization fraction above which a shard counts as
/// budget-pressured for the synthesis trigger even without a backlog
/// crossing.
const SYNTH_POOL_PRESSURE: f64 = 0.95;

/// Adaptive-batching configuration: when and how hard to coalesce.
///
/// The default is the *identity* dispatch (`max_batch = 1`): every query
/// is placed alone and serving behaves exactly as if this module did not
/// exist. Batching only changes anything for open-loop scenarios —
/// closed loops are self-clocking and never build backlog.
#[derive(Clone, Debug, PartialEq)]
pub struct Dispatch {
    /// Largest number of same-task queries coalesced into one placement
    /// decision. `1` disables batching.
    pub max_batch: usize,
    /// Backlog threshold: coalescing starts only once at least this
    /// many queries of one task are already waiting at dispatch time.
    /// Below the threshold queries dispatch alone, keeping per-query
    /// latency untouched when the system is keeping up.
    pub min_queue: usize,
}

impl Default for Dispatch {
    fn default() -> Self {
        Self { max_batch: 1, min_queue: 2 }
    }
}

impl Dispatch {
    /// The identity dispatch: no batching (same as `Default`).
    pub fn none() -> Self {
        Self::default()
    }

    /// Batch up to `max_batch` queries with the default backlog
    /// threshold.
    pub fn batched(max_batch: usize) -> Self {
        Self { max_batch: max_batch.max(1), ..Self::default() }
    }

    /// Whether this configuration can ever coalesce.
    pub fn is_batching(&self) -> bool {
        self.max_batch > 1
    }

    /// How many of `waiting` already-arrived same-task queries one
    /// dispatch decision takes: the FIFO prefix up to `max_batch` once
    /// at least `min_queue` wait; 1 when `batching` is off or the
    /// threshold is not met. The single coalescing rule shared by
    /// [`Dispatcher::drive`] and the online drive — change it here and
    /// both paths stay comparable.
    ///
    /// The result is always ≥ 1, deterministically: `take(0, _)` is 1
    /// (the head query always qualifies — it is the reason dispatch is
    /// happening), and a degenerate hand-built `max_batch = 0` behaves
    /// like `max_batch = 1` rather than dispatching nothing (the
    /// constructors already clamp, but a struct literal can bypass
    /// them). Pinned by `take_edge_cases_are_deterministic`.
    pub fn take(&self, waiting: usize, batching: bool) -> usize {
        if batching && waiting >= self.min_queue.max(1) {
            waiting.min(self.max_batch.max(1))
        } else {
            1
        }
    }
}

/// How tasks map to shards.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardAssignment {
    /// FNV-1a hash of the task name modulo the shard count
    /// ([`crate::workload::shard_of_task`]) — deterministic across runs
    /// and processes.
    Hash,
    /// Explicit task → shard map; tasks absent from the map fall back
    /// to the hash rule. Raw [`Sharding::shard_of`] wraps out-of-range
    /// indices modulo the shard count, but a *built* deployment
    /// ([`ShardedServer::build`]) rejects maps that name unknown tasks
    /// or out-of-range shards (`SL-SCN-008`/`SL-SCN-009`) instead of
    /// silently rerouting them.
    Explicit(BTreeMap<String, usize>),
}

/// Multi-server sharding configuration: how many servers, and which
/// tasks each one owns.
#[derive(Clone, Debug, PartialEq)]
pub struct Sharding {
    /// Number of independent servers. `1` (the default) means no
    /// sharding.
    pub shards: usize,
    /// Task → shard rule.
    pub assignment: ShardAssignment,
}

impl Default for Sharding {
    fn default() -> Self {
        Self { shards: 1, assignment: ShardAssignment::Hash }
    }
}

impl Sharding {
    /// Hash-partition tasks across `shards` servers.
    pub fn hash(shards: usize) -> Self {
        Self { shards: shards.max(1), assignment: ShardAssignment::Hash }
    }

    /// Explicitly map tasks to `shards` servers (unlisted tasks hash).
    pub fn explicit(map: BTreeMap<String, usize>, shards: usize) -> Self {
        Self { shards: shards.max(1), assignment: ShardAssignment::Explicit(map) }
    }

    /// Which shard serves `task`.
    pub fn shard_of(&self, task: &str) -> usize {
        let n = self.shards.max(1);
        match &self.assignment {
            ShardAssignment::Hash => shard_of_task(task, n),
            ShardAssignment::Explicit(map) => match map.get(task) {
                Some(&shard) => shard % n,
                None => shard_of_task(task, n),
            },
        }
    }
}

/// Replays an arrival stream into a [`Session`], coalescing same-task
/// FIFO runs into batches when backlog builds.
///
/// At every step the dispatcher issues for the task whose next query
/// would start earliest (exactly like [`Session::drive`]); if at least
/// [`Dispatch::min_queue`] queries of that task are already waiting at
/// that instant, the waiting FIFO prefix — never more than
/// [`Dispatch::max_batch`] — is submitted as one batch. Queries that
/// have not yet arrived at issue time are never pulled into a batch, so
/// batching cannot reorder a task's queries or violate causality.
pub struct Dispatcher {
    cfg: Dispatch,
}

impl Dispatcher {
    /// A dispatcher for one batching configuration.
    pub fn new(cfg: Dispatch) -> Self {
        Self { cfg }
    }

    /// The batching configuration this dispatcher applies.
    pub fn config(&self) -> &Dispatch {
        &self.cfg
    }

    /// Drive a whole stream through `session` in simulated-time order —
    /// the one replay loop behind both [`Session::drive`] (which
    /// delegates here with the identity dispatch) and batched serving.
    ///
    /// With the identity dispatch — or a self-clocking (closed-loop)
    /// session, which cannot build backlog — every query dispatches
    /// alone.
    pub fn drive(&self, session: &mut Session, queries: &[Query]) -> Result<()> {
        let batching = self.cfg.is_batching() && !session.is_self_clocked();
        let order: Vec<String> = session.task_order().to_vec();
        let mut pending: BTreeMap<&str, VecDeque<&Query>> = BTreeMap::new();
        for q in queries {
            if session.ready_of(&q.task).is_none() {
                bail!(
                    "query {} targets task {:?} not in this scenario",
                    q.id,
                    q.task
                );
            }
            pending.entry(q.task.as_str()).or_default().push_back(q);
        }
        loop {
            // Earliest-issue task first (arrival vs per-task FIFO ready).
            let mut next: Option<(&str, f64)> = None;
            for name in &order {
                let Some(queue) = pending.get(name.as_str()) else { continue };
                let Some(q) = queue.front() else { continue };
                let ready = session.ready_of(name).unwrap_or(0.0);
                let issue = q.arrival_ms.max(ready);
                if next.map(|(_, t)| issue < t).unwrap_or(true) {
                    next = Some((name.as_str(), issue));
                }
            }
            let Some((task, issue)) = next else { break };
            let queue = pending.get_mut(task).unwrap();
            // The FIFO prefix already waiting at issue time; the head
            // always qualifies (issue ≥ its arrival by construction).
            let waiting = queue.iter().take_while(|q| q.arrival_ms <= issue).count();
            let take = self.cfg.take(waiting, batching);
            let batch: Vec<&Query> =
                (0..take).map(|_| queue.pop_front().unwrap()).collect();
            session.submit_batch(&batch)?;
        }
        Ok(())
    }
}

/// N independent [`Server`]s — each with its own planning cache, memory
/// pool, and simulated SoC — serving a partition of the task set.
///
/// Sharding models scaling *out*: shards run in parallel on separate
/// (simulated) hardware, so the aggregate report takes the maximum
/// makespan across shards while summing query counts. Per-task arrival
/// streams are identical to the unsharded run (streams are generated
/// from the scenario, then routed), which makes single-server and
/// sharded runs directly comparable.
///
/// The sharded path is simulation-only: attach a PJRT runtime to a plain
/// [`Server`] instead when real execution is needed.
pub struct ShardedServer<'a> {
    shards: Vec<Server<'a>>,
    sharding: Sharding,
}

impl<'a> ShardedServer<'a> {
    /// Build `sharding.shards` servers over the shared zoo, latency
    /// model, and profiles, all with the same serving options.
    ///
    /// Fail-fast sparselint gate: an explicit assignment naming a task
    /// with no profile (`SL-SCN-008`) or a shard index outside the
    /// shard count (`SL-SCN-009`) is rejected with coded diagnostics —
    /// such a map would silently hash- or wrap-route the task somewhere
    /// the operator did not ask for.
    pub fn build(
        zoo: &'a Zoo,
        lm: &'a LatencyModel,
        profiles: &'a BTreeMap<String, TaskProfile>,
        opts: ServeOpts,
        sharding: Sharding,
    ) -> Result<ShardedServer<'a>> {
        crate::analysis::scenario::build_gate(&sharding, profiles, &FaultProfile::default())
            .fail_on_errors("sharding")?;
        let n = sharding.shards.max(1);
        let shards = (0..n)
            .map(|_| Server::builder(zoo, lm, profiles).opts(opts.clone()).build())
            .collect();
        Ok(ShardedServer { shards, sharding: Sharding { shards: n, ..sharding } })
    }

    /// Number of shards (≥ 1).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard serves `task` under this server's assignment.
    pub fn shard_of(&self, task: &str) -> usize {
        self.sharding.shard_of(task)
    }

    /// The shard servers themselves (e.g. to inspect per-shard plans).
    pub fn servers(&self) -> &[Server<'a>] {
        &self.shards
    }

    /// Run a whole scenario across the shards: generate each phase's
    /// stream once, route queries to their task's shard — routing
    /// follows this server's **build-time** [`Sharding`], so build from
    /// `scenario.sharding` (as the CLI does) when the scenario declares
    /// one — and drive every shard's session through the scenario's
    /// [`Dispatch`] config. Each
    /// shard plans against the scenario restricted to its own partition
    /// (task list *and* SLO schedule filtered; an explicit `universe` is
    /// kept as-is, an empty one derives per shard), so a shard's
    /// budgeted selections cover only tasks it actually serves.
    ///
    /// Multi-phase schedules are merged per shard with the same
    /// summation [`Server::run`] applies, but each phase plans against a
    /// freshly budgeted pool — the persistent cross-phase pool of
    /// `Server::run_schedule` (§3.4 switch-cost dynamics) is not modeled
    /// on the sharded path.
    pub fn run(&self, scenario: &Scenario) -> Result<ShardedReport> {
        // Fail-fast sparselint gate on the fault overlay: a profile
        // naming shards this deployment does not have, or a malformed
        // link matrix, would otherwise silently never fire (or
        // mis-price transfers).
        if !scenario.faults.is_default() {
            crate::analysis::scenario::build_gate(
                &self.sharding,
                self.shards[0].coordinator().profiles,
                &scenario.faults,
            )
            .fail_on_errors("fault profile")?;
        }
        // The online path (scenario.planner.replan / .steal /
        // .synthesize) drives all shards through one interleaved loop so
        // telemetry can observe cross-shard backlog and migrate tasks —
        // or steal individual batches, or synthesize cheaper stitched
        // variants — mid-phase. Replan and steal are cross-shard moves
        // and need at least two shards; synthesis is a per-shard action
        // and routes online even on a single shard. Closed loops are
        // self-clocking (no backlog) and never saturate.
        let online = ((scenario.planner.replan || scenario.planner.steal)
            && self.shards.len() > 1)
            || scenario.planner.synthesize;
        if online && !matches!(scenario.arrival, Arrival::ClosedLoop { .. }) {
            return self.run_online(scenario);
        }
        let n = self.shards.len();
        let mut shard_tasks: Vec<Vec<String>> = vec![Vec::new(); n];
        for task in &scenario.tasks {
            shard_tasks[self.shard_of(task)].push(task.clone());
        }
        let dispatcher = Dispatcher::new(scenario.dispatch.clone());
        // The static partition makes shards fully independent — each
        // has its own plan cache, pool, and pre-routed slice of the
        // stream — so driving them on OS threads (`ServeOpts::parallel`)
        // is bit-identical to the sequential loop by construction.
        let threaded = self.shards[0].opts().parallel && n > 1;
        let mut per_shard: Vec<RunReport> = vec![RunReport::default(); n];
        let mut budget_utilization = vec![0.0f64; n];
        for phase in 0..scenario.phases() {
            let mut parts: Vec<Vec<Query>> = vec![Vec::new(); n];
            for q in scenario.stream(phase) {
                let shard = self.shard_of(&q.task);
                parts[shard].push(q);
            }
            let run_shard = |i: usize, server: &Server<'a>| -> Result<(f64, RunReport)> {
                let sub = sub_scenario(scenario, &shard_tasks[i], i);
                let mut session = server.session(&sub, phase)?;
                session.set_trace_shard(i);
                dispatcher.drive(&mut session, &parts[i])?;
                Ok((session.pool_utilization(), session.finish()))
            };
            // One slot per shard, filled in shard order either way.
            let slots: Vec<Option<Result<(f64, RunReport)>>> = if threaded {
                std::thread::scope(|scope| {
                    let handles: Vec<_> = self
                        .shards
                        .iter()
                        .enumerate()
                        .map(|(i, server)| {
                            if shard_tasks[i].is_empty() {
                                return None;
                            }
                            let run_shard = &run_shard;
                            Some(scope.spawn(move || run_shard(i, server)))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.map(|h| h.join().expect("shard thread panicked")))
                        .collect()
                })
            } else {
                self.shards
                    .iter()
                    .enumerate()
                    .map(|(i, server)| {
                        if shard_tasks[i].is_empty() {
                            None
                        } else {
                            Some(run_shard(i, server))
                        }
                    })
                    .collect()
            };
            // Deterministic merge: shard-index order, first error wins
            // (the same shard whose error the sequential loop reports).
            for (i, slot) in slots.into_iter().enumerate() {
                if let Some(res) = slot {
                    let (util, report) = res?;
                    budget_utilization[i] = util;
                    // Phases of one shard are sequential, like Server::run.
                    per_shard[i].merge_sequential(report);
                }
            }
        }
        let mut aggregate = RunReport::default();
        for report in &per_shard {
            // Shards are parallel SoCs: wall-clock is the slowest shard.
            aggregate.merge_parallel(report.clone());
        }
        Ok(ShardedReport {
            per_shard,
            aggregate,
            replans: 0,
            migrations: 0,
            steals: 0,
            synths: 0,
            budget_utilization,
            arrival_est_qps: BTreeMap::new(),
            link_cost_ms: 0.0,
            // The static path has no control plane: every trace event
            // is a request-lifecycle event inside some shard's report.
            control_trace: Vec::new(),
        })
    }

    /// The online drive — re-planning and/or work stealing, driven by
    /// [`Telemetry`]: every shard gets a session (empty shards included
    /// — they are migration targets), queries are issued in global
    /// simulated-time order, and every [`crate::metrics::RequestOutcome`]
    /// feeds the per-task arrival estimators and per-shard load
    /// accounting.
    ///
    /// **Stealing** (`PlannerConfig::steal`): before a batch is issued,
    /// if its home shard's backlog exceeds the saturation threshold
    /// (`saturation_slack ×` the mean SLO latency bound of its tasks)
    /// and another shard sits under *half* the home backlog, the batch
    /// is served there instead — query-granularity load balancing.
    /// Warm thieves (already serving the task, or holding a complete
    /// variant in pool) win; a cold thief may bootstrap-adopt only
    /// while the task is still single-homed, bounding cold adoptions
    /// to one per task per phase. Per-task FIFO survives because every
    /// shard serving a task shares one ready floor, re-synced to the
    /// latest completion after every batch.
    ///
    /// **Re-planning** (`PlannerConfig::replan`): after each booking
    /// the home shard's backlog is checked against the same threshold;
    /// on saturation `Planner::replan` proposes one bounded migration —
    /// the hottest still-queued task (Eq. 7 mass × telemetry arrival
    /// rate) moves to the least-loaded shard, its variant re-selected
    /// batch-aware under its traffic-weighted share of the target pool
    /// budget, its first query floored at the source's last completion.
    ///
    /// **Warm migration** (`PlannerConfig::warm_migrate`): both
    /// adoption paths carry the migrant's resident pool entries to the
    /// target — charged against the target's budget, evicting cold
    /// entries if needed — so the move pays a cross-shard load instead
    /// of a cold compile+load. A replanned migrant's entries *move*
    /// (the source's budget frees up); a stolen task's entries *copy*
    /// (the home keeps serving it too).
    ///
    /// **Predictive triggers** (`PlannerConfig::predictive`): both
    /// saturation checks judge `max(observed, forecast)` backlog —
    /// the telemetry Holt projection `PlannerConfig::horizon_ms`
    /// ahead — so stealing and migration start while a burst is still
    /// building, and `ShardObservation::arrival_qps` carries projected
    /// rather than trailing rates. The observed crossing is the
    /// degenerate horizon-0 forecast, so predictive mode never reacts
    /// *later* than reactive mode.
    fn run_online(&self, scenario: &Scenario) -> Result<ShardedReport> {
        // `PlannerConfig::epoch_ms > 0` selects the epoch-barrier
        // protocol: shard threads each drive one virtual-time window,
        // and all adaptation (steal, crash redirect, replan) happens at
        // the lockstep barriers between windows. `0` (the default)
        // keeps this classic per-batch sequential drive.
        if scenario.planner.epoch_ms > 0.0 {
            return self.run_online_epoch(scenario);
        }
        let n = self.shards.len();
        let coord = self.shards[0].coordinator();
        let cfg = &scenario.planner;
        let planner = {
            let p = SparsityAwarePlanner::new(coord.zoo, coord.lm, coord.profiles);
            if cfg.synthesize { p.with_synthesis() } else { p }
        };
        let universe = scenario.slo_universe();
        let mut telemetry = Telemetry::new(n);
        let mut assignment: BTreeMap<String, usize> = scenario
            .tasks
            .iter()
            .map(|t| (t.clone(), self.shard_of(t)))
            .collect();
        let mut per_shard: Vec<RunReport> = vec![RunReport::default(); n];
        let mut budget_utilization = vec![0.0f64; n];
        let mut replans = 0usize;
        let mut migrations = 0usize;
        let mut synths = 0usize;
        // Fault lab: total virtual ms adoptions paid to cross-shard
        // link transfers under `scenario.faults.links`.
        let mut link_cost_ms = 0.0f64;
        // Control-plane audit events: emitted only from this
        // coordinator-sequential loop, so their order is deterministic
        // by construction.
        let tracing = self.shards[0].opts().trace;
        let mut control: Vec<TraceEvent> = Vec::new();
        for phase in 0..scenario.phases() {
            let slos = &scenario.schedule[phase];
            // Phase shift: cached synthesis decisions were priced under
            // the previous phase's SLOs and pool state.
            planner.provider().invalidate();
            let mut sessions = Vec::with_capacity(n);
            for (i, server) in self.shards.iter().enumerate() {
                let tasks_i: Vec<String> = scenario
                    .tasks
                    .iter()
                    .filter(|t| assignment[*t] == i)
                    .cloned()
                    .collect();
                let mut session =
                    server.session(&sub_scenario(scenario, &tasks_i, i), phase)?;
                session.set_trace_shard(i);
                sessions.push(session);
            }
            // Committed placement orders + pool capacities per shard:
            // the planner re-selects a migrant against the target's.
            let shard_orders: Vec<Vec<Processor>> = sessions
                .iter()
                .map(|s| s.planned_order().to_vec())
                .collect();
            let shard_pool_bytes: Vec<u64> =
                sessions.iter().map(|s| s.pool_capacity()).collect();
            let mut pending: BTreeMap<String, VecDeque<Query>> = BTreeMap::new();
            for q in scenario.stream(phase) {
                if !assignment.contains_key(&q.task) {
                    bail!(
                        "query {} targets task {:?} not in this scenario",
                        q.id,
                        q.task
                    );
                }
                pending.entry(q.task.clone()).or_default().push_back(q);
            }
            // Which shards hold serving state for each task this phase
            // (the home first; steal/migration adopters appended). All
            // of them share one FIFO ready floor, re-synced after every
            // batch of the task completes anywhere.
            let mut serving: BTreeMap<String, Vec<usize>> = assignment
                .iter()
                .map(|(t, &s)| (t.clone(), vec![s]))
                .collect();
            let batching = scenario.dispatch.is_batching();
            let mut budget_left = cfg.max_migrations;
            // Saturation thresholds depend only on the assignment (and
            // this phase's SLOs): cached here, recomputed on migration.
            let mut thresholds: Vec<Option<f64>> = (0..n)
                .map(|i| saturation_threshold(cfg.saturation_slack, slos, &assignment, i))
                .collect();
            loop {
                // Globally earliest-issue task first, across all shards.
                let mut next: Option<(&String, f64)> = None;
                for task in &scenario.tasks {
                    let Some(queue) = pending.get(task) else { continue };
                    let Some(q) = queue.front() else { continue };
                    let ready = sessions[assignment[task]]
                        .ready_of(task)
                        .unwrap_or(0.0);
                    let issue = q.arrival_ms.max(ready);
                    if next.map(|(_, t)| issue < t).unwrap_or(true) {
                        next = Some((task, issue));
                    }
                }
                let Some((task, issue)) = next else { break };
                let task = task.clone();
                let home = assignment[&task];

                // --- telemetry-driven query-level work stealing -------
                // The home shard's backlog is a cheap scalar scan; the
                // full per-shard vector (thief selection) is only built
                // once the home is actually saturated.
                let mut serve_on = home;
                if cfg.steal {
                    let home_backlog =
                        backlog_of_shard(&sessions, &pending, &assignment, home);
                    telemetry.observe_backlog(home, home_backlog, issue);
                    // Predictive mode judges saturation on the
                    // Holt-projected backlog, floored at the observed
                    // one (crossing now is the degenerate horizon-0
                    // forecast — predictive never reacts later).
                    let effective_backlog = if cfg.predictive {
                        home_backlog.max(telemetry.forecast_shard_backlog_ms(
                            home,
                            issue,
                            cfg.horizon_ms,
                        ))
                    } else {
                        home_backlog
                    };
                    let saturated = thresholds[home]
                        .map(|thr| effective_backlog > thr)
                        .unwrap_or(false);
                    if saturated {
                        let backlog =
                            backlog_per_shard(&sessions, &pending, &assignment, n);
                        for (i, &b) in backlog.iter().enumerate() {
                            telemetry.observe_backlog(i, b, issue);
                        }
                        // Thief: least-backlogged shard under half the
                        // home's backlog; warm beats cold, and a cold
                        // shard may bootstrap-adopt only a single-homed
                        // task (one cold adoption per task per phase).
                        let mut warm_best: Option<(f64, usize)> = None;
                        let mut cold_best: Option<(f64, usize)> = None;
                        for (i, &b) in backlog.iter().enumerate() {
                            if i == home || 2.0 * b >= backlog[home] {
                                continue;
                            }
                            let slot = (b, i);
                            if sessions[i].has_warm_variant(&task) {
                                if warm_best.map(|w| slot < w).unwrap_or(true) {
                                    warm_best = Some(slot);
                                }
                            } else if cold_best.map(|c| slot < c).unwrap_or(true) {
                                cold_best = Some(slot);
                            }
                        }
                        let bootstrap = if serving[&task].len() == 1 {
                            cold_best
                        } else {
                            None
                        };
                        if let Some((_, thief)) = warm_best.or(bootstrap) {
                            if sessions[thief].ready_of(&task).is_none() {
                                if let Some(slo) = slos.get(&task).copied() {
                                    let prior = ShardPlan {
                                        assignment: assignment.clone(),
                                        shards: n,
                                        slos: slos.clone(),
                                        universe: universe.clone(),
                                    };
                                    let observed = ShardObservation {
                                        saturated: home,
                                        shard_backlog_ms: backlog.clone(),
                                        shard_orders: shard_orders.clone(),
                                        shard_pool_bytes: shard_pool_bytes.clone(),
                                        movable: vec![task.clone()],
                                        mean_batch: observed_mean_batch(
                                            &sessions,
                                            &assignment,
                                            &scenario.tasks,
                                        ),
                                        arrival_qps: if cfg.predictive {
                                            telemetry.projected_arrival_hint(
                                                issue,
                                                cfg.horizon_ms,
                                            )
                                        } else {
                                            telemetry.arrival_hint()
                                        },
                                    };
                                    let selection =
                                        planner.reselect(&task, &prior, &observed, thief);
                                    // A stolen task's pool entries are
                                    // *copied* — the home keeps serving
                                    // it between steals.
                                    let warm_blobs = if cfg.warm_migrate {
                                        Some(sessions[home].pool_task_blobs(&task))
                                    } else {
                                        None
                                    };
                                    let blobs =
                                        warm_blobs.as_ref().map(|b| b.len()).unwrap_or(0);
                                    let mut floor =
                                        sessions[home].ready_of(&task).unwrap_or(0.0);
                                    // Fault lab: adoption pays the
                                    // topology's transfer price.
                                    let mut link = 0.0;
                                    if let Some(links) = &scenario.faults.links {
                                        link = links.cost(home, thief);
                                        floor += link;
                                        link_cost_ms += link;
                                    }
                                    sessions[thief].adopt_task(
                                        &task, slo, selection, floor, link, warm_blobs,
                                    )?;
                                    // Adoption reshapes the thief's pool;
                                    // cached synthesis prices are stale.
                                    planner.provider().invalidate();
                                    serving
                                        .get_mut(&task)
                                        .expect("known task")
                                        .push(thief);
                                    if tracing {
                                        control.push(TraceEvent::new(
                                            trace::TR_CTL_MIGRATE,
                                            thief,
                                            &task,
                                            None,
                                            issue,
                                            issue,
                                            &[
                                                ("from", home as f64),
                                                ("to", thief as f64),
                                                ("link_ms", link),
                                                ("blobs", blobs as f64),
                                            ],
                                        ));
                                    }
                                }
                            }
                            if sessions[thief].ready_of(&task).is_some() {
                                serve_on = thief;
                                telemetry.note_steal(thief);
                                if tracing {
                                    control.push(TraceEvent::new(
                                        trace::TR_CTL_STEAL,
                                        thief,
                                        &task,
                                        None,
                                        issue,
                                        issue,
                                        &[
                                            ("thief", thief as f64),
                                            ("home", home as f64),
                                            ("observed_ms", home_backlog),
                                            ("forecast_ms", effective_backlog),
                                            ("threshold_ms", thresholds[home].unwrap_or(0.0)),
                                        ],
                                    ));
                                }
                            }
                        }
                    }
                }

                // --- fault lab: crash redirect ------------------------
                // The shard picked to serve is inside a crash window at
                // issue time. With stealing enabled the batch reroutes
                // to a live shard (warm targets first), paying the link
                // transfer price if the task must be adopted there;
                // without it the batch stays home and the session's
                // swallow rule drops it — which is exactly the
                // no-adaptation baseline the fault-recovery suite
                // measures against.
                if cfg.steal
                    && !scenario.faults.crashes.is_empty()
                    && scenario.faults.down_at(serve_on, issue)
                {
                    // Rank live shards: already serving < warm pool <
                    // cold; ties break to the lowest index —
                    // deterministic.
                    let mut target: Option<(usize, usize)> = None;
                    for i in 0..n {
                        if i == serve_on || scenario.faults.down_at(i, issue) {
                            continue;
                        }
                        let rank = if sessions[i].ready_of(&task).is_some() {
                            0
                        } else if sessions[i].has_warm_variant(&task) {
                            1
                        } else {
                            2
                        };
                        let cand = (rank, i);
                        if target.map(|t| cand < t).unwrap_or(true) {
                            target = Some(cand);
                        }
                    }
                    if let Some((_, dst)) = target {
                        if sessions[dst].ready_of(&task).is_none() {
                            if let Some(slo) = slos.get(&task).copied() {
                                // The payload is the crashed shard's
                                // pre-crash pool snapshot (state was
                                // replicated before the window opened).
                                let warm_blobs = if cfg.warm_migrate {
                                    Some(sessions[serve_on].pool_task_blobs(&task))
                                } else {
                                    None
                                };
                                let blobs =
                                    warm_blobs.as_ref().map(|b| b.len()).unwrap_or(0);
                                let mut floor =
                                    sessions[serve_on].ready_of(&task).unwrap_or(0.0);
                                let mut link = 0.0;
                                if let Some(links) = &scenario.faults.links {
                                    link = links.cost(serve_on, dst);
                                    floor += link;
                                    link_cost_ms += link;
                                }
                                sessions[dst]
                                    .adopt_task(&task, slo, None, floor, link, warm_blobs)?;
                                planner.provider().invalidate();
                                serving.get_mut(&task).expect("known task").push(dst);
                                if tracing {
                                    control.push(TraceEvent::new(
                                        trace::TR_CTL_MIGRATE,
                                        dst,
                                        &task,
                                        None,
                                        issue,
                                        issue,
                                        &[
                                            ("from", serve_on as f64),
                                            ("to", dst as f64),
                                            ("link_ms", link),
                                            ("blobs", blobs as f64),
                                        ],
                                    ));
                                }
                            }
                        }
                        if sessions[dst].ready_of(&task).is_some() {
                            if tracing {
                                control.push(TraceEvent::new(
                                    trace::TR_CTL_REDIRECT,
                                    dst,
                                    &task,
                                    None,
                                    issue,
                                    issue,
                                    &[("from", serve_on as f64), ("to", dst as f64)],
                                ));
                            }
                            serve_on = dst;
                            telemetry.note_steal(dst);
                        }
                    }
                }

                let queue = pending.get_mut(&task).unwrap();
                // Same coalescing rule as Dispatcher::drive.
                let waiting =
                    queue.iter().take_while(|q| q.arrival_ms <= issue).count();
                let take = scenario.dispatch.take(waiting, batching);
                let batch: Vec<Query> =
                    (0..take).map(|_| queue.pop_front().unwrap()).collect();
                let refs: Vec<&Query> = batch.iter().collect();
                let evs = sessions[serve_on].submit_batch(&refs)?;
                for ev in &evs {
                    telemetry.observe_outcome(serve_on, ev);
                }
                // FIFO across the shards serving this task: raise every
                // floor to the latest completion.
                if serving[&task].len() > 1 {
                    sync_ready_floors(&mut sessions, &serving[&task], &task);
                }

                // --- online variant synthesis -------------------------
                // Pressure trigger: the serving shard's observed (or
                // Holt-forecast) backlog crossed its saturation
                // threshold, or its pool runs hot. The synthesizing
                // provider searches the stitch space for a cheaper
                // composition at the live batch operating point; the
                // switch commits only when the candidate strictly
                // undercuts the incumbent's estimate (and is charged
                // the same load penalty as a feedback switch).
                if cfg.synthesize {
                    let backlog =
                        backlog_of_shard(&sessions, &pending, &assignment, serve_on);
                    let effective = if cfg.predictive {
                        backlog.max(telemetry.forecast_shard_backlog_ms(
                            serve_on,
                            issue,
                            cfg.horizon_ms,
                        ))
                    } else {
                        backlog
                    };
                    let threshold = thresholds[serve_on];
                    let pool_util = sessions[serve_on].pool_utilization();
                    let pressured = threshold
                        .map(|thr| effective > thr)
                        .unwrap_or(false)
                        || pool_util > SYNTH_POOL_PRESSURE;
                    if pressured {
                        if let Some(slo) = slos.get(&task).copied() {
                            let incumbent = sessions[serve_on].serving_index(&task);
                            let mut tenants: Vec<String> = scenario
                                .tasks
                                .iter()
                                .filter(|t| assignment[*t] == serve_on)
                                .cloned()
                                .collect();
                            if !tenants.iter().any(|t| t == &task) {
                                tenants.push(task.clone());
                            }
                            let pressure = PressureSignal {
                                forecast_ms: effective,
                                threshold_ms: threshold.unwrap_or(0.0),
                                pool_utilization: pool_util,
                            };
                            let batch = sessions[serve_on]
                                .mean_batch_of(&task)
                                .unwrap_or(1.0);
                            let arrival_qps = if cfg.predictive {
                                telemetry.projected_arrival_hint(issue, cfg.horizon_ms)
                            } else {
                                telemetry.arrival_hint()
                            };
                            if let Some((dec, incumbent_sel)) = planner.synthesize(
                                &task,
                                &slo,
                                &universe,
                                &tenants,
                                sessions[serve_on].pool_capacity(),
                                Some(sessions[serve_on].planned_order().to_vec()),
                                batch,
                                &arrival_qps,
                                phase,
                                pressure,
                                incumbent,
                            ) {
                                let cur = incumbent_sel
                                    .map(|s| s.latency_ms)
                                    .unwrap_or(f64::INFINITY);
                                if incumbent != Some(dec.selection.stitched_index)
                                    && dec.selection.latency_ms < SYNTH_MARGIN * cur
                                {
                                    let penalty = sessions[serve_on]
                                        .resynthesize_task(&task, dec.selection)?;
                                    synths += 1;
                                    if tracing {
                                        control.push(TraceEvent::new(
                                            trace::TR_CTL_SYNTH,
                                            serve_on,
                                            &task,
                                            None,
                                            issue,
                                            issue,
                                            &[
                                                ("forecast_ms", effective),
                                                ("threshold_ms", threshold.unwrap_or(0.0)),
                                                ("pool_util", pool_util),
                                                ("expanded", dec.stats.expanded as f64),
                                                ("evaluated", dec.stats.evaluated as f64),
                                                (
                                                    "cache_hit",
                                                    if dec.stats.cache_hit { 1.0 } else { 0.0 },
                                                ),
                                                (
                                                    "old_index",
                                                    incumbent
                                                        .map(|k| k as f64)
                                                        .unwrap_or(-1.0),
                                                ),
                                                (
                                                    "new_index",
                                                    dec.selection.stitched_index as f64,
                                                ),
                                                (
                                                    "old_est_ms",
                                                    incumbent_sel
                                                        .map(|s| s.latency_ms)
                                                        .unwrap_or(-1.0),
                                                ),
                                                ("new_est_ms", dec.selection.latency_ms),
                                                ("penalty_ms", penalty),
                                            ],
                                        ));
                                    }
                                }
                            }
                        }
                    }
                }

                if !cfg.replan || budget_left == 0 {
                    continue;
                }
                // --- saturation check -------------------------------------
                // Same two-step shape as the steal path: scalar check
                // first, full vector only on saturation.
                let Some(threshold) = thresholds[home] else {
                    continue;
                };
                let home_backlog =
                    backlog_of_shard(&sessions, &pending, &assignment, home);
                telemetry.observe_backlog(home, home_backlog, issue);
                // Same forecast-or-observed trigger as the steal path.
                let effective_backlog = if cfg.predictive {
                    home_backlog.max(telemetry.forecast_shard_backlog_ms(
                        home,
                        issue,
                        cfg.horizon_ms,
                    ))
                } else {
                    home_backlog
                };
                if effective_backlog <= threshold {
                    continue;
                }
                let shard_backlog = backlog_per_shard(&sessions, &pending, &assignment, n);
                for (i, &b) in shard_backlog.iter().enumerate() {
                    telemetry.observe_backlog(i, b, issue);
                }
                // Cheap pre-checks before invoking the planner (the
                // hotness scan is the expensive part): a strictly
                // less-loaded target must exist, and some task on the
                // saturated shard must still have queued work AND not
                // have been served by another shard this phase (a
                // second adoption would break the one-floor-per-shard
                // invariant of whole-task migration).
                let has_target = shard_backlog
                    .iter()
                    .enumerate()
                    .any(|(i2, &b)| i2 != home && b < shard_backlog[home]);
                let movable: Vec<String> = scenario
                    .tasks
                    .iter()
                    .filter(|t| assignment[*t] == home)
                    .filter(|t| {
                        pending.get(*t).map(|q| !q.is_empty()).unwrap_or(false)
                    })
                    .filter(|t| {
                        !sessions.iter().enumerate().any(|(i2, s)| {
                            i2 != home && s.ready_of(t).is_some()
                        })
                    })
                    .cloned()
                    .collect();
                if !has_target || movable.is_empty() {
                    continue;
                }
                replans += 1;
                let prior = ShardPlan {
                    assignment: assignment.clone(),
                    shards: n,
                    slos: slos.clone(),
                    universe: universe.clone(),
                };
                let observed = ShardObservation {
                    saturated: home,
                    shard_backlog_ms: shard_backlog,
                    shard_orders: shard_orders.clone(),
                    shard_pool_bytes: shard_pool_bytes.clone(),
                    movable,
                    mean_batch: observed_mean_batch(
                        &sessions,
                        &assignment,
                        &scenario.tasks,
                    ),
                    arrival_qps: if cfg.predictive {
                        telemetry.projected_arrival_hint(issue, cfg.horizon_ms)
                    } else {
                        telemetry.arrival_hint()
                    },
                };
                let Some(mig) = planner.replan(&prior, &observed) else {
                    continue;
                };
                debug_assert!(sessions[mig.to].ready_of(&mig.task).is_none());
                let Some(slo) = slos.get(&mig.task).copied() else { continue };
                let mut floor = sessions[mig.from].ready_of(&mig.task).unwrap_or(0.0);
                // Fault lab: migration pays the topology's transfer price.
                let mut link = 0.0;
                if let Some(links) = &scenario.faults.links {
                    link = links.cost(mig.from, mig.to);
                    floor += link;
                    link_cost_ms += link;
                }
                // A replanned migrant's pool entries *move* with it —
                // the source's budget share frees up.
                let warm_blobs = if cfg.warm_migrate {
                    Some(sessions[mig.from].take_task_blobs(&mig.task))
                } else {
                    None
                };
                let blobs = warm_blobs.as_ref().map(|b| b.len()).unwrap_or(0);
                sessions[mig.to].adopt_task(
                    &mig.task,
                    slo,
                    mig.selection,
                    floor,
                    link,
                    warm_blobs,
                )?;
                // The migrant's blobs moved pools on both ends; cached
                // synthesis decisions priced the old placement.
                planner.provider().invalidate();
                let adopters = serving.get_mut(&mig.task).expect("known task");
                if !adopters.contains(&mig.to) {
                    adopters.push(mig.to);
                }
                assignment.insert(mig.task.clone(), mig.to);
                thresholds = (0..n)
                    .map(|i| {
                        saturation_threshold(cfg.saturation_slack, slos, &assignment, i)
                    })
                    .collect();
                migrations += 1;
                budget_left -= 1;
                if tracing {
                    control.push(TraceEvent::new(
                        trace::TR_CTL_REPLAN,
                        home,
                        &mig.task,
                        None,
                        issue,
                        issue,
                        &[
                            ("from", mig.from as f64),
                            ("to", mig.to as f64),
                            ("observed_ms", home_backlog),
                            ("forecast_ms", effective_backlog),
                            ("threshold_ms", threshold),
                            ("budget_left", budget_left as f64),
                        ],
                    ));
                    control.push(TraceEvent::new(
                        trace::TR_CTL_MIGRATE,
                        mig.to,
                        &mig.task,
                        None,
                        issue,
                        issue,
                        &[
                            ("from", mig.from as f64),
                            ("to", mig.to as f64),
                            ("link_ms", link),
                            ("blobs", blobs as f64),
                        ],
                    ));
                }
            }
            for (i, session) in sessions.into_iter().enumerate() {
                budget_utilization[i] = session.pool_utilization();
                per_shard[i].merge_sequential(session.finish());
            }
        }
        let mut aggregate = RunReport::default();
        for report in &per_shard {
            aggregate.merge_parallel(report.clone());
        }
        Ok(ShardedReport {
            per_shard,
            aggregate,
            replans,
            migrations,
            // Telemetry is the one tracking site for stolen batches.
            steals: telemetry.steals() as usize,
            synths,
            budget_utilization,
            arrival_est_qps: telemetry.rates(),
            link_cost_ms,
            control_trace: control,
        })
    }

    /// The epoch-barrier threaded online drive
    /// (`PlannerConfig::epoch_ms > 0`). Virtual time is cut into
    /// windows of `epoch_ms`; inside a window every shard serves its
    /// own partition of the pending queues — on its own OS thread when
    /// `ServeOpts::parallel` is set — and between windows all shards
    /// meet at a lockstep barrier where the coordinator, alone and
    /// sequentially, folds the workers' telemetry parts
    /// ([`Telemetry::merge`], shard-index order), feeds the task-level
    /// arrival estimators from the returned events, re-syncs FIFO
    /// floors, and applies every adaptation move (steal, crash
    /// redirect, replan). All cross-shard decisions happen at
    /// barriers over data folded in shard-index order with
    /// virtual-time tie-breaks, so the report is bit-identical whether
    /// the windows ran on threads or inline — determinism by
    /// construction, not by scheduling luck. See DESIGN.md
    /// §Fleet-scale execution for the protocol and the merge-order
    /// argument.
    fn run_online_epoch(&self, scenario: &Scenario) -> Result<ShardedReport> {
        let n = self.shards.len();
        let epoch = scenario.planner.epoch_ms;
        let coord = self.shards[0].coordinator();
        let cfg = &scenario.planner;
        let planner = {
            let p = SparsityAwarePlanner::new(coord.zoo, coord.lm, coord.profiles);
            if cfg.synthesize {
                p.with_synthesis()
            } else {
                p
            }
        };
        let universe = scenario.slo_universe();
        let threaded = self.shards[0].opts().parallel && n > 1;
        let mut telemetry = Telemetry::new(n);
        let mut assignment: BTreeMap<String, usize> = scenario
            .tasks
            .iter()
            .map(|t| (t.clone(), self.shard_of(t)))
            .collect();
        let mut per_shard: Vec<RunReport> = vec![RunReport::default(); n];
        let mut budget_utilization = vec![0.0f64; n];
        let mut replans = 0usize;
        let mut migrations = 0usize;
        let mut synths = 0usize;
        let mut link_cost_ms = 0.0f64;
        // Control-plane audit events: emitted only here, between
        // barriers, where the coordinator runs alone — never from
        // worker threads — so their order is sequential by
        // construction.
        let tracing = self.shards[0].opts().trace;
        let mut control: Vec<TraceEvent> = Vec::new();
        for phase in 0..scenario.phases() {
            let slos = &scenario.schedule[phase];
            // Phase shift: cached synthesis decisions were priced under
            // the previous phase's SLOs and pool state.
            planner.provider().invalidate();
            let mut sessions = Vec::with_capacity(n);
            for (i, server) in self.shards.iter().enumerate() {
                let tasks_i: Vec<String> = scenario
                    .tasks
                    .iter()
                    .filter(|t| assignment[*t] == i)
                    .cloned()
                    .collect();
                let mut session =
                    server.session(&sub_scenario(scenario, &tasks_i, i), phase)?;
                session.set_trace_shard(i);
                sessions.push(session);
            }
            let shard_orders: Vec<Vec<Processor>> = sessions
                .iter()
                .map(|s| s.planned_order().to_vec())
                .collect();
            let shard_pool_bytes: Vec<u64> =
                sessions.iter().map(|s| s.pool_capacity()).collect();
            let mut pending: BTreeMap<String, VecDeque<Query>> = BTreeMap::new();
            for q in scenario.stream(phase) {
                if !assignment.contains_key(&q.task) {
                    bail!(
                        "query {} targets task {:?} not in this scenario",
                        q.id,
                        q.task
                    );
                }
                pending.entry(q.task.clone()).or_default().push_back(q);
            }
            let mut serving: BTreeMap<String, Vec<usize>> = assignment
                .iter()
                .map(|(t, &s)| (t.clone(), vec![s]))
                .collect();
            let batching = scenario.dispatch.is_batching();
            let mut budget_left = cfg.max_migrations;
            let mut thresholds: Vec<Option<f64>> = (0..n)
                .map(|i| saturation_threshold(cfg.saturation_slack, slos, &assignment, i))
                .collect();
            // Zero-progress escalation: when a whole window serves
            // nothing (every issue time sits at or beyond its end),
            // the next window starts where this one ended — the clock
            // always advances, so the phase terminates.
            let mut window_floor = f64::NEG_INFINITY;
            loop {
                // Earliest issue time across all pending work, judged
                // at the current homes; ties keep the first task in
                // declaration order, as in the classic drive.
                let mut t0: Option<f64> = None;
                for task in &scenario.tasks {
                    let Some(queue) = pending.get(task) else { continue };
                    let Some(q) = queue.front() else { continue };
                    let ready =
                        sessions[assignment[task]].ready_of(task).unwrap_or(0.0);
                    let issue = q.arrival_ms.max(ready);
                    if t0.map(|t| issue < t).unwrap_or(true) {
                        t0 = Some(issue);
                    }
                }
                let Some(t0) = t0 else { break };
                let start = t0.max(window_floor);
                // Clip the window at the first crash boundary after its
                // start, so a shard's up/down status is constant across
                // the window and the redirect decision — judged once,
                // at `start` — holds for every batch in it. (Per-query
                // drop accounting inside a down window stays exact
                // either way: the session's swallow rule prices each
                // query against the crash window itself.)
                let mut end = start + epoch;
                for w in &scenario.faults.crashes {
                    for b in [w.start_ms, w.end_ms] {
                        if b > start && b < end {
                            end = b;
                        }
                    }
                }

                // --- barrier: placement decisions (coordinator only) --
                // Which shard serves each task's queue this window.
                let mut serve_as: BTreeMap<String, usize> = assignment.clone();
                if cfg.steal {
                    for home in 0..n {
                        let home_backlog =
                            backlog_of_shard(&sessions, &pending, &assignment, home);
                        telemetry.observe_backlog(home, home_backlog, start);
                        let effective_backlog = if cfg.predictive {
                            home_backlog.max(telemetry.forecast_shard_backlog_ms(
                                home,
                                start,
                                cfg.horizon_ms,
                            ))
                        } else {
                            home_backlog
                        };
                        let saturated = thresholds[home]
                            .map(|thr| effective_backlog > thr)
                            .unwrap_or(false);
                        if !saturated {
                            continue;
                        }
                        // Victim: the home's earliest-issue pending
                        // task — the same queue the classic drive
                        // would steal from first.
                        let mut victim: Option<(f64, &String)> = None;
                        for task in &scenario.tasks {
                            if assignment[task] != home {
                                continue;
                            }
                            let Some(queue) = pending.get(task) else { continue };
                            let Some(q) = queue.front() else { continue };
                            let ready =
                                sessions[home].ready_of(task).unwrap_or(0.0);
                            let issue = q.arrival_ms.max(ready);
                            if victim.map(|(t, _)| issue < t).unwrap_or(true) {
                                victim = Some((issue, task));
                            }
                        }
                        let Some((_, task)) = victim else { continue };
                        let task = task.clone();
                        let backlog =
                            backlog_per_shard(&sessions, &pending, &assignment, n);
                        for (i, &b) in backlog.iter().enumerate() {
                            telemetry.observe_backlog(i, b, start);
                        }
                        // Same thief ranking as the classic drive:
                        // least-backlogged shard under half the home's
                        // backlog, warm beats cold, cold only while the
                        // task is single-homed.
                        let mut warm_best: Option<(f64, usize)> = None;
                        let mut cold_best: Option<(f64, usize)> = None;
                        for (i, &b) in backlog.iter().enumerate() {
                            if i == home || 2.0 * b >= backlog[home] {
                                continue;
                            }
                            let slot = (b, i);
                            if sessions[i].has_warm_variant(&task) {
                                if warm_best.map(|w| slot < w).unwrap_or(true) {
                                    warm_best = Some(slot);
                                }
                            } else if cold_best.map(|c| slot < c).unwrap_or(true) {
                                cold_best = Some(slot);
                            }
                        }
                        let bootstrap = if serving[&task].len() == 1 {
                            cold_best
                        } else {
                            None
                        };
                        if let Some((_, thief)) = warm_best.or(bootstrap) {
                            if sessions[thief].ready_of(&task).is_none() {
                                if let Some(slo) = slos.get(&task).copied() {
                                    let prior = ShardPlan {
                                        assignment: assignment.clone(),
                                        shards: n,
                                        slos: slos.clone(),
                                        universe: universe.clone(),
                                    };
                                    let observed = ShardObservation {
                                        saturated: home,
                                        shard_backlog_ms: backlog.clone(),
                                        shard_orders: shard_orders.clone(),
                                        shard_pool_bytes: shard_pool_bytes.clone(),
                                        movable: vec![task.clone()],
                                        mean_batch: observed_mean_batch(
                                            &sessions,
                                            &assignment,
                                            &scenario.tasks,
                                        ),
                                        arrival_qps: if cfg.predictive {
                                            telemetry.projected_arrival_hint(
                                                start,
                                                cfg.horizon_ms,
                                            )
                                        } else {
                                            telemetry.arrival_hint()
                                        },
                                    };
                                    let selection = planner.reselect(
                                        &task, &prior, &observed, thief,
                                    );
                                    let warm_blobs = if cfg.warm_migrate {
                                        Some(sessions[home].pool_task_blobs(&task))
                                    } else {
                                        None
                                    };
                                    let blobs =
                                        warm_blobs.as_ref().map(|b| b.len()).unwrap_or(0);
                                    let mut floor =
                                        sessions[home].ready_of(&task).unwrap_or(0.0);
                                    let mut link = 0.0;
                                    if let Some(links) = &scenario.faults.links {
                                        link = links.cost(home, thief);
                                        floor += link;
                                        link_cost_ms += link;
                                    }
                                    sessions[thief].adopt_task(
                                        &task, slo, selection, floor, link, warm_blobs,
                                    )?;
                                    // Adoption reshapes the thief's pool;
                                    // cached synthesis prices are stale.
                                    planner.provider().invalidate();
                                    serving
                                        .get_mut(&task)
                                        .expect("known task")
                                        .push(thief);
                                    if tracing {
                                        control.push(TraceEvent::new(
                                            trace::TR_CTL_MIGRATE,
                                            thief,
                                            &task,
                                            None,
                                            start,
                                            start,
                                            &[
                                                ("from", home as f64),
                                                ("to", thief as f64),
                                                ("link_ms", link),
                                                ("blobs", blobs as f64),
                                            ],
                                        ));
                                    }
                                }
                            }
                            if sessions[thief].ready_of(&task).is_some() {
                                if tracing {
                                    control.push(TraceEvent::new(
                                        trace::TR_CTL_STEAL,
                                        thief,
                                        &task,
                                        None,
                                        start,
                                        start,
                                        &[
                                            ("thief", thief as f64),
                                            ("home", home as f64),
                                            ("observed_ms", home_backlog),
                                            ("forecast_ms", effective_backlog),
                                            ("threshold_ms", thresholds[home].unwrap_or(0.0)),
                                        ],
                                    ));
                                }
                                serve_as.insert(task, thief);
                            }
                        }
                    }
                }

                // --- barrier: crash redirect (fault lab) --------------
                // A task routed to a shard that is down for this whole
                // window reroutes to a live shard (serving < warm <
                // cold, lowest index), paying the link price on
                // adoption — mirroring the classic drive's per-batch
                // redirect. Without stealing the queue stays home and
                // the session's swallow rule drops it, which is the
                // no-adaptation baseline.
                if cfg.steal && !scenario.faults.crashes.is_empty() {
                    for task in &scenario.tasks {
                        let has_work =
                            pending.get(task).map(|q| !q.is_empty()).unwrap_or(false);
                        if !has_work {
                            continue;
                        }
                        let from = serve_as[task];
                        if !scenario.faults.down_at(from, start) {
                            continue;
                        }
                        let mut target: Option<(usize, usize)> = None;
                        for i in 0..n {
                            if i == from || scenario.faults.down_at(i, start) {
                                continue;
                            }
                            let rank = if sessions[i].ready_of(task).is_some() {
                                0
                            } else if sessions[i].has_warm_variant(task) {
                                1
                            } else {
                                2
                            };
                            let cand = (rank, i);
                            if target.map(|t| cand < t).unwrap_or(true) {
                                target = Some(cand);
                            }
                        }
                        if let Some((_, dst)) = target {
                            if sessions[dst].ready_of(task).is_none() {
                                if let Some(slo) = slos.get(task).copied() {
                                    let warm_blobs = if cfg.warm_migrate {
                                        Some(sessions[from].pool_task_blobs(task))
                                    } else {
                                        None
                                    };
                                    let blobs =
                                        warm_blobs.as_ref().map(|b| b.len()).unwrap_or(0);
                                    let mut floor =
                                        sessions[from].ready_of(task).unwrap_or(0.0);
                                    let mut link = 0.0;
                                    if let Some(links) = &scenario.faults.links {
                                        link = links.cost(from, dst);
                                        floor += link;
                                        link_cost_ms += link;
                                    }
                                    sessions[dst].adopt_task(
                                        task, slo, None, floor, link, warm_blobs,
                                    )?;
                                    planner.provider().invalidate();
                                    serving
                                        .get_mut(task)
                                        .expect("known task")
                                        .push(dst);
                                    if tracing {
                                        control.push(TraceEvent::new(
                                            trace::TR_CTL_MIGRATE,
                                            dst,
                                            task,
                                            None,
                                            start,
                                            start,
                                            &[
                                                ("from", from as f64),
                                                ("to", dst as f64),
                                                ("link_ms", link),
                                                ("blobs", blobs as f64),
                                            ],
                                        ));
                                    }
                                }
                            }
                            if sessions[dst].ready_of(task).is_some() {
                                if tracing {
                                    control.push(TraceEvent::new(
                                        trace::TR_CTL_REDIRECT,
                                        dst,
                                        task,
                                        None,
                                        start,
                                        start,
                                        &[("from", from as f64), ("to", dst as f64)],
                                    ));
                                }
                                serve_as.insert(task.clone(), dst);
                            }
                        }
                    }
                }

                // --- window: every shard drives its own partition -----
                let mut work: Vec<BTreeMap<String, VecDeque<Query>>> =
                    (0..n).map(|_| BTreeMap::new()).collect();
                for (task, queue) in std::mem::take(&mut pending) {
                    if queue.is_empty() {
                        continue;
                    }
                    let dst = serve_as[&task];
                    work[dst].insert(task, queue);
                }
                // Batches a worker serves for a task homed elsewhere
                // are stolen batches; the worker counts them on its
                // telemetry part (merged below).
                let foreign: Vec<BTreeSet<String>> = (0..n)
                    .map(|i| {
                        work[i]
                            .keys()
                            .filter(|t| assignment[*t] != i)
                            .cloned()
                            .collect()
                    })
                    .collect();
                let slots: Vec<Result<WindowResult>> = if threaded {
                    std::thread::scope(|scope| {
                        let handles: Vec<_> = sessions
                            .iter_mut()
                            .zip(work.iter_mut())
                            .enumerate()
                            .map(|(i, (session, queues))| {
                                let foreign = &foreign[i];
                                let dispatch = &scenario.dispatch;
                                scope.spawn(move || {
                                    drive_window(
                                        session, queues, dispatch, batching, end,
                                        i, foreign, n,
                                    )
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .map(|h| h.join().expect("shard thread panicked"))
                            .collect()
                    })
                } else {
                    sessions
                        .iter_mut()
                        .zip(work.iter_mut())
                        .enumerate()
                        .map(|(i, (session, queues))| {
                            drive_window(
                                session,
                                queues,
                                &scenario.dispatch,
                                batching,
                                end,
                                i,
                                &foreign[i],
                                n,
                            )
                        })
                        .collect()
                };

                // --- barrier: deterministic merge (shard-index order) -
                let mut progressed = false;
                for slot in slots {
                    let (part, events, batches) = slot?;
                    telemetry.merge(&part);
                    for ev in &events {
                        telemetry.observe_task_outcome(ev);
                    }
                    progressed = progressed || batches > 0;
                }
                window_floor = if progressed { f64::NEG_INFINITY } else { end };
                // Part-drained queues go back for the next window.
                for queues in work {
                    for (task, queue) in queues {
                        if !queue.is_empty() {
                            pending.insert(task, queue);
                        }
                    }
                }
                // FIFO across shards serving one task: only one shard
                // served it this window, so raising every floor to the
                // latest completion here keeps per-task order intact.
                for (task, on) in &serving {
                    if on.len() > 1 {
                        sync_ready_floors(&mut sessions, on, task);
                    }
                }

                // --- barrier: online variant synthesis ----------------
                // Same pressure trigger as the classic drive, applied
                // where the coordinator runs alone: shards are scanned
                // in index order, and a pressured shard may re-pin any
                // of its assigned tasks that still has pending work to
                // a cheaper synthesized composition. Everything reads
                // barrier-merged state, so the outcome is independent
                // of worker-thread scheduling.
                if cfg.synthesize {
                    for shard in 0..n {
                        let backlog =
                            backlog_of_shard(&sessions, &pending, &assignment, shard);
                        let effective = if cfg.predictive {
                            backlog.max(telemetry.forecast_shard_backlog_ms(
                                shard,
                                end,
                                cfg.horizon_ms,
                            ))
                        } else {
                            backlog
                        };
                        let threshold = thresholds[shard];
                        let pool_util = sessions[shard].pool_utilization();
                        let pressured = threshold
                            .map(|thr| effective > thr)
                            .unwrap_or(false)
                            || pool_util > SYNTH_POOL_PRESSURE;
                        if !pressured {
                            continue;
                        }
                        let tenants: Vec<String> = scenario
                            .tasks
                            .iter()
                            .filter(|t| assignment[*t] == shard)
                            .cloned()
                            .collect();
                        let arrival_qps = if cfg.predictive {
                            telemetry.projected_arrival_hint(end, cfg.horizon_ms)
                        } else {
                            telemetry.arrival_hint()
                        };
                        for task in &tenants {
                            if pending
                                .get(task)
                                .map(|q| q.is_empty())
                                .unwrap_or(true)
                            {
                                continue;
                            }
                            let Some(slo) = slos.get(task).copied() else {
                                continue;
                            };
                            let incumbent = sessions[shard].serving_index(task);
                            let pressure = PressureSignal {
                                forecast_ms: effective,
                                threshold_ms: threshold.unwrap_or(0.0),
                                pool_utilization: pool_util,
                            };
                            let batch =
                                sessions[shard].mean_batch_of(task).unwrap_or(1.0);
                            let Some((dec, incumbent_sel)) = planner.synthesize(
                                task,
                                &slo,
                                &universe,
                                &tenants,
                                sessions[shard].pool_capacity(),
                                Some(sessions[shard].planned_order().to_vec()),
                                batch,
                                &arrival_qps,
                                phase,
                                pressure,
                                incumbent,
                            ) else {
                                continue;
                            };
                            let cur = incumbent_sel
                                .map(|s| s.latency_ms)
                                .unwrap_or(f64::INFINITY);
                            if incumbent != Some(dec.selection.stitched_index)
                                && dec.selection.latency_ms < SYNTH_MARGIN * cur
                            {
                                let penalty = sessions[shard]
                                    .resynthesize_task(task, dec.selection)?;
                                synths += 1;
                                if tracing {
                                    control.push(TraceEvent::new(
                                        trace::TR_CTL_SYNTH,
                                        shard,
                                        task,
                                        None,
                                        end,
                                        end,
                                        &[
                                            ("forecast_ms", effective),
                                            (
                                                "threshold_ms",
                                                threshold.unwrap_or(0.0),
                                            ),
                                            ("pool_util", pool_util),
                                            ("expanded", dec.stats.expanded as f64),
                                            (
                                                "evaluated",
                                                dec.stats.evaluated as f64,
                                            ),
                                            (
                                                "cache_hit",
                                                if dec.stats.cache_hit {
                                                    1.0
                                                } else {
                                                    0.0
                                                },
                                            ),
                                            (
                                                "old_index",
                                                incumbent
                                                    .map(|k| k as f64)
                                                    .unwrap_or(-1.0),
                                            ),
                                            (
                                                "new_index",
                                                dec.selection.stitched_index as f64,
                                            ),
                                            (
                                                "old_est_ms",
                                                incumbent_sel
                                                    .map(|s| s.latency_ms)
                                                    .unwrap_or(-1.0),
                                            ),
                                            ("new_est_ms", dec.selection.latency_ms),
                                            ("penalty_ms", penalty),
                                        ],
                                    ));
                                }
                            }
                        }
                    }
                }

                if !cfg.replan || budget_left == 0 {
                    continue;
                }
                // --- barrier: bounded replan (≤ 1 migration) ----------
                // Shards are scanned in index order; the first
                // saturated one with a viable move gets this barrier's
                // migration.
                for home in 0..n {
                    let Some(threshold) = thresholds[home] else { continue };
                    let home_backlog =
                        backlog_of_shard(&sessions, &pending, &assignment, home);
                    telemetry.observe_backlog(home, home_backlog, end);
                    let effective_backlog = if cfg.predictive {
                        home_backlog.max(telemetry.forecast_shard_backlog_ms(
                            home,
                            end,
                            cfg.horizon_ms,
                        ))
                    } else {
                        home_backlog
                    };
                    if effective_backlog <= threshold {
                        continue;
                    }
                    let shard_backlog =
                        backlog_per_shard(&sessions, &pending, &assignment, n);
                    for (i, &b) in shard_backlog.iter().enumerate() {
                        telemetry.observe_backlog(i, b, end);
                    }
                    let has_target = shard_backlog
                        .iter()
                        .enumerate()
                        .any(|(i2, &b)| i2 != home && b < shard_backlog[home]);
                    let movable: Vec<String> = scenario
                        .tasks
                        .iter()
                        .filter(|t| assignment[*t] == home)
                        .filter(|t| {
                            pending.get(*t).map(|q| !q.is_empty()).unwrap_or(false)
                        })
                        .filter(|t| {
                            !sessions.iter().enumerate().any(|(i2, s)| {
                                i2 != home && s.ready_of(t).is_some()
                            })
                        })
                        .cloned()
                        .collect();
                    if !has_target || movable.is_empty() {
                        continue;
                    }
                    replans += 1;
                    let prior = ShardPlan {
                        assignment: assignment.clone(),
                        shards: n,
                        slos: slos.clone(),
                        universe: universe.clone(),
                    };
                    let observed = ShardObservation {
                        saturated: home,
                        shard_backlog_ms: shard_backlog,
                        shard_orders: shard_orders.clone(),
                        shard_pool_bytes: shard_pool_bytes.clone(),
                        movable,
                        mean_batch: observed_mean_batch(
                            &sessions,
                            &assignment,
                            &scenario.tasks,
                        ),
                        arrival_qps: if cfg.predictive {
                            telemetry.projected_arrival_hint(end, cfg.horizon_ms)
                        } else {
                            telemetry.arrival_hint()
                        },
                    };
                    let Some(mig) = planner.replan(&prior, &observed) else {
                        continue;
                    };
                    debug_assert!(sessions[mig.to].ready_of(&mig.task).is_none());
                    let Some(slo) = slos.get(&mig.task).copied() else { continue };
                    let mut floor =
                        sessions[mig.from].ready_of(&mig.task).unwrap_or(0.0);
                    let mut link = 0.0;
                    if let Some(links) = &scenario.faults.links {
                        link = links.cost(mig.from, mig.to);
                        floor += link;
                        link_cost_ms += link;
                    }
                    // As in the classic drive: a replanned migrant's
                    // pool entries *move* with it.
                    let warm_blobs = if cfg.warm_migrate {
                        Some(sessions[mig.from].take_task_blobs(&mig.task))
                    } else {
                        None
                    };
                    let blobs = warm_blobs.as_ref().map(|b| b.len()).unwrap_or(0);
                    sessions[mig.to].adopt_task(
                        &mig.task,
                        slo,
                        mig.selection,
                        floor,
                        link,
                        warm_blobs,
                    )?;
                    // The migrant's blobs moved pools on both ends;
                    // cached synthesis decisions priced the old
                    // placement.
                    planner.provider().invalidate();
                    let adopters = serving.get_mut(&mig.task).expect("known task");
                    if !adopters.contains(&mig.to) {
                        adopters.push(mig.to);
                    }
                    assignment.insert(mig.task.clone(), mig.to);
                    thresholds = (0..n)
                        .map(|i| {
                            saturation_threshold(
                                cfg.saturation_slack,
                                slos,
                                &assignment,
                                i,
                            )
                        })
                        .collect();
                    migrations += 1;
                    budget_left -= 1;
                    if tracing {
                        control.push(TraceEvent::new(
                            trace::TR_CTL_REPLAN,
                            home,
                            &mig.task,
                            None,
                            end,
                            end,
                            &[
                                ("from", mig.from as f64),
                                ("to", mig.to as f64),
                                ("observed_ms", home_backlog),
                                ("forecast_ms", effective_backlog),
                                ("threshold_ms", threshold),
                                ("budget_left", budget_left as f64),
                            ],
                        ));
                        control.push(TraceEvent::new(
                            trace::TR_CTL_MIGRATE,
                            mig.to,
                            &mig.task,
                            None,
                            end,
                            end,
                            &[
                                ("from", mig.from as f64),
                                ("to", mig.to as f64),
                                ("link_ms", link),
                                ("blobs", blobs as f64),
                            ],
                        ));
                    }
                    break;
                }
            }
            for (i, session) in sessions.into_iter().enumerate() {
                budget_utilization[i] = session.pool_utilization();
                per_shard[i].merge_sequential(session.finish());
            }
        }
        let mut aggregate = RunReport::default();
        for report in &per_shard {
            aggregate.merge_parallel(report.clone());
        }
        Ok(ShardedReport {
            per_shard,
            aggregate,
            replans,
            migrations,
            steals: telemetry.steals() as usize,
            synths,
            budget_utilization,
            arrival_est_qps: telemetry.rates(),
            link_cost_ms,
            control_trace: control,
        })
    }
}

/// What one shard worker hands back at an epoch barrier: its telemetry
/// part (shard counters only — see [`Telemetry::merge`]), the request
/// outcomes it produced this window in submit order (the coordinator
/// feeds these to the task-level estimators), and how many batches it
/// served (zero across all workers triggers the window-floor
/// escalation).
type WindowResult = (Telemetry, Vec<RequestOutcome>, usize);

/// Drive one shard through one epoch window: serve every batch of the
/// shard's partition whose issue time falls before `end_ms`,
/// earliest-issue first (queue-name order breaks ties — deterministic
/// regardless of thread interleaving). Uses the same coalescing rule
/// as `Dispatcher::drive`. Touches only this shard's session and
/// queues plus a fresh telemetry part, so windows of different shards
/// are data-independent and safe to run on separate threads.
#[allow(clippy::too_many_arguments)]
fn drive_window(
    session: &mut Session<'_, '_>,
    queues: &mut BTreeMap<String, VecDeque<Query>>,
    dispatch: &Dispatch,
    batching: bool,
    end_ms: f64,
    me: usize,
    foreign: &BTreeSet<String>,
    n_shards: usize,
) -> Result<WindowResult> {
    let mut part = Telemetry::new(n_shards);
    let mut events = Vec::new();
    let mut batches = 0usize;
    loop {
        let mut next: Option<(f64, &String)> = None;
        for (task, queue) in queues.iter() {
            let Some(q) = queue.front() else { continue };
            let ready = session.ready_of(task).unwrap_or(0.0);
            let issue = q.arrival_ms.max(ready);
            if next.map(|(t, _)| issue < t).unwrap_or(true) {
                next = Some((issue, task));
            }
        }
        let Some((issue, task)) = next else { break };
        if issue >= end_ms {
            break;
        }
        let task = task.clone();
        let queue = queues.get_mut(&task).expect("picked from these queues");
        // Same coalescing rule as Dispatcher::drive.
        let waiting = queue.iter().take_while(|q| q.arrival_ms <= issue).count();
        let take = dispatch.take(waiting, batching);
        let batch: Vec<Query> = (0..take).map(|_| queue.pop_front().unwrap()).collect();
        let refs: Vec<&Query> = batch.iter().collect();
        let evs = session.submit_batch(&refs)?;
        for ev in &evs {
            part.observe_shard_outcome(me, ev);
        }
        if foreign.contains(&task) {
            part.note_steal(me);
        }
        events.extend(evs);
        batches += 1;
    }
    Ok((part, events, batches))
}

/// Per-shard queueing backlog as admission sees it: per task, the
/// delay its *next pending* query is headed for (ready − arrival),
/// summed over each shard's tasks. Tasks with no queued work
/// contribute nothing.
fn backlog_per_shard(
    sessions: &[Session<'_, '_>],
    pending: &BTreeMap<String, VecDeque<Query>>,
    assignment: &BTreeMap<String, usize>,
    n: usize,
) -> Vec<f64> {
    let mut backlog = vec![0.0f64; n];
    for (t, &si) in assignment {
        let Some(front) = pending.get(t).and_then(|q| q.front()) else {
            continue;
        };
        let ready = sessions[si].ready_of(t).unwrap_or(0.0);
        backlog[si] += (ready - front.arrival_ms).max(0.0);
    }
    backlog
}

/// One shard's queueing backlog alone — the allocation-free scalar the
/// per-batch saturation checks use ([`backlog_per_shard`] restricted
/// to `shard`).
fn backlog_of_shard(
    sessions: &[Session<'_, '_>],
    pending: &BTreeMap<String, VecDeque<Query>>,
    assignment: &BTreeMap<String, usize>,
    shard: usize,
) -> f64 {
    let mut backlog = 0.0f64;
    for (t, &si) in assignment {
        if si != shard {
            continue;
        }
        let Some(front) = pending.get(t).and_then(|q| q.front()) else {
            continue;
        };
        let ready = sessions[si].ready_of(t).unwrap_or(0.0);
        backlog += (ready - front.arrival_ms).max(0.0);
    }
    backlog
}

/// One shard's saturation threshold: `slack ×` the mean SLO latency
/// bound of its tasks (`None` when the shard has no SLO'd tasks).
fn saturation_threshold(
    slack: f64,
    slos: &BTreeMap<String, Slo>,
    assignment: &BTreeMap<String, usize>,
    shard: usize,
) -> Option<f64> {
    let mut sum = 0.0;
    let mut count = 0usize;
    for (t, &si) in assignment {
        if si == shard {
            if let Some(slo) = slos.get(t) {
                sum += slo.max_latency_ms;
                count += 1;
            }
        }
    }
    if count == 0 {
        None
    } else {
        Some(slack * sum / count as f64)
    }
}

/// Observed mean coalesced batch size per task (the batch hint for
/// migrant re-selection), read from each task's home session.
fn observed_mean_batch(
    sessions: &[Session<'_, '_>],
    assignment: &BTreeMap<String, usize>,
    tasks: &[String],
) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for t in tasks {
        if let Some(mb) = sessions[assignment[t]].mean_batch_of(t) {
            out.insert(t.clone(), mb);
        }
    }
    out
}

/// Raise every serving shard's FIFO floor for `task` to the latest
/// completion among them — the invariant that keeps a stolen task's
/// queries ordered no matter which shard serves the next batch.
fn sync_ready_floors(sessions: &mut [Session<'_, '_>], serving: &[usize], task: &str) {
    let mut floor = 0.0f64;
    for &i in serving {
        if let Some(r) = sessions[i].ready_of(task) {
            floor = floor.max(r);
        }
    }
    for &i in serving {
        sessions[i].raise_ready_floor(task, floor);
    }
}

/// Restrict a scenario to one shard's partition: the task list, every
/// schedule entry, and the fault profile — re-indexed so the shard's
/// own crash windows and degradations sit at shard 0 (the session's
/// view of itself; cross-shard concerns drop out, see
/// [`FaultProfile::for_shard`]). SLOs of foreign tasks would otherwise
/// leak into the shard's planning and (budget < 1) preloading.
fn sub_scenario(scenario: &Scenario, tasks: &[String], shard: usize) -> Scenario {
    let schedule: Vec<BTreeMap<String, Slo>> = scenario
        .schedule
        .iter()
        .map(|cfg| {
            cfg.iter()
                .filter(|&(t, _)| tasks.contains(t))
                .map(|(t, slo)| (t.clone(), *slo))
                .collect()
        })
        .collect();
    scenario
        .clone()
        .with_tasks(tasks)
        .with_schedule(schedule)
        .with_faults(scenario.faults.for_shard(shard))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tests::{setup, slos};
    use crate::fixtures;
    use crate::scenario::{Admission, PlannerConfig};
    use crate::workload::Slo;

    fn tiny_tasks() -> Vec<String> {
        vec!["tiny".to_string()]
    }

    /// A dense same-task arrival ramp that must build backlog.
    fn ramp(task: &str, n: usize, gap_ms: f64) -> Vec<Query> {
        (0..n)
            .map(|i| Query {
                task: task.to_string(),
                arrival_ms: i as f64 * gap_ms,
                id: i as u64,
            })
            .collect()
    }

    #[test]
    fn batching_never_reorders_requests_within_a_task() {
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        // ~17 ms service vs 1 ms inter-arrival: heavy backlog.
        let sc = Scenario::trace(&tiny_tasks(), slos(0.5, 1e9), ramp("tiny", 40, 1.0))
            .with_dispatch(Dispatch { max_batch: 4, min_queue: 2 });
        let report = server.run(&sc).unwrap();
        assert_eq!(report.total_queries, 40);
        assert!(
            report.total_batches < 40,
            "backlog must trigger coalescing ({} batches)",
            report.total_batches
        );
        assert!(report.mean_batch_size() > 1.0);
        assert!(report.outcomes[0].max_batch > 1);
        assert!(report.outcomes[0].max_batch <= 4);
        // FIFO within the task: ids in arrival order, times monotone.
        let ids: Vec<u64> = report.requests.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted, "batching must not reorder a task's queries");
        for w in report.requests.windows(2) {
            assert!(w[1].start_ms >= w[0].start_ms - 1e-9);
            assert!(w[1].finish_ms >= w[0].finish_ms - 1e-9);
        }
    }

    #[test]
    fn below_threshold_dispatch_matches_unbatched_run() {
        // A batching dispatcher whose threshold is never reached must
        // reproduce the unbatched run event-for-event.
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let base = Scenario::poisson(&tiny_tasks(), slos(0.5, 1e9), 30.0, 3_000.0)
            .with_seed(5);
        let plain = server.run(&base).unwrap();
        let gated = server
            .run(
                &base
                    .clone()
                    .with_dispatch(Dispatch { max_batch: 8, min_queue: usize::MAX }),
            )
            .unwrap();
        assert_eq!(plain.total_queries, gated.total_queries);
        assert_eq!(plain.total_batches, gated.total_batches);
        assert!((plain.makespan_ms - gated.makespan_ms).abs() < 1e-6);
        for (a, b) in plain.requests.iter().zip(&gated.requests) {
            assert_eq!(a.id, b.id);
            assert!((a.start_ms - b.start_ms).abs() < 1e-9);
            assert!((a.finish_ms - b.finish_ms).abs() < 1e-9);
        }
    }

    #[test]
    fn batching_drains_backlog_faster() {
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let sc = Scenario::trace(&tiny_tasks(), slos(0.5, 1e9), ramp("tiny", 60, 1.0));
        let alone = server.run(&sc).unwrap();
        let batched = server
            .run(&sc.clone().with_dispatch(Dispatch::batched(4)))
            .unwrap();
        assert_eq!(alone.total_queries, batched.total_queries);
        assert!(
            batched.makespan_ms < alone.makespan_ms,
            "batch 4 must drain faster: {} vs {} ms",
            batched.makespan_ms,
            alone.makespan_ms
        );
        // Sub-linear batch cost ⇒ strictly higher throughput.
        assert!(batched.throughput_qps() > alone.throughput_qps());
    }

    #[test]
    fn sharding_partitions_tasks_and_aggregates_reports() {
        let (zoo, lm, profiles) = fixtures::trio();
        let tasks = fixtures::task_names(&zoo);
        let slo_map = fixtures::slos(&zoo, 0.5, 1e9);
        let sc = Scenario::poisson(&tasks, slo_map, 10.0, 2_000.0).with_seed(3);

        let single = Server::builder(&zoo, &lm, &profiles).build().run(&sc).unwrap();
        let sharded = ShardedServer::build(
            &zoo,
            &lm,
            &profiles,
            ServeOpts::default(),
            Sharding::hash(2),
        )
        .unwrap();
        let report = sharded.run(&sc).unwrap();

        assert_eq!(report.per_shard.len(), 2);
        // Every task is served by exactly one shard.
        let served: usize = report.per_shard.iter().map(|r| r.outcomes.len()).sum();
        assert_eq!(served, tasks.len());
        // Aggregate counts are the per-shard sums; makespan is the max.
        assert_eq!(
            report.aggregate.total_queries,
            report.per_shard.iter().map(|r| r.total_queries).sum::<usize>()
        );
        let max_ms = report
            .per_shard
            .iter()
            .map(|r| r.makespan_ms)
            .fold(0.0f64, f64::max);
        assert!((report.aggregate.makespan_ms - max_ms).abs() < 1e-9);
        // Same arrivals, everything admitted: identical completed counts.
        assert_eq!(report.aggregate.total_queries, single.total_queries);
        assert_eq!(report.aggregate.total_dropped, 0);
        // Less contention can only finish no later than the single SoC.
        assert!(report.aggregate.makespan_ms <= single.makespan_ms + 1e-6);
    }

    #[test]
    fn explicit_assignment_and_fallbacks() {
        let sharding = Sharding::explicit(
            BTreeMap::from([("alpha".to_string(), 1), ("beta".to_string(), 5)]),
            2,
        );
        assert_eq!(sharding.shard_of("alpha"), 1);
        // Out-of-range indices wrap instead of panicking.
        assert_eq!(sharding.shard_of("beta"), 1);
        // Every unlisted task falls back to the hash rule, bit-for-bit.
        for task in ["gamma", "delta", "tiny", "task00", "task17"] {
            assert_eq!(
                sharding.shard_of(task),
                crate::workload::shard_of_task(task, 2),
                "{task} must hash-fall-back"
            );
        }
        // Degenerate configs are clamped.
        assert_eq!(Sharding::hash(0).shards, 1);
        assert_eq!(Dispatch::batched(0).max_batch, 1);
        assert!(!Dispatch::none().is_batching());
    }

    #[test]
    fn take_edge_cases_are_deterministic() {
        // The coalescing rule's corners, pinned: `take` never returns 0
        // and never exceeds the waiting count or (clamped) max_batch.
        let d = Dispatch { max_batch: 4, min_queue: 2 };
        assert_eq!(d.take(0, true), 1, "the head query always dispatches");
        assert_eq!(d.take(0, false), 1);
        assert_eq!(d.take(1, true), 1, "below min_queue: no coalescing");
        assert_eq!(d.take(2, true), 2);
        assert_eq!(d.take(7, true), 4, "capped at max_batch");
        assert_eq!(d.take(7, false), 1, "batching off: always 1");
        // A hand-built degenerate max_batch = 0 behaves like 1 — it
        // must never dispatch an empty batch (the drive loops rely on
        // every step consuming at least one query).
        let degenerate = Dispatch { max_batch: 0, min_queue: 0 };
        assert_eq!(degenerate.take(0, true), 1);
        assert_eq!(degenerate.take(5, true), 1, "max_batch 0 ≡ max_batch 1");
        assert_eq!(degenerate.take(5, false), 1);
        // min_queue = 0 behaves like 1 (the head always qualifies).
        let eager = Dispatch { max_batch: 3, min_queue: 0 };
        assert_eq!(eager.take(1, true), 1);
        assert_eq!(eager.take(2, true), 2);
        assert_eq!(eager.take(9, true), 3);
    }

    #[test]
    fn sharded_batched_beats_single_server_under_backlog() {
        // The headline property: a bursty overload scenario completes
        // strictly more requests with 2 shards × batch-4 dispatch than
        // the single-server unbatched baseline under the same deadline
        // admission (see `experiments::endtoend::backlog_comparison`).
        let (zoo, lm, profiles) = fixtures::trio();
        let tasks = fixtures::task_names(&zoo);
        let slo_map = fixtures::slos(&zoo, 0.5, 60.0);
        let sc = Scenario::bursty(&tasks, slo_map, 4.0, 120.0, 500.0, 4_000.0)
            .with_seed(11)
            .with_admission(Admission::Deadline { slack: 2.0 });

        let single = Server::builder(&zoo, &lm, &profiles).build().run(&sc).unwrap();
        assert!(single.total_dropped > 0, "baseline must actually be overloaded");

        let scaled = ShardedServer::build(
            &zoo,
            &lm,
            &profiles,
            ServeOpts::default(),
            Sharding::hash(2),
        )
        .unwrap()
        .run(&sc.clone().with_dispatch(Dispatch::batched(4)))
        .unwrap();

        assert!(
            scaled.aggregate.total_queries > single.total_queries,
            "2 shards × batch 4 must complete strictly more: {} vs {}",
            scaled.aggregate.total_queries,
            single.total_queries
        );
        assert!(scaled.aggregate.total_dropped < single.total_dropped);
    }

    /// The skewed explicit partition of the backlog studies: the three
    /// flood tasks share shard 0, `gamma` idles on shard 1.
    fn skewed_sharding() -> Sharding {
        Sharding::explicit(
            BTreeMap::from([
                ("alpha".to_string(), 0),
                ("beta".to_string(), 0),
                ("delta".to_string(), 0),
                ("gamma".to_string(), 1),
            ]),
            2,
        )
    }

    #[test]
    fn replan_beats_static_sharding_under_backlog() {
        // The acceptance property: under bursty overload with a skewed
        // static partition (three flooded tasks share shard 0, one
        // idles on shard 1), the batch-aware plan with online
        // re-planning completes at least as many requests with fewer
        // SLO-shed drops than the PR 2 static sharded baseline — and
        // never reorders queries within a task.
        let (zoo, lm, profiles) = fixtures::quartet();
        let tasks = fixtures::task_names(&zoo);
        let slo_map = fixtures::slos(&zoo, 0.5, 60.0);
        let sharding = skewed_sharding();
        let sc = Scenario::bursty(&tasks, slo_map, 4.0, 100.0, 500.0, 4_000.0)
            .with_seed(11)
            .with_admission(Admission::Deadline { slack: 2.0 })
            .with_dispatch(Dispatch::batched(4))
            .with_sharding(sharding.clone());

        let static_run = ShardedServer::build(
            &zoo,
            &lm,
            &profiles,
            ServeOpts::default(),
            sharding.clone(),
        )
        .unwrap()
        .run(&sc)
        .unwrap();
        assert!(
            static_run.aggregate.total_dropped > 0,
            "the static partition must actually be overloaded"
        );
        assert_eq!(static_run.migrations, 0, "static path never migrates");

        let replan_sc = sc
            .clone()
            .with_planner(PlannerConfig { max_migrations: 2, ..PlannerConfig::replanning() });
        // Batch-aware Algorithm 1 at the dispatch operating point.
        let opts = ServeOpts { batch_hint: 4.0, ..Default::default() };
        let replanned = ShardedServer::build(&zoo, &lm, &profiles, opts, sharding)
            .unwrap()
            .run(&replan_sc)
            .unwrap();

        assert!(replanned.migrations >= 1, "saturation must trigger a migration");
        assert!(replanned.replans >= replanned.migrations);
        assert!(
            replanned.aggregate.total_queries >= static_run.aggregate.total_queries,
            "replan must complete at least as many: {} vs {}",
            replanned.aggregate.total_queries,
            static_run.aggregate.total_queries
        );
        assert!(
            replanned.aggregate.total_dropped < static_run.aggregate.total_dropped,
            "replan must shed less: {} vs {}",
            replanned.aggregate.total_dropped,
            static_run.aggregate.total_dropped
        );
        // Per-shard budget utilization is reported for every shard.
        assert_eq!(replanned.budget_utilization.len(), 2);
        assert!(replanned.budget_utilization.iter().all(|&u| (0.0..=1.0).contains(&u)));
        // Planner::replan never reorders queries within a task: in
        // id (= per-task arrival) order, completions stay monotone
        // even across the migration boundary.
        for task in ["alpha", "beta", "delta", "gamma"] {
            let mut reqs: Vec<_> = replanned
                .aggregate
                .requests
                .iter()
                .filter(|r| r.task == task && !r.dropped)
                .collect();
            reqs.sort_by_key(|r| r.id);
            for w in reqs.windows(2) {
                assert!(
                    w[1].start_ms >= w[0].start_ms - 1e-9,
                    "{task}: query {} started before query {}",
                    w[1].id,
                    w[0].id
                );
                assert!(w[1].finish_ms >= w[0].finish_ms - 1e-9, "{task}");
            }
        }
    }

    #[test]
    fn stealing_warm_migration_beats_replan_under_backlog() {
        // The telemetry-control-plane acceptance property, on the same
        // backlog fixture as `replan_beats_static_sharding_under_backlog`:
        // with query-level stealing + warm migration on top of
        // re-planning, the steal+warm arm completes at least as many
        // requests with fewer drops and *strictly fewer cold compiles*
        // than the PR 3 replan baseline — and per-task FIFO order still
        // holds across every steal and migration.
        let (zoo, lm, profiles) = fixtures::quartet();
        let tasks = fixtures::task_names(&zoo);
        let slo_map = fixtures::slos(&zoo, 0.5, 60.0);
        let sharding = skewed_sharding();
        let sc = Scenario::bursty(&tasks, slo_map, 4.0, 100.0, 500.0, 4_000.0)
            .with_seed(11)
            .with_admission(Admission::Deadline { slack: 2.0 })
            .with_dispatch(Dispatch::batched(4))
            .with_sharding(sharding.clone());
        let opts = ServeOpts { batch_hint: 4.0, ..Default::default() };

        // PR 3 baseline: whole-task re-planning, cold adoption.
        let replan_sc = sc.clone().with_planner(PlannerConfig {
            max_migrations: 2,
            ..PlannerConfig::replanning()
        });
        let replan =
            ShardedServer::build(&zoo, &lm, &profiles, opts.clone(), sharding.clone())
                .unwrap()
                .run(&replan_sc)
                .unwrap();
        assert!(replan.migrations >= 1, "the baseline must actually migrate");
        assert_eq!(replan.steals, 0, "the replan-only path never steals");
        assert!(
            replan.aggregate.cold_compiles >= 1,
            "a cold adoption must compile the migrant's blobs"
        );
        assert_eq!(replan.aggregate.warm_loads, 0, "nothing transfers cold");

        // The full online stack: replan + steal + warm migration.
        let warm_sc = sc.clone().with_planner(PlannerConfig {
            max_migrations: 2,
            ..PlannerConfig::online()
        });
        let warm = ShardedServer::build(&zoo, &lm, &profiles, opts, sharding)
            .unwrap()
            .run(&warm_sc)
            .unwrap();

        assert!(warm.steals >= 1, "saturation must trigger query stealing");
        assert!(
            warm.aggregate.warm_loads >= 1,
            "adoption must carry pool contents across shards"
        );
        assert!(
            warm.aggregate.total_queries >= replan.aggregate.total_queries,
            "steal+warm must complete at least as many: {} vs {}",
            warm.aggregate.total_queries,
            replan.aggregate.total_queries
        );
        assert!(
            warm.aggregate.total_dropped < replan.aggregate.total_dropped,
            "steal+warm must shed less: {} vs {}",
            warm.aggregate.total_dropped,
            replan.aggregate.total_dropped
        );
        assert!(
            warm.aggregate.cold_compiles < replan.aggregate.cold_compiles,
            "warm migration must strictly reduce cold compiles: {} vs {}",
            warm.aggregate.cold_compiles,
            replan.aggregate.cold_compiles
        );
        // Telemetry reports an arrival-rate estimate for served tasks.
        assert!(
            !warm.arrival_est_qps.is_empty(),
            "the online drive must report telemetry estimates"
        );
        for (task, qps) in &warm.arrival_est_qps {
            assert!(qps.is_finite() && *qps > 0.0, "{task}: {qps}");
        }
        // Per-task FIFO order holds across steals and migrations: in id
        // (= per-task arrival) order, starts and completions stay
        // monotone even when consecutive queries ran on different
        // shards.
        for task in ["alpha", "beta", "delta", "gamma"] {
            let mut reqs: Vec<_> = warm
                .aggregate
                .requests
                .iter()
                .filter(|r| r.task == task && !r.dropped)
                .collect();
            reqs.sort_by_key(|r| r.id);
            for w in reqs.windows(2) {
                assert!(
                    w[1].start_ms >= w[0].start_ms - 1e-9,
                    "{task}: query {} started before query {}",
                    w[1].id,
                    w[0].id
                );
                assert!(w[1].finish_ms >= w[0].finish_ms - 1e-9, "{task}");
            }
        }
    }

    #[test]
    fn predictive_admission_beats_reactive_under_burst() {
        // The PR 5 acceptance property, on the same skewed bursty
        // fixture as the replan/steal studies: the predictive arm —
        // `Admission::Predictive` (shed on projected queueing) plus the
        // forecast-triggered online stack — must record strictly fewer
        // deadline misses (completed queries whose end-to-end
        // arrival→finish time blew the 60 ms SLO bound) than the
        // reactive `Admission::Fair` static baseline, while completing
        // no fewer requests.
        let (zoo, lm, profiles) = fixtures::quartet();
        let tasks = fixtures::task_names(&zoo);
        let bound_ms = 60.0;
        let slo_map = fixtures::slos(&zoo, 0.5, bound_ms);
        let sharding = skewed_sharding();
        let base = Scenario::bursty(&tasks, slo_map, 4.0, 100.0, 500.0, 4_000.0)
            .with_seed(11)
            .with_dispatch(Dispatch::batched(4))
            .with_sharding(sharding.clone());

        // Reactive baseline: sheds only once deadline slack is gone.
        let fair_sc = base.clone().with_admission(Admission::Fair {
            slack: 2.0,
            weights: BTreeMap::new(),
        });
        let fair = ShardedServer::build(
            &zoo,
            &lm,
            &profiles,
            ServeOpts::default(),
            sharding.clone(),
        )
        .unwrap()
        .run(&fair_sc)
        .unwrap();
        assert!(
            fair.aggregate.total_dropped > 0,
            "the reactive baseline must actually be overloaded"
        );

        // Predictive arm: forecast admission + forecast-driven
        // replan/steal/warm-migration.
        let pred_sc = base
            .clone()
            .with_admission(Admission::Predictive {
                horizon_ms: 100.0,
                headroom: 2.0,
            })
            .with_planner(PlannerConfig {
                max_migrations: 2,
                ..PlannerConfig::predictive()
            });
        let opts = ServeOpts { batch_hint: 4.0, ..Default::default() };
        let pred = ShardedServer::build(&zoo, &lm, &profiles, opts, sharding)
            .unwrap()
            .run(&pred_sc)
            .unwrap();

        let deadline_misses = |r: &crate::metrics::ShardedReport| {
            r.aggregate
                .requests
                .iter()
                .filter(|q| !q.dropped && q.finish_ms - q.arrival_ms > bound_ms)
                .count()
        };
        let fair_misses = deadline_misses(&fair);
        let pred_misses = deadline_misses(&pred);
        assert!(fair_misses > 0, "reactive admission must serve doomed queries");
        assert!(
            pred_misses < fair_misses,
            "predictive arm must record strictly fewer deadline misses: \
             {pred_misses} vs {fair_misses}"
        );
        assert!(
            pred.aggregate.total_queries >= fair.aggregate.total_queries,
            "predictive arm must complete no fewer: {} vs {}",
            pred.aggregate.total_queries,
            fair.aggregate.total_queries
        );
        // The forecast trigger fired (the fixture saturates by design —
        // stealing may pre-empt whole-task replanning, so assert on the
        // union), and the report surfaces carry the SLO forecast.
        assert!(
            pred.migrations + pred.steals >= 1,
            "the forecast-triggered online stack must actually move work"
        );
        assert!(
            !pred.slo_forecast().is_empty(),
            "the sharded report must export a per-task SLO forecast"
        );
        assert!(pred
            .slo_forecast()
            .values()
            .all(|p| p.is_finite() && (0.0..=1.0).contains(p)));
    }

    #[test]
    fn steal_only_noop_without_saturation() {
        // A steal-enabled run that never saturates must match the
        // static path's outcome counts exactly — stealing is a backlog
        // response, not a steady-state rebalancer.
        let (zoo, lm, profiles) = fixtures::trio();
        let tasks = fixtures::task_names(&zoo);
        let light = Scenario::poisson(&tasks, fixtures::slos(&zoo, 0.5, 1e9), 2.0, 2_000.0)
            .with_seed(3);
        let build = || {
            ShardedServer::build(
                &zoo,
                &lm,
                &profiles,
                ServeOpts::default(),
                Sharding::hash(2),
            )
            .unwrap()
        };
        let plain = build().run(&light).unwrap();
        let stealing = build()
            .run(&light.clone().with_planner(PlannerConfig::stealing()))
            .unwrap();
        assert_eq!(stealing.steals, 0, "no saturation ⇒ no stealing");
        assert_eq!(stealing.migrations, 0, "steal-only path never migrates");
        assert_eq!(stealing.aggregate.total_queries, plain.aggregate.total_queries);
        assert_eq!(stealing.aggregate.total_dropped, plain.aggregate.total_dropped);
        assert_eq!(stealing.aggregate.cold_compiles, 0);
        // The online drive still reports telemetry estimates.
        assert!(!stealing.arrival_est_qps.is_empty());
    }

    #[test]
    fn replan_noop_without_saturation_or_on_closed_loops() {
        // A replan-enabled run that never saturates must match the
        // static path's outcome counts; closed loops take the static
        // path outright (self-clocking ⇒ no backlog to observe).
        let (zoo, lm, profiles) = fixtures::trio();
        let tasks = fixtures::task_names(&zoo);
        let light = Scenario::poisson(&tasks, fixtures::slos(&zoo, 0.5, 1e9), 2.0, 2_000.0)
            .with_seed(3);
        let build = || {
            ShardedServer::build(
                &zoo,
                &lm,
                &profiles,
                ServeOpts::default(),
                Sharding::hash(2),
            )
            .unwrap()
        };
        let plain = build().run(&light).unwrap();
        let replan = build()
            .run(&light.clone().with_planner(PlannerConfig::replanning()))
            .unwrap();
        assert_eq!(replan.migrations, 0, "no saturation ⇒ no migration");
        assert_eq!(replan.aggregate.total_queries, plain.aggregate.total_queries);
        assert_eq!(replan.aggregate.total_dropped, plain.aggregate.total_dropped);

        let closed = Scenario::closed_loop(&tasks, fixtures::slos(&zoo, 0.5, 1e9))
            .with_queries(5)
            .with_planner(PlannerConfig::replanning());
        let r = build().run(&closed).unwrap();
        assert_eq!(r.migrations, 0);
        assert_eq!(r.aggregate.total_queries, 15);
    }

    #[test]
    fn fair_with_single_task_equals_deadline() {
        // With no other tasks the share clause can never fire (both
        // sides of the strict comparison are zero), so Fair must shed
        // exactly like Deadline — a single-task shard keeps admission
        // control.
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let heavy = Scenario::poisson(&tiny_tasks(), slos(0.5, 50.0), 200.0, 2_000.0)
            .with_seed(7);
        let deadline = server
            .run(&heavy.clone().with_admission(Admission::Deadline { slack: 2.0 }))
            .unwrap();
        let fair = server
            .run(&heavy.with_admission(Admission::Fair {
                slack: 2.0,
                weights: BTreeMap::new(),
            }))
            .unwrap();
        assert!(deadline.total_dropped > 0, "overload must shed");
        assert_eq!(fair.total_dropped, deadline.total_dropped);
        assert_eq!(fair.total_queries, deadline.total_queries);
        assert!((fair.makespan_ms - deadline.makespan_ms).abs() < 1e-9);
        // Asserted, not assumed: the two runs agree event-for-event.
        assert_eq!(fair.requests.len(), deadline.requests.len());
        for (f, d) in fair.requests.iter().zip(&deadline.requests) {
            assert_eq!(f.id, d.id);
            assert_eq!(f.dropped, d.dropped);
            assert!((f.start_ms - d.start_ms).abs() < 1e-9);
            assert!((f.finish_ms - d.finish_ms).abs() < 1e-9);
        }
    }

    #[test]
    fn fair_admission_degenerate_weights_never_divide_by_zero() {
        // Explicit zero weights must be inert, not a division hazard:
        // with every weight zero the share clause compares 0 < 0 and
        // Fair degrades to exactly Deadline — finite outcomes, no NaN
        // timestamps, identical event logs.
        let (zoo, lm, profiles) = fixtures::trio();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let tasks = fixtures::task_names(&zoo);
        let heavy = Scenario::poisson(&tasks, fixtures::slos(&zoo, 0.5, 40.0), 120.0, 2_000.0)
            .with_seed(9);
        let deadline = server
            .run(&heavy.clone().with_admission(Admission::Deadline { slack: 1.5 }))
            .unwrap();
        assert!(deadline.total_dropped > 0, "overload must shed");
        let zero_weights: BTreeMap<String, f64> =
            tasks.iter().map(|t| (t.clone(), 0.0)).collect();
        let fair = server
            .run(&heavy.clone().with_admission(Admission::Fair {
                slack: 1.5,
                weights: zero_weights,
            }))
            .unwrap();
        assert_eq!(fair.total_dropped, deadline.total_dropped);
        assert_eq!(fair.total_queries, deadline.total_queries);
        assert_eq!(fair.requests.len(), deadline.requests.len());
        for (f, d) in fair.requests.iter().zip(&deadline.requests) {
            assert_eq!((f.id, f.dropped), (d.id, d.dropped));
            assert!(f.start_ms.is_finite() && f.finish_ms.is_finite());
            assert!((f.finish_ms - d.finish_ms).abs() < 1e-9);
        }
        // A single zero-weighted task among weighted floods loses only
        // its share-clause bonus — it still keeps the Deadline floor,
        // so every outcome stays finite and accounted.
        let one_zero = server
            .run(&heavy.with_admission(Admission::Fair {
                slack: 1.5,
                weights: BTreeMap::from([("alpha".to_string(), 0.0)]),
            }))
            .unwrap();
        assert_eq!(
            one_zero.total_queries + one_zero.total_dropped,
            one_zero.requests.len()
        );
        assert!(one_zero.requests.iter().all(|r| r.finish_ms.is_finite()));
        let f = one_zero.fairness_index();
        assert!(f.is_finite() && f > 0.0);
    }

    #[test]
    fn fair_admission_protects_weighted_task_burst() {
        let (zoo, lm, profiles) = fixtures::trio();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        // alpha and beta flood (1 query/ms each); deadline admission
        // throttles them at their own generous budget (2 × 100 ms), so
        // by t ≈ 400 ms both hold ≈ 200 ms of standing backlog. Then
        // gamma — the latency-critical tenant with a tight 2 × 30 ms
        // budget — takes a 20-query burst at t = 600 ms. Under plain
        // `Deadline` the burst's own queue blows gamma's small budget
        // after a handful of queries and the tail is shed; under
        // weighted-fair admission gamma's per-weight backlog (8× weight)
        // stays well under the floods' standing per-weight backlog, so
        // the whole burst is admitted.
        let mut queries = ramp("alpha", 1_500, 1.0);
        for (k, q) in ramp("beta", 1_500, 1.0).into_iter().enumerate() {
            queries.push(Query { id: 5_000 + k as u64, ..q });
        }
        for i in 0..20u64 {
            queries.push(Query {
                task: "gamma".to_string(),
                arrival_ms: 600.0 + 0.1 * i as f64,
                id: 10_000 + i,
            });
        }
        let tasks: Vec<String> =
            ["alpha", "beta", "gamma"].iter().map(|s| s.to_string()).collect();
        let mut slo_map = BTreeMap::new();
        for flood in ["alpha", "beta"] {
            slo_map
                .insert(flood.to_string(), Slo { min_accuracy: 0.5, max_latency_ms: 100.0 });
        }
        slo_map.insert("gamma".to_string(), Slo { min_accuracy: 0.5, max_latency_ms: 30.0 });
        let base = Scenario::trace(&tasks, slo_map, queries);

        let deadline = server
            .run(&base.clone().with_admission(Admission::Deadline { slack: 2.0 }))
            .unwrap();
        let fair = server
            .run(&base.with_admission(Admission::Fair {
                slack: 2.0,
                weights: BTreeMap::from([("gamma".to_string(), 8.0)]),
            }))
            .unwrap();

        let completed = |r: &RunReport, task: &str| {
            r.outcomes
                .iter()
                .find(|o| o.task == task)
                .map(|o| o.queries_completed)
                .unwrap()
        };
        // Plain deadline admission sheds most of the burst…
        assert!(deadline.outcomes.iter().any(|o| o.queries_dropped > 0));
        assert!(
            completed(&deadline, "gamma") < 10,
            "deadline admission must shed the burst tail (completed {})",
            completed(&deadline, "gamma")
        );
        // …while weighted-fair admission keeps the weighted task whole.
        assert_eq!(
            completed(&fair, "gamma"),
            20,
            "fair admission must keep the weighted task's burst whole"
        );
        // The floods are still shed at their own deadline budget.
        assert!(
            fair.outcomes.iter().find(|o| o.task == "alpha").unwrap().queries_dropped > 0,
            "fair admission must still throttle the flood"
        );
        // The index stays within Jain bounds on both runs.
        for r in [&deadline, &fair] {
            let f = r.fairness_index();
            assert!((1.0 / 3.0..=1.0).contains(&f), "Jain bounds: {f}");
        }
    }
}
