//! The platform latency model: measured PJRT base × processor scaling.
//!
//! `BaseLatencies` holds *measured* per-(task, subgraph, kernel-path)
//! batch-1 latencies from the real PJRT executables (filled by the
//! profiler at startup, or synthesized from HLO flops for pure-simulation
//! runs). `LatencyModel` projects those onto a `Platform`'s processors —
//! this is the Lat(s_j, p_j) the paper's equations consume.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::profile::{Platform, Processor};
use crate::zoo::{KernelPath, TaskZoo, Zoo};

/// Measured batch-1 latency (ms) per (task, subgraph, kernel path).
#[derive(Clone, Debug, Default)]
pub struct BaseLatencies {
    map: BTreeMap<(String, usize, KernelPath), f64>,
}

impl BaseLatencies {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, task: &str, sg: usize, path: KernelPath, ms: f64) {
        self.map.insert((task.to_string(), sg, path), ms);
    }

    pub fn get(&self, task: &str, sg: usize, path: KernelPath) -> Result<f64> {
        self.map
            .get(&(task.to_string(), sg, path))
            .copied()
            .with_context(|| format!("no base latency for {task}/sg{sg}/{}", path.name()))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Synthesize base latencies from manifest flops — used by pure
    /// simulation paths (benches, property tests) where running PJRT for
    /// every measurement would swamp the experiment with noise. The
    /// measured path (`profiler::measure_base_latencies`) is used by the
    /// serving binary and examples.
    pub fn from_flops(zoo: &Zoo, ns_per_flop: f64) -> Self {
        let mut out = Self::new();
        for (tname, task) in &zoo.tasks {
            for (&(sg, path, batch), hlo) in &task.hlo {
                if batch != 1 {
                    continue;
                }
                // Charge flops plus a fixed dispatch overhead; the masked
                // path touches 2× weight bytes, reflected via bytes_accessed.
                let flop_ms = hlo.flops * ns_per_flop * 1e-6;
                let mem_ms = hlo.bytes_accessed * 0.02e-6;
                out.set(tname, sg, path, 0.05 + flop_ms + mem_ms);
            }
        }
        out
    }
}

/// Lat(s_j^{t,i}, p_j): the full per-subgraph latency model.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    pub platform: Platform,
    pub base: BaseLatencies,
}

impl LatencyModel {
    pub fn new(platform: Platform, base: BaseLatencies) -> Self {
        Self { platform, base }
    }

    /// Latency of subgraph `sg` of original variant `vi` (task `tz`) on
    /// processor `proc`. `None` if the variant type is unsupported there.
    ///
    /// The *size/shape* effect comes from the measured dense-path base
    /// latency; the *variant-type* effect (INT8 speedup, sparse-engine
    /// gains, masked overhead) comes from the platform model only. Using
    /// the host-measured per-path bases here would double-count: host
    /// XLA's quant path is unusually fast at batch 1 and its masked path
    /// pays a 2× weight read that real sparse engines elide, neither of
    /// which is a property of the simulated accelerators (DESIGN.md
    /// §Substitutions).
    pub fn subgraph_ms(
        &self,
        tz: &TaskZoo,
        vi: usize,
        sg: usize,
        proc: Processor,
    ) -> Option<f64> {
        let variant = &tz.variants[vi];
        let model = self.platform.model(proc)?;
        let scale = model.scale_for(&variant.spec)?;
        let base = self.base.get(&tz.name, sg, KernelPath::Dense).ok()?;
        Some(base * scale * self.platform.dvfs_slowdown)
    }

    /// End-to-end latency (Eq. 5): sum over positions of the composed
    /// subgraph latencies on the placement order, plus the measured
    /// inter-processor hop overhead (§5.4). `None` if any subgraph is
    /// unsupported on its assigned processor.
    pub fn stitched_ms(
        &self,
        tz: &TaskZoo,
        composition: &[usize],
        order: &[Processor],
    ) -> Option<f64> {
        assert_eq!(composition.len(), order.len());
        let mut total = 0.0;
        for (j, (&vi, &proc)) in composition.iter().zip(order).enumerate() {
            let ms = self.subgraph_ms(tz, vi, j, proc)?;
            // Hop overhead applies to every stage boundary after the first.
            let hop = if j > 0 { 1.0 + self.platform.interproc_overhead } else { 1.0 };
            total += ms * hop;
        }
        Some(total)
    }

    /// Batch service-time multiplier: a coalesced batch of `batch`
    /// same-task queries occupies each stage for
    /// `1 + batch_marginal·(batch−1)` single-query latencies. The factor
    /// is 1.0 at batch 1 (the unbatched path is unchanged) and grows
    /// strictly sub-linearly, which is what makes batching under backlog
    /// a throughput win: per-query occupancy `factor/batch` falls as the
    /// batch grows.
    pub fn batch_factor(&self, batch: usize) -> f64 {
        1.0 + self.platform.batch_marginal * batch.saturating_sub(1) as f64
    }

    /// Batch-aware `subgraph_ms`: the stage occupancy of serving `batch`
    /// coalesced queries of variant `vi`'s subgraph `sg` on `proc`.
    pub fn subgraph_batch_ms(
        &self,
        tz: &TaskZoo,
        vi: usize,
        sg: usize,
        proc: Processor,
        batch: usize,
    ) -> Option<f64> {
        self.subgraph_ms(tz, vi, sg, proc)
            .map(|ms| ms * self.batch_factor(batch))
    }

    /// Compile-time cost (ms) of preparing one subgraph's executable for
    /// `proc` (paper Fig. 5a: ≈23.7× inference).
    pub fn compile_ms(&self, bytes: u64, proc: Processor) -> f64 {
        let mib = bytes as f64 / (1024.0 * 1024.0);
        self.platform
            .model(proc)
            .map(|m| m.compile_ms_per_mib * mib)
            .unwrap_or(0.0)
    }

    /// Weight-load cost (ms) for moving a blob into `proc`'s pool
    /// (paper Fig. 5a: ≈3× inference; dominates switching).
    pub fn load_ms(&self, bytes: u64, proc: Processor) -> f64 {
        let mib = bytes as f64 / (1024.0 * 1024.0);
        self.platform
            .model(proc)
            .map(|m| m.load_ms_per_mib * mib)
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::zoo::{
        DType, HloArtifact, Precision, SubgraphWeights, TaskVariant, TensorSpec,
        VariantSpec, VariantType,
    };
    use std::path::PathBuf;

    /// Hand-build a minimal 2-variant, 2-subgraph TaskZoo for unit tests.
    pub fn tiny_taskzoo() -> TaskZoo {
        let mk_spec = |name: &str, vt, sp, kp| VariantSpec {
            name: name.into(),
            vtype: vt,
            sparsity: sp,
            kernel_path: kp,
            precision: Precision::Fp32,
        };
        let sw = |bytes| SubgraphWeights {
            file: PathBuf::from("/dev/null"),
            bytes,
            params: vec![TensorSpec { dtype: DType::F32, shape: vec![4] }],
        };
        let mut hlo = BTreeMap::new();
        for sg in 0..2 {
            for path in [KernelPath::Dense, KernelPath::BlockSparse] {
                hlo.insert(
                    (sg, path, 1),
                    HloArtifact {
                        file: PathBuf::from("/dev/null"),
                        flops: 1000.0,
                        bytes_accessed: 100.0,
                        params: vec![],
                        input_dim: 8,
                        output_dim: 8,
                    },
                );
            }
        }
        TaskZoo {
            name: "tiny".into(),
            family: "test".into(),
            input_dim: 8,
            iface: vec![8, 8, 8],
            variants: vec![
                TaskVariant {
                    spec: mk_spec("dense", VariantType::Dense, 0.0, KernelPath::Dense),
                    accuracy: 0.9,
                    subgraphs: vec![sw(1000), sw(1000)],
                },
                TaskVariant {
                    spec: mk_spec("struct50", VariantType::Structured, 0.5, KernelPath::BlockSparse),
                    accuracy: 0.7,
                    subgraphs: vec![sw(600), sw(600)],
                },
            ],
            hlo,
        }
    }

    fn base_for(tz: &TaskZoo) -> BaseLatencies {
        let mut b = BaseLatencies::new();
        for sg in 0..2 {
            b.set(&tz.name, sg, KernelPath::Dense, 10.0);
            b.set(&tz.name, sg, KernelPath::BlockSparse, 10.0);
        }
        b
    }

    #[test]
    fn scaling_applies_per_processor() {
        let tz = tiny_taskzoo();
        let lm = LatencyModel::new(Platform::desktop(), base_for(&tz));
        let cpu = lm.subgraph_ms(&tz, 0, 0, Processor::Cpu).unwrap();
        let gpu = lm.subgraph_ms(&tz, 0, 0, Processor::Gpu).unwrap();
        assert!((cpu - 10.0).abs() < 1e-9);
        assert!(gpu < cpu);
    }

    #[test]
    fn structured_variant_faster_than_dense_on_gpu() {
        let tz = tiny_taskzoo();
        let lm = LatencyModel::new(Platform::desktop(), base_for(&tz));
        let dense = lm.subgraph_ms(&tz, 0, 0, Processor::Gpu).unwrap();
        let sparse = lm.subgraph_ms(&tz, 1, 0, Processor::Gpu).unwrap();
        assert!(sparse < dense);
    }

    #[test]
    fn stitched_sums_with_hop_overhead() {
        let tz = tiny_taskzoo();
        let lm = LatencyModel::new(Platform::desktop(), base_for(&tz));
        use Processor::*;
        let lat = lm.stitched_ms(&tz, &[0, 0], &[Cpu, Cpu]).unwrap();
        let hop = lm.platform.interproc_overhead;
        assert!((lat - (10.0 + 10.0 * (1.0 + hop))).abs() < 1e-9);
    }

    #[test]
    fn dvfs_scales_everything() {
        let tz = tiny_taskzoo();
        let mut plat = Platform::desktop();
        plat.dvfs_slowdown = 2.0;
        let lm = LatencyModel::new(plat, base_for(&tz));
        let cpu = lm.subgraph_ms(&tz, 0, 0, Processor::Cpu).unwrap();
        assert!((cpu - 20.0).abs() < 1e-9);
    }

    #[test]
    fn batch_factor_sublinear_and_identity_at_one() {
        let tz = tiny_taskzoo();
        let lm = LatencyModel::new(Platform::desktop(), base_for(&tz));
        assert!((lm.batch_factor(1) - 1.0).abs() < 1e-12);
        for b in 2..=8usize {
            let f = lm.batch_factor(b);
            assert!(f > 1.0, "batch {b} must cost more than one query");
            assert!(f < b as f64, "batch {b} must amortize (factor {f})");
            // Per-query occupancy falls monotonically with batch size.
            assert!(f / b as f64 < lm.batch_factor(b - 1) / (b - 1) as f64);
        }
        let single = lm.subgraph_ms(&tz, 0, 0, Processor::Cpu).unwrap();
        let batched = lm.subgraph_batch_ms(&tz, 0, 0, Processor::Cpu, 4).unwrap();
        assert!((batched - single * lm.batch_factor(4)).abs() < 1e-9);
    }

    #[test]
    fn compile_dwarfs_load_dwarfs_inference() {
        // The Fig. 5a structure: compile ≫ load ≫ infer for MiB-scale blobs.
        let tz = tiny_taskzoo();
        let lm = LatencyModel::new(Platform::desktop(), base_for(&tz));
        let mib = 1024 * 1024;
        let c = lm.compile_ms(mib, Processor::Cpu);
        let l = lm.load_ms(mib, Processor::Cpu);
        assert!(c > 5.0 * l, "compile {c} load {l}");
    }
}
