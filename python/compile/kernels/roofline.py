"""Analytic VMEM-footprint + MXU-utilization estimates for the Pallas
kernels' real-TPU schedule.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the
L1 performance pass (EXPERIMENTS.md §Perf) reasons about the *structure*
of the BlockSpec schedule instead: per-tile VMEM residency, MXU issue
efficiency, and HBM traffic, on TPUv4-like constants.

Usage:
    python -m compile.kernels.roofline            # analyze model GEMMs
"""

from __future__ import annotations

import dataclasses

from . import sparse_matmul as sm

# TPUv4-like constants (per core).
VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128
MXU_FLOPS_PER_CYCLE = 2 * MXU_DIM * MXU_DIM  # MAC = 2 flops
HBM_BYTES_PER_CYCLE = 1.2 * 1024  # ~1.2 TB/s at ~1 GHz


@dataclasses.dataclass
class TileReport:
    """Schedule analysis of one GEMM under a (bm, bk, bn) tiling."""

    m: int
    k: int
    n: int
    bm: int
    bk: int
    bn: int
    kernel: str  # dense | masked | blocksparse | quant
    weight_bytes_per_elem: float = 4.0

    @property
    def grid(self):
        return (self.m // self.bm, self.n // self.bn, self.k // self.bk)

    @property
    def vmem_bytes(self) -> int:
        """Resident per grid step: x-tile + w-tile(+mask) + acc + bias.
        Double-buffered inputs (×2) as the Mosaic pipeline does."""
        x = self.bm * self.bk * 4
        w = self.bk * self.bn * self.weight_bytes_per_elem
        if self.kernel == "masked":
            w *= 2.0  # mask tile rides along
        if self.kernel == "quant":
            w = self.bk * self.bn * 1 + self.bn * 4  # int8 + scales
        acc = self.bm * self.bn * 4
        bias = self.bn * 4
        return int(2 * (x + w) + acc + bias)

    @property
    def vmem_ok(self) -> bool:
        return self.vmem_bytes <= VMEM_BYTES

    @property
    def mxu_utilization(self) -> float:
        """Issue efficiency: fraction of the 128×128 systolic array the
        tile shape keeps busy (edge-padding waste)."""
        eff_m = min(self.bm, MXU_DIM) / MXU_DIM if self.bm < MXU_DIM else 1.0
        eff_n = min(self.bn, MXU_DIM) / MXU_DIM if self.bn < MXU_DIM else 1.0
        # K streams through the array; only sub-128 K tiles waste issue.
        eff_k = min(self.bk, MXU_DIM) / MXU_DIM if self.bk < MXU_DIM else 1.0
        return eff_m * eff_n * eff_k

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k * self.n

    @property
    def hbm_bytes(self) -> float:
        """HBM traffic under this schedule: x tiles re-read per N-block,
        w tiles re-read per M-block, single output write."""
        gm, gn, _gk = self.grid
        x_reads = gn * self.m * self.k * 4
        w_elem = self.weight_bytes_per_elem if self.kernel != "quant" else 1.0
        w_reads = gm * self.k * self.n * w_elem
        if self.kernel == "masked":
            w_reads *= 2.0
        out = self.m * self.n * 4
        return x_reads + w_reads + out

    @property
    def compute_cycles(self) -> float:
        return self.flops / (MXU_FLOPS_PER_CYCLE * max(self.mxu_utilization, 1e-9))

    @property
    def memory_cycles(self) -> float:
        return self.hbm_bytes / HBM_BYTES_PER_CYCLE

    @property
    def bound(self) -> str:
        return "compute" if self.compute_cycles >= self.memory_cycles else "memory"

    @property
    def efficiency(self) -> float:
        """Achieved/roofline ratio: ideal cycles over scheduled cycles."""
        ideal = self.flops / MXU_FLOPS_PER_CYCLE
        return ideal / max(self.compute_cycles, self.memory_cycles)

    def row(self) -> str:
        return (
            f"{self.kernel:<11} {self.m:>5}x{self.k:<5}x{self.n:<5} "
            f"bm/bk/bn {self.bm:>3}/{self.bk:>3}/{self.bn:>3} "
            f"VMEM {self.vmem_bytes/1024:>7.1f} KiB "
            f"MXU {100*self.mxu_utilization:>5.1f} % "
            f"{self.bound:<7} eff {100*self.efficiency:>5.1f} %"
        )


def default_tiles(m: int, k: int, n: int, kernel: str = "dense") -> TileReport:
    """The tiling `sparse_matmul._block` actually picks."""
    return TileReport(
        m=m, k=k, n=n,
        bm=sm._block(m), bk=sm._block(k), bn=sm._block(n),
        kernel=kernel,
    )


def model_gemms():
    """The distinct GEMM shapes the four task models execute (batch 256
    eval shape — the throughput-relevant one)."""
    b = 256
    return [
        # imgcls: embed + residual blocks + head
        (b, 768, 256, "dense"),
        (b, 256, 256, "masked"),
        (b, 256, 256, "blocksparse"),
        (b, 256, 256, "quant"),
        # transformer tasks: qkv/o + ffn
        (b * 16, 64, 64, "dense"),
        (b * 16, 64, 128, "quant"),
        (b * 16, 96, 192, "masked"),
        (b * 32, 32, 64, "blocksparse"),
    ]


def main() -> None:
    print(f"TPUv4-like roofline: VMEM {VMEM_BYTES // (1024*1024)} MiB, "
          f"MXU {MXU_DIM}x{MXU_DIM}, HBM {HBM_BYTES_PER_CYCLE / 1024:.1f} KiB/cycle\n")
    for m, k, n, kernel in model_gemms():
        r = default_tiles(m, k, n, kernel)
        assert r.vmem_ok, f"tile spills VMEM: {r.row()}"
        print(r.row())
    print("\nsweep: K-block size for the imgcls residual GEMM (masked)")
    for bk in (32, 64, 128, 256):
        r = TileReport(m=256, k=256, n=256, bm=128, bk=bk, bn=128, kernel="masked")
        print(f"  bk={bk:<4} {r.row()}")


if __name__ == "__main__":
    main()
