//! Whole-system integration over the real artifacts: profile → plan →
//! preload → serve, for every policy and platform, with paper-shape
//! assertions (SparseLoom never worse than the baselines on violations;
//! estimator quality bounds; budget monotonicity).
//!
//! Skipped gracefully when `artifacts/` is absent.

use std::collections::BTreeMap;

use sparseloom::baselines::Policy;
use sparseloom::experiments::Ctx;
use sparseloom::metrics::Aggregate;
use sparseloom::profiler::{evaluate_estimators, ProfilerConfig};
use sparseloom::scenario::{Scenario, Server};
use sparseloom::soc::Platform;
use sparseloom::workload::{placement_orders, slo_grid, Slo, TaskRanges};

fn ctx() -> Option<Ctx> {
    Ctx::load("artifacts", false).ok()
}

fn grid_slos(
    ctx: &Ctx,
    lm: &sparseloom::soc::LatencyModel,
) -> (BTreeMap<String, Vec<Slo>>, Vec<Slo>) {
    let zoo = ctx.zoo_for(&lm.platform);
    let mut grids = BTreeMap::new();
    let mut universe = Vec::new();
    for (name, tz) in &zoo.tasks {
        let g = slo_grid(&TaskRanges::measure(tz, lm));
        universe.extend(g.iter().copied());
        grids.insert(name.clone(), g);
    }
    (grids, universe)
}

#[test]
fn all_policies_serve_all_platforms() {
    let Some(ctx) = ctx() else { return };
    let cfg = ProfilerConfig::default();
    for platform in Platform::all() {
        let lm = ctx.lm(platform.clone());
        let profiles = ctx.profiles(&lm, &cfg).unwrap();
        let zoo = ctx.zoo_for(&platform);
        let (grids, universe) = grid_slos(&ctx, &lm);
        let slos: BTreeMap<String, Slo> =
            grids.iter().map(|(n, g)| (n.clone(), g[12])).collect();
        let arrival: Vec<String> = profiles.keys().cloned().collect();
        let sc = Scenario::closed_loop(&arrival, slos)
            .with_queries(20)
            .with_universe(universe);
        for policy in Policy::all() {
            let server = Server::builder(zoo, &lm, &profiles).policy(policy).build();
            let r = server.run(&sc).unwrap();
            assert_eq!(
                r.total_queries,
                20 * profiles.len(),
                "{policy:?} on {} must serve everything (best-effort)",
                platform.name
            );
            assert!(r.throughput_qps() > 0.0);
        }
    }
}

#[test]
fn sparseloom_not_worse_than_baselines_on_violations() {
    let Some(ctx) = ctx() else { return };
    let cfg = ProfilerConfig::default();
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let profiles = ctx.profiles(&lm, &cfg).unwrap();
    let zoo = ctx.zoo_for(&platform);
    let (grids, universe) = grid_slos(&ctx, &lm);
    let arrival: Vec<String> = profiles.keys().cloned().collect();

    let mut rates = BTreeMap::new();
    for policy in Policy::all() {
        let server = Server::builder(zoo, &lm, &profiles).policy(policy).build();
        let mut agg = Aggregate::default();
        for i in 0..25 {
            let slos: BTreeMap<String, Slo> =
                grids.iter().map(|(n, g)| (n.clone(), g[i])).collect();
            let sc = Scenario::closed_loop(&arrival, slos)
                .with_queries(20)
                .with_universe(universe.clone());
            agg.push(&server.run(&sc).unwrap());
        }
        rates.insert(policy.name(), agg.mean_violation_pct());
    }
    let sl = rates["SparseLoom"];
    for policy in Policy::baselines() {
        assert!(
            sl <= rates[policy.name()] + 1e-9,
            "SparseLoom {sl} % must not exceed {} {} %",
            policy.name(),
            rates[policy.name()]
        );
    }
}

#[test]
fn estimator_quality_meets_floor() {
    let Some(ctx) = ctx() else { return };
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let orders = placement_orders(&platform, ctx.zoo.subgraphs);
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default()).unwrap();
    let mut recalls = Vec::new();
    let mut mapes = Vec::new();
    for p in profiles.values() {
        let rep = evaluate_estimators(p, &orders, &[10, 50], 300, 5);
        for (_, r) in rep.recall_at {
            recalls.push(r);
        }
        mapes.push(rep.lat_mape_pct);
    }
    let mean_recall = recalls.iter().sum::<f64>() / recalls.len() as f64;
    let mean_mape = mapes.iter().sum::<f64>() / mapes.len() as f64;
    assert!(mean_recall > 0.6, "recall {mean_recall}");
    assert!(mean_mape < 15.0, "MAPE {mean_mape}");
}

#[test]
fn memory_budget_monotone_on_real_zoo() {
    let Some(ctx) = ctx() else { return };
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default()).unwrap();
    let zoo = ctx.zoo_for(&platform);
    let (grids, universe) = grid_slos(&ctx, &lm);
    let slos: BTreeMap<String, Slo> =
        grids.iter().map(|(n, g)| (n.clone(), g[12])).collect();
    let arrival: Vec<String> = profiles.keys().cloned().collect();
    let run = |frac: f64| {
        let server = Server::builder(zoo, &lm, &profiles)
            .memory_budget_frac(frac)
            .build();
        let prepared = server.prepare(&slos, &universe).unwrap();
        let penalty: f64 = prepared.switch_penalty_ms.values().sum();
        let sc = Scenario::closed_loop(&arrival, slos.clone())
            .with_queries(20)
            .with_universe(universe.clone());
        let r = server.run(&sc).unwrap();
        (penalty, r.violation_rate())
    };
    let (pen_full, _) = run(1.0);
    let (pen_tiny, _) = run(0.05);
    assert!(
        pen_tiny >= pen_full,
        "smaller budget cannot reduce switch cost ({pen_tiny} < {pen_full})"
    );
}

#[test]
fn poisson_scenario_end_to_end_on_real_zoo() {
    let Some(ctx) = ctx() else { return };
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default()).unwrap();
    let zoo = ctx.zoo_for(&platform);
    let (grids, universe) = grid_slos(&ctx, &lm);
    let slos: BTreeMap<String, Slo> =
        grids.iter().map(|(n, g)| (n.clone(), g[12])).collect();
    let tasks: Vec<String> = profiles.keys().cloned().collect();
    let server = Server::builder(zoo, &lm, &profiles).build();
    let sc = Scenario::poisson(&tasks, slos, 20.0, 5_000.0)
        .with_universe(universe)
        .with_seed(1);
    let r = server.run(&sc).unwrap();
    assert!(r.total_queries > 0);
    assert_eq!(r.requests.len(), r.total_queries + r.total_dropped);
    for o in &r.outcomes {
        assert!(o.p50_latency_ms <= o.p99_latency_ms + 1e-9, "{o:?}");
    }
    // Replay determinism: same scenario, same stream, same report shape.
    let r2 = server.run(&sc).unwrap();
    assert_eq!(r.total_queries, r2.total_queries);
    assert!((r.makespan_ms - r2.makespan_ms).abs() < 1e-6);
}

#[test]
fn jetson_zoo_used_for_orin_when_present() {
    let Some(ctx) = ctx() else { return };
    if ctx.jetson.is_none() {
        return;
    }
    let orin = Platform::orin();
    let zoo = ctx.zoo_for(&orin);
    assert_eq!(zoo.zoo_name, "jetson");
    // Jetson zoo (Table 5) has no unstructured variants…
    assert!(zoo
        .tasks
        .values()
        .next()
        .unwrap()
        .variants
        .iter()
        .all(|v| v.spec.vtype != sparseloom::zoo::VariantType::Unstructured));
    // …and every variant is supported on every orin processor.
    for tz in zoo.tasks.values() {
        for v in &tz.variants {
            for m in &orin.processors {
                assert!(m.scale_for(&v.spec).is_some(), "{} on {:?}", v.spec.name, m.proc);
            }
        }
    }
}

#[test]
fn experiment_registry_dispatches_cheap_entries() {
    let Some(ctx) = ctx() else { return };
    for id in ["table1", "fig8", "table5", "fig9", "overhead"] {
        let out = sparseloom::experiments::run(&ctx, id).unwrap();
        assert!(!out.is_empty(), "{id}");
    }
    assert!(sparseloom::experiments::run(&ctx, "nope").is_err());
}
