//! Performance Profiler (paper §3.2): accuracy + latency estimators.
//!
//! Exhaustively profiling the stitched space costs `T·V^S·(P!+1)` runs
//! (Table 1). SparseLoom instead:
//!
//! * profiles each **original** variant's accuracy once (`T·V` runs) and
//!   assigns it to its constituent subgraphs (Eq. 2) — the feature map;
//! * profiles each **subgraph** latency per processor (`T·S·V·P` runs) —
//!   the additive latency model of Eq. 5;
//! * fits a GBDT regressor (Eq. 4) on a *small* set of labelled stitched
//!   variants and predicts the rest (Eq. 3);
//!
//! total cost `T·V + T·S·V·P` (Eq. 6).

pub mod cost;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::gbdt::{Gbdt, GbdtParams};
use crate::soc::{LatencyModel, Processor};
use crate::stitching::{type_histogram, Composition, StitchSpace};
use crate::util::{stats, Rng};
use crate::zoo::{TaskZoo, Zoo};

/// Per-subgraph-per-processor latency table: `[sg][variant][proc.idx()]`.
/// Entries are `None` where the variant type is unsupported (e.g.
/// unstructured pruning on Orin). Dense arrays, not maps — this table
/// sits on the innermost loop of Alg. 1 and the hotness computation
/// (see EXPERIMENTS.md §Perf).
pub type SubgraphLatencies = Vec<Vec<[Option<f64>; 3]>>;

/// The profile of one task: everything the optimizer consumes.
#[derive(Clone, Debug)]
pub struct TaskProfile {
    pub task: String,
    pub space: StitchSpace,
    /// Estimated accuracy for every stitched index k ∈ [0, V^S).
    pub acc_pred: Vec<f64>,
    /// Ground-truth accuracies (oracle) when available — experiments use
    /// this for recall evaluation; the optimizer uses `acc_pred`.
    pub acc_truth: Option<Vec<f64>>,
    /// Measured per-subgraph latencies (the T·S·V·P runs).
    pub sg_lat: SubgraphLatencies,
    /// Inter-processor hop overhead fraction (from the platform).
    pub hop_overhead: f64,
    /// Indices used to train the estimator (accounting).
    pub train_indices: Vec<usize>,
}

impl TaskProfile {
    /// Estimated accuracy of stitched variant k (Eq. 3 via the GBDT).
    pub fn accuracy(&self, k: usize) -> f64 {
        self.acc_pred[k]
    }

    /// Eq. 5: end-to-end latency of composition `comp` under placement
    /// order `order` — the pure additive estimate (no hop overhead; the
    /// paper's estimator deliberately ignores communication).
    #[inline]
    pub fn latency_est(&self, comp: &Composition, order: &[Processor]) -> Option<f64> {
        self.latency_est_digits(&comp.0, order)
    }

    /// Allocation-free Eq. 5 over raw digits (the hot-loop form).
    #[inline]
    pub fn latency_est_digits(&self, digits: &[usize], order: &[Processor]) -> Option<f64> {
        let mut total = 0.0;
        for (j, (&vi, proc)) in digits.iter().zip(order).enumerate() {
            total += self.sg_lat[j][vi][proc.idx()]?;
        }
        Some(total)
    }

    /// Batch-aware Eq. 5 hook: the additive estimate scaled by a batch
    /// service factor (`LatencyModel::batch_factor` for the platform's
    /// `batch_marginal`); at `batch_factor = 1.0` it is exactly
    /// [`TaskProfile::latency_est`]. The serving engine books batches
    /// via `LatencyModel::subgraph_batch_ms`; this estimator-side twin
    /// exists for batch-aware *planning* (Algorithm 1 currently
    /// optimizes batch-1 latency only — see the ROADMAP item), so
    /// selection logic can score candidate variants at a target batch
    /// size without touching the platform model.
    pub fn latency_est_batch(
        &self,
        comp: &Composition,
        order: &[Processor],
        batch_factor: f64,
    ) -> Option<f64> {
        self.latency_est(comp, order).map(|l| l * batch_factor)
    }

    /// "Ground-truth" end-to-end latency: additive plus the per-hop
    /// inter-processor overhead the estimator ignores (§5.4 ≈ 5 %).
    pub fn latency_true(&self, comp: &Composition, order: &[Processor]) -> Option<f64> {
        let mut total = 0.0;
        for (j, (&vi, proc)) in comp.0.iter().zip(order).enumerate() {
            let ms = self.sg_lat[j][vi][proc.idx()]?;
            let hop = if j > 0 { 1.0 + self.hop_overhead } else { 1.0 };
            total += ms * hop;
        }
        Some(total)
    }

    /// Can composition `comp` run at all under `order` (all subgraph
    /// types supported on their assigned processors)?
    pub fn supported(&self, comp: &Composition, order: &[Processor]) -> bool {
        self.latency_est(comp, order).is_some()
    }
}

/// Estimator feature vector for a composition (the X of Eq. 4):
/// per-position parent-variant accuracy (Eq. 2), their mean/min/max,
/// per-position sparsity, and the variant-type histogram.
pub fn features(c: &Composition, tz: &TaskZoo) -> Vec<f64> {
    let v = tz.variants.len();
    let s = c.0.len();
    let accs: Vec<f64> = c.0.iter().map(|&i| tz.variants[i].accuracy).collect();
    let mut f = Vec::with_capacity(2 * s + 9 + s * v);
    f.extend_from_slice(&accs);
    f.push(stats::mean(&accs));
    f.push(accs.iter().cloned().fold(f64::INFINITY, f64::min));
    f.push(accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max));
    f.push(accs.iter().product());
    for &i in &c.0 {
        f.push(tz.variants[i].spec.sparsity);
    }
    for h in type_histogram(c, tz) {
        f.push(h as f64);
    }
    // Per-position variant identity (one-hot, S·V features): lets the
    // trees learn position-specific subgraph effects directly — the
    // dominant term of stitched accuracy in practice.
    for (j, &i) in c.0.iter().enumerate() {
        let _ = j;
        for cand in 0..v {
            f.push(if cand == i { 1.0 } else { 0.0 });
        }
    }
    f
}

/// Profiler configuration.
#[derive(Clone, Debug)]
pub struct ProfilerConfig {
    /// Stitched variants sampled to train the accuracy estimator
    /// ("a small set of profiled stitched variants", §3.2).
    pub train_samples: usize,
    pub gbdt: GbdtParams,
    pub seed: u64,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        Self { train_samples: 250, gbdt: GbdtParams::default(), seed: 23 }
    }
}

/// Build a task's profile with the estimator path (SparseLoom mode).
///
/// `oracle` supplies the measured accuracy for a stitched index — in
/// production this is `Runtime::measure_accuracy` (real PJRT inference
/// over the eval set); experiments use the python-exported exact table.
/// Only `train_samples` + V of its entries are ever read (the paper's
/// cost model), plus all entries when `keep_truth` is set for evaluation.
pub fn profile_task(
    tz: &TaskZoo,
    lm: &LatencyModel,
    oracle: &[f64],
    cfg: &ProfilerConfig,
    keep_truth: bool,
) -> TaskProfile {
    let space = StitchSpace::for_task(tz);
    let v = space.n_variants;
    let s = space.n_subgraphs;
    let procs = lm.platform.processor_list();

    // --- latency profiling: T·S·V·P measured points (Eq. 6 term 2) ---
    let mut sg_lat: SubgraphLatencies = vec![vec![[None; 3]; v]; s];
    for (j, row) in sg_lat.iter_mut().enumerate() {
        for (vi, cell) in row.iter_mut().enumerate() {
            for &p in &procs {
                cell[p.idx()] = lm.subgraph_ms(tz, vi, j, p);
            }
        }
    }

    // --- accuracy estimator: train on a small labelled sample ---
    let mut rng = Rng::new(cfg.seed ^ tz.name.len() as u64);
    let mut train_idx = rng.sample_indices(space.len(), cfg.train_samples.min(space.len()));
    // Always include the pure variants — their accuracies are the T·V
    // baseline measurements SparseLoom takes anyway (Eq. 6 term 1).
    for i in 0..v {
        let k = space.pure_index(i);
        if !train_idx.contains(&k) {
            train_idx.push(k);
        }
    }
    train_idx.sort_unstable();

    let xs: Vec<Vec<f64>> = train_idx
        .iter()
        .map(|&k| features(&space.composition(k), tz))
        .collect();
    let ys: Vec<f64> = train_idx.iter().map(|&k| oracle[k]).collect();
    let model = Gbdt::fit(&xs, &ys, &cfg.gbdt);

    let acc_pred: Vec<f64> = (0..space.len())
        .map(|k| {
            model
                .predict(&features(&space.composition(k), tz))
                .clamp(0.0, 1.0)
        })
        .collect();

    TaskProfile {
        task: tz.name.clone(),
        space,
        acc_pred,
        acc_truth: keep_truth.then(|| oracle.to_vec()),
        sg_lat,
        hop_overhead: lm.platform.interproc_overhead,
        train_indices: train_idx,
    }
}

/// Exhaustive-mode profile (the no-estimator baseline of Figs. 8/12):
/// every stitched accuracy read from measurements, latencies identical.
pub fn profile_task_exhaustive(
    tz: &TaskZoo,
    lm: &LatencyModel,
    oracle: &[f64],
) -> TaskProfile {
    let mut p = profile_task(tz, lm, oracle, &ProfilerConfig::default(), true);
    p.acc_pred = oracle.to_vec();
    p.train_indices = (0..p.space.len()).collect();
    p
}

/// Profile every task of a zoo (estimator mode).
pub fn profile_zoo(
    zoo: &Zoo,
    lm: &LatencyModel,
    cfg: &ProfilerConfig,
    keep_truth: bool,
) -> Result<BTreeMap<String, TaskProfile>> {
    let mut out = BTreeMap::new();
    for (name, tz) in &zoo.tasks {
        let oracle = zoo.load_oracle(name)?;
        out.insert(name.clone(), profile_task(tz, lm, &oracle, cfg, keep_truth));
    }
    Ok(out)
}

/// Estimator-quality report (paper Fig. 7).
#[derive(Clone, Debug)]
pub struct EstimatorReport {
    /// Top-K recall of the accuracy estimator at several K.
    pub recall_at: Vec<(usize, f64)>,
    /// Latency estimator MAE (ms) and MAPE (%) vs ground truth.
    pub lat_mae_ms: f64,
    pub lat_mape_pct: f64,
}

/// Evaluate estimator quality for one profiled task (needs truth).
pub fn evaluate_estimators(
    p: &TaskProfile,
    orders: &[Vec<Processor>],
    ks: &[usize],
    lat_sample: usize,
    seed: u64,
) -> EstimatorReport {
    let truth = p
        .acc_truth
        .as_ref()
        .expect("evaluate_estimators needs acc_truth");
    // Recall over the full retrieval space: the system's job is to
    // surface the true top-K among ALL V^S variants (labelled training
    // points included — the system has measured those and may return
    // them). Measured values replace predictions for trained indices,
    // exactly as the lookup table the optimizer consumes does.
    let mut retrieval: Vec<f64> = p.acc_pred.clone();
    for &k in &p.train_indices {
        retrieval[k] = truth[k];
    }
    // Tie margin = one accuracy quantum (1/n_eval): our eval split is
    // 512 samples (the paper's datasets are 50k+), so the top of the
    // true ranking is saturated with one-quantum ties.
    let quantum = 1.0 / 512.0;
    let recall_at = ks
        .iter()
        .map(|&k| (k, stats::top_k_recall_eps(&retrieval, truth, k, quantum)))
        .collect();

    // Latency: estimator (Eq. 5, no hop) vs ground truth (with hop).
    let mut rng = Rng::new(seed);
    let mut est = Vec::new();
    let mut tru = Vec::new();
    for _ in 0..lat_sample {
        let k = rng.below(p.space.len());
        let comp = p.space.composition(k);
        let order = rng.choose(orders);
        if let (Some(e), Some(t)) = (p.latency_est(&comp, order), p.latency_true(&comp, order)) {
            est.push(e);
            tru.push(t);
        }
    }
    EstimatorReport {
        recall_at,
        lat_mae_ms: stats::mae(&est, &tru),
        lat_mape_pct: stats::mape(&est, &tru),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::latency::tests::tiny_taskzoo;
    use crate::soc::{BaseLatencies, Platform};
    use crate::zoo::KernelPath;

    fn setup() -> (crate::zoo::TaskZoo, LatencyModel) {
        let tz = tiny_taskzoo();
        let mut b = BaseLatencies::new();
        for sg in 0..2 {
            b.set("tiny", sg, KernelPath::Dense, 10.0);
            b.set("tiny", sg, KernelPath::BlockSparse, 8.0);
        }
        (tz, LatencyModel::new(Platform::desktop(), b))
    }


    fn tiny_cfg() -> ProfilerConfig {
        // The 2x2 toy space has only 4 points; let the GBDT memorize it.
        ProfilerConfig {
            train_samples: 4,
            gbdt: crate::gbdt::GbdtParams {
                n_trees: 200,
                max_depth: 3,
                eta: 0.2,
                min_leaf: 1,
                subsample: 1.0,
                seed: 1,
            },
            seed: 23,
        }
    }

    fn fake_oracle(tz: &crate::zoo::TaskZoo) -> Vec<f64> {
        // Mean of parent accuracies — a smooth target the GBDT can learn.
        let space = StitchSpace::for_task(tz);
        space
            .iter()
            .map(|c| {
                let accs: Vec<f64> =
                    c.0.iter().map(|&i| tz.variants[i].accuracy).collect();
                stats::mean(&accs)
            })
            .collect()
    }

    #[test]
    fn profile_shapes() {
        let (tz, lm) = setup();
        let oracle = fake_oracle(&tz);
        let p = profile_task(&tz, &lm, &oracle, &tiny_cfg(), true);
        assert_eq!(p.acc_pred.len(), 4); // V=2, S=2
        assert_eq!(p.sg_lat.len(), 2);
        assert_eq!(p.sg_lat[0].len(), 2);
    }

    #[test]
    fn pure_variants_predicted_exactly_enough() {
        let (tz, lm) = setup();
        let oracle = fake_oracle(&tz);
        let p = profile_task(&tz, &lm, &oracle, &tiny_cfg(), true);
        for i in 0..2 {
            let k = p.space.pure_index(i);
            assert!((p.acc_pred[k] - oracle[k]).abs() < 0.08,
                    "pure variant {i}: pred {} vs true {}", p.acc_pred[k], oracle[k]);
        }
    }

    #[test]
    fn latency_est_batch_scales_by_factor() {
        let (tz, lm) = setup();
        let oracle = fake_oracle(&tz);
        let p = profile_task(&tz, &lm, &oracle, &ProfilerConfig::default(), false);
        use Processor::*;
        let comp = Composition(vec![0, 0]);
        let est = p.latency_est(&comp, &[Cpu, Gpu]).unwrap();
        // Identity at factor 1, linear otherwise (mirrors the platform
        // model's batch_factor contract).
        assert_eq!(p.latency_est_batch(&comp, &[Cpu, Gpu], 1.0).unwrap(), est);
        let f = lm.batch_factor(4);
        let batched = p.latency_est_batch(&comp, &[Cpu, Gpu], f).unwrap();
        assert!((batched - est * f).abs() < 1e-12);
        assert!(batched > est && batched < 4.0 * est);
    }

    #[test]
    fn latency_est_is_additive_and_ignores_hops() {
        let (tz, lm) = setup();
        let oracle = fake_oracle(&tz);
        let p = profile_task(&tz, &lm, &oracle, &ProfilerConfig::default(), false);
        use Processor::*;
        let comp = Composition(vec![0, 0]);
        let est = p.latency_est(&comp, &[Cpu, Gpu]).unwrap();
        let a = p.sg_lat[0][0][Cpu.idx()].unwrap();
        let b = p.sg_lat[1][0][Gpu.idx()].unwrap();
        assert!((est - (a + b)).abs() < 1e-12);
        let tru = p.latency_true(&comp, &[Cpu, Gpu]).unwrap();
        assert!(tru > est, "truth includes hop overhead");
    }

    #[test]
    fn estimator_report_reasonable() {
        let (tz, lm) = setup();
        let oracle = fake_oracle(&tz);
        let p = profile_task(&tz, &lm, &oracle, &ProfilerConfig::default(), true);
        use Processor::*;
        let orders = vec![vec![Cpu, Gpu], vec![Gpu, Cpu]];
        let rep = evaluate_estimators(&p, &orders, &[1], 50, 7);
        assert!(rep.lat_mape_pct < 10.0, "MAPE {}", rep.lat_mape_pct);
        assert!(rep.lat_mae_ms >= 0.0);
    }

    #[test]
    fn exhaustive_mode_uses_truth_directly() {
        let (tz, lm) = setup();
        let oracle = fake_oracle(&tz);
        let p = profile_task_exhaustive(&tz, &lm, &oracle);
        assert_eq!(p.acc_pred, oracle);
        assert_eq!(p.train_indices.len(), p.space.len());
    }
}
