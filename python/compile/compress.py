"""Post-training compression → the sparse model zoos of Table 5.

Stands in for NNCF (Intel) / ONNX-Runtime (NVIDIA) compression (see
DESIGN.md §Substitutions). All methods are post-training and
calibration-free, and all preserve tensor shapes so subgraph interfaces
stay layer-aligned (the paper's operational-scope requirement (ii)):

* **Unstructured pruning** — global per-layer magnitude pruning realized
  as a {0,1} zero-mask (kernel path ``masked``).
* **Structured pruning** — input-channel pruning realized as a {0,1}
  per-row keep vector (kernel path ``blocksparse``); rows are ranked by
  L2 norm. Channels are masked rather than reshaped, which is exactly how
  architecture-changing pruning must be expressed for stitching to keep
  aligned interfaces.
* **INT8 quantization** — symmetric per-output-channel fake quantization
  (kernel path ``quant``); weights stored as int8 + f32 scales.
* **FP16 quantization** (Jetson zoo only) — weights round-tripped through
  fp16; runs on the ``dense`` path.

LayerNorm/bias parameters are never compressed (standard practice; they
are a negligible fraction of bytes).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .kernels import ref

# Variant-type tags mirrored into manifest.json and the rust zoo module.
DENSE = "dense"
FP16 = "fp16"
INT8 = "int8"
UNSTRUCTURED = "unstructured"
STRUCTURED = "structured"


@dataclasses.dataclass(frozen=True)
class VariantSpec:
    """One row of Table 5: a zoo entry."""

    name: str
    vtype: str  # dense | fp16 | int8 | unstructured | structured
    sparsity: float  # fraction of weights pruned (0 for dense/quant)
    kernel_path: str  # which L1 kernel family executes its GEMMs

    @property
    def precision(self) -> str:
        return {FP16: "fp16", INT8: "int8"}.get(self.vtype, "fp32")


def intel_zoo() -> list:
    """Table 5, Intel SoCs: dense + INT8 + 6 unstructured + 2 structured."""
    zoo = [
        VariantSpec("dense", DENSE, 0.0, "dense"),
        VariantSpec("int8", INT8, 0.0, "quant"),
    ]
    for s in (90, 85, 80, 75, 70, 65):
        zoo.append(VariantSpec(f"unstr{s}", UNSTRUCTURED, s / 100.0, "masked"))
    for s in (40, 50):
        zoo.append(VariantSpec(f"struct{s}", STRUCTURED, s / 100.0, "blocksparse"))
    return zoo


def jetson_zoo() -> list:
    """Table 5, NVIDIA Jetson: dense + FP16 + INT8 + 7 structured."""
    zoo = [
        VariantSpec("dense", DENSE, 0.0, "dense"),
        VariantSpec("fp16", FP16, 0.0, "dense"),
        VariantSpec("int8", INT8, 0.0, "quant"),
    ]
    for s in (20, 30, 35, 40, 45, 50, 55):
        zoo.append(VariantSpec(f"struct{s}", STRUCTURED, s / 100.0, "blocksparse"))
    return zoo


ZOOS = {"intel": intel_zoo, "jetson": jetson_zoo}


def _is_gemm_layer(key: str) -> bool:
    """GEMM layers are compressed; layernorms (``ln*``) are not."""
    return not key.startswith("ln")


def _map_gemms(sg_params, fn):
    """Apply ``fn`` to every GEMM layer [w, b] in a subgraph param tree."""
    out = {}
    for key, val in sg_params.items():
        if isinstance(val, dict):
            out[key] = _map_gemms(val, fn)
        elif _is_gemm_layer(key):
            out[key] = fn(val)
        else:
            out[key] = list(val)
    return out


def _prune_unstructured(wb, sparsity: float):
    """[w, b] -> [w, mask, b]: zero-mask the smallest-|w| entries."""
    w, b = wb
    wn = np.asarray(w)
    k = int(round(sparsity * wn.size))
    mask = np.ones(wn.size, np.float32)
    if k > 0:
        idx = np.argsort(np.abs(wn).ravel(), kind="stable")[:k]
        mask[idx] = 0.0
    mask = mask.reshape(wn.shape)
    return [w, jnp.asarray(mask), b]


def _prune_structured(wb, sparsity: float):
    """[w, b] -> [w, keep, b]: drop lowest-L2 input channels (rows of w)."""
    w, b = wb
    wn = np.asarray(w)
    k_rows = wn.shape[0]
    n_drop = int(round(sparsity * k_rows))
    # Never prune every channel — keep at least one live row.
    n_drop = min(n_drop, k_rows - 1)
    keep = np.ones(k_rows, np.float32)
    if n_drop > 0:
        norms = np.linalg.norm(wn, axis=1)
        keep[np.argsort(norms, kind="stable")[:n_drop]] = 0.0
    return [w, jnp.asarray(keep), b]


def _quant_int8(wb):
    """[w, b] -> [wq(int8), scale, b]."""
    w, b = wb
    wq, scale = ref.fake_quant_weights_ref(jnp.asarray(w), bits=8)
    return [wq, scale, b]


def _cast_fp16(wb):
    """[w, b] -> [fp16-round-tripped w, b] (dense path)."""
    w, b = wb
    return [jnp.asarray(w, jnp.float16).astype(jnp.float32), b]


def compress_subgraph(sg_params, spec: VariantSpec):
    """Produce the variant's params for one subgraph from the dense base."""
    if spec.vtype == DENSE:
        return _map_gemms(sg_params, lambda wb: list(wb))
    if spec.vtype == FP16:
        return _map_gemms(sg_params, _cast_fp16)
    if spec.vtype == INT8:
        return _map_gemms(sg_params, _quant_int8)
    if spec.vtype == UNSTRUCTURED:
        return _map_gemms(sg_params, lambda wb: _prune_unstructured(wb, spec.sparsity))
    if spec.vtype == STRUCTURED:
        return _map_gemms(sg_params, lambda wb: _prune_structured(wb, spec.sparsity))
    raise ValueError(f"unknown variant type {spec.vtype!r}")


def compress_model(params, spec: VariantSpec):
    """Compress all S subgraphs of a base model."""
    return [compress_subgraph(sg, spec) for sg in params]
