//! The paper's Fig. 1 AR scenario, end to end: four concurrent tasks
//! (image classification, sentiment, activity recognition, speech
//! recognition) served on the simulated desktop SoC with real PJRT
//! inference, comparing SparseLoom against all six baselines across the
//! full 5×5 SLO grid and 24 arrival combinations.
//!
//! This is the repository's end-to-end validation driver (recorded in
//! EXPERIMENTS.md): it loads real (tiny) models, serves batched
//! requests, and reports SLO violation rate + throughput per policy.
//!
//! ```text
//! cargo run --release --example ar_multitask [-- <platform>]
//! ```

use std::collections::BTreeMap;
use std::time::Instant;

use sparseloom::baselines::Policy;
use sparseloom::experiments::Ctx;
use sparseloom::metrics::{render_table, Aggregate};
use sparseloom::profiler::ProfilerConfig;
use sparseloom::runtime::Runtime;
use sparseloom::scenario::{Scenario, Server};
use sparseloom::soc::Platform;
use sparseloom::util::Rng;
use sparseloom::workload::{arrival_combinations, slo_grid, Slo, TaskRanges};

fn main() -> anyhow::Result<()> {
    let platform_name = std::env::args().nth(1).unwrap_or_else(|| "desktop".into());
    let platform = Platform::by_name(&platform_name)?;
    let ctx = Ctx::load("artifacts", false)?;
    let lm = ctx.lm(platform.clone());
    let zoo = ctx.zoo_for(&platform);
    // Real PJRT inference per first query when the runtime is available
    // (needs --features xla); simulation-only otherwise.
    let rt = match Runtime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            println!("(simulation only — no PJRT: {e:#})");
            None
        }
    };

    println!("AR multi-task serving on {} — {}", platform.name, platform.description);
    let t0 = Instant::now();
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
    println!("profiled {} tasks in {:.2} s (estimator mode)\n",
             profiles.len(), t0.elapsed().as_secs_f64());

    let mut grids: BTreeMap<String, Vec<Slo>> = BTreeMap::new();
    let mut universe = Vec::new();
    for (name, _) in &profiles {
        let g = slo_grid(&TaskRanges::measure(zoo.task(name)?, &lm));
        universe.extend(g.iter().copied());
        grids.insert(name.clone(), g);
    }

    let tasks: Vec<String> = profiles.keys().cloned().collect();
    let mut rng = Rng::new(42);
    let mut arrivals = arrival_combinations(&tasks);
    rng.shuffle(&mut arrivals);
    arrivals.truncate(8);

    let mut rows = Vec::new();
    let mut sl = (0.0, 0.0);
    let mut best_baseline = (f64::INFINITY, 0.0f64);
    for policy in Policy::all() {
        let t0 = Instant::now();
        let mut agg = Aggregate::default();
        let mut builder = Server::builder(zoo, &lm, &profiles).policy(policy);
        if let Some(rt) = &rt {
            builder = builder.runtime(rt);
        }
        let server = builder.build();
        for i in 0..25 {
            let slos: BTreeMap<String, Slo> =
                grids.iter().map(|(n, g)| (n.clone(), g[i])).collect();
            for arrival in &arrivals {
                let sc = Scenario::closed_loop(arrival, slos.clone())
                    .with_universe(universe.clone());
                agg.push(&server.run(&sc)?);
            }
        }
        let v = agg.mean_violation_pct();
        let tput = agg.mean_throughput();
        if policy == Policy::SparseLoom {
            sl = (v, tput);
        } else {
            best_baseline.0 = best_baseline.0.min(v);
            best_baseline.1 = best_baseline.1.max(tput);
        }
        rows.push(vec![
            policy.name().to_string(),
            format!("{v:.1}"),
            format!("{tput:.0}"),
            format!("{:.2}", t0.elapsed().as_secs_f64()),
        ]);
    }

    println!("{}", render_table(
        &["policy", "violation %", "throughput q/s", "wall s"], &rows));
    println!(
        "SparseLoom vs best baseline: violations {:.1} % vs {:.1} %, throughput {:.2}x",
        sl.0, best_baseline.0, sl.1 / best_baseline.1.max(1e-9)
    );
    Ok(())
}
