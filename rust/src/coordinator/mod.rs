//! The multi-DNN planning engine (paper Fig. 6, phases 1–2).
//!
//! Given per-task SLOs and a policy, the coordinator:
//!
//! 1. **plans** — selects variants + placement order (`baselines::plan`,
//!    which dispatches to Algorithm 1 for SparseLoom);
//! 2. **preloads** — fills the unified memory pool (Algorithm 2 hotness
//!    plan under a budget for SparseLoom; all selected blobs for
//!    baselines), charging compile/load time for anything missing.
//!
//! Serving (phases 3–4: driving query streams through the per-processor
//! pipelines and monitoring SLO feedback) lives in `scenario::Server`,
//! which owns a `Coordinator` and exposes the typed `Scenario` API.
//! The coordinator is the *internal* planning engine behind it.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::baselines::{self, Policy};
use crate::metrics::SwitchBreakdown;
use crate::optimizer::Selection;
use crate::planner::{algo, memory, CostModel};
use crate::preloader::{full_preload_bytes, Hotness, PreloadPlan};
use crate::profiler::TaskProfile;
use crate::runtime::Runtime;
use crate::soc::{BlobId, LatencyModel, MemoryPool, Processor};
use crate::stitching::Composition;
use crate::workload::{placement_orders, Slo};
use crate::zoo::{TaskZoo, Zoo};

/// Serving options (planning + monitoring policy knobs). Workload shape
/// — arrival process, query counts, SLO schedule — lives in
/// `scenario::Scenario`, not here.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Memory budget as a fraction of full-preload bytes (Fig. 14 axis).
    pub memory_budget_frac: f64,
    pub policy: Policy,
    /// Switch variants mid-run when a task is observed violating.
    pub feedback_switching: bool,
    /// Judge outcomes on oracle accuracy when available (experiments).
    pub judge_on_truth: bool,
    /// Force a specific placement order instead of optimizing over Ω
    /// (the Fig. 13 sweep).
    pub force_order: Option<Vec<Processor>>,
    /// SparseLoom verifies its top candidates against a measured
    /// accuracy before committing (a handful of extra profiling runs
    /// per task — cheap insurance against estimator error).
    pub verify_selection: bool,
    /// Expected mean coalesced batch size for batch-aware planning
    /// (`planner::CostModel`): 1.0 is the paper's batch-1 planning;
    /// set it to the dispatch operating point (e.g. `max_batch`) when
    /// serving batched backlog so Algorithm 1 scores candidates at the
    /// occupancy the engine will actually book.
    pub batch_hint: f64,
    /// Retain the full per-request event log in reports. On (the
    /// library default) every `RequestOutcome` is kept, as the replay
    /// verifier and the event-level tests need; off, reports carry only
    /// streaming aggregates (running sums + quantile sketches), so peak
    /// memory is O(tasks), not O(requests). The CLI turns this off for
    /// `bench` and for `serve` without `--verify`.
    pub record_events: bool,
    /// Drive the shards of a `ShardedServer` on OS threads (one per
    /// shard, lockstep barriers at phase/epoch boundaries). Results are
    /// bit-identical to the sequential drive; turn off to debug or to
    /// measure the single-thread baseline.
    pub parallel: bool,
    /// Emit the deterministic structured trace (`crate::trace`):
    /// request-lifecycle spans and control-plane audit events, drained
    /// into `RunReport::trace` / `ShardedReport::control_trace`. Off
    /// (the default) installs the no-op sink — zero events retained,
    /// zero behavioral perturbation.
    pub trace: bool,
}

impl Default for ServeOpts {
    fn default() -> Self {
        Self {
            memory_budget_frac: 1.0,
            policy: Policy::SparseLoom,
            feedback_switching: true,
            judge_on_truth: true,
            force_order: None,
            verify_selection: true,
            batch_hint: 1.0,
            record_events: true,
            parallel: true,
            trace: false,
        }
    }
}

/// Result of the planning + preloading phase (pre-serve state).
#[derive(Clone, Debug)]
pub struct Prepared {
    pub selections: BTreeMap<String, Option<Selection>>,
    pub order: Vec<Processor>,
    pub preload_plan: PreloadPlan,
    pub pool: MemoryPool,
    /// Per-task serve-start penalty (ms) from loading missing blobs.
    pub switch_penalty_ms: BTreeMap<String, f64>,
    pub switch_breakdown: SwitchBreakdown,
}

/// The coordinator: owns profiles + the platform latency model, and
/// optionally a live PJRT runtime for real execution.
pub struct Coordinator<'a> {
    pub zoo: &'a Zoo,
    pub lm: &'a LatencyModel,
    pub profiles: &'a BTreeMap<String, TaskProfile>,
    pub runtime: Option<&'a Runtime>,
}

impl<'a> Coordinator<'a> {
    pub fn new(
        zoo: &'a Zoo,
        lm: &'a LatencyModel,
        profiles: &'a BTreeMap<String, TaskProfile>,
    ) -> Self {
        Self { zoo, lm, profiles, runtime: None }
    }

    pub fn with_runtime(mut self, rt: &'a Runtime) -> Self {
        self.runtime = Some(rt);
        self
    }

    pub(crate) fn subgraphs(&self) -> usize {
        self.zoo.subgraphs
    }

    /// Phase 2 (Alg. 2): build the preload plan + memory pool once for
    /// an SLO universe Ψ and a budget, over every profiled task. The
    /// pool persists across SLO changes (scheduled scenarios).
    pub fn build_pool(
        &self,
        slo_universe: &[Slo],
        opts: &ServeOpts,
    ) -> Result<(PreloadPlan, MemoryPool)> {
        let names: Vec<&String> = self.profiles.keys().collect();
        self.build_pool_over(&names, slo_universe, opts)
    }

    /// As [`Coordinator::build_pool`], restricted to `tasks`: the
    /// budget fraction applies to the full-preload bytes of exactly
    /// those tasks, and only their subgraphs preload.
    /// [`Coordinator::prepare`] passes the served SLO configuration's
    /// task set, so a sharded deployment gives every shard a pool that
    /// holds its *own partition* rather than the whole fleet's — which
    /// is why a migrating task pays compile+load on arrival unless
    /// warm migration carries its blobs across.
    fn build_pool_over(
        &self,
        tasks: &[&String],
        slo_universe: &[Slo],
        opts: &ServeOpts,
    ) -> Result<(PreloadPlan, MemoryPool)> {
        let platform = &self.lm.platform;
        let s = self.subgraphs();
        let task_zoos: Vec<_> = tasks
            .iter()
            .map(|&name| self.zoo.task(name))
            .collect::<Result<Vec<_>>>()?;
        let budget = self.pool_budget(&task_zoos, opts);
        let orders = placement_orders(platform, s);

        let preload_plan = if opts.policy == Policy::SparseLoom {
            // task_zoos is index-aligned with `tasks` (collected above
            // with hard error propagation — no silent drops here).
            let pairs: Vec<_> = tasks
                .iter()
                .zip(&task_zoos)
                .filter_map(|(&name, &tz)| {
                    let p = self.profiles.get(name)?;
                    Some((tz, Hotness::compute(p, slo_universe, &orders)))
                })
                .collect();
            let refs: Vec<_> = pairs.iter().map(|(tz, h)| (*tz, h)).collect();
            memory::preload(&refs, budget)
        } else {
            // Baselines preload every variant subgraph (the memory-heavy
            // practice §2.2 describes), budget permitting, zoo order.
            let mut blobs = Vec::new();
            let mut used = 0u64;
            for tz in &task_zoos {
                for (i, v) in tz.variants.iter().enumerate() {
                    for (j, sw) in v.subgraphs.iter().enumerate() {
                        if used + sw.bytes > budget {
                            continue;
                        }
                        used += sw.bytes;
                        blobs.push(BlobId::new(&tz.name, i, j));
                    }
                }
            }
            PreloadPlan { blobs, total_bytes: used, budget_bytes: budget }
        };

        let mut pool = MemoryPool::new(budget.max(1));
        for id in &preload_plan.blobs {
            let tz = self.zoo.task(&id.task)?;
            let bytes = tz.variants[id.variant].subgraphs[id.subgraph].bytes;
            pool.load(id.clone(), bytes);
        }
        Ok((preload_plan, pool))
    }

    /// Phase 1+2: plan and preload for one SLO configuration. The pool
    /// is budgeted and preloaded over the configuration's own task set
    /// (for a full deployment that is every profiled task; for a
    /// shard's sub-scenario it is the shard's partition). An *empty*
    /// partition — a spare shard held as a migration target — still
    /// gets real pool capacity (budgeted over the whole fleet,
    /// preloading nothing), so migrants can land warm instead of
    /// finding a zero-byte pool.
    pub fn prepare(
        &self,
        slos: &BTreeMap<String, Slo>,
        slo_universe: &[Slo],
        opts: &ServeOpts,
    ) -> Result<Prepared> {
        let names: Vec<&String> = self
            .profiles
            .keys()
            .filter(|name| slos.contains_key(*name))
            .collect();
        let (preload_plan, pool) = if names.is_empty() {
            let task_zoos: Vec<_> = self
                .profiles
                .keys()
                .map(|name| self.zoo.task(name))
                .collect::<Result<Vec<_>>>()?;
            let budget = self.pool_budget(&task_zoos, opts);
            (
                PreloadPlan { budget_bytes: budget, ..Default::default() },
                MemoryPool::new(budget.max(1)),
            )
        } else {
            self.build_pool_over(&names, slo_universe, opts)?
        };
        self.prepare_with_pool(slos, opts, preload_plan, pool)
    }

    /// The one pool-budget formula: `memory_budget_frac ×` the
    /// full-preload bytes of `task_zoos` (Fig. 14's axis). Shared by
    /// every pool-construction path so shard and spare-shard pools can
    /// never diverge on rounding.
    fn pool_budget(&self, task_zoos: &[&TaskZoo], opts: &ServeOpts) -> u64 {
        (full_preload_bytes(task_zoos) as f64 * opts.memory_budget_frac).round() as u64
    }

    /// Plan + refine selections against an existing pool state; charge
    /// compile/load for whatever the plan needs that is not resident.
    pub fn prepare_with_pool(
        &self,
        slos: &BTreeMap<String, Slo>,
        opts: &ServeOpts,
        preload_plan: PreloadPlan,
        mut pool: MemoryPool,
    ) -> Result<Prepared> {
        let platform = &self.lm.platform;
        let s = self.subgraphs();
        let orders = placement_orders(platform, s);
        pool.clear_active();
        // The planner's cost model: exactly Eq. 5 at the default
        // batch_hint of 1.0, batch-aware otherwise.
        let cost = CostModel::batch_aware(self.lm, opts.batch_hint);
        let mut plan = baselines::plan(opts.policy, self.profiles, slos, platform, &cost);
        if let Some(fo) = &opts.force_order {
            // Fig. 13 mode: re-plan with Ω restricted to the forced order.
            plan = algo::optimize(&cost, self.profiles, slos, std::slice::from_ref(fo));
        }

        // --- selection refinement: prefer preloaded, verify truth -------
        // Walk each task's Θ in (resident-first, latency-ascending)
        // order; commit the first candidate whose *measured* accuracy
        // (≤ VERIFY_BUDGET real runs; production: Runtime::measure_
        // accuracy, here the exported oracle) confirms feasibility.
        // Falls back to the best unverified resident candidate, then to
        // the original plan.
        const VERIFY_BUDGET: usize = 12;
        if opts.policy == Policy::SparseLoom {
            for (name, sel) in plan.selections.iter_mut() {
                let p = &self.profiles[name];
                let slo = &slos[name];
                let theta = algo::feasible_set(&cost, p, slo, &orders);
                if theta.is_empty() {
                    continue;
                }
                let mut cands: Vec<(bool, f64, usize)> = theta
                    .indices
                    .iter()
                    .filter_map(|&k| {
                        let comp = p.space.composition(k);
                        cost.latency(p, &comp, &plan.order).map(|l| {
                            (!self.resident(&pool, name, &comp), l, k)
                        })
                    })
                    .collect();
                // resident first (false < true), then fastest
                cands.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let mut verified: Option<Selection> = None;
                if opts.verify_selection {
                    if let Some(truth) = p.acc_truth.as_ref() {
                        for &(_, lat, k) in cands.iter().take(VERIFY_BUDGET) {
                            if truth[k] >= slo.min_accuracy && lat <= slo.max_latency_ms {
                                verified = Some(Selection {
                                    stitched_index: k,
                                    latency_ms: lat,
                                    accuracy: truth[k],
                                });
                                break;
                            }
                        }
                    }
                }
                if verified.is_none() {
                    verified = cands.first().map(|&(_, lat, k)| Selection {
                        stitched_index: k,
                        latency_ms: lat,
                        accuracy: p.accuracy(k),
                    });
                }
                if verified.is_some() {
                    *sel = verified;
                }
            }
        }

        // --- charge compile+load for still-missing blobs -----------------
        let mut switch_penalty_ms = BTreeMap::new();
        let mut breakdown = SwitchBreakdown::default();
        for (name, sel) in &plan.selections {
            let mut penalty = 0.0;
            if let Some(sel) = sel {
                let p = &self.profiles[name];
                let tz = self.zoo.task(name)?;
                let comp = p.space.composition(sel.stitched_index);
                for (j, &vi) in comp.0.iter().enumerate() {
                    let id = BlobId::new(name, vi, j);
                    if !pool.touch(&id) {
                        let bytes = tz.variants[vi].subgraphs[j].bytes;
                        let proc = plan.order[j.min(plan.order.len() - 1)];
                        let c = self.lm.compile_ms(bytes, proc);
                        let l = self.lm.load_ms(bytes, proc);
                        breakdown.compile_ms += c;
                        breakdown.load_ms += l;
                        penalty += c + l;
                        pool.make_room(bytes);
                        pool.load(id, bytes);
                    }
                }
                breakdown.inference_ms += sel.latency_ms;
                // Mark active (pinned) blobs.
                for (j, &vi) in comp.0.iter().enumerate() {
                    pool.set_active(&BlobId::new(name, vi, j), true);
                }
            }
            switch_penalty_ms.insert(name.clone(), penalty);
        }

        Ok(Prepared {
            selections: plan.selections,
            order: plan.order,
            preload_plan,
            pool,
            switch_penalty_ms,
            switch_breakdown: breakdown,
        })
    }

    fn resident(&self, pool: &MemoryPool, task: &str, comp: &Composition) -> bool {
        comp.0
            .iter()
            .enumerate()
            .all(|(j, &vi)| pool.contains(&BlobId::new(task, vi, j)))
    }

    /// Judged accuracy: oracle truth when available and requested, else
    /// the estimator's prediction.
    pub(crate) fn judged_accuracy(&self, p: &TaskProfile, k: usize, opts: &ServeOpts) -> f64 {
        if opts.judge_on_truth {
            if let Some(truth) = &p.acc_truth {
                return truth[k];
            }
        }
        p.acc_pred[k]
    }

    /// Feedback switch: find a feasible composition with estimated
    /// latency enough below the observed mean to matter.
    pub(crate) fn switch_variant(
        &self,
        p: &TaskProfile,
        slo: &Slo,
        order: &[Processor],
        omega: &[Vec<Processor>],
        observed_mean: f64,
    ) -> Option<Selection> {
        let theta = algo::feasible_set(&CostModel::unit(), p, slo, omega);
        let mut best: Option<Selection> = None;
        for &k in &theta.indices {
            let c = p.space.composition(k);
            let l = p.latency_est(&c, order)?;
            if best.map(|b| l < b.latency_ms).unwrap_or(true) {
                best = Some(Selection {
                    stitched_index: k,
                    latency_ms: l,
                    accuracy: p.accuracy(k),
                });
            }
        }
        best.filter(|b| b.latency_ms < 0.8 * observed_mean)
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::profiler::{profile_task, ProfilerConfig};
    use crate::soc::latency::tests::tiny_taskzoo;
    use crate::soc::{BaseLatencies, Platform};
    use crate::zoo::KernelPath;

    /// Build a one-task Zoo around the tiny taskzoo for serve tests.
    pub fn tiny_zoo() -> Zoo {
        let tz = tiny_taskzoo();
        Zoo {
            root: std::path::PathBuf::from("/nonexistent"),
            seed: 0,
            zoo_name: "test".into(),
            subgraphs: 2,
            n_classes: 10,
            batch_sizes: vec![1, 256],
            probe_batch: 4,
            n_eval: 512,
            tasks: BTreeMap::from([("tiny".to_string(), tz)]),
        }
    }

    /// Shared serve-test fixture (also used by `scenario` tests).
    pub fn setup() -> (Zoo, LatencyModel, BTreeMap<String, TaskProfile>) {
        let zoo = tiny_zoo();
        let mut b = BaseLatencies::new();
        for sg in 0..2 {
            b.set("tiny", sg, KernelPath::Dense, 10.0);
            b.set("tiny", sg, KernelPath::BlockSparse, 8.0);
        }
        let lm = LatencyModel::new(Platform::desktop(), b);
        let tz = zoo.task("tiny").unwrap();
        let space = crate::stitching::StitchSpace::for_task(tz);
        let oracle: Vec<f64> = space
            .iter()
            .map(|c| c.0.iter().map(|&i| tz.variants[i].accuracy).sum::<f64>() / 2.0)
            .collect();
        let cfg = ProfilerConfig {
            train_samples: 4,
            gbdt: crate::gbdt::GbdtParams {
                n_trees: 200,
                max_depth: 3,
                eta: 0.2,
                min_leaf: 1,
                subsample: 1.0,
                seed: 1,
            },
            seed: 23,
        };
        let profiles = BTreeMap::from([(
            "tiny".to_string(),
            profile_task(tz, &lm, &oracle, &cfg, true),
        )]);
        (zoo, lm, profiles)
    }

    pub fn slos(acc: f64, lat: f64) -> BTreeMap<String, Slo> {
        BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: acc, max_latency_ms: lat },
        )])
    }

    #[test]
    fn prepare_reports_switch_costs_for_cold_start() {
        let (zoo, lm, profiles) = setup();
        let coord = Coordinator::new(&zoo, &lm, &profiles);
        let s = slos(0.5, 1e9);
        let uni: Vec<Slo> = s.values().copied().collect();
        // Nothing preloaded: cold start must pay compile+load.
        let opts = ServeOpts { memory_budget_frac: 0.0, ..Default::default() };
        let prepared = coord.prepare(&s, &uni, &opts).unwrap();
        let penalty = prepared.switch_penalty_ms["tiny"];
        assert!(penalty > 0.0, "cold start must pay compile+load");
        // Per-MiB costs keep the Fig. 5a ratio: compile ≫ load.
        let b = &prepared.switch_breakdown;
        assert!(b.compile_ms > 5.0 * b.load_ms, "{b:?}");
    }

    #[test]
    fn opts_and_prepared_are_debuggable() {
        let (zoo, lm, profiles) = setup();
        let coord = Coordinator::new(&zoo, &lm, &profiles);
        let s = slos(0.5, 1e9);
        let uni: Vec<Slo> = s.values().copied().collect();
        let opts = ServeOpts::default();
        let prepared = coord.prepare(&s, &uni, &opts).unwrap();
        assert!(format!("{opts:?}").contains("SparseLoom"));
        assert!(format!("{prepared:?}").contains("selections"));
    }
}
