//! Serving metrics: SLO violation rate, throughput, latency/memory
//! breakdowns — the quantities every figure in §5 reports.

use std::collections::BTreeMap;

use crate::util::stats;

/// One request's life cycle through the serving engine — emitted per
/// query by `scenario::Session::submit` (arrival → queueing → placement
/// → completion → SLO verdict).
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub id: u64,
    pub task: String,
    /// When the query entered the system (virtual ms).
    pub arrival_ms: f64,
    /// When its first subgraph stage started executing.
    pub start_ms: f64,
    /// When its last stage completed.
    pub finish_ms: f64,
    /// Inference (service) latency — the SLO-judged quantity: stage
    /// executions plus any switch penalty charged to this query.
    pub service_ms: f64,
    /// Time spent waiting before the first stage started.
    pub queueing_ms: f64,
    /// Rejected by admission control (or had no runnable variant):
    /// nothing was booked for it.
    pub dropped: bool,
    /// Per-request latency verdict against the task's SLO at submit
    /// time (`None` when dropped).
    pub slo_ok: Option<bool>,
}

/// Outcome of serving one task under one SLO configuration.
#[derive(Clone, Debug)]
pub struct TaskOutcome {
    pub task: String,
    /// Accuracy of the variant that served the task (estimated at plan
    /// time, oracle-checked in experiments), if any was selected.
    pub accuracy: Option<f64>,
    /// Mean per-query end-to-end latency (virtual ms).
    pub mean_latency_ms: f64,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Mean time queries spent queued before their first stage ran.
    pub mean_queueing_ms: f64,
    pub queries_completed: usize,
    /// Queries rejected by admission control (open-loop overload).
    pub queries_dropped: usize,
    /// SLO bounds it was judged against.
    pub slo_accuracy: f64,
    pub slo_latency_ms: f64,
}

impl TaskOutcome {
    /// The paper's violation predicate: fails accuracy OR latency (or
    /// had no feasible variant at all).
    pub fn violated(&self) -> bool {
        match self.accuracy {
            None => true,
            Some(acc) => {
                acc < self.slo_accuracy || self.mean_latency_ms > self.slo_latency_ms
            }
        }
    }
}

/// One serving run: all tasks, one SLO config, one arrival order.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub outcomes: Vec<TaskOutcome>,
    /// Total virtual time to drain all queries (ms).
    pub makespan_ms: f64,
    pub total_queries: usize,
    /// Queries rejected by admission control across all tasks.
    pub total_dropped: usize,
    /// Per-request event log (arrival/queueing/placement/completion),
    /// in submission order. Empty for legacy aggregate-only callers.
    pub requests: Vec<RequestOutcome>,
}

impl RunReport {
    /// Fraction of tasks that violated their SLO.
    pub fn violation_rate(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().filter(|o| o.violated()).count() as f64
            / self.outcomes.len() as f64
    }

    /// Queries per second over the virtual makespan.
    pub fn throughput_qps(&self) -> f64 {
        if self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.total_queries as f64 / (self.makespan_ms / 1000.0)
    }
}

/// Aggregation over many runs (SLO configs × arrival orders).
#[derive(Clone, Debug, Default)]
pub struct Aggregate {
    pub violation_rates: Vec<f64>,
    pub throughputs: Vec<f64>,
}

impl Aggregate {
    pub fn push(&mut self, r: &RunReport) {
        self.violation_rates.push(r.violation_rate());
        self.throughputs.push(r.throughput_qps());
    }

    pub fn mean_violation_pct(&self) -> f64 {
        100.0 * stats::mean(&self.violation_rates)
    }

    pub fn mean_throughput(&self) -> f64 {
        stats::mean(&self.throughputs)
    }
}

/// Latency breakdown of adding a new variant (paper Fig. 5a).
#[derive(Clone, Copy, Debug, Default)]
pub struct SwitchBreakdown {
    pub compile_ms: f64,
    pub load_ms: f64,
    pub inference_ms: f64,
}

impl SwitchBreakdown {
    pub fn total(&self) -> f64 {
        self.compile_ms + self.load_ms + self.inference_ms
    }

    /// Fraction of the total spent loading (the paper reports ≤ 96.4 %
    /// for compile+load combined, with compile 23.7× and load 3× infer).
    pub fn load_fraction(&self) -> f64 {
        if self.total() <= 0.0 {
            return 0.0;
        }
        (self.compile_ms + self.load_ms) / self.total()
    }
}

/// Render an aligned text table (experiment output).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Per-platform experiment results keyed by method name — the common
/// shape of Figs. 10, 11, 15, 16.
pub type MethodResults = BTreeMap<String, f64>;

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(acc: Option<f64>, lat: f64) -> TaskOutcome {
        TaskOutcome {
            task: "t".into(),
            accuracy: acc,
            mean_latency_ms: lat,
            p50_latency_ms: lat,
            p95_latency_ms: lat,
            p99_latency_ms: lat,
            mean_queueing_ms: 0.0,
            queries_completed: 100,
            queries_dropped: 0,
            slo_accuracy: 0.8,
            slo_latency_ms: 50.0,
        }
    }

    #[test]
    fn violation_predicate() {
        assert!(!outcome(Some(0.9), 40.0).violated());
        assert!(outcome(Some(0.7), 40.0).violated(), "accuracy miss");
        assert!(outcome(Some(0.9), 60.0).violated(), "latency miss");
        assert!(outcome(None, 0.0).violated(), "no variant");
    }

    #[test]
    fn rates_and_throughput() {
        let r = RunReport {
            outcomes: vec![outcome(Some(0.9), 40.0), outcome(Some(0.7), 40.0)],
            makespan_ms: 2000.0,
            total_queries: 400,
            ..Default::default()
        };
        assert!((r.violation_rate() - 0.5).abs() < 1e-12);
        assert!((r.throughput_qps() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn aggregate_means() {
        let mut agg = Aggregate::default();
        agg.push(&RunReport {
            outcomes: vec![outcome(Some(0.9), 40.0)],
            makespan_ms: 1000.0,
            total_queries: 100,
            ..Default::default()
        });
        agg.push(&RunReport {
            outcomes: vec![outcome(None, 0.0)],
            makespan_ms: 1000.0,
            total_queries: 50,
            ..Default::default()
        });
        assert!((agg.mean_violation_pct() - 50.0).abs() < 1e-9);
        assert!((agg.mean_throughput() - 75.0).abs() < 1e-9);
    }

    #[test]
    fn switch_breakdown_fractions() {
        // Paper Fig. 5a: compile 23.7× infer, load 3× infer.
        let b = SwitchBreakdown { compile_ms: 23.7, load_ms: 3.0, inference_ms: 1.0 };
        assert!(b.load_fraction() > 0.96);
    }

    #[test]
    fn table_rendering_aligns() {
        let t = render_table(
            &["method", "value"],
            &[
                vec!["SparseLoom".into(), "1.0".into()],
                vec!["SV-AO-P".into(), "22.5".into()],
            ],
        );
        assert!(t.contains("SparseLoom"));
        assert!(t.lines().count() == 4);
    }
}
