//! Chrome trace-event export: loads in Perfetto / `chrome://tracing`.
//!
//! Mapping: one process (pid 0), one track (tid) per shard, named via
//! `"M"` metadata records. Spans (`TR-REQ-QUEUE`, `TR-REQ-EXEC`,
//! `TR-CTL-CRASH`) become complete events (`"ph":"X"`); everything
//! else becomes a thread-scoped instant (`"ph":"i"`). Steal, redirect,
//! and migrate events additionally emit a flow-arrow pair
//! (`"ph":"s"`/`"f"`) from the source shard's track to the
//! destination's, so cross-shard moves are visible as arrows on the
//! timeline. Timestamps convert from virtual ms to the format's µs.

use std::collections::BTreeMap;

use crate::json::Json;

use super::{TraceEvent, TR_CTL_CRASH, TR_CTL_MIGRATE, TR_CTL_REDIRECT, TR_CTL_STEAL, TR_REQ_EXEC, TR_REQ_QUEUE};

const MS_TO_US: f64 = 1000.0;

/// Serialize a canonical trace as a Chrome trace-event JSON document.
pub fn to_chrome(events: &[TraceEvent]) -> Json {
    let mut records: Vec<Json> = Vec::new();
    // Track naming: every shard that appears gets a labelled track.
    let mut shards: Vec<usize> = events.iter().map(|e| e.shard).collect();
    shards.sort_unstable();
    shards.dedup();
    for shard in shards {
        records.push(Json::obj(vec![
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("thread_name".into())),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num(shard as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::Str(format!("shard {shard}")))]),
            ),
        ]));
    }
    let mut flow_id = 0u64;
    for ev in events {
        let name = if ev.task.is_empty() {
            ev.code.clone()
        } else {
            format!("{} {}", ev.code, ev.task)
        };
        let cat = if ev.code.starts_with("TR-CTL") { "ctl" } else { "req" };
        let mut args: BTreeMap<String, Json> = ev
            .args
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        if let Some(id) = ev.id {
            args.insert("request_id".into(), Json::Num(id as f64));
        }
        let base = |ph: &str, tid: usize, ts_ms: f64| {
            vec![
                ("ph", Json::Str(ph.into())),
                ("name", Json::Str(name.clone())),
                ("cat", Json::Str(cat.into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(tid as f64)),
                ("ts", Json::Num(ts_ms * MS_TO_US)),
            ]
        };
        match ev.code.as_str() {
            TR_REQ_QUEUE | TR_REQ_EXEC | TR_CTL_CRASH => {
                let mut fields = base("X", ev.shard, ev.begin_ms);
                fields.push((
                    "dur",
                    Json::Num((ev.end_ms - ev.begin_ms) * MS_TO_US),
                ));
                fields.push(("args", Json::Obj(args)));
                records.push(Json::obj(fields));
            }
            TR_CTL_STEAL | TR_CTL_REDIRECT | TR_CTL_MIGRATE => {
                // The instant on the destination track…
                let mut fields = base("i", ev.shard, ev.begin_ms);
                fields.push(("s", Json::Str("t".into())));
                fields.push(("args", Json::Obj(args)));
                records.push(Json::obj(fields));
                // …plus a flow arrow source → destination. Steals name
                // their source "home"; redirects and migrations "from".
                let src = ev
                    .arg("home")
                    .or_else(|| ev.arg("from"))
                    .map(|s| s as usize)
                    .unwrap_or(ev.shard);
                let mut s_fields = base("s", src, ev.begin_ms);
                s_fields.push(("id", Json::Num(flow_id as f64)));
                records.push(Json::obj(s_fields));
                let mut f_fields = base("f", ev.shard, ev.begin_ms);
                f_fields.push(("id", Json::Num(flow_id as f64)));
                f_fields.push(("bp", Json::Str("e".into())));
                records.push(Json::obj(f_fields));
                flow_id += 1;
            }
            _ => {
                let mut fields = base("i", ev.shard, ev.begin_ms);
                fields.push(("s", Json::Str("t".into())));
                fields.push(("args", Json::Obj(args)));
                records.push(Json::obj(fields));
            }
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(records)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;
    use crate::trace::{TR_CTL_STEAL, TR_REQ_DONE, TR_REQ_EXEC};

    #[test]
    fn chrome_export_is_valid_and_typed() {
        let events = vec![
            TraceEvent::new(TR_REQ_EXEC, 0, "alpha", Some(3), 1.0, 5.0, &[
                ("service_ms", 4.0),
            ]),
            TraceEvent::new(TR_REQ_DONE, 0, "alpha", Some(3), 5.0, 5.0, &[]),
            TraceEvent::new(TR_CTL_STEAL, 1, "alpha", None, 6.0, 6.0, &[
                ("thief", 1.0),
                ("home", 0.0),
            ]),
        ];
        let doc = to_chrome(&events);
        // Round-trips through the JSON parser (well-formedness).
        let parsed = json::parse(&doc.to_string()).unwrap();
        let recs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 shard-name metadata per shard + X + i + (i, s, f) for steal.
        assert_eq!(recs.len(), 2 + 1 + 1 + 3);
        let phases: Vec<&str> = recs
            .iter()
            .filter_map(|r| r.get("ph").and_then(|p| p.as_str()))
            .collect();
        assert!(phases.contains(&"M"));
        assert!(phases.contains(&"X"));
        assert!(phases.contains(&"s"), "steal emits a flow source");
        assert!(phases.contains(&"f"), "steal emits a flow sink");
        // The EXEC span converts ms → µs.
        let x = recs
            .iter()
            .find(|r| r.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .unwrap();
        assert_eq!(x.get("ts").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(x.get("dur").unwrap().as_f64().unwrap(), 4000.0);
        // Flow arrow leaves the home shard's track.
        let s = recs
            .iter()
            .find(|r| r.get("ph").and_then(|p| p.as_str()) == Some("s"))
            .unwrap();
        assert_eq!(s.get("tid").unwrap().as_f64().unwrap(), 0.0);
    }
}
