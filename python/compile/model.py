"""L2: the four task models, each a pipeline of S=3 layer-aligned subgraphs.

The paper evaluates ResNet-101 / BERT-Base / ViT-Small / Wav2vec2. Those
are hardware-gated at this scale, so we build tiny models of the same
*families* (see DESIGN.md §Substitutions): a residual CNN-style MLP, a
transformer encoder, a patch-ViT, and a conv+transformer ASR head. What
matters for the paper's contribution is that each task has S layer-aligned
subgraphs whose sparse variants can be recombined (stitched), with genuine
accuracy/latency trade-offs.

Every weight GEMM goes through the L1 Pallas kernels
(:mod:`kernels.sparse_matmul`); data-dependent math (attention scores,
layernorm, softmax, activations) is plain jnp. Each subgraph's forward is
pure: ``f(x, params) -> y`` where ``params`` is a flat list of arrays in a
deterministic order (the HLO parameter order the rust runtime feeds).

Kernel paths — one per variant type, uniform across a variant's GEMMs:

* ``dense``       — f32 weights                      → ``matmul``
* ``masked``      — unstructured pruning, {0,1} mask → ``masked_matmul``
* ``blocksparse`` — structured channel pruning       → ``block_sparse_matmul``
* ``quant``       — INT8 weights + per-col scale     → ``quant_matmul``

``fp16`` variants reuse the ``dense`` path with weights round-tripped
through fp16 at compression time.

Set ``use_kernel=False`` to run the pure-jnp reference forward (used for
training and as an oracle for the pallas path).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels import sparse_matmul as sm

KERNEL_PATHS = ("dense", "masked", "blocksparse", "quant")

N_CLASSES = 10
SUBGRAPHS = 3  # S in the paper; == #processors (paper §5.4)


# --------------------------------------------------------------------------
# Layer primitives
# --------------------------------------------------------------------------


def _gemm(x2d, layer_params, path: str, use_kernel: bool):
    """Dispatch one weight GEMM to the pallas kernel (or jnp oracle)."""
    if path == "dense":
        w, b = layer_params
        if use_kernel:
            return sm.matmul(x2d, w, b)
        return ref.matmul_ref(x2d, w, b)
    if path == "masked":
        w, mask, b = layer_params
        if use_kernel:
            return sm.masked_matmul(x2d, w, mask, b)
        return ref.masked_matmul_ref(x2d, w, mask, b)
    if path == "blocksparse":
        w, keep, b = layer_params
        if use_kernel:
            return sm.block_sparse_matmul(x2d, w, keep, b)
        return ref.block_sparse_matmul_ref(x2d, w, keep, b)
    if path == "quant":
        wq, scale, b = layer_params
        if use_kernel:
            return sm.quant_matmul(x2d, wq, scale, b)
        return ref.quant_matmul_ref(x2d, wq, scale, b)
    raise ValueError(f"unknown kernel path {path!r}")


def _layernorm(x, gamma, beta, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def _attention(x3d, params, path, use_kernel, n_heads):
    """Multi-head self-attention; QKV/O projections via the pallas GEMM."""
    b, s, d = x3d.shape
    x2d = x3d.reshape(b * s, d)
    q = _gemm(x2d, params["wq"], path, use_kernel).reshape(b, s, d)
    k = _gemm(x2d, params["wk"], path, use_kernel).reshape(b, s, d)
    v = _gemm(x2d, params["wv"], path, use_kernel).reshape(b, s, d)
    dh = d // n_heads

    def split(t):  # (b, s, d) -> (b, h, s, dh)
        return t.reshape(b, s, n_heads, dh).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    scores = jnp.einsum("bhsd,bhtd->bhst", qh, kh) / np.sqrt(dh)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,bhtd->bhsd", attn, vh)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b * s, d)
    out = _gemm(ctx, params["wo"], path, use_kernel).reshape(b, s, d)
    return out


def _encoder_block(x3d, params, path, use_kernel, n_heads):
    """Pre-LN transformer encoder block."""
    b, s, d = x3d.shape
    h = _layernorm(x3d, *params["ln1"])
    x3d = x3d + _attention(h, params, path, use_kernel, n_heads)
    h = _layernorm(x3d, *params["ln2"])
    h2 = _gemm(h.reshape(b * s, d), params["ff1"], path, use_kernel)
    h2 = jax.nn.gelu(h2)
    h2 = _gemm(h2, params["ff2"], path, use_kernel)
    return x3d + h2.reshape(b, s, d)


def _res_block(x2d, params, path, use_kernel):
    """Residual MLP block: x + W2·relu(W1·x), post-activation relu."""
    h = jax.nn.relu(_gemm(x2d, params["fc1"], path, use_kernel))
    h = _gemm(h, params["fc2"], path, use_kernel)
    return jax.nn.relu(x2d + h)


# --------------------------------------------------------------------------
# Parameter initialization (dense/f32 base models)
# --------------------------------------------------------------------------


def _init_linear(rng, din, dout):
    w = rng.standard_normal((din, dout)).astype(np.float32) * np.sqrt(2.0 / din)
    b = np.zeros((dout,), np.float32)
    return [jnp.asarray(w), jnp.asarray(b)]


def _init_ln(d):
    return [jnp.ones((d,), jnp.float32), jnp.zeros((d,), jnp.float32)]


def _init_encoder(rng, d, dff):
    return {
        "ln1": _init_ln(d),
        "wq": _init_linear(rng, d, d),
        "wk": _init_linear(rng, d, d),
        "wv": _init_linear(rng, d, d),
        "wo": _init_linear(rng, d, d),
        "ln2": _init_ln(d),
        "ff1": _init_linear(rng, d, dff),
        "ff2": _init_linear(rng, dff, d),
    }


def _init_res(rng, d):
    return {"fc1": _init_linear(rng, d, d), "fc2": _init_linear(rng, d, d)}


# --------------------------------------------------------------------------
# Task model definitions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """Static description of a task model.

    ``iface`` lists the activation widths at the S+1 pipeline boundaries
    (input dim, sg1→sg2 dim, sg2→sg3 dim, output dim = N_CLASSES). These
    are identical across all variants of a task — the layer-aligned
    interface contract that makes stitching shape-safe (paper §2.1,
    operational scope (ii)).
    """

    name: str
    family: str
    input_dim: int
    iface: tuple
    init: Callable  # rng -> params (list of S per-subgraph param pytrees)
    forward_sg: Callable  # (j, x2d, sg_params, path, use_kernel) -> y2d


# ---- imgcls: residual CNN-style model (ResNet-101 stand-in) ----

IMG_D = 256


def _imgcls_init(rng):
    return [
        {"embed": _init_linear(rng, 768, IMG_D), "res1": _init_res(rng, IMG_D)},
        {"res2": _init_res(rng, IMG_D), "res3": _init_res(rng, IMG_D)},
        {"res4": _init_res(rng, IMG_D), "head": _init_linear(rng, IMG_D, N_CLASSES)},
    ]


def _imgcls_fwd(j, x, p, path, uk):
    if j == 0:
        x = jax.nn.relu(_gemm(x, p["embed"], path, uk))
        return _res_block(x, p["res1"], path, uk)
    if j == 1:
        x = _res_block(x, p["res2"], path, uk)
        return _res_block(x, p["res3"], path, uk)
    x = _res_block(x, p["res4"], path, uk)
    return _gemm(x, p["head"], path, uk)


# ---- sentiment: transformer encoder (BERT-Base stand-in) ----

SENT_SEQ, SENT_D, SENT_FF, SENT_HEADS = 16, 64, 128, 2


def _sentiment_init(rng):
    return [
        {"embed": _init_linear(rng, SENT_D, SENT_D),
         "enc1": _init_encoder(rng, SENT_D, SENT_FF)},
        {"enc2": _init_encoder(rng, SENT_D, SENT_FF)},
        {"ln": _init_ln(SENT_D),
         "fc": _init_linear(rng, SENT_D, SENT_FF),
         "head": _init_linear(rng, SENT_FF, N_CLASSES)},
    ]


def _sentiment_fwd(j, x, p, path, uk):
    b = x.shape[0]
    if j == 0:
        t = x.reshape(b * SENT_SEQ, SENT_D)
        t = _gemm(t, p["embed"], path, uk).reshape(b, SENT_SEQ, SENT_D)
        t = _encoder_block(t, p["enc1"], path, uk, SENT_HEADS)
        return t.reshape(b, SENT_SEQ * SENT_D)
    if j == 1:
        t = x.reshape(b, SENT_SEQ, SENT_D)
        t = _encoder_block(t, p["enc2"], path, uk, SENT_HEADS)
        return t.reshape(b, SENT_SEQ * SENT_D)
    t = x.reshape(b, SENT_SEQ, SENT_D)
    t = _layernorm(t, *p["ln"]).mean(axis=1)  # (b, d) mean-pool
    t = jax.nn.gelu(_gemm(t, p["fc"], path, uk))
    return _gemm(t, p["head"], path, uk)


# ---- har: patch ViT (ViT-Small stand-in) ----

HAR_PATCHES, HAR_PATCH_DIM, HAR_D, HAR_FF, HAR_HEADS = 16, 48, 96, 192, 3


def _har_init(rng):
    return [
        {"embed": _init_linear(rng, HAR_PATCH_DIM, HAR_D),
         "enc1": _init_encoder(rng, HAR_D, HAR_FF)},
        {"enc2": _init_encoder(rng, HAR_D, HAR_FF)},
        {"ln": _init_ln(HAR_D),
         "head": _init_linear(rng, HAR_D, N_CLASSES)},
    ]


def _har_fwd(j, x, p, path, uk):
    b = x.shape[0]
    if j == 0:
        t = x.reshape(b * HAR_PATCHES, HAR_PATCH_DIM)
        t = _gemm(t, p["embed"], path, uk).reshape(b, HAR_PATCHES, HAR_D)
        t = _encoder_block(t, p["enc1"], path, uk, HAR_HEADS)
        return t.reshape(b, HAR_PATCHES * HAR_D)
    if j == 1:
        t = x.reshape(b, HAR_PATCHES, HAR_D)
        t = _encoder_block(t, p["enc2"], path, uk, HAR_HEADS)
        return t.reshape(b, HAR_PATCHES * HAR_D)
    t = x.reshape(b, HAR_PATCHES, HAR_D)
    t = _layernorm(t, *p["ln"]).mean(axis=1)
    return _gemm(t, p["head"], path, uk)


# ---- asr: conv frame-encoder + transformer (Wav2vec2 stand-in) ----

ASR_FRAMES, ASR_FRAME_DIM, ASR_D, ASR_FF, ASR_HEADS = 32, 32, 64, 128, 2


def _asr_init(rng):
    return [
        {"embed": _init_linear(rng, ASR_FRAME_DIM, ASR_D),
         "ff_a": _init_linear(rng, ASR_D, ASR_FF),
         "ff_b": _init_linear(rng, ASR_FF, ASR_D),
         "ln": _init_ln(ASR_D)},
        {"enc": _init_encoder(rng, ASR_D, ASR_FF)},
        {"ln": _init_ln(ASR_D),
         "head": _init_linear(rng, ASR_D, N_CLASSES)},
    ]


def _asr_fwd(j, x, p, path, uk):
    b = x.shape[0]
    if j == 0:
        # conv-as-matmul frame feature extractor
        t = x.reshape(b * ASR_FRAMES, ASR_FRAME_DIM)
        t = jax.nn.gelu(_gemm(t, p["embed"], path, uk))
        h = jax.nn.gelu(_gemm(t, p["ff_a"], path, uk))
        h = _gemm(h, p["ff_b"], path, uk)
        t = _layernorm((t + h).reshape(b, ASR_FRAMES, ASR_D), *p["ln"])
        return t.reshape(b, ASR_FRAMES * ASR_D)
    if j == 1:
        t = x.reshape(b, ASR_FRAMES, ASR_D)
        t = _encoder_block(t, p["enc"], path, uk, ASR_HEADS)
        return t.reshape(b, ASR_FRAMES * ASR_D)
    t = x.reshape(b, ASR_FRAMES, ASR_D)
    t = _layernorm(t, *p["ln"]).mean(axis=1)
    return _gemm(t, p["head"], path, uk)


TASKS = {
    "imgcls": TaskSpec(
        "imgcls", "resnet", 768,
        (768, IMG_D, IMG_D, N_CLASSES), _imgcls_init, _imgcls_fwd),
    "sentiment": TaskSpec(
        "sentiment", "bert", SENT_SEQ * SENT_D,
        (SENT_SEQ * SENT_D, SENT_SEQ * SENT_D, SENT_SEQ * SENT_D, N_CLASSES),
        _sentiment_init, _sentiment_fwd),
    "har": TaskSpec(
        "har", "vit", HAR_PATCHES * HAR_PATCH_DIM,
        (HAR_PATCHES * HAR_PATCH_DIM, HAR_PATCHES * HAR_D,
         HAR_PATCHES * HAR_D, N_CLASSES), _har_init, _har_fwd),
    "asr": TaskSpec(
        "asr", "wav2vec", ASR_FRAMES * ASR_FRAME_DIM,
        (ASR_FRAMES * ASR_FRAME_DIM, ASR_FRAMES * ASR_D,
         ASR_FRAMES * ASR_D, N_CLASSES), _asr_init, _asr_fwd),
}

TASK_NAMES = tuple(TASKS)


# --------------------------------------------------------------------------
# Whole-model forward + param flattening
# --------------------------------------------------------------------------


def forward(task: str, x, params, path="dense", use_kernel=False):
    """Full S-subgraph forward: chain the per-subgraph forwards."""
    spec = TASKS[task]
    for j in range(SUBGRAPHS):
        x = spec.forward_sg(j, x, params[j], path, use_kernel)
    return x


def forward_subgraph(task, j, x, sg_params, path="dense", use_kernel=False):
    """Single subgraph forward (what each HLO artifact implements)."""
    return TASKS[task].forward_sg(j, x, sg_params, path, use_kernel)


def flatten_params(sg_params):
    """Deterministic flat tensor list for one subgraph's params.

    Sorted-key traversal of the nested dict; within a layer the list order
    is as stored (w, [mask|keep|scale], b — see compress.py). This order
    defines the HLO parameter order after the activation input and is
    mirrored in the manifest for the rust runtime.
    """
    flat = []
    for key in sorted(sg_params):
        val = sg_params[key]
        if isinstance(val, dict):
            flat.extend(flatten_params(val))
        else:
            flat.extend(val)
    return flat


def unflatten_like(sg_params, flat):
    """Inverse of :func:`flatten_params` given a structure template."""
    flat = list(flat)

    def take(template):
        out = {}
        for key in sorted(template):
            val = template[key]
            if isinstance(val, dict):
                out[key] = take(val)
            else:
                out[key] = [flat.pop(0) for _ in val]
        return out

    return take(sg_params)


def init_params(task: str, seed: int = 0):
    """Initialize the dense/f32 base-model params for a task."""
    import zlib

    rng = np.random.default_rng(seed + zlib.crc32(task.encode()) % (2**16))
    return TASKS[task].init(rng)
