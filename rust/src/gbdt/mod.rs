//! Gradient-boosted regression trees (from-scratch XGBoost substitute).
//!
//! The paper's accuracy estimator (§3.2, Eq. 4) is an XGBoost regressor
//! over subgraph-level features. This is a clean-room implementation of
//! the same model class: squared-loss gradient boosting with depth-
//! limited regression trees, exact greedy split search, shrinkage, and
//! optional row subsampling. Feature matrices here are tiny (hundreds of
//! rows × ~20 columns), so exact splits beat histogram approximations.

use crate::util::Rng;

/// Training hyperparameters.
#[derive(Clone, Debug)]
pub struct GbdtParams {
    pub n_trees: usize,
    pub max_depth: usize,
    /// Learning rate (shrinkage) applied to every leaf.
    pub eta: f64,
    /// Minimum rows in a leaf; splits creating smaller leaves are rejected.
    pub min_leaf: usize,
    /// Row subsample fraction per tree (stochastic gradient boosting).
    pub subsample: f64,
    pub seed: u64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        Self {
            n_trees: 420,
            max_depth: 6,
            eta: 0.05,
            min_leaf: 2,
            subsample: 0.9,
            seed: 17,
        }
    }
}

#[derive(Clone, Debug)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// One regression tree (arena-allocated nodes).
#[derive(Clone, Debug)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    i = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

/// A fitted gradient-boosted model.
#[derive(Clone, Debug)]
pub struct Gbdt {
    base: f64,
    eta: f64,
    trees: Vec<Tree>,
    n_features: usize,
}

impl Gbdt {
    /// Fit on rows `x` (n × d, row-major slices) and targets `y`.
    pub fn fit(x: &[Vec<f64>], y: &[f64], p: &GbdtParams) -> Gbdt {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "empty training set");
        let n = x.len();
        let d = x[0].len();
        let base = y.iter().sum::<f64>() / n as f64;
        let mut residual: Vec<f64> = y.iter().map(|v| v - base).collect();
        let mut trees = Vec::with_capacity(p.n_trees);
        let mut rng = Rng::new(p.seed);

        for _ in 0..p.n_trees {
            let rows: Vec<usize> = if p.subsample < 1.0 {
                let k = ((n as f64) * p.subsample).ceil() as usize;
                rng.sample_indices(n, k.clamp(1, n))
            } else {
                (0..n).collect()
            };
            let tree = grow_tree(x, &residual, &rows, d, p);
            // Update residuals with the shrunken tree prediction.
            for i in 0..n {
                residual[i] -= p.eta * tree.predict(&x[i]);
            }
            trees.push(tree);
        }

        Gbdt { base, eta: p.eta, trees, n_features: d }
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.n_features);
        let mut acc = self.base;
        for t in &self.trees {
            acc += self.eta * t.predict(x);
        }
        acc
    }

    pub fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict(x)).collect()
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

/// Grow one depth-limited tree on the residuals of the given rows.
fn grow_tree(x: &[Vec<f64>], r: &[f64], rows: &[usize], d: usize, p: &GbdtParams) -> Tree {
    let mut nodes = Vec::new();
    build(x, r, rows, d, p, 0, &mut nodes);
    Tree { nodes }
}

fn leaf_value(r: &[f64], rows: &[usize]) -> f64 {
    rows.iter().map(|&i| r[i]).sum::<f64>() / rows.len().max(1) as f64
}

fn build(
    x: &[Vec<f64>],
    r: &[f64],
    rows: &[usize],
    d: usize,
    p: &GbdtParams,
    depth: usize,
    nodes: &mut Vec<Node>,
) -> usize {
    let me = nodes.len();
    if depth >= p.max_depth || rows.len() < 2 * p.min_leaf {
        nodes.push(Node::Leaf { value: leaf_value(r, rows) });
        return me;
    }
    let Some((feature, threshold)) = best_split(x, r, rows, d, p.min_leaf) else {
        nodes.push(Node::Leaf { value: leaf_value(r, rows) });
        return me;
    };
    // Placeholder; children indices patched after recursion.
    nodes.push(Node::Leaf { value: 0.0 });
    let (lrows, rrows): (Vec<usize>, Vec<usize>) =
        rows.iter().partition(|&&i| x[i][feature] <= threshold);
    let left = build(x, r, &lrows, d, p, depth + 1, nodes);
    let right = build(x, r, &rrows, d, p, depth + 1, nodes);
    nodes[me] = Node::Split { feature, threshold, left, right };
    me
}

/// Exact greedy split: maximize variance reduction (equivalently, the
/// squared-loss gain) over all (feature, threshold) candidates.
fn best_split(
    x: &[Vec<f64>],
    r: &[f64],
    rows: &[usize],
    d: usize,
    min_leaf: usize,
) -> Option<(usize, f64)> {
    let n = rows.len();
    let total_sum: f64 = rows.iter().map(|&i| r[i]).sum();
    let parent_score = total_sum * total_sum / n as f64;
    let mut best: Option<(f64, usize, f64)> = None;

    let mut order: Vec<usize> = Vec::with_capacity(n);
    for f in 0..d {
        order.clear();
        order.extend_from_slice(rows);
        order.sort_by(|&a, &b| x[a][f].partial_cmp(&x[b][f]).unwrap());

        let mut lsum = 0.0;
        for (pos, &i) in order.iter().enumerate().take(n - 1) {
            lsum += r[i];
            let nl = pos + 1;
            let nr = n - nl;
            if nl < min_leaf || nr < min_leaf {
                continue;
            }
            let xv = x[i][f];
            let xnext = x[order[pos + 1]][f];
            if xv == xnext {
                continue; // can't split between equal values
            }
            let rsum = total_sum - lsum;
            let gain =
                lsum * lsum / nl as f64 + rsum * rsum / nr as f64 - parent_score;
            if gain > best.map(|(g, _, _)| g).unwrap_or(1e-12) {
                best = Some((gain, f, 0.5 * (xv + xnext)));
            }
        }
    }
    best.map(|(_, f, t)| (f, t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn synth(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let a = rng.f64();
            let b = rng.f64();
            let c = rng.f64();
            // Nonlinear target with interactions — tree-friendly.
            let y = 2.0 * a + if b > 0.5 { 1.5 } else { -0.5 } * c + (a * b).sin();
            xs.push(vec![a, b, c]);
            ys.push(y);
        }
        (xs, ys)
    }

    #[test]
    fn fits_nonlinear_function() {
        let (xs, ys) = synth(400, 1);
        let model = Gbdt::fit(&xs, &ys, &GbdtParams::default());
        let (xt, yt) = synth(200, 2);
        let pred = model.predict_batch(&xt);
        let r2 = stats::r2(&pred, &yt);
        assert!(r2 > 0.9, "R² = {r2}");
    }

    #[test]
    fn constant_target_gives_constant_model() {
        let xs: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let ys = vec![3.25; 50];
        let model = Gbdt::fit(&xs, &ys, &GbdtParams::default());
        for x in &xs {
            assert!((model.predict(x) - 3.25).abs() < 1e-9);
        }
    }

    #[test]
    fn single_row_training_is_safe() {
        let model = Gbdt::fit(&[vec![1.0, 2.0]], &[5.0], &GbdtParams::default());
        assert!((model.predict(&[1.0, 2.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let (xs, ys) = synth(100, 3);
        let p = GbdtParams::default();
        let a = Gbdt::fit(&xs, &ys, &p);
        let b = Gbdt::fit(&xs, &ys, &p);
        for x in xs.iter().take(10) {
            assert_eq!(a.predict(x), b.predict(x));
        }
    }

    #[test]
    fn more_trees_fit_better_in_sample() {
        let (xs, ys) = synth(200, 4);
        let small = Gbdt::fit(&xs, &ys, &GbdtParams { n_trees: 5, ..Default::default() });
        let large = Gbdt::fit(&xs, &ys, &GbdtParams { n_trees: 200, ..Default::default() });
        let err = |m: &Gbdt| {
            xs.iter()
                .zip(&ys)
                .map(|(x, y)| (m.predict(x) - y).powi(2))
                .sum::<f64>()
        };
        assert!(err(&large) < err(&small));
    }

    #[test]
    fn step_function_recovered() {
        let xs: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 / 100.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| if x[0] < 0.37 { 0.0 } else { 1.0 }).collect();
        let model = Gbdt::fit(
            &xs,
            &ys,
            &GbdtParams { n_trees: 60, max_depth: 2, eta: 0.3, min_leaf: 2, subsample: 1.0, seed: 5 },
        );
        assert!(model.predict(&[0.1]) < 0.2);
        assert!(model.predict(&[0.9]) > 0.8);
    }
}
