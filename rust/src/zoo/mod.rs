//! Sparse model zoo: typed view of `artifacts/manifest.json`.
//!
//! The zoo (paper Table 5) is produced at build time by
//! `python/compile/aot.py`: per task, V=10 sparse variants of one base
//! model, each split into S=3 layer-aligned subgraphs. This module loads
//! the manifest into typed structures consumed by stitching, the
//! profiler, the optimizer, the preloader, and the runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::{self, Json};

/// The compression family of a variant (Table 5 "Variant Type").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum VariantType {
    Dense,
    Fp16,
    Int8,
    Unstructured,
    Structured,
}

impl VariantType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "dense" => Self::Dense,
            "fp16" => Self::Fp16,
            "int8" => Self::Int8,
            "unstructured" => Self::Unstructured,
            "structured" => Self::Structured,
            other => bail!("unknown variant type {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Dense => "dense",
            Self::Fp16 => "fp16",
            Self::Int8 => "int8",
            Self::Unstructured => "unstructured",
            Self::Structured => "structured",
        }
    }

    /// Short tag used in paper-style variant strings (P-Q-D notation).
    pub fn tag(&self) -> char {
        match self {
            Self::Dense => 'D',
            Self::Fp16 => 'H',
            Self::Int8 => 'Q',
            Self::Unstructured | Self::Structured => 'P',
        }
    }
}

/// Numeric precision of the stored weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    Fp32,
    Fp16,
    Int8,
}

impl Precision {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fp32" => Self::Fp32,
            "fp16" => Self::Fp16,
            "int8" => Self::Int8,
            other => bail!("unknown precision {other:?}"),
        })
    }
}

/// Which L1 kernel family executes a variant's GEMMs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KernelPath {
    Dense,
    Masked,
    BlockSparse,
    Quant,
}

impl KernelPath {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "dense" => Self::Dense,
            "masked" => Self::Masked,
            "blocksparse" => Self::BlockSparse,
            "quant" => Self::Quant,
            other => bail!("unknown kernel path {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Dense => "dense",
            Self::Masked => "masked",
            Self::BlockSparse => "blocksparse",
            Self::Quant => "quant",
        }
    }
}

/// One zoo entry (a row of Table 5).
#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    pub vtype: VariantType,
    /// Fraction of weights pruned (0 for dense/quantized variants).
    pub sparsity: f64,
    pub kernel_path: KernelPath,
    pub precision: Precision,
}

/// Dtype of one serialized tensor in a weight blob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Self::F32,
            "i8" => Self::I8,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    pub fn size(&self) -> usize {
        match self {
            Self::F32 => 4,
            Self::I8 => 1,
        }
    }
}

/// Shape+dtype of one tensor parameter (HLO parameter order).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.size()
    }
}

/// One subgraph of one variant: its weight blob on disk.
#[derive(Clone, Debug)]
pub struct SubgraphWeights {
    pub file: PathBuf,
    pub bytes: u64,
    pub params: Vec<TensorSpec>,
}

/// One HLO artifact: a (subgraph, kernel-path, batch) executable source.
#[derive(Clone, Debug)]
pub struct HloArtifact {
    pub file: PathBuf,
    pub flops: f64,
    pub bytes_accessed: f64,
    pub params: Vec<TensorSpec>,
    pub input_dim: usize,
    pub output_dim: usize,
}

/// One variant of one task: accuracy + per-subgraph weights.
#[derive(Clone, Debug)]
pub struct TaskVariant {
    pub spec: VariantSpec,
    pub accuracy: f64,
    pub subgraphs: Vec<SubgraphWeights>,
}

impl TaskVariant {
    /// Total weight bytes across all subgraphs (the preloader's Mem()).
    pub fn total_bytes(&self) -> u64 {
        self.subgraphs.iter().map(|s| s.bytes).sum()
    }
}

/// One task: its variants plus HLO artifacts keyed by
/// `(subgraph, kernel_path, batch)`.
#[derive(Clone, Debug)]
pub struct TaskZoo {
    pub name: String,
    pub family: String,
    pub input_dim: usize,
    /// Activation widths at the S+1 pipeline boundaries.
    pub iface: Vec<usize>,
    /// Variants in zoo order (the stitched-index digit alphabet).
    pub variants: Vec<TaskVariant>,
    pub hlo: BTreeMap<(usize, KernelPath, usize), HloArtifact>,
}

impl TaskZoo {
    pub fn variant(&self, i: usize) -> &TaskVariant {
        &self.variants[i]
    }

    pub fn variant_by_name(&self, name: &str) -> Option<(usize, &TaskVariant)> {
        self.variants
            .iter()
            .enumerate()
            .find(|(_, v)| v.spec.name == name)
    }

    pub fn n_variants(&self) -> usize {
        self.variants.len()
    }

    pub fn hlo_for(&self, sg: usize, path: KernelPath, batch: usize) -> Result<&HloArtifact> {
        self.hlo
            .get(&(sg, path, batch))
            .with_context(|| format!("no HLO for sg{sg}/{}/b{batch} in {}", path.name(), self.name))
    }
}

/// The whole artifact bundle.
#[derive(Clone, Debug)]
pub struct Zoo {
    pub root: PathBuf,
    pub seed: u64,
    pub zoo_name: String,
    /// S — subgraphs per variant (== pipeline stages == processors used).
    pub subgraphs: usize,
    pub n_classes: usize,
    pub batch_sizes: Vec<usize>,
    pub probe_batch: usize,
    pub n_eval: usize,
    pub tasks: BTreeMap<String, TaskZoo>,
}

impl Zoo {
    /// Load and validate `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Zoo> {
        let root = root.as_ref().to_path_buf();
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let m = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;

        let version = m.req("version")?.as_u64().context("version")?;
        if version < 3 {
            bail!("manifest version {version} too old (need ≥ 3); re-run `make artifacts`");
        }

        let variants_json = m.req("variants")?.as_arr().context("variants")?;
        let mut specs = Vec::new();
        for v in variants_json {
            specs.push(VariantSpec {
                name: v.req("name")?.as_str().context("name")?.to_string(),
                vtype: VariantType::parse(v.req("vtype")?.as_str().context("vtype")?)?,
                sparsity: v.req("sparsity")?.as_f64().context("sparsity")?,
                kernel_path: KernelPath::parse(
                    v.req("kernel_path")?.as_str().context("kernel_path")?,
                )?,
                precision: Precision::parse(
                    v.req("precision")?.as_str().context("precision")?,
                )?,
            });
        }

        let subgraphs = m.req("subgraphs")?.as_usize().context("subgraphs")?;
        let mut tasks = BTreeMap::new();
        for (tname, tj) in m.req("tasks")?.as_obj().context("tasks")? {
            let iface: Vec<usize> = tj
                .req("iface")?
                .as_arr()
                .context("iface")?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            if iface.len() != subgraphs + 1 {
                bail!("task {tname}: iface has {} entries, want {}", iface.len(), subgraphs + 1);
            }

            let mut variants = Vec::new();
            let vmap = tj.req("variants")?.as_obj().context("variants")?;
            for spec in &specs {
                let vj = vmap
                    .get(&spec.name)
                    .with_context(|| format!("task {tname}: missing variant {}", spec.name))?;
                let mut sgs = Vec::new();
                for sj in vj.req("subgraphs")?.as_arr().context("subgraphs")? {
                    sgs.push(SubgraphWeights {
                        file: root.join(sj.req("file")?.as_str().context("file")?),
                        bytes: sj.req("bytes")?.as_u64().context("bytes")?,
                        params: parse_params(sj.req("params")?)?,
                    });
                }
                if sgs.len() != subgraphs {
                    bail!("task {tname}/{}: {} subgraphs, want {subgraphs}", spec.name, sgs.len());
                }
                variants.push(TaskVariant {
                    spec: spec.clone(),
                    accuracy: vj.req("accuracy")?.as_f64().context("accuracy")?,
                    subgraphs: sgs,
                });
            }

            let mut hlo = BTreeMap::new();
            for (key, hj) in tj.req("hlo")?.as_obj().context("hlo")? {
                let (sg, path, batch) = parse_hlo_key(key)?;
                hlo.insert(
                    (sg, path, batch),
                    HloArtifact {
                        file: root.join(hj.req("file")?.as_str().context("file")?),
                        flops: hj.req("flops")?.as_f64().unwrap_or(0.0),
                        bytes_accessed: hj
                            .get("bytes_accessed")
                            .and_then(|x| x.as_f64())
                            .unwrap_or(0.0),
                        params: parse_params(hj.req("params")?)?,
                        input_dim: hj.req("input_dim")?.as_usize().context("input_dim")?,
                        output_dim: hj.req("output_dim")?.as_usize().context("output_dim")?,
                    },
                );
            }

            tasks.insert(
                tname.clone(),
                TaskZoo {
                    name: tname.clone(),
                    family: tj.req("family")?.as_str().context("family")?.to_string(),
                    input_dim: tj.req("input_dim")?.as_usize().context("input_dim")?,
                    iface,
                    variants,
                    hlo,
                },
            );
        }

        Ok(Zoo {
            root,
            seed: m.req("seed")?.as_u64().context("seed")?,
            zoo_name: m.req("zoo_name")?.as_str().context("zoo_name")?.to_string(),
            subgraphs,
            n_classes: m.req("n_classes")?.as_usize().context("n_classes")?,
            batch_sizes: m
                .req("batch_sizes")?
                .as_arr()
                .context("batch_sizes")?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect(),
            probe_batch: m.req("probe_batch")?.as_usize().context("probe_batch")?,
            n_eval: m.req("n_eval")?.as_usize().context("n_eval")?,
            tasks,
        })
    }

    pub fn task(&self, name: &str) -> Result<&TaskZoo> {
        self.tasks
            .get(name)
            .with_context(|| format!("unknown task {name:?}"))
    }

    pub fn task_names(&self) -> Vec<&str> {
        self.tasks.keys().map(|s| s.as_str()).collect()
    }

    /// V — variants per task (identical across tasks by construction).
    pub fn n_variants(&self) -> usize {
        self.tasks
            .values()
            .next()
            .map(|t| t.variants.len())
            .unwrap_or(0)
    }

    /// Load the exact stitched-accuracy oracle for a task
    /// (`oracle/<task>.bin`, f32-LE, index k = ((i1·V)+i2)·V+i3).
    pub fn load_oracle(&self, task: &str) -> Result<Vec<f64>> {
        let path = self.root.join("oracle").join(format!("{task}.bin"));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64)
            .collect())
    }

    /// Load the eval dataset for a task: (X row-major f32, labels).
    pub fn load_eval(&self, task: &str) -> Result<(Vec<f32>, Vec<u32>)> {
        let t = self.task(task)?;
        let path = self.root.join("data").join(format!("{task}_eval.bin"));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let n = self.n_eval;
        let d = t.input_dim;
        let want = n * d * 4 + n * 4;
        if bytes.len() != want {
            bail!("eval file {} has {} bytes, want {want}", path.display(), bytes.len());
        }
        let xs = bytes[..n * d * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let ys = bytes[n * d * 4..]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok((xs, ys))
    }

    /// Load probe input + expected per-variant logits for a task.
    pub fn load_probe(&self, task: &str) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let t = self.task(task)?;
        let path = self.root.join("probes").join(format!("{task}.bin"));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let pb = self.probe_batch;
        let d = t.input_dim;
        let logit_len = pb * self.n_classes;
        let want = pb * d * 4 + t.variants.len() * logit_len * 4;
        if bytes.len() != want {
            bail!("probe file {} has {} bytes, want {want}", path.display(), bytes.len());
        }
        let f32s: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let x = f32s[..pb * d].to_vec();
        let mut logits = Vec::new();
        for i in 0..t.variants.len() {
            let start = pb * d + i * logit_len;
            logits.push(f32s[start..start + logit_len].to_vec());
        }
        Ok((x, logits))
    }

    /// Read one subgraph weight blob into per-tensor byte slices.
    pub fn load_weights(&self, sw: &SubgraphWeights) -> Result<Vec<Vec<u8>>> {
        let bytes = std::fs::read(&sw.file)
            .with_context(|| format!("reading {}", sw.file.display()))?;
        if bytes.len() as u64 != sw.bytes {
            bail!("blob {} has {} bytes, manifest says {}", sw.file.display(), bytes.len(), sw.bytes);
        }
        let mut out = Vec::with_capacity(sw.params.len());
        let mut off = 0usize;
        for p in &sw.params {
            let n = p.bytes();
            out.push(bytes[off..off + n].to_vec());
            off += n;
        }
        if off != bytes.len() {
            bail!("blob {} trailing bytes", sw.file.display());
        }
        Ok(out)
    }
}

fn parse_params(j: &Json) -> Result<Vec<TensorSpec>> {
    let mut out = Vec::new();
    for p in j.as_arr().context("params array")? {
        out.push(TensorSpec {
            dtype: DType::parse(p.req("dtype")?.as_str().context("dtype")?)?,
            shape: p
                .req("shape")?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect(),
        });
    }
    Ok(out)
}

fn parse_hlo_key(key: &str) -> Result<(usize, KernelPath, usize)> {
    // "sg<j>/<path>/b<batch>"
    let parts: Vec<&str> = key.split('/').collect();
    if parts.len() != 3 || !parts[0].starts_with("sg") || !parts[2].starts_with('b') {
        bail!("bad hlo key {key:?}");
    }
    Ok((
        parts[0][2..].parse().with_context(|| format!("hlo key {key:?}"))?,
        KernelPath::parse(parts[1])?,
        parts[2][1..].parse().with_context(|| format!("hlo key {key:?}"))?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_type_roundtrip() {
        for s in ["dense", "fp16", "int8", "unstructured", "structured"] {
            assert_eq!(VariantType::parse(s).unwrap().name(), s);
        }
        assert!(VariantType::parse("bogus").is_err());
    }

    #[test]
    fn tags_match_paper_notation() {
        assert_eq!(VariantType::Dense.tag(), 'D');
        assert_eq!(VariantType::Int8.tag(), 'Q');
        assert_eq!(VariantType::Unstructured.tag(), 'P');
        assert_eq!(VariantType::Structured.tag(), 'P');
    }

    #[test]
    fn tensor_spec_bytes() {
        let t = TensorSpec { dtype: DType::F32, shape: vec![4, 8] };
        assert_eq!(t.elems(), 32);
        assert_eq!(t.bytes(), 128);
        let q = TensorSpec { dtype: DType::I8, shape: vec![4, 8] };
        assert_eq!(q.bytes(), 32);
    }

    #[test]
    fn hlo_key_parsing() {
        let (sg, path, b) = parse_hlo_key("sg2/masked/b256").unwrap();
        assert_eq!(sg, 2);
        assert_eq!(path, KernelPath::Masked);
        assert_eq!(b, 256);
        assert!(parse_hlo_key("nonsense").is_err());
        assert!(parse_hlo_key("sg1/masked").is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let Ok(zoo) = Zoo::load("artifacts") else { return };
        assert_eq!(zoo.subgraphs, 3);
        assert_eq!(zoo.n_variants(), 10);
        assert!(zoo.tasks.len() >= 1);
        for t in zoo.tasks.values() {
            assert_eq!(t.variants.len(), 10);
            // accuracy is a probability
            for v in &t.variants {
                assert!((0.0..=1.0).contains(&v.accuracy));
                assert!(v.total_bytes() > 0);
            }
        }
        let first = zoo.task_names()[0].to_string();
        let oracle = zoo.load_oracle(&first).unwrap();
        assert_eq!(oracle.len(), 1000);
        let (xs, ys) = zoo.load_eval(&first).unwrap();
        assert_eq!(ys.len(), zoo.n_eval);
        assert_eq!(xs.len(), zoo.n_eval * zoo.task(&first).unwrap().input_dim);
    }
}
