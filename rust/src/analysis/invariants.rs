//! Pass group 4: dynamic invariant verification (`SL-INV-*`).
//!
//! Replays a finished session's [`RequestOutcome`] stream and checks
//! the engine's serving invariants *after the fact* — the `serve
//! --verify` contract:
//!
//! - **SL-INV-001, per-task FIFO**: within a task, queries start in
//!   submission (id) order — the engine never reorders a task's queue.
//! - **SL-INV-002, ready-floor monotonicity**: within a task,
//!   completions are nondecreasing in id order (each query's ready
//!   floor is its predecessor's finish), and every event's clock is
//!   sane (`arrival ≤ start ≤ finish`, nonnegative service/queueing).
//! - **SL-INV-003, budget conservation**: the event log, the per-task
//!   outcomes, and the report totals all account for the same queries —
//!   nothing double-counted, nothing vanished; dropped requests carry
//!   no SLO verdict; pool utilization stays within capacity.
//! - **SL-INV-004, NaN-free metrics**: every reported number is finite.
//! - **SL-INV-005** (info): FIFO/monotonicity skipped for a task whose
//!   log holds duplicate query ids — the signature of a merged
//!   multi-phase log, where per-phase clocks restart and id order is no
//!   longer submission order.
//!
//! Traced runs (`serve --trace --verify`) get three more checks over
//! the canonical trace, proving the audit trail tells the same story
//! as the scoreboard it rode along with:
//!
//! - **SL-INV-006, trace span sanity**: every record is finite, spans
//!   run forward, and each request's queue → exec → done records meet
//!   edge-to-edge in virtual time (skipped for (task, id) pairs that
//!   appear in more than one lifecycle — merged multi-phase traces,
//!   mirroring SL-INV-005).
//! - **SL-INV-007, trace conservation**: every `TR-REQ-ARRIVE`
//!   resolves to exactly one done/shed/drop, and the resolution counts
//!   equal the report totals.
//! - **SL-INV-008, trace/metric agreement**: the trace's SLO-miss,
//!   recovery, and throttle-debt tallies reproduce the report counters.
//!
//! Dropped requests are excluded from the ordering checks: a drop is
//! decided at arrival (its event pins `start = finish = arrival`), so
//! it legally "finishes" before earlier-admitted queries complete.
//!
//! One diagnostic is emitted per (task, check): the first offending
//! event is named, rather than one line per violation — a broken
//! invariant usually breaks for a whole stream at once.

use std::collections::BTreeMap;

use crate::metrics::{RequestOutcome, RunReport, ShardedReport};
use crate::trace::{self, TraceEvent};

use super::{Diagnostic, Report};

/// Clock comparisons tolerate accumulated f64 error, matching the
/// engine's own test tolerances.
const TOL: f64 = 1e-6;

/// Verify the serving invariants over a raw event stream.
pub fn verify_events(events: &[RequestOutcome]) -> Report {
    let mut r = Report::new();
    check_event_sanity(events, &mut r);
    let mut by_task: BTreeMap<&str, Vec<&RequestOutcome>> = BTreeMap::new();
    for e in events.iter().filter(|e| !e.dropped) {
        by_task.entry(e.task.as_str()).or_default().push(e);
    }
    for (task, mut evs) in by_task {
        evs.sort_by_key(|e| e.id);
        if evs.windows(2).any(|w| w[0].id == w[1].id) {
            r.push(Diagnostic::info(
                "SL-INV-005",
                format!("task {task:?}"),
                "duplicate query ids (merged multi-phase log): FIFO and \
                 ready-floor ordering not checkable across phases",
            ));
            continue;
        }
        if let Some(w) = evs.windows(2).find(|w| w[1].start_ms < w[0].start_ms - TOL) {
            r.push(Diagnostic::error(
                "SL-INV-001",
                format!("task {task:?}"),
                format!(
                    "per-task FIFO violated: query {} started at {} ms, before \
                     query {}'s start at {} ms",
                    w[1].id, w[1].start_ms, w[0].id, w[0].start_ms
                ),
            ));
        }
        if let Some(w) = evs.windows(2).find(|w| w[1].finish_ms < w[0].finish_ms - TOL) {
            r.push(Diagnostic::error(
                "SL-INV-002",
                format!("task {task:?}"),
                format!(
                    "ready floor violated: query {} finished at {} ms, before \
                     query {}'s finish at {} ms",
                    w[1].id, w[1].finish_ms, w[0].id, w[0].finish_ms
                ),
            ));
        }
    }
    r
}

/// Per-event clock sanity + finiteness, one diagnostic per task per kind.
fn check_event_sanity(events: &[RequestOutcome], r: &mut Report) {
    let mut clock_flagged: BTreeMap<&str, ()> = BTreeMap::new();
    let mut nan_flagged: BTreeMap<&str, ()> = BTreeMap::new();
    for e in events {
        let fields = [e.arrival_ms, e.start_ms, e.finish_ms, e.service_ms, e.queueing_ms];
        if fields.iter().any(|x| !x.is_finite()) {
            if nan_flagged.insert(e.task.as_str(), ()).is_none() {
                r.push(Diagnostic::error(
                    "SL-INV-004",
                    format!("task {:?}", e.task),
                    format!("query {} carries a non-finite timing field", e.id),
                ));
            }
            continue;
        }
        let bad_clock = e.start_ms < e.arrival_ms - TOL
            || e.finish_ms < e.start_ms - TOL
            || e.service_ms < -TOL
            || e.queueing_ms < -TOL;
        if bad_clock && clock_flagged.insert(e.task.as_str(), ()).is_none() {
            r.push(Diagnostic::error(
                "SL-INV-002",
                format!("task {:?}", e.task),
                format!(
                    "query {} has an inconsistent clock: arrival {} ms, start {} ms, \
                     finish {} ms, service {} ms, queueing {} ms",
                    e.id, e.arrival_ms, e.start_ms, e.finish_ms, e.service_ms, e.queueing_ms
                ),
            ));
        }
    }
}

/// Verify one run report: the event-stream invariants plus budget
/// conservation between the event log, the per-task outcomes, and the
/// report totals, and NaN-freedom of every reported metric.
pub fn verify_report(report: &RunReport) -> Report {
    let mut r = verify_events(&report.requests);
    check_conservation(report, &mut r);
    check_metric_finiteness(report, &mut r);
    check_trace(report, &mut r);
    r
}

/// Trace-consistency pass, run only when the report carries a trace
/// (`serve --trace`): span sanity, request conservation, and agreement
/// with the streaming counters.
fn check_trace(report: &RunReport, r: &mut Report) {
    if report.trace.is_empty() {
        return;
    }
    check_trace_spans(&report.trace, r);
    check_trace_conservation(report, r);
    check_trace_agreement(report, r);
}

/// `SL-INV-006`: every trace record is finite and runs forward, and
/// each request's QUEUE → EXEC → DONE records meet edge-to-edge in
/// virtual time. One diagnostic per code (or task) per kind, matching
/// the event-sanity style.
fn check_trace_spans(events: &[TraceEvent], r: &mut Report) {
    let mut nan_flagged: BTreeMap<&str, ()> = BTreeMap::new();
    let mut span_flagged: BTreeMap<&str, ()> = BTreeMap::new();
    for ev in events {
        if !ev.begin_ms.is_finite()
            || !ev.end_ms.is_finite()
            || ev.args.iter().any(|(_, v)| !v.is_finite())
        {
            if nan_flagged.insert(ev.code.as_str(), ()).is_none() {
                r.push(Diagnostic::error(
                    "SL-INV-006",
                    format!("trace {}", ev.code),
                    "trace record carries a non-finite time or argument",
                ));
            }
            continue;
        }
        if ev.end_ms < ev.begin_ms - TOL
            && span_flagged.insert(ev.code.as_str(), ()).is_none()
        {
            r.push(Diagnostic::error(
                "SL-INV-006",
                format!("trace {}", ev.code),
                format!(
                    "span runs backwards: begin {} ms, end {} ms",
                    ev.begin_ms, ev.end_ms
                ),
            ));
        }
    }
    // Lifecycle linkage, keyed by (task, id). A pair that appears in
    // more than one lifecycle is a merged multi-phase trace (per-phase
    // ids restart) and is skipped, mirroring SL-INV-005.
    type Lifecycle<'a> =
        (Vec<&'a TraceEvent>, Vec<&'a TraceEvent>, Vec<&'a TraceEvent>);
    let mut groups: BTreeMap<(&str, u64), Lifecycle> = BTreeMap::new();
    for ev in events {
        let Some(id) = ev.id else { continue };
        let slot = groups.entry((ev.task.as_str(), id)).or_default();
        match ev.code.as_str() {
            trace::TR_REQ_QUEUE => slot.0.push(ev),
            trace::TR_REQ_EXEC => slot.1.push(ev),
            trace::TR_REQ_DONE => slot.2.push(ev),
            _ => {}
        }
    }
    let mut seam_flagged: BTreeMap<&str, ()> = BTreeMap::new();
    for ((task, id), (queue, exec, done)) in groups {
        if queue.len() > 1 || exec.len() > 1 || done.len() > 1 {
            continue;
        }
        let mut broken = None;
        if let (Some(q), Some(x)) = (queue.first(), exec.first()) {
            if (q.end_ms - x.begin_ms).abs() > TOL {
                broken = Some(format!(
                    "queue ends at {} ms but exec begins at {} ms",
                    q.end_ms, x.begin_ms
                ));
            }
        }
        if broken.is_none() {
            if let (Some(x), Some(d)) = (exec.first(), done.first()) {
                if (d.begin_ms - x.end_ms).abs() > TOL {
                    broken = Some(format!(
                        "exec ends at {} ms but done is stamped at {} ms",
                        x.end_ms, d.begin_ms
                    ));
                }
            }
        }
        if let Some(msg) = broken {
            if seam_flagged.insert(task, ()).is_none() {
                r.push(Diagnostic::error(
                    "SL-INV-006",
                    format!("task {task:?}"),
                    format!("query {id} lifecycle seam broken: {msg}"),
                ));
            }
        }
    }
}

/// `SL-INV-007`: request conservation in the trace — every arrival
/// resolves exactly once, and the resolutions equal the report totals.
fn check_trace_conservation(report: &RunReport, r: &mut Report) {
    let count =
        |code: &str| report.trace.iter().filter(|e| e.code == code).count();
    let arrived = count(trace::TR_REQ_ARRIVE);
    let done = count(trace::TR_REQ_DONE);
    let shed = count(trace::TR_REQ_SHED);
    let dropped = count(trace::TR_REQ_DROP);
    if arrived != done + shed + dropped {
        r.push(Diagnostic::error(
            "SL-INV-007",
            "trace",
            format!(
                "{arrived} arrival(s) resolved to {done} done + {shed} shed + \
                 {dropped} drop(s): requests leaked or double-resolved"
            ),
        ));
    }
    if done != report.total_queries {
        r.push(Diagnostic::error(
            "SL-INV-007",
            "trace",
            format!(
                "trace holds {done} completion(s), report says {}",
                report.total_queries
            ),
        ));
    }
    if shed + dropped != report.total_dropped {
        r.push(Diagnostic::error(
            "SL-INV-007",
            "trace",
            format!(
                "trace holds {shed} shed(s) + {dropped} drop(s), report says \
                 {} dropped",
                report.total_dropped
            ),
        ));
    }
}

/// `SL-INV-008`: the trace must reproduce the report's SLO and fault
/// counters — the audit trail and the scoreboard tell one story.
fn check_trace_agreement(report: &RunReport, r: &mut Report) {
    let exec_misses = report
        .trace
        .iter()
        .filter(|e| e.code == trace::TR_REQ_EXEC && e.arg("slo_ok") == Some(0.0))
        .count();
    if exec_misses != report.slo_miss_count {
        r.push(Diagnostic::error(
            "SL-INV-008",
            "trace",
            format!(
                "trace holds {exec_misses} SLO-missing exec span(s), the \
                 streaming counter says {}",
                report.slo_miss_count
            ),
        ));
    }
    let recovers = report
        .trace
        .iter()
        .filter(|e| e.code == trace::TR_CTL_RECOVER)
        .count();
    if recovers != report.recoveries.len() {
        r.push(Diagnostic::error(
            "SL-INV-008",
            "trace",
            format!(
                "trace holds {recovers} recovery record(s), the report holds {}",
                report.recoveries.len()
            ),
        ));
    }
    let throttle_sum: f64 = report
        .trace
        .iter()
        .filter(|e| e.code == trace::TR_CTL_THROTTLE)
        .filter_map(|e| e.arg("extra_ms"))
        .sum();
    // Per-batch throttle records swallow float noise below 1e-9, and
    // each booking's start/end subtraction rounds at the clock's
    // magnitude — the tolerance widens with the batch count.
    let tol = TOL + 1e-9 * report.total_batches as f64;
    if (throttle_sum - report.throttled_ms).abs() > tol {
        r.push(Diagnostic::error(
            "SL-INV-008",
            "trace",
            format!(
                "trace throttle debt sums to {throttle_sum} ms, the SoC clock \
                 banked {} ms",
                report.throttled_ms
            ),
        ));
    }
}

fn check_conservation(report: &RunReport, r: &mut Report) {
    let completed_sum: usize = report.outcomes.iter().map(|o| o.queries_completed).sum();
    let dropped_sum: usize = report.outcomes.iter().map(|o| o.queries_dropped).sum();
    let batch_sum: usize = report.outcomes.iter().map(|o| o.batches).sum();
    if !report.outcomes.is_empty() {
        if completed_sum != report.total_queries {
            r.push(Diagnostic::error(
                "SL-INV-003",
                "outcomes",
                format!(
                    "per-task completions sum to {completed_sum}, report says {}",
                    report.total_queries
                ),
            ));
        }
        if dropped_sum != report.total_dropped {
            r.push(Diagnostic::error(
                "SL-INV-003",
                "outcomes",
                format!(
                    "per-task drops sum to {dropped_sum}, report says {}",
                    report.total_dropped
                ),
            ));
        }
        if batch_sum != report.total_batches {
            r.push(Diagnostic::error(
                "SL-INV-003",
                "outcomes",
                format!(
                    "per-task batches sum to {batch_sum}, report says {}",
                    report.total_batches
                ),
            ));
        }
        let miss_sum: usize = report.outcomes.iter().map(|o| o.slo_misses).sum();
        if miss_sum != report.slo_miss_count {
            r.push(Diagnostic::error(
                "SL-INV-003",
                "outcomes",
                format!(
                    "per-task SLO misses sum to {miss_sum}, report says {}",
                    report.slo_miss_count
                ),
            ));
        }
    }
    if report.total_batches > report.total_queries {
        r.push(Diagnostic::error(
            "SL-INV-003",
            "totals",
            format!(
                "{} batches served only {} queries: a batch holds at least one query",
                report.total_batches, report.total_queries
            ),
        ));
    }
    if !report.requests.is_empty() {
        let served = report.requests.iter().filter(|e| !e.dropped).count();
        let shed = report.requests.len() - served;
        if served != report.total_queries {
            r.push(Diagnostic::error(
                "SL-INV-003",
                "requests",
                format!(
                    "event log holds {served} completed request(s), report says {}",
                    report.total_queries
                ),
            ));
        }
        if shed != report.total_dropped {
            r.push(Diagnostic::error(
                "SL-INV-003",
                "requests",
                format!(
                    "event log holds {shed} dropped request(s), report says {}",
                    report.total_dropped
                ),
            ));
        }
        if let Some(e) = report.requests.iter().find(|e| e.dropped && e.slo_ok.is_some()) {
            r.push(Diagnostic::error(
                "SL-INV-003",
                format!("task {:?}", e.task),
                format!(
                    "dropped query {} carries an SLO verdict: drops are never judged",
                    e.id
                ),
            ));
        }
        // The streaming miss counter must agree with the retained
        // verdicts — this is the replay check that keeps streaming-mode
        // runs honest (their counters are produced by the same code
        // path; only a `serve --verify` run retains the log to prove
        // it).
        let miss_events =
            report.requests.iter().filter(|e| e.slo_ok == Some(false)).count();
        if miss_events != report.slo_miss_count {
            r.push(Diagnostic::error(
                "SL-INV-003",
                "requests",
                format!(
                    "event log holds {miss_events} SLO miss(es), the streaming \
                     counter says {}",
                    report.slo_miss_count
                ),
            ));
        }
    }
}

fn push_nonfinite(r: &mut Report, at: String, what: &str) {
    r.push(Diagnostic::error(
        "SL-INV-004",
        at,
        format!("{what} is not finite"),
    ));
}

fn check_metric_finiteness(report: &RunReport, r: &mut Report) {
    if !report.makespan_ms.is_finite() {
        push_nonfinite(r, "makespan_ms".into(), "makespan");
    }
    for o in &report.outcomes {
        let at = format!("task {:?}", o.task);
        let stats = [
            ("mean latency", o.mean_latency_ms),
            ("max latency", o.max_latency_ms),
            ("p50 latency", o.p50_latency_ms),
            ("p95 latency", o.p95_latency_ms),
            ("p99 latency", o.p99_latency_ms),
            ("mean queueing", o.mean_queueing_ms),
            ("SLO accuracy bound", o.slo_accuracy),
            ("SLO latency bound", o.slo_latency_ms),
        ];
        for (what, x) in stats {
            if !x.is_finite() {
                push_nonfinite(r, at.clone(), what);
            }
        }
        if let Some(acc) = o.accuracy {
            if !acc.is_finite() {
                push_nonfinite(r, at.clone(), "served accuracy");
            }
        }
    }
    for (task, p) in &report.slo_forecast {
        if !p.is_finite() || !(0.0..=1.0).contains(p) {
            r.push(Diagnostic::error(
                "SL-INV-004",
                format!("slo_forecast.{task}"),
                format!("projected violation rate {p} outside [0, 1]"),
            ));
        }
    }
    for (what, x) in [
        ("violation rate", report.violation_rate()),
        ("throughput", report.throughput_qps()),
        ("fairness index", report.fairness_index()),
        ("mean batch size", report.mean_batch_size()),
    ] {
        if !x.is_finite() {
            push_nonfinite(r, "derived".into(), what);
        }
    }
    for (what, x) in [
        ("downtime", report.downtime_ms),
        ("throttled time", report.throttled_ms),
    ] {
        if !x.is_finite() {
            push_nonfinite(r, "faults".into(), what);
        } else if x < 0.0 {
            r.push(Diagnostic::error(
                "SL-INV-003",
                "faults".into(),
                format!("{what} {x} ms is negative: fault accounting only accrues"),
            ));
        }
    }
    for (i, &lat) in report.recoveries.iter().enumerate() {
        if !lat.is_finite() || lat < 0.0 {
            r.push(Diagnostic::error(
                "SL-INV-004",
                format!("recoveries[{i}]"),
                format!("recovery latency {lat} ms is not a finite nonnegative"),
            ));
        }
    }
}

/// Verify a sharded run: every shard report, the cross-shard aggregate,
/// conservation between the two, and the sharded-only telemetry fields.
pub fn verify_sharded(report: &ShardedReport) -> Report {
    let mut r = Report::new();
    for (i, shard) in report.per_shard.iter().enumerate() {
        merge_prefixed(&mut r, verify_report(shard), &format!("shard {i}"));
    }
    merge_prefixed(&mut r, verify_report(&report.aggregate), "aggregate");
    if !report.per_shard.is_empty() {
        let q: usize = report.per_shard.iter().map(|s| s.total_queries).sum();
        let d: usize = report.per_shard.iter().map(|s| s.total_dropped).sum();
        if q != report.aggregate.total_queries || d != report.aggregate.total_dropped {
            r.push(Diagnostic::error(
                "SL-INV-003",
                "aggregate",
                format!(
                    "shards served {q} (+{d} dropped) but the aggregate says {} (+{})",
                    report.aggregate.total_queries, report.aggregate.total_dropped
                ),
            ));
        }
    }
    for (i, &u) in report.budget_utilization.iter().enumerate() {
        if !u.is_finite() {
            r.push(Diagnostic::error(
                "SL-INV-004",
                format!("shard {i}"),
                "budget utilization is not finite",
            ));
        } else if !(0.0..=1.0 + TOL).contains(&u) {
            r.push(Diagnostic::error(
                "SL-INV-003",
                format!("shard {i}"),
                format!("budget utilization {u} outside [0, 1]: pool over capacity"),
            ));
        }
    }
    for (task, &qps) in &report.arrival_est_qps {
        if !qps.is_finite() || qps < 0.0 {
            r.push(Diagnostic::error(
                "SL-INV-004",
                format!("arrival_est.{task}"),
                format!("estimated arrival rate {qps} qps is not a finite nonnegative"),
            ));
        }
    }
    if !report.link_cost_ms.is_finite() || report.link_cost_ms < 0.0 {
        r.push(Diagnostic::error(
            "SL-INV-004",
            "link_cost_ms",
            format!(
                "cross-shard link cost {} ms is not a finite nonnegative",
                report.link_cost_ms
            ),
        ));
    }
    r
}

fn merge_prefixed(into: &mut Report, sub: Report, prefix: &str) {
    for mut d in sub.diagnostics {
        d.at = format!("{prefix}, {}", d.at);
        into.push(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServeOpts;
    use crate::fixtures;
    use crate::scenario::{Scenario, Server};

    fn event(id: u64, arrival: f64, start: f64, finish: f64) -> RequestOutcome {
        RequestOutcome {
            id,
            task: "t".into(),
            arrival_ms: arrival,
            start_ms: start,
            finish_ms: finish,
            service_ms: finish - start,
            queueing_ms: start - arrival,
            dropped: false,
            slo_ok: Some(true),
        }
    }

    fn codes(r: &Report) -> Vec<&'static str> {
        r.diagnostics.iter().map(|d| d.code).collect()
    }

    #[test]
    fn real_run_satisfies_all_invariants() {
        let (zoo, lm, profiles) = fixtures::trio();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let sc = Scenario::closed_loop(
            &fixtures::task_names(&zoo),
            fixtures::slos(&zoo, 0.5, 1e9),
        )
        .with_queries(20);
        let report = server.run(&sc).unwrap();
        let r = verify_report(&report);
        assert!(r.is_empty(), "{}", r.render_text());
    }

    #[test]
    fn fifo_violation_is_flagged() {
        let evs = vec![event(0, 0.0, 10.0, 20.0), event(1, 1.0, 5.0, 25.0)];
        let r = verify_events(&evs);
        assert!(codes(&r).contains(&"SL-INV-001"), "{}", r.render_text());
    }

    #[test]
    fn ready_floor_violation_is_flagged() {
        let evs = vec![event(0, 0.0, 1.0, 30.0), event(1, 1.0, 2.0, 20.0)];
        let r = verify_events(&evs);
        assert!(codes(&r).contains(&"SL-INV-002"), "{}", r.render_text());
    }

    #[test]
    fn inconsistent_clock_is_flagged_once_per_task() {
        let evs = vec![
            event(0, 10.0, 5.0, 20.0), // starts before it arrives
            event(1, 10.0, 6.0, 21.0), // also broken, same task: one diag
        ];
        let r = verify_events(&evs);
        assert_eq!(
            codes(&r).iter().filter(|&&c| c == "SL-INV-002").count(),
            1,
            "{}",
            r.render_text()
        );
    }

    #[test]
    fn dropped_requests_are_exempt_from_ordering() {
        // Query 1 is dropped at arrival (finish = arrival = 1.0), long
        // before query 0 completes — legal, drops decide at arrival.
        let mut drop = event(1, 1.0, 1.0, 1.0);
        drop.dropped = true;
        drop.slo_ok = None;
        drop.service_ms = 0.0;
        drop.queueing_ms = 0.0;
        let evs = vec![event(0, 0.0, 10.0, 20.0), drop];
        let r = verify_events(&evs);
        assert!(r.is_empty(), "{}", r.render_text());
    }

    #[test]
    fn duplicate_ids_skip_ordering_with_a_note() {
        // A merged two-phase log: ids restart, clocks restart.
        let evs = vec![
            event(0, 0.0, 5.0, 15.0),
            event(1, 1.0, 15.0, 25.0),
            event(0, 0.0, 2.0, 12.0),
            event(1, 1.0, 12.0, 22.0),
        ];
        let r = verify_events(&evs);
        assert!(codes(&r).contains(&"SL-INV-005"), "{}", r.render_text());
        assert!(!r.has_errors(), "{}", r.render_text());
    }

    #[test]
    fn conservation_mismatch_is_flagged() {
        let (zoo, lm, profiles) = fixtures::tiny();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let sc = Scenario::closed_loop(
            &fixtures::task_names(&zoo),
            fixtures::slos(&zoo, 0.5, 1e9),
        )
        .with_queries(5);
        let mut report = server.run(&sc).unwrap();
        report.total_queries += 1;
        let r = verify_report(&report);
        assert!(codes(&r).contains(&"SL-INV-003"), "{}", r.render_text());
    }

    #[test]
    fn judged_drop_is_flagged() {
        let (zoo, lm, profiles) = fixtures::tiny();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let sc = Scenario::closed_loop(
            &fixtures::task_names(&zoo),
            fixtures::slos(&zoo, 0.5, 1e9),
        )
        .with_queries(5);
        let mut report = server.run(&sc).unwrap();
        report.requests[2].dropped = true;
        report.requests[2].slo_ok = Some(true);
        let r = verify_report(&report);
        // The forged drop breaks both the drop accounting and the
        // no-verdict rule.
        assert!(codes(&r).contains(&"SL-INV-003"), "{}", r.render_text());
    }

    #[test]
    fn nan_metrics_are_flagged() {
        let mut evs = vec![event(0, 0.0, 1.0, 2.0)];
        evs[0].service_ms = f64::NAN;
        let r = verify_events(&evs);
        assert!(codes(&r).contains(&"SL-INV-004"), "{}", r.render_text());
    }

    #[test]
    fn fault_accounting_fields_are_checked() {
        let mut report = ShardedReport::default();
        report.aggregate.downtime_ms = -5.0;
        report.aggregate.recoveries.push(f64::NAN);
        report.link_cost_ms = f64::INFINITY;
        let r = verify_sharded(&report);
        assert!(codes(&r).contains(&"SL-INV-003"), "{}", r.render_text());
        assert_eq!(
            codes(&r).iter().filter(|&&c| c == "SL-INV-004").count(),
            2,
            "{}",
            r.render_text()
        );
    }

    fn tev(
        code: &str,
        id: Option<u64>,
        begin: f64,
        end: f64,
        args: &[(&str, f64)],
    ) -> TraceEvent {
        TraceEvent::new(code, 0, "t", id, begin, end, args)
    }

    /// One served query (an SLO miss), one shed, one drop, plus the
    /// fault-lab audit records — all consistent with the counters.
    fn traced_report() -> RunReport {
        RunReport {
            total_queries: 1,
            total_dropped: 2,
            total_batches: 1,
            slo_miss_count: 1,
            throttled_ms: 2.5,
            recoveries: vec![4.0],
            trace: vec![
                tev(trace::TR_REQ_ARRIVE, Some(0), 0.0, 0.0, &[]),
                tev(trace::TR_REQ_ADMIT, Some(0), 0.0, 0.0, &[]),
                tev(trace::TR_REQ_ARRIVE, Some(1), 1.0, 1.0, &[]),
                tev(trace::TR_REQ_SHED, Some(1), 1.0, 1.0, &[]),
                tev(trace::TR_REQ_ARRIVE, Some(2), 2.0, 2.0, &[]),
                tev(trace::TR_REQ_DROP, Some(2), 2.0, 2.0, &[("cause", 1.0)]),
                tev(trace::TR_REQ_QUEUE, Some(0), 0.0, 3.0, &[]),
                tev(trace::TR_REQ_EXEC, Some(0), 3.0, 9.0, &[("slo_ok", 0.0)]),
                tev(trace::TR_REQ_DONE, Some(0), 9.0, 9.0, &[]),
                tev(trace::TR_CTL_THROTTLE, None, 3.0, 9.0, &[("extra_ms", 2.5)]),
                tev(trace::TR_CTL_RECOVER, None, 9.0, 9.0, &[("latency_ms", 4.0)]),
            ],
            ..Default::default()
        }
    }

    #[test]
    fn consistent_trace_is_clean() {
        let r = verify_report(&traced_report());
        assert!(r.is_empty(), "{}", r.render_text());
    }

    #[test]
    fn real_traced_run_satisfies_trace_invariants() {
        let (zoo, lm, profiles) = fixtures::trio();
        let server = Server::builder(&zoo, &lm, &profiles)
            .opts(ServeOpts { trace: true, ..Default::default() })
            .build();
        let sc = Scenario::closed_loop(
            &fixtures::task_names(&zoo),
            fixtures::slos(&zoo, 0.5, 1e9),
        )
        .with_queries(20);
        let report = server.run(&sc).unwrap();
        assert!(!report.trace.is_empty(), "tracing was on");
        let r = verify_report(&report);
        assert!(r.is_empty(), "{}", r.render_text());
    }

    #[test]
    fn trace_conservation_mismatch_is_flagged() {
        // A completion the trace never saw.
        let mut report = traced_report();
        report.total_queries = 2;
        let r = verify_report(&report);
        assert!(codes(&r).contains(&"SL-INV-007"), "{}", r.render_text());
        // A leaked arrival: never resolved to done/shed/drop.
        let mut report = traced_report();
        report.trace.push(tev(trace::TR_REQ_ARRIVE, Some(3), 10.0, 10.0, &[]));
        let r = verify_report(&report);
        assert!(codes(&r).contains(&"SL-INV-007"), "{}", r.render_text());
    }

    #[test]
    fn trace_span_defects_are_flagged() {
        // A backwards span.
        let mut report = traced_report();
        report.trace.push(tev(trace::TR_CTL_CRASH, None, 9.0, 3.0, &[]));
        let r = verify_report(&report);
        assert!(codes(&r).contains(&"SL-INV-006"), "{}", r.render_text());
        // A non-finite argument.
        let mut report = traced_report();
        report.trace.push(tev(
            trace::TR_CTL_PLAN,
            None,
            0.0,
            0.0,
            &[("penalty_ms", f64::NAN)],
        ));
        let r = verify_report(&report);
        assert!(codes(&r).contains(&"SL-INV-006"), "{}", r.render_text());
        // A broken queue → exec seam.
        let mut report = traced_report();
        report.trace[6].end_ms = 2.0; // queue now ends before exec begins
        let r = verify_report(&report);
        assert!(codes(&r).contains(&"SL-INV-006"), "{}", r.render_text());
    }

    #[test]
    fn multi_phase_duplicate_trace_lifecycles_skip_linkage() {
        // Two lifecycles for (t, 0) with incompatible seams: a merged
        // multi-phase trace, not an engine defect.
        let mut report = traced_report();
        report.trace.push(tev(trace::TR_REQ_QUEUE, Some(0), 20.0, 25.0, &[]));
        report.trace.push(tev(
            trace::TR_REQ_EXEC,
            Some(0),
            26.0, // off by 1 ms from the second queue's end
            30.0,
            &[("slo_ok", 1.0)],
        ));
        // Keep conservation and the counters consistent.
        report.trace.push(tev(trace::TR_REQ_ARRIVE, Some(0), 20.0, 20.0, &[]));
        report.trace.push(tev(trace::TR_REQ_DONE, Some(0), 30.0, 30.0, &[]));
        report.total_queries = 2;
        let r = verify_report(&report);
        assert!(
            !codes(&r).contains(&"SL-INV-006"),
            "duplicate lifecycles must skip the seam check: {}",
            r.render_text()
        );
    }

    #[test]
    fn trace_counter_disagreement_is_flagged() {
        // The trace says the exec made its SLO; the counter says miss.
        let mut report = traced_report();
        report.trace[7].args = vec![("slo_ok".into(), 1.0)];
        let r = verify_report(&report);
        assert!(codes(&r).contains(&"SL-INV-008"), "{}", r.render_text());
        // A recovery the trace never recorded.
        let mut report = traced_report();
        report.recoveries.push(5.0);
        let r = verify_report(&report);
        assert!(codes(&r).contains(&"SL-INV-008"), "{}", r.render_text());
        // Throttle debt missing from the audit trail.
        let mut report = traced_report();
        report.throttled_ms = 9.0;
        let r = verify_report(&report);
        assert!(codes(&r).contains(&"SL-INV-008"), "{}", r.render_text());
    }

    #[test]
    fn sharded_utilization_and_conservation() {
        let clean = ShardedReport::default();
        assert!(verify_sharded(&clean).is_empty());
        let over = ShardedReport {
            budget_utilization: vec![0.5, 1.7],
            ..Default::default()
        };
        let r = verify_sharded(&over);
        assert!(codes(&r).contains(&"SL-INV-003"), "{}", r.render_text());
        let mut skewed = ShardedReport::default();
        skewed.per_shard.push(RunReport { total_queries: 3, ..Default::default() });
        skewed.aggregate.total_queries = 5;
        let r = verify_sharded(&skewed);
        assert!(codes(&r).contains(&"SL-INV-003"), "{}", r.render_text());
    }
}
