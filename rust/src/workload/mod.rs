//! Workload generation: SLO configurations and query streams.
//!
//! Mirrors the paper's §5.1 protocol exactly:
//!
//! * **SLO grid** — per task, measure the accuracy/latency ranges over
//!   the *original* zoo variants, extend latency by ±20 % and accuracy by
//!   ±2 pp, uniformly sample 5 accuracy × 5 latency points → 25
//!   configurations (the Ψ of Eq. 7).
//! * **C1–C8 ladder** (Fig. 3) — eight configurations of monotonically
//!   increasing strictness sampled from the same extended ranges.
//! * **Accuracy-/latency-guaranteed SLOs** (Appendix D, Figs. 15–16) —
//!   pin one dimension to its extreme, sweep the other over 5 points.
//! * **Arrival combinations** — all T! orders in which the tasks arrive
//!   (24 for T=4); violation rates are averaged over them.

use crate::soc::{LatencyModel, Platform, Processor};
use crate::util::{permutations, Rng};
use crate::zoo::{TaskZoo, Zoo};

/// One SLO configuration σ for one task.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Slo {
    /// Minimum acceptable accuracy (fraction).
    pub min_accuracy: f64,
    /// Maximum acceptable end-to-end latency (ms).
    pub max_latency_ms: f64,
}

/// Observed accuracy/latency ranges of a task's original variants.
#[derive(Clone, Copy, Debug)]
pub struct TaskRanges {
    pub acc_min: f64,
    pub acc_max: f64,
    pub lat_min_ms: f64,
    pub lat_max_ms: f64,
}

impl TaskRanges {
    /// Measure ranges over the *original* (pure) variants: accuracy from
    /// the manifest; latency as the best placement-order pure-variant
    /// latency under the platform model (what profiling a zoo on-device
    /// yields).
    pub fn measure(tz: &TaskZoo, lm: &LatencyModel) -> TaskRanges {
        let s = tz.iface.len() - 1;
        let orders = placement_orders(&lm.platform, s);
        let mut acc_min = f64::INFINITY;
        let mut acc_max = f64::NEG_INFINITY;
        let mut lat_min = f64::INFINITY;
        let mut lat_max = f64::NEG_INFINITY;
        for (i, v) in tz.variants.iter().enumerate() {
            acc_min = acc_min.min(v.accuracy);
            acc_max = acc_max.max(v.accuracy);
            let comp = vec![i; s];
            let best = orders
                .iter()
                .filter_map(|o| lm.stitched_ms(tz, &comp, o))
                .fold(f64::INFINITY, f64::min);
            if best.is_finite() {
                lat_min = lat_min.min(best);
                lat_max = lat_max.max(best);
            }
        }
        TaskRanges { acc_min, acc_max, lat_min_ms: lat_min, lat_max_ms: lat_max }
    }

    /// The paper's extension: latency [80 % of min, 120 % of max],
    /// accuracy [min − 2 pp, max + 2 pp].
    pub fn extended(&self) -> TaskRanges {
        TaskRanges {
            acc_min: (self.acc_min - 0.02).max(0.0),
            acc_max: (self.acc_max + 0.02).min(1.0),
            lat_min_ms: 0.8 * self.lat_min_ms,
            lat_max_ms: 1.2 * self.lat_max_ms,
        }
    }
}

fn linspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    if n == 1 {
        return vec![lo];
    }
    (0..n)
        .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
        .collect()
}

/// The 5×5 SLO grid of §5.1 (Ψ, |Ψ| = 25).
pub fn slo_grid(ranges: &TaskRanges) -> Vec<Slo> {
    let ext = ranges.extended();
    let accs = linspace(ext.acc_min, ext.acc_max, 5);
    let lats = linspace(ext.lat_min_ms, ext.lat_max_ms, 5);
    let mut out = Vec::with_capacity(25);
    for &a in &accs {
        for &l in &lats {
            out.push(Slo { min_accuracy: a, max_latency_ms: l });
        }
    }
    out
}

/// The C1–C8 strictness ladder of Fig. 3: C1 is the laxest (lowest
/// accuracy bound, highest latency bound), C8 the strictest.
pub fn slo_ladder(ranges: &TaskRanges) -> Vec<Slo> {
    let ext = ranges.extended();
    let accs = linspace(ext.acc_min, ext.acc_max, 8);
    let lats = linspace(ext.lat_max_ms, ext.lat_min_ms, 8);
    accs.into_iter()
        .zip(lats)
        .map(|(a, l)| Slo { min_accuracy: a, max_latency_ms: l })
        .collect()
}

/// Accuracy-guaranteed SLOs (Appendix D): accuracy pinned to the max
/// observed, latency swept over 5 points of the *observed* range.
pub fn accuracy_guaranteed(ranges: &TaskRanges) -> Vec<Slo> {
    linspace(ranges.lat_min_ms, ranges.lat_max_ms, 5)
        .into_iter()
        .map(|l| Slo { min_accuracy: ranges.acc_max, max_latency_ms: l })
        .collect()
}

/// Latency-guaranteed SLOs (Appendix D): latency pinned to the min
/// observed, accuracy swept over 5 points.
pub fn latency_guaranteed(ranges: &TaskRanges) -> Vec<Slo> {
    linspace(ranges.acc_min, ranges.acc_max, 5)
        .into_iter()
        .map(|a| Slo { min_accuracy: a, max_latency_ms: ranges.lat_min_ms })
        .collect()
}

/// All T! task-arrival orders (24 for the paper's four tasks).
pub fn arrival_combinations(tasks: &[String]) -> Vec<Vec<String>> {
    permutations(tasks)
}

/// One inference query in a stream.
#[derive(Clone, Debug)]
pub struct Query {
    pub task: String,
    /// Arrival time in virtual ms.
    pub arrival_ms: f64,
    pub id: u64,
}

/// Build the paper's closed-loop stream: each task issues `queries`
/// back-to-back requests (batch 1); tasks start in `arrival_order`, each
/// offset by `stagger_ms`.
pub fn closed_loop_stream(
    arrival_order: &[String],
    queries: usize,
    stagger_ms: f64,
) -> Vec<Query> {
    let mut out = Vec::with_capacity(arrival_order.len() * queries);
    let mut id = 0u64;
    for (slot, task) in arrival_order.iter().enumerate() {
        for _ in 0..queries {
            out.push(Query {
                task: task.clone(),
                arrival_ms: slot as f64 * stagger_ms,
                id,
            });
            id += 1;
        }
    }
    out
}

/// Open-loop Poisson stream at `rate_qps` per task for `horizon_ms`.
/// Arrivals are sorted, ids are unique across tasks, and the stream is
/// a pure function of the `Rng` state (deterministic replay). A rate of
/// zero yields an empty stream.
pub fn poisson_stream(
    tasks: &[String],
    rate_qps: f64,
    horizon_ms: f64,
    rng: &mut Rng,
) -> Vec<Query> {
    let mut out = Vec::new();
    let mut id = 0u64;
    if rate_qps <= 0.0 || horizon_ms <= 0.0 {
        return out;
    }
    for task in tasks {
        let mut t = 0.0;
        loop {
            t += rng.exponential(rate_qps / 1000.0);
            if t >= horizon_ms {
                break;
            }
            out.push(Query { task: task.clone(), arrival_ms: t, id });
            id += 1;
        }
    }
    out.sort_by(|a, b| a.arrival_ms.partial_cmp(&b.arrival_ms).unwrap());
    out
}

/// Open-loop bursty stream: a two-level modulated Poisson process. Each
/// period of `period_ms` spends its first half at `base_qps` and its
/// second half at `burst_qps` (per task), generated by thinning against
/// the peak rate so the stream stays exact and deterministic under a
/// fixed `Rng`. Ids are unique; arrivals are sorted.
pub fn bursty_stream(
    tasks: &[String],
    base_qps: f64,
    burst_qps: f64,
    period_ms: f64,
    horizon_ms: f64,
    rng: &mut Rng,
) -> Vec<Query> {
    let peak = base_qps.max(burst_qps);
    let mut out = Vec::new();
    if peak <= 0.0 || period_ms <= 0.0 || horizon_ms <= 0.0 {
        return out;
    }
    let mut id = 0u64;
    for task in tasks {
        let mut t = 0.0;
        loop {
            t += rng.exponential(peak / 1000.0);
            if t >= horizon_ms {
                break;
            }
            let in_burst = (t % period_ms) >= period_ms / 2.0;
            let rate = if in_burst { burst_qps } else { base_qps };
            if rng.f64() < rate / peak {
                out.push(Query { task: task.clone(), arrival_ms: t, id });
                id += 1;
            }
        }
    }
    out.sort_by(|a, b| a.arrival_ms.partial_cmp(&b.arrival_ms).unwrap());
    out
}

/// Deterministic task → shard assignment: FNV-1a over the task name,
/// modulo the shard count. Stable across runs, platforms, and processes
/// (no `DefaultHasher` seed dependence), so saved scenarios and printed
/// reports always agree on who serves what.
pub fn shard_of_task(task: &str, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in task.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % shards.max(1) as u64) as usize
}

/// Convenience: per-task SLO grids for a whole zoo on a platform.
pub fn grids_for_zoo(zoo: &Zoo, lm: &LatencyModel) -> Vec<(String, Vec<Slo>)> {
    zoo.tasks
        .values()
        .map(|tz| (tz.name.clone(), slo_grid(&TaskRanges::measure(tz, lm))))
        .collect()
}

/// The non-overlapping placement orders Ω (paper footnote 2): all P!
/// permutations of the platform's processors, extended cyclically when
/// the platform has fewer processors than subgraph positions (Orin:
/// P=2 < S=3, giving the paper's "G-C" style orders).
pub fn placement_orders(platform: &Platform, s: usize) -> Vec<Vec<Processor>> {
    let procs = platform.processor_list();
    let perms = permutations(&procs);
    let mut out: Vec<Vec<Processor>> = Vec::new();
    for p in perms {
        let base = p.clone();
        let mut o = p;
        let mut i = 0usize;
        while o.len() < s {
            o.push(base[i % base.len()]);
            i += 1;
        }
        o.truncate(s);
        if !out.contains(&o) {
            out.push(o);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ranges() -> TaskRanges {
        TaskRanges { acc_min: 0.85, acc_max: 0.92, lat_min_ms: 50.0, lat_max_ms: 120.0 }
    }

    #[test]
    fn extension_matches_paper_example() {
        // §5.1's worked example: [85,92]% → [83,94]%, [50,120] → [40,144].
        let e = ranges().extended();
        assert!((e.acc_min - 0.83).abs() < 1e-9);
        assert!((e.acc_max - 0.94).abs() < 1e-9);
        assert!((e.lat_min_ms - 40.0).abs() < 1e-9);
        assert!((e.lat_max_ms - 144.0).abs() < 1e-9);
    }

    #[test]
    fn grid_is_5x5_cartesian() {
        let g = slo_grid(&ranges());
        assert_eq!(g.len(), 25);
        // Matches the paper's sampled endpoints.
        assert!((g[0].min_accuracy - 0.83).abs() < 1e-9);
        assert!((g[0].max_latency_ms - 40.0).abs() < 1e-9);
        assert!((g[24].min_accuracy - 0.94).abs() < 1e-9);
        assert!((g[24].max_latency_ms - 144.0).abs() < 1e-9);
    }

    #[test]
    fn ladder_strictness_monotone() {
        let l = slo_ladder(&ranges());
        assert_eq!(l.len(), 8);
        for w in l.windows(2) {
            assert!(w[1].min_accuracy > w[0].min_accuracy);
            assert!(w[1].max_latency_ms < w[0].max_latency_ms);
        }
    }

    #[test]
    fn guaranteed_slos_pin_one_dimension() {
        let a = accuracy_guaranteed(&ranges());
        assert!(a.iter().all(|s| (s.min_accuracy - 0.92).abs() < 1e-9));
        assert_eq!(a.len(), 5);
        let l = latency_guaranteed(&ranges());
        assert!(l.iter().all(|s| (s.max_latency_ms - 50.0).abs() < 1e-9));
        // Appendix D example: accuracy thresholds 85..92 in 5 steps.
        assert!((l[1].min_accuracy - 0.8675).abs() < 1e-9);
    }

    #[test]
    fn arrival_combinations_count() {
        let tasks: Vec<String> =
            ["a", "b", "c", "d"].iter().map(|s| s.to_string()).collect();
        assert_eq!(arrival_combinations(&tasks).len(), 24);
    }

    #[test]
    fn closed_loop_counts() {
        let order = vec!["x".to_string(), "y".to_string()];
        let qs = closed_loop_stream(&order, 100, 1.0);
        assert_eq!(qs.len(), 200);
        assert_eq!(qs.iter().filter(|q| q.task == "x").count(), 100);
    }

    #[test]
    fn closed_loop_ids_unique_and_stagger_applied() {
        let order = vec!["x".to_string(), "y".to_string(), "z".to_string()];
        let qs = closed_loop_stream(&order, 10, 2.5);
        let mut ids: Vec<u64> = qs.iter().map(|q| q.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 30, "ids must be unique");
        // Task at slot k arrives offset by k × stagger.
        for (slot, task) in order.iter().enumerate() {
            assert!(qs
                .iter()
                .filter(|q| &q.task == task)
                .all(|q| (q.arrival_ms - slot as f64 * 2.5).abs() < 1e-12));
        }
        // Zero stagger: everything arrives at t = 0.
        let flat = closed_loop_stream(&order, 3, 0.0);
        assert!(flat.iter().all(|q| q.arrival_ms == 0.0));
    }

    #[test]
    fn poisson_stream_sorted_and_rate_sane() {
        let mut rng = Rng::new(1);
        let tasks = vec!["a".to_string()];
        let qs = poisson_stream(&tasks, 100.0, 10_000.0, &mut rng);
        // 100 qps over 10 s ⇒ ~1000 queries.
        assert!((800..1200).contains(&qs.len()), "{}", qs.len());
        assert!(qs.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
    }

    #[test]
    fn poisson_stream_ids_unique_across_tasks() {
        let mut rng = Rng::new(4);
        let tasks = vec!["a".to_string(), "b".to_string()];
        let qs = poisson_stream(&tasks, 50.0, 2_000.0, &mut rng);
        let mut ids: Vec<u64> = qs.iter().map(|q| q.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), qs.len(), "ids must be unique");
        assert!(qs.iter().any(|q| q.task == "a"));
        assert!(qs.iter().any(|q| q.task == "b"));
    }

    #[test]
    fn poisson_stream_deterministic_under_fixed_seed() {
        let tasks = vec!["a".to_string(), "b".to_string()];
        let a = poisson_stream(&tasks, 80.0, 3_000.0, &mut Rng::new(7));
        let b = poisson_stream(&tasks, 80.0, 3_000.0, &mut Rng::new(7));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.task, y.task);
            assert_eq!(x.id, y.id);
            assert!((x.arrival_ms - y.arrival_ms).abs() < 1e-12);
        }
        // A different seed gives a different stream.
        let c = poisson_stream(&tasks, 80.0, 3_000.0, &mut Rng::new(8));
        assert!(
            c.len() != a.len()
                || a.iter().zip(&c).any(|(x, y)| x.arrival_ms != y.arrival_ms)
        );
    }

    #[test]
    fn poisson_stream_empty_at_zero_rate() {
        let mut rng = Rng::new(3);
        let tasks = vec!["a".to_string()];
        assert!(poisson_stream(&tasks, 0.0, 10_000.0, &mut rng).is_empty());
        assert!(poisson_stream(&tasks, 10.0, 0.0, &mut rng).is_empty());
    }

    #[test]
    fn bursty_stream_rate_modulated_and_deterministic() {
        let tasks = vec!["a".to_string()];
        let a = bursty_stream(&tasks, 20.0, 200.0, 1_000.0, 20_000.0, &mut Rng::new(11));
        let b = bursty_stream(&tasks, 20.0, 200.0, 1_000.0, 20_000.0, &mut Rng::new(11));
        assert_eq!(a.len(), b.len());
        assert!(a.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        let mut ids: Vec<u64> = a.iter().map(|q| q.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), a.len());
        // Burst halves must hold clearly more arrivals than base halves.
        let (mut base_n, mut burst_n) = (0usize, 0usize);
        for q in &a {
            if (q.arrival_ms % 1_000.0) >= 500.0 {
                burst_n += 1;
            } else {
                base_n += 1;
            }
        }
        assert!(burst_n > 3 * base_n, "burst {burst_n} vs base {base_n}");
        assert!(bursty_stream(&tasks, 0.0, 0.0, 1_000.0, 5_000.0, &mut Rng::new(1)).is_empty());
    }

    #[test]
    fn shard_assignment_deterministic_and_in_range() {
        let names = ["imgcls", "audio", "nlp", "det", "alpha", "beta", "gamma"];
        for shards in 1..=4usize {
            for name in names {
                let s = shard_of_task(name, shards);
                assert!(s < shards, "{name} → {s} out of range for {shards}");
                assert_eq!(s, shard_of_task(name, shards), "must be stable");
            }
        }
        // Zero shards is clamped rather than panicking.
        assert_eq!(shard_of_task("x", 0), 0);
        // The hash actually spreads: over 26 names and 2 shards, both
        // shards must receive someone.
        let mut seen = [false; 2];
        for c in b'a'..=b'z' {
            seen[shard_of_task(&(c as char).to_string(), 2)] = true;
        }
        assert!(seen[0] && seen[1], "degenerate hash");
    }

    #[test]
    fn shard_of_task_distribution_stays_balanced() {
        // Distribution sanity over the fixture-zoo naming universe (the
        // synthetic task names plus a numbered family, as the backlog
        // benches generate): for 2–4 shards, no shard may receive more
        // than 2× the mean load. FNV-1a is deterministic, so this pins
        // the actual assignment quality, not a statistical hope.
        let mut names: Vec<String> = ["tiny", "alpha", "beta", "gamma", "delta"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        for i in 0..27 {
            names.push(format!("task{i:02}"));
        }
        for shards in 2..=4usize {
            let mut counts = vec![0usize; shards];
            for name in &names {
                counts[shard_of_task(name, shards)] += 1;
            }
            let mean = names.len() as f64 / shards as f64;
            for (shard, &c) in counts.iter().enumerate() {
                assert!(
                    (c as f64) <= 2.0 * mean,
                    "shard {shard} got {c} of {} tasks (mean {mean:.1})",
                    names.len()
                );
            }
            // Nothing is lost either: counts cover every task.
            assert_eq!(counts.iter().sum::<usize>(), names.len());
        }
    }

    #[test]
    fn placement_orders_desktop_and_orin() {
        let d = placement_orders(&Platform::desktop(), 3);
        assert_eq!(d.len(), 6); // 3! non-overlapping orders
        let o = placement_orders(&Platform::orin(), 3);
        assert_eq!(o.len(), 2); // P=2: G-C-G and C-G-C (wrapped)
        for ord in &o {
            assert_eq!(ord.len(), 3);
        }
    }
}
