//! Profiler-module experiments: Table 1 (complexity), Fig. 7 (estimator
//! quality), Fig. 8 (profiling runs vs T and V), Fig. 12 (profiling
//! minutes with/without estimators).

use std::time::Instant;

use anyhow::Result;

use super::Ctx;
use crate::metrics::render_table;
use crate::profiler::cost::{CostParams, RunCosts};
use crate::profiler::{evaluate_estimators, profile_task, ProfilerConfig};
use crate::runtime::Runtime;
use crate::soc::Platform;
use crate::util::stats;
use crate::workload::placement_orders;

/// Table 1: profiling complexity with and without stitching.
pub fn table1() -> Result<String> {
    let c = CostParams { tasks: 4, variants: 10, subgraphs: 3, processors: 3 };
    let rows = vec![
        vec![
            "Processor placement orders".to_string(),
            format!("{}", c.orders()),
            format!("{}", c.orders()),
        ],
        vec![
            "Total variants".to_string(),
            format!("{}", c.tasks * c.variants),
            format!("{}", c.exhaustive_accuracy_runs()),
        ],
        vec![
            "Accuracy profiling runs".to_string(),
            format!("{}", c.no_stitch_accuracy_runs()),
            format!("{}", c.exhaustive_accuracy_runs()),
        ],
        vec![
            "Latency profiling runs".to_string(),
            format!("{}", c.no_stitch_latency_runs()),
            format!("{}", c.exhaustive_latency_runs()),
        ],
        vec![
            "Total profiling runs".to_string(),
            format!("{}", c.no_stitch_total_runs()),
            format!("{}", c.exhaustive_total_runs()),
        ],
    ];
    Ok(format!(
        "Table 1 — profiling complexity (T=4, V=10, S=3, P=3)\n\n{}\n\
         SparseLoom with estimators (Eq. 6): {} runs ({:.1} % reduction)\n",
        render_table(&["quantity", "without stitching", "with stitching"], &rows),
        c.sparseloom_total_runs(),
        100.0 * c.reduction(),
    ))
}

/// Fig. 7: (a) Top-K recall of the accuracy estimator; (b) latency
/// estimator MAE/MAPE vs ground truth. All tasks, desktop platform.
pub fn fig7(ctx: &Ctx) -> Result<String> {
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let orders = placement_orders(&platform, ctx.zoo.subgraphs);
    let cfg = ProfilerConfig::default();

    let ks = [5usize, 10, 20, 50];
    let mut rows = Vec::new();
    let mut all_recalls = Vec::new();
    let mut maes = Vec::new();
    let mut mapes = Vec::new();
    for (name, tz) in &ctx.zoo.tasks {
        let oracle = ctx.zoo.load_oracle(name)?;
        let p = profile_task(tz, &lm, &oracle, &cfg, true);
        let rep = evaluate_estimators(&p, &orders, &ks, 400, 11);
        let mut row = vec![name.clone()];
        for (_, r) in &rep.recall_at {
            row.push(format!("{:.1}", 100.0 * r));
            all_recalls.push(*r);
        }
        row.push(format!("{:.3}", rep.lat_mae_ms));
        row.push(format!("{:.1}", rep.lat_mape_pct));
        maes.push(rep.lat_mae_ms);
        mapes.push(rep.lat_mape_pct);
        rows.push(row);
    }
    Ok(format!(
        "Fig. 7 — estimator quality (desktop)\n\n{}\n\
         mean Top-K recall: {:.2} %   [paper: 90.78 %]\n\
         mean latency MAE:  {:.3} ms  [paper: 1.05 ms]\n\
         mean latency MAPE: {:.1} %   [paper: 8.9 %]\n",
        render_table(
            &["task", "R@5", "R@10", "R@20", "R@50", "MAE ms", "MAPE %"],
            &rows,
        ),
        100.0 * stats::mean(&all_recalls),
        stats::mean(&maes),
        stats::mean(&mapes),
    ))
}

/// Fig. 8: profiling runs with/without estimators, varying T and V.
pub fn fig8() -> Result<String> {
    let mut out = String::from("Fig. 8a — profiling runs vs T (P=3, S=3, V=3)\n\n");
    let mut rows = Vec::new();
    for t in [1usize, 2, 4, 6, 8] {
        let c = CostParams { tasks: t, variants: 3, subgraphs: 3, processors: 3 };
        rows.push(vec![
            format!("{t}"),
            format!("{}", c.exhaustive_total_runs()),
            format!("{}", c.sparseloom_total_runs()),
            format!("{:.0}", 100.0 * c.reduction()),
        ]);
    }
    out.push_str(&render_table(&["T", "exhaustive", "SparseLoom", "reduction %"], &rows));

    out.push_str("\nFig. 8b — profiling runs vs V (T=4, P=3, S=3)\n\n");
    let mut rows = Vec::new();
    for v in [2usize, 4, 6, 8, 10] {
        let c = CostParams { tasks: 4, variants: v, subgraphs: 3, processors: 3 };
        rows.push(vec![
            format!("{v}"),
            format!("{}", c.exhaustive_total_runs()),
            format!("{}", c.sparseloom_total_runs()),
            format!("{:.0}", 100.0 * c.reduction()),
        ]);
    }
    out.push_str(&render_table(&["V", "exhaustive", "SparseLoom", "reduction %"], &rows));
    out.push_str("\n[paper: up to 84 % reduction varying T, 98 % varying V;\n SparseLoom cost linear in V, exhaustive exponential]\n");
    Ok(out)
}

/// Fig. 12: wall-clock profiling minutes with vs without estimators on
/// all three platforms. Per-run costs are *measured* through PJRT
/// (one accuracy run = eval-set pass; one latency run = timed batch-1
/// execution) and scaled by each platform's mean processor speed.
pub fn fig12(ctx: &Ctx) -> Result<String> {
    // Measure real per-run costs once on the host.
    let rt = Runtime::new()?;
    let task = ctx.zoo.task_names()[0].to_string();
    let tz = ctx.zoo.task(&task)?;
    let comp = vec![0usize; ctx.zoo.subgraphs];

    let t0 = Instant::now();
    let _ = rt.measure_accuracy(&ctx.zoo, &task, &comp)?;
    let acc_run_ms = t0.elapsed().as_secs_f64() * 1e3;

    let lat_run_ms = {
        let t0 = Instant::now();
        let _ = rt.measure_subgraph_ms(
            &ctx.zoo, &task, 0, tz.variants[0].spec.kernel_path, 10,
        )?;
        t0.elapsed().as_secs_f64() * 1e3
    };

    let mut out = format!(
        "Fig. 12 — profiling time (minutes), with vs without estimators\n\
         measured per-run costs on this host: accuracy {acc_run_ms:.0} ms, latency {lat_run_ms:.1} ms\n\n",
    );
    let mut rows = Vec::new();
    for platform in Platform::all() {
        // Scale host-measured costs by the platform's mean dense speed.
        let scale = platform
            .processors
            .iter()
            .map(|m| m.dense_scale)
            .sum::<f64>()
            / platform.n_processors() as f64;
        let rc = RunCosts {
            accuracy_run_ms: acc_run_ms * scale,
            latency_run_ms: lat_run_ms * scale,
        };
        for v in [4usize, 10] {
            let c = CostParams {
                tasks: ctx.zoo.tasks.len(),
                variants: v,
                subgraphs: ctx.zoo.subgraphs,
                processors: platform.n_processors(),
            };
            rows.push(vec![
                platform.name.to_string(),
                format!("{v}"),
                format!("{:.1}", c.exhaustive_minutes(&rc)),
                format!("{:.2}", c.sparseloom_minutes(&rc)),
                format!("{:.1}", 100.0 * (1.0 - c.sparseloom_minutes(&rc) / c.exhaustive_minutes(&rc))),
            ]);
        }
    }
    out.push_str(&render_table(
        &["platform", "V", "exhaustive min", "SparseLoom min", "reduction %"],
        &rows,
    ));
    out.push_str("\n[paper: 468 min → 5 min on laptop at V=10; up to 99 % reduction]\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders() {
        let t = table1().unwrap();
        assert!(t.contains("28000"), "exhaustive total T·V^S·(P!+1) = 28000:\n{t}");
        assert!(t.contains("400"), "Eq.6 total = 400");
    }

    #[test]
    fn fig8_renders() {
        let t = fig8().unwrap();
        assert!(t.contains("Fig. 8a"));
        assert!(t.contains("Fig. 8b"));
    }
}
