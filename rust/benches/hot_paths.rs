//! Hot-path micro-benchmarks (feeds EXPERIMENTS.md §Perf):
//! the operations on the coordinator's request path and the planning
//! path, measured with the in-repo `benchkit` harness.
//!
//! Run: `cargo bench --bench hot_paths`

use std::collections::BTreeMap;

use sparseloom::benchkit::{black_box, Bench};
use sparseloom::coordinator::{Coordinator, ServeOpts};
use sparseloom::experiments::Ctx;
use sparseloom::scenario::{Scenario, Server};
use sparseloom::gbdt::{Gbdt, GbdtParams};
use sparseloom::planner::{algo, CostModel};
use sparseloom::preloader::Hotness;
use sparseloom::profiler::{features, ProfilerConfig};
use sparseloom::soc::Platform;
use sparseloom::stitching::StitchSpace;
use sparseloom::util::Rng;
use sparseloom::workload::{placement_orders, slo_grid, Slo, TaskRanges};

fn main() -> anyhow::Result<()> {
    let Ok(ctx) = Ctx::load("artifacts", false) else {
        eprintln!("no artifacts/ — run `make artifacts` first");
        return Ok(());
    };
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
    let orders = placement_orders(&platform, ctx.zoo.subgraphs);
    let task = ctx.zoo.task_names()[0].to_string();
    let p = &profiles[&task];
    let tz = ctx.zoo.task(&task)?;

    let mut grids: BTreeMap<String, Vec<Slo>> = BTreeMap::new();
    let mut universe = Vec::new();
    for (name, tzz) in &ctx.zoo.tasks {
        let g = slo_grid(&TaskRanges::measure(tzz, &lm));
        universe.extend(g.iter().copied());
        grids.insert(name.clone(), g);
    }
    let slos: BTreeMap<String, Slo> =
        grids.iter().map(|(n, g)| (n.clone(), g[12])).collect();

    println!("\n== hot paths (desktop profile, {} zoo) ==\n", ctx.zoo.zoo_name);
    Bench::header();
    let mut b = Bench::new();

    // --- planning-path primitives -----------------------------------
    let space = StitchSpace::for_task(tz);
    b.case("stitch: index→composition→index (V^S)", || {
        let mut acc = 0usize;
        for k in 0..space.len() {
            acc += space.index(&space.composition(k));
        }
        acc
    });

    b.case("eq5: latency_est over all V^S × 1 order", || {
        let mut acc = 0.0;
        for k in 0..p.space.len() {
            if let Some(l) = p.latency_est(&p.space.composition(k), &orders[0]) {
                acc += l;
            }
        }
        acc
    });

    let unit = CostModel::unit();
    b.case("alg1: feasible_set (Θ) one task", || {
        algo::feasible_set(&unit, p, &slos[&task], &orders).len()
    });

    b.case("alg1: optimize() 4 tasks × 6 orders", || {
        algo::optimize(&unit, &profiles, &slos, &orders).mean_latency_ms
    });

    b.case("alg2: hotness over |Ψ|=100", || {
        Hotness::compute(p, &universe, &orders).scores.len()
    });

    // --- estimator ----------------------------------------------------
    let train: Vec<Vec<f64>> = (0..200)
        .map(|k| features(&space.composition(k * 5 % space.len()), tz))
        .collect();
    let ys: Vec<f64> = (0..200).map(|i| (i as f64 * 0.618).fract()).collect();
    let model = Gbdt::fit(&train, &ys, &GbdtParams::default());
    let x = features(&space.composition(123), tz);
    b.case("gbdt: fit 200×d default params", || {
        Gbdt::fit(&train, &ys, &GbdtParams::default()).n_trees()
    });
    b.case("gbdt: predict one variant", || model.predict(black_box(&x)));

    // --- serving path ---------------------------------------------------
    let coord = Coordinator::new(&ctx.zoo, &lm, &profiles);
    let opts = ServeOpts::default();
    b.case("coordinator: prepare (plan+preload)", || {
        coord.prepare(&slos, &universe, &opts).unwrap().order.len()
    });
    let server = Server::builder(&ctx.zoo, &lm, &profiles).build();
    let arrival: Vec<String> = profiles.keys().cloned().collect();
    let scenario = Scenario::closed_loop(&arrival, slos.clone())
        .with_universe(universe.clone());
    server.run(&scenario)?; // warm the plan cache: the case times serving
    b.case("server: run 4×100 closed-loop queries (sim)", || {
        server.run(&scenario).unwrap().total_queries
    });
    let open = Scenario::poisson(&arrival, slos.clone(), 50.0, 2_000.0)
        .with_seed(3)
        .with_universe(universe.clone());
    b.case("server: run Poisson open loop 4×~100 (sim)", || {
        server.run(&open).unwrap().total_queries
    });

    // --- rng / substrate sanity ----------------------------------------
    let mut rng = Rng::new(1);
    b.case("rng: 1k xoshiro256++ draws", || {
        let mut s = 0u64;
        for _ in 0..1000 {
            s ^= rng.next_u64();
        }
        s
    });

    Ok(())
}
