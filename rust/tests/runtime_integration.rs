//! Integration tests across the artifact bridge: manifest → PJRT
//! executables → stitched-chain execution → accuracy measurement.
//!
//! These need `make artifacts` to have run; they are skipped (not
//! failed) when `artifacts/manifest.json` is absent so `cargo test`
//! stays green on a fresh checkout. The whole file is gated on the
//! `xla` feature — without it the runtime is a stub and there is
//! nothing to integrate against.
#![cfg(feature = "xla")]

use sparseloom::runtime::Runtime;
use sparseloom::stitching::StitchSpace;
use sparseloom::zoo::Zoo;

fn zoo() -> Option<Zoo> {
    Zoo::load("artifacts").ok()
}

#[test]
fn probe_numerics_match_python() {
    let Some(zoo) = zoo() else { return };
    let rt = Runtime::new().unwrap();
    // Quant variants amplify cross-XLA-version ULP noise by one dynamic-
    // quantization step (≈0.1 % of logit scale) — see `sparseloom probe`.
    let tol = 5e-2f32;
    for (tname, tz) in &zoo.tasks {
        let (x, expected) = zoo.load_probe(tname).unwrap();
        // Check the dense and one compressed variant per task (the full
        // sweep runs via `sparseloom probe`).
        for vi in [0usize, zoo.n_variants() - 1] {
            let want = &expected[vi];
            let comp = vec![vi; zoo.subgraphs];
            let batch = *zoo
                .batch_sizes
                .iter()
                .filter(|&&b| b >= zoo.probe_batch)
                .min()
                .unwrap();
            let d = tz.input_dim;
            let mut input = vec![0f32; batch * d];
            input[..zoo.probe_batch * d].copy_from_slice(&x);
            let (got, _) = rt.run_chain(&zoo, tname, &comp, batch, &input).unwrap();
            for r in 0..zoo.probe_batch {
                for c in 0..zoo.n_classes {
                    let g = got[r * zoo.n_classes + c];
                    let w = want[r * zoo.n_classes + c];
                    assert!(
                        (g - w).abs() <= tol,
                        "{tname} v{vi} [{r},{c}]: {g} vs {w}"
                    );
                }
            }
        }
    }
}

#[test]
fn stitched_chain_differs_from_pure_but_is_finite() {
    let Some(zoo) = zoo() else { return };
    let rt = Runtime::new().unwrap();
    let task = zoo.task_names()[0].to_string();
    let tz = zoo.task(&task).unwrap();
    let d = tz.input_dim;
    let input: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).cos()).collect();
    let pure = vec![0usize; zoo.subgraphs];
    let mut mixed = vec![0usize; zoo.subgraphs];
    mixed[zoo.subgraphs - 1] = zoo.n_variants() - 1;
    let (a, _) = rt.run_chain(&zoo, &task, &pure, 1, &input).unwrap();
    let (b, _) = rt.run_chain(&zoo, &task, &mixed, 1, &input).unwrap();
    assert!(a.iter().all(|x| x.is_finite()));
    assert!(b.iter().all(|x| x.is_finite()));
    assert_ne!(a, b, "stitching must change the function");
}

#[test]
fn measured_accuracy_matches_oracle() {
    let Some(zoo) = zoo() else { return };
    let rt = Runtime::new().unwrap();
    let task = zoo.task_names()[0].to_string();
    let oracle = zoo.load_oracle(&task).unwrap();
    let space = StitchSpace::new(zoo.n_variants(), zoo.subgraphs);
    // Pure dense + one stitched composition: PJRT-measured accuracy must
    // equal the python-exported oracle exactly (same eval set, argmax).
    for comp in [vec![0; zoo.subgraphs], {
        let mut c = vec![0; zoo.subgraphs];
        c[0] = 1;
        c
    }] {
        let k = space.index(&sparseloom::stitching::Composition(comp.clone()));
        let measured = rt.measure_accuracy(&zoo, &task, &comp).unwrap();
        assert!(
            (measured - oracle[k]).abs() < 1e-6,
            "comp {comp:?}: measured {measured} vs oracle {}",
            oracle[k]
        );
    }
}

#[test]
fn executable_and_weight_caches_hit() {
    let Some(zoo) = zoo() else { return };
    let rt = Runtime::new().unwrap();
    let task = zoo.task_names()[0].to_string();
    let tz = zoo.task(&task).unwrap();
    let path = tz.variants[0].spec.kernel_path;
    let before = rt.n_executables();
    let _ = rt.executable(&zoo, &task, 0, path, 1).unwrap();
    let _ = rt.executable(&zoo, &task, 0, path, 1).unwrap();
    assert_eq!(rt.n_executables(), before + 1, "second compile is a cache hit");
    let (_, first_ms) = rt.weight_buffers(&zoo, &task, 0, 0).unwrap();
    let (_, second_ms) = rt.weight_buffers(&zoo, &task, 0, 0).unwrap();
    assert!(first_ms > 0.0);
    assert_eq!(second_ms, 0.0, "second upload is a cache hit");
}

#[test]
fn chain_timing_has_one_entry_per_stage() {
    let Some(zoo) = zoo() else { return };
    let rt = Runtime::new().unwrap();
    let task = zoo.task_names()[0].to_string();
    let tz = zoo.task(&task).unwrap();
    let input = vec![0.5f32; tz.input_dim];
    let comp = vec![0usize; zoo.subgraphs];
    let (_, timing) = rt.run_chain(&zoo, &task, &comp, 1, &input).unwrap();
    assert_eq!(timing.stage_ms.len(), zoo.subgraphs);
    assert!(timing.total_ms >= timing.stage_ms.iter().sum::<f64>() * 0.5);
}
