"""Build-path integration: run the AOT exporter end-to-end (tiny budget)
and validate the artifact contract the rust side depends on."""

import json
import os
import struct
import subprocess
import sys

import numpy as np
import pytest

from compile import model as M

ART = "/tmp/sparseloom_test_artifacts"


@pytest.fixture(scope="module")
def artifacts():
    """One-task, low-step AOT run (shared across the module's tests)."""
    repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", ART,
         "--tasks", "imgcls", "--steps", "8"],
        cwd=repo_py, check=True, capture_output=True,
    )
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_schema(artifacts):
    m = artifacts
    assert m["version"] >= 3
    assert m["subgraphs"] == M.SUBGRAPHS
    assert len(m["variants"]) == 10
    assert "imgcls" in m["tasks"]
    t = m["tasks"]["imgcls"]
    assert len(t["iface"]) == M.SUBGRAPHS + 1
    assert set(t["variants"]) == {v["name"] for v in m["variants"]}


def test_hlo_files_exist_and_parse_header(artifacts):
    t = artifacts["tasks"]["imgcls"]
    assert len(t["hlo"]) == M.SUBGRAPHS * 4 * 2  # sg × path × batch
    for entry in t["hlo"].values():
        path = os.path.join(ART, entry["file"])
        assert os.path.exists(path)
        head = open(path).read(200)
        assert "HloModule" in head
        assert entry["flops"] >= 0


def test_weight_blob_sizes_match_param_specs(artifacts):
    dt = {"f32": 4, "i8": 1}
    t = artifacts["tasks"]["imgcls"]
    for vname, v in t["variants"].items():
        for sg in v["subgraphs"]:
            want = sum(
                dt[p["dtype"]] * int(np.prod(p["shape"]))
                for p in sg["params"]
            )
            assert sg["bytes"] == want, (vname, sg["file"])
            assert os.path.getsize(os.path.join(ART, sg["file"])) == want


def test_hlo_param_specs_match_variant_blobs(artifacts):
    """HLO lowering order and blob serialization order agree per path."""
    t = artifacts["tasks"]["imgcls"]
    vtypes = {v["name"]: v["kernel_path"] for v in artifacts["variants"]}
    for vname, v in t["variants"].items():
        path = vtypes[vname]
        for j, sg in enumerate(v["subgraphs"]):
            hlo = t["hlo"][f"sg{j}/{path}/b1"]
            assert hlo["params"] == sg["params"], (vname, j)


def test_eval_data_shape(artifacts):
    d = M.TASKS["imgcls"].input_dim
    n = artifacts["n_eval"]
    size = os.path.getsize(os.path.join(ART, "data", "imgcls_eval.bin"))
    assert size == n * d * 4 + n * 4


def test_oracle_table(artifacts):
    v = len(artifacts["variants"])
    raw = open(os.path.join(ART, "oracle", "imgcls.bin"), "rb").read()
    accs = np.frombuffer(raw, np.float32)
    assert accs.shape == (v ** M.SUBGRAPHS,)
    assert (accs >= 0).all() and (accs <= 1).all()
    # Pure-variant entries must equal the manifest accuracies.
    t = artifacts["tasks"]["imgcls"]
    for i, vs in enumerate(artifacts["variants"]):
        k = (i * v + i) * v + i
        np.testing.assert_allclose(
            accs[k], t["variants"][vs["name"]]["accuracy"], atol=1e-6
        )


def test_probe_file_layout(artifacts):
    pb = artifacts["probe_batch"]
    d = M.TASKS["imgcls"].input_dim
    nv = len(artifacts["variants"])
    size = os.path.getsize(os.path.join(ART, "probes", "imgcls.bin"))
    assert size == pb * d * 4 + nv * pb * M.N_CLASSES * 4


def test_stitched_space_is_richer_than_zoo(artifacts):
    """Fig-4 precondition: stitching expands the accuracy space beyond
    the 10 zoo points (more unique accuracy values than zoo variants)."""
    raw = open(os.path.join(ART, "oracle", "imgcls.bin"), "rb").read()
    accs = np.frombuffer(raw, np.float32)
    assert len(np.unique(np.round(accs, 4))) > 10
