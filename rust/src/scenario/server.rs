//! The serving facade: `ServerBuilder` → `Server` → `Session`.
//!
//! A [`Server`] owns the planning engine ([`Coordinator`]) — profiles,
//! latency model, memory pool, optional PJRT runtime — and executes
//! [`Scenario`]s. `Server::run` drives a whole scenario to a
//! [`RunReport`]; `Server::session` + [`Session::submit`] is the
//! per-request path, emitting one [`RequestOutcome`] event per query
//! (arrival → queueing → placement → completion → SLO verdict).
//!
//! Phase 3+4 of the paper's Fig. 6 pipeline live here: virtual timing
//! comes from the platform model via `SocSim`; when a runtime is
//! attached, the first query of each task also executes the *real*
//! PJRT chain (correct logits; real wall time is the caller's to
//! record). SLO feedback switches variants mid-run when a task is
//! observed violating (the runtime-rescheduling path of Fig. 5a).
//!
//! Streams are replayed through the [`super::dispatch::Dispatcher`],
//! which coalesces same-task queries into [`Session::submit_batch`]
//! calls when the scenario enables batching; the per-request path is
//! otherwise [`Session::submit`]:
//!
//! ```
//! use sparseloom::fixtures;
//! use sparseloom::scenario::{Scenario, Server};
//!
//! let (zoo, lm, profiles) = fixtures::tiny();
//! let server = Server::builder(&zoo, &lm, &profiles).build();
//! let scenario = Scenario::closed_loop(&fixtures::task_names(&zoo),
//!                                      fixtures::slos(&zoo, 0.5, 1e9))
//!     .with_queries(3);
//!
//! let mut session = server.session(&scenario, 0).unwrap();
//! for q in scenario.stream(0) {
//!     let outcome = session.submit(&q).unwrap();
//!     assert!(!outcome.dropped);
//!     assert!(outcome.finish_ms >= outcome.start_ms);
//! }
//! let report = session.finish();
//! assert_eq!(report.total_queries, 3);
//! assert_eq!(report.total_batches, 3, "unbatched: one batch per query");
//! ```

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::baselines::{self, Policy};
use crate::coordinator::{Coordinator, Prepared, ServeOpts};
use crate::metrics::{QuantileSketch, RequestOutcome, RunReport, TaskOutcome};
use crate::profiler::TaskProfile;
use crate::runtime::Runtime;
use crate::soc::{BlobId, LatencyModel, Processor, SocSim};
use crate::stitching::Composition;
use crate::telemetry::forecast::{self, RateForecaster, TrendTracker};
use crate::trace::{self, TraceEvent, TraceSink};
use crate::util::stats;
use crate::workload::{placement_orders, Query, Slo};
use crate::zoo::Zoo;

use super::dispatch::{Dispatch, Dispatcher};
use super::faults::{FaultProfile, RejoinMode};
use super::{Admission, Scenario};

/// Queries observed before a feedback-switch decision re-evaluates.
const FEEDBACK_WINDOW: usize = 20;

/// Horizon (virtual ms) the end-of-run SLO forecast projects over when
/// the scenario's admission does not carry one
/// ([`Admission::Predictive`] supplies its own).
const DEFAULT_FORECAST_HORIZON_MS: f64 = 500.0;

/// Hysteresis for [`Admission::Fair`]'s share clause: a task is only
/// admitted past its deadline budget while its per-weight backlog is
/// under this fraction of the *other* tasks' per-weight backlog.
/// Without the margin, the one-service-quantum leapfrog between
/// equally-backlogged tasks (whoever booked last looks more backlogged)
/// would let symmetric floods admit each other forever, silently
/// disabling the deadline floor.
const FAIR_SHARE_MARGIN: f64 = 0.75;

/// Builder for a [`Server`]: the only way to construct one.
pub struct ServerBuilder<'a> {
    zoo: &'a Zoo,
    lm: &'a LatencyModel,
    profiles: &'a BTreeMap<String, TaskProfile>,
    runtime: Option<&'a Runtime>,
    opts: ServeOpts,
}

impl<'a> ServerBuilder<'a> {
    pub fn new(
        zoo: &'a Zoo,
        lm: &'a LatencyModel,
        profiles: &'a BTreeMap<String, TaskProfile>,
    ) -> Self {
        Self { zoo, lm, profiles, runtime: None, opts: ServeOpts::default() }
    }

    /// Attach a live PJRT runtime: the first query of each task then
    /// executes the real stitched chain.
    pub fn runtime(mut self, rt: &'a Runtime) -> Self {
        self.runtime = Some(rt);
        self
    }

    /// Replace the whole option block at once.
    pub fn opts(mut self, opts: ServeOpts) -> Self {
        self.opts = opts;
        self
    }

    pub fn policy(mut self, policy: Policy) -> Self {
        self.opts.policy = policy;
        self
    }

    /// Memory budget as a fraction of full-preload bytes (Fig. 14 axis).
    pub fn memory_budget_frac(mut self, frac: f64) -> Self {
        self.opts.memory_budget_frac = frac;
        self
    }

    pub fn feedback_switching(mut self, on: bool) -> Self {
        self.opts.feedback_switching = on;
        self
    }

    pub fn verify_selection(mut self, on: bool) -> Self {
        self.opts.verify_selection = on;
        self
    }

    pub fn judge_on_truth(mut self, on: bool) -> Self {
        self.opts.judge_on_truth = on;
        self
    }

    /// Expected mean coalesced batch size for batch-aware planning
    /// (1.0 — the default — is the paper's batch-1 planning).
    pub fn batch_hint(mut self, hint: f64) -> Self {
        self.opts.batch_hint = hint.max(1.0);
        self
    }

    /// Force a placement order instead of optimizing over Ω (Fig. 13).
    pub fn force_order(mut self, order: Vec<Processor>) -> Self {
        self.opts.force_order = Some(order);
        self
    }

    pub fn build(self) -> Server<'a> {
        let mut coord = Coordinator::new(self.zoo, self.lm, self.profiles);
        if let Some(rt) = self.runtime {
            coord = coord.with_runtime(rt);
        }
        Server { coord, opts: self.opts, plan_cache: Mutex::new(BTreeMap::new()) }
    }
}

/// Exact planning-cache key: SLO map + universe, with f64 bounds
/// compared bitwise (cheaper than formatting, no collision risk).
type PlanKey = (Vec<(String, u64, u64)>, Vec<(u64, u64)>);

fn plan_key(slos: &BTreeMap<String, Slo>, universe: &[Slo]) -> PlanKey {
    (
        slos.iter()
            .map(|(name, s)| {
                (name.clone(), s.min_accuracy.to_bits(), s.max_latency_ms.to_bits())
            })
            .collect(),
        universe
            .iter()
            .map(|s| (s.min_accuracy.to_bits(), s.max_latency_ms.to_bits()))
            .collect(),
    )
}

/// Look up one phase's SLO configuration (shared bounds check).
fn phase_slos<'b>(
    scenario: &'b Scenario,
    phase: usize,
) -> Result<&'b BTreeMap<String, Slo>> {
    scenario.schedule.get(phase).ok_or_else(|| {
        anyhow::anyhow!(
            "scenario {:?} has {} phase(s), no phase {phase}",
            scenario.name,
            scenario.schedule.len()
        )
    })
}

/// The serving facade. Construct via [`Server::builder`].
pub struct Server<'a> {
    coord: Coordinator<'a>,
    opts: ServeOpts,
    /// Planning is deterministic in (SLOs, universe) for fixed opts, so
    /// repeated runs of the same phase (e.g. sweeps over arrival
    /// orders) reuse one `Prepared` instead of re-optimizing. A mutex
    /// (not a `RefCell`) so `Server` is `Sync` and the sharded drive
    /// can open sessions from shard threads.
    plan_cache: Mutex<BTreeMap<PlanKey, Prepared>>,
}

impl<'a> Server<'a> {
    pub fn builder(
        zoo: &'a Zoo,
        lm: &'a LatencyModel,
        profiles: &'a BTreeMap<String, TaskProfile>,
    ) -> ServerBuilder<'a> {
        ServerBuilder::new(zoo, lm, profiles)
    }

    pub fn opts(&self) -> &ServeOpts {
        &self.opts
    }

    /// The internal planning engine (read-only escape hatch).
    pub fn coordinator(&self) -> &Coordinator<'a> {
        &self.coord
    }

    /// Plan + preload one SLO configuration (phases 1–2), memoized per
    /// (SLOs, universe). Exposed so callers can inspect selections and
    /// placement before (or without) serving.
    pub fn prepare(
        &self,
        slos: &BTreeMap<String, Slo>,
        universe: &[Slo],
    ) -> Result<Prepared> {
        let key = plan_key(slos, universe);
        if let Some(p) = self.plan_cache.lock().expect("plan cache poisoned").get(&key) {
            return Ok(p.clone());
        }
        let p = self.coord.prepare(slos, universe, &self.opts)?;
        self.plan_cache
            .lock()
            .expect("plan cache poisoned")
            .insert(key, p.clone());
        Ok(p)
    }

    /// Run a whole scenario. Multi-phase schedules are merged into one
    /// report (outcomes and events concatenated, makespans summed);
    /// use [`Server::run_schedule`] for per-phase reports.
    pub fn run(&self, scenario: &Scenario) -> Result<RunReport> {
        let mut reports = self.run_schedule(scenario)?;
        if reports.len() == 1 {
            return Ok(reports.pop().unwrap());
        }
        let mut merged = RunReport::default();
        for r in reports {
            merged.merge_sequential(r);
        }
        Ok(merged)
    }

    /// Run every phase of the scenario's SLO schedule, one report per
    /// phase. Multi-phase schedules keep a persistent memory pool
    /// across phases (§3.4 / Fig. 14): each re-plan pays compile+load
    /// for whatever the budgeted pool does not hold.
    pub fn run_schedule(&self, scenario: &Scenario) -> Result<Vec<RunReport>> {
        if scenario.schedule.is_empty() {
            bail!("scenario {:?} has an empty SLO schedule", scenario.name);
        }
        let universe = scenario.slo_universe();
        // The dispatcher honors the scenario's batching config; with the
        // default identity dispatch it replays exactly like
        // `Session::drive`.
        let dispatcher = Dispatcher::new(scenario.dispatch.clone());
        if scenario.schedule.len() == 1 {
            let prepared = self.prepare(&scenario.schedule[0], &universe)?;
            let mut session = self.session_with(scenario, 0, prepared)?;
            dispatcher.drive(&mut session, &scenario.stream(0))?;
            return Ok(vec![session.finish()]);
        }
        let (preload_plan, mut pool) = self.coord.build_pool(&universe, &self.opts)?;
        let mut reports = Vec::with_capacity(scenario.schedule.len());
        for (phase, slos) in scenario.schedule.iter().enumerate() {
            let prepared = self.coord.prepare_with_pool(
                slos,
                &self.opts,
                preload_plan.clone(),
                pool.clone(),
            )?;
            let mut session = self.session_with(scenario, phase, prepared)?;
            dispatcher.drive(&mut session, &scenario.stream(phase))?;
            // Carry the *post-serve* pool forward so blobs loaded by
            // mid-phase feedback switches stay resident for the next
            // phase (the pool really is persistent across phases).
            pool = session.prepared.pool.clone();
            reports.push(session.finish());
        }
        Ok(reports)
    }

    /// Open a serving session for one phase of a scenario — the
    /// per-request path. Plans (memoized) and initializes per-task
    /// state; the caller then [`Session::submit`]s queries and
    /// [`Session::finish`]es for the report.
    pub fn session<'s>(
        &'s self,
        scenario: &Scenario,
        phase: usize,
    ) -> Result<Session<'s, 'a>> {
        let slos = phase_slos(scenario, phase)?;
        let prepared = self.prepare(slos, &scenario.slo_universe())?;
        self.session_with(scenario, phase, prepared)
    }

    fn session_with<'s>(
        &'s self,
        scenario: &Scenario,
        phase: usize,
        prepared: Prepared,
    ) -> Result<Session<'s, 'a>> {
        let slos = phase_slos(scenario, phase)?;
        // Fail-fast sparselint gate: duplicate tasks, tasks without a
        // profile, tasks without a (well-formed) SLO in this phase, and
        // bad arrival parameters are rejected with coded diagnostics
        // before any serving state is built. Restricted to checks that
        // also hold for the per-shard sub-scenarios the sharded drive
        // opens (see `analysis::scenario::session_gate`).
        crate::analysis::scenario::session_gate(scenario, phase, self.coord.profiles)
            .fail_on_errors(&format!("scenario {:?}", scenario.name))?;
        let platform = &self.coord.lm.platform;
        let s = self.coord.zoo.subgraphs;
        // Fault lab: the session sees the scenario's profile through its
        // own shard's lens (the sharded drive hands each sub-scenario a
        // re-indexed profile; for a single server, shard 0 *is* the
        // server). The throttle curve installs on the SoC clock; an
        // empty profile changes nothing, bit for bit.
        let faults = scenario.faults.for_shard(0);
        let mut sim = SocSim::new(&platform.processor_list());
        if let Some(curve) = &faults.throttle {
            sim.set_throttle(curve.as_steps());
        }
        let np_assign = baselines::np_task_processor(self.coord.profiles, platform);
        let orders_omega = placement_orders(platform, s);

        let mut states: BTreeMap<String, TaskState> = BTreeMap::new();
        for name in &scenario.tasks {
            let Some(p) = self.coord.profiles.get(name) else {
                bail!("scenario references unknown task {name:?}");
            };
            let order: Vec<Processor> = if self.opts.policy.is_partitioned() {
                prepared.order.clone()
            } else {
                vec![np_assign[name]; s]
            };
            // NP execution runs all T tasks concurrently on one
            // processor and pays the co-execution slowdown κ; the
            // pipeline time-multiplexes exclusively and does not.
            let coexec = if self.opts.policy.is_partitioned() {
                1.0
            } else {
                1.0 + platform.coexec_slowdown
                    * (scenario.tasks.len().saturating_sub(1)) as f64
            };
            // Best-effort serving: a task with no SLO-feasible variant
            // still runs (real systems do not refuse service) — it takes
            // the minimum-latency *pure* variant supported on its order
            // and is judged (and will violate) against its SLO.
            let planned = prepared.selections.get(name).copied().flatten();
            let sel = planned.or_else(|| best_pure_selection(p, &order));
            let accuracy = match (planned, sel) {
                // Planned feasible: judge on truth when available.
                (Some(_), Some(sel)) => {
                    Some(self.coord.judged_accuracy(p, sel.stitched_index, &self.opts))
                }
                // Judged infeasible: no accuracy → counted as violated.
                _ => None,
            };
            // The planned switch penalty is a cold start (compile +
            // load for whatever the preload left out).
            let initial_penalty_ms =
                prepared.switch_penalty_ms.get(name).copied().unwrap_or(0.0);
            states.insert(
                name.clone(),
                TaskState {
                    comp: sel.map(|sel| p.space.composition(sel.stitched_index)),
                    accuracy,
                    ready_ms: 0.0,
                    pending_penalty_ms: initial_penalty_ms,
                    pending_cold_ms: initial_penalty_ms,
                    pending_warm_ms: 0.0,
                    pending_link_ms: 0.0,
                    completed: 0,
                    lat_sum: 0.0,
                    lat_max: 0.0,
                    queue_sum: 0.0,
                    lat_sketch: QuantileSketch::default(),
                    recent: VecDeque::with_capacity(FEEDBACK_WINDOW),
                    switches: 0,
                    dropped: 0,
                    batches: 0,
                    max_batch: 0,
                    inflight: VecDeque::new(),
                    ran_real: false,
                    order,
                    coexec,
                    misses: 0,
                    rate: RateForecaster::default(),
                    backlog_trend: TrendTracker::default(),
                },
            );
        }

        Ok(Session {
            tsink: trace::sink_for(self.opts.trace),
            trace_shard: 0,
            batch_seq: 0,
            server: self,
            prepared,
            slos: slos.clone(),
            admission: scenario.admission.clone(),
            self_clocked: matches!(scenario.arrival, super::Arrival::ClosedLoop { .. }),
            tasks: scenario.tasks.clone(),
            sim,
            states,
            orders_omega,
            requests: Vec::new(),
            cold_compiles: 0,
            warm_loads: 0,
            rejoined: vec![false; faults.crashes.len()],
            pending_recovery: Vec::new(),
            recoveries: Vec::new(),
            faults,
        })
    }
}

/// Per-task mutable serving state.
struct TaskState {
    comp: Option<Composition>,
    accuracy: Option<f64>,
    /// When this task's previous query finished (per-task FIFO).
    ready_ms: f64,
    /// One-off latency charged to the next query (switch cost).
    pending_penalty_ms: f64,
    /// Cold-path (compile + load) share of `pending_penalty_ms` —
    /// consumed into the next batch's `TR-REQ-EXEC` trace decomposition
    /// and zeroed with it.
    pending_cold_ms: f64,
    /// Warm-migration (cross-shard load) share of `pending_penalty_ms`.
    pending_warm_ms: f64,
    /// Link-transfer delay charged to this task's FIFO floor at
    /// adoption. Not part of service (the floor already carries it);
    /// reported in the trace decomposition only.
    pending_link_ms: f64,
    /// Completed (admitted, served) queries.
    completed: usize,
    /// Running sum of service latencies — `lat_sum / completed` is
    /// bit-identical to the mean over a retained vector, because
    /// additions happen in the same (completion) order.
    lat_sum: f64,
    /// Largest service latency observed.
    lat_max: f64,
    /// Running sum of queueing delays.
    queue_sum: f64,
    /// GK quantile sketch over service latencies (p50/p95/p99 with the
    /// ε rank-error bound, O(1/ε · log εn) memory).
    lat_sketch: QuantileSketch,
    /// The trailing `FEEDBACK_WINDOW` service latencies — all the
    /// feedback switcher ever reads, kept as a bounded ring so the
    /// unbounded latency vector can go away.
    recent: VecDeque<f64>,
    switches: usize,
    dropped: usize,
    /// Dispatch batches served (a lone query counts as one batch).
    batches: usize,
    /// Largest coalesced batch served for this task.
    max_batch: usize,
    /// Completion times of admitted queries (queue-cap accounting).
    inflight: VecDeque<f64>,
    ran_real: bool,
    /// Stage → processor for this task (pipeline order or NP repeat).
    order: Vec<Processor>,
    /// Co-execution slowdown factor for NP policies.
    coexec: f64,
    /// Completed queries whose service latency missed the SLO bound —
    /// the observed share the end-of-run SLO forecast projects.
    misses: usize,
    /// Holt trend + burst detector over this task's arrival rate (the
    /// SLO-forecast load factor).
    rate: RateForecaster,
    /// Holt trend over this task's observed queueing backlog — the
    /// growth term of [`Admission::Predictive`].
    backlog_trend: TrendTracker,
}

/// One in-flight serving run: accepts queries, books them on the
/// simulated SoC, and accumulates per-request events.
pub struct Session<'s, 'a> {
    server: &'s Server<'a>,
    prepared: Prepared,
    slos: BTreeMap<String, Slo>,
    admission: Admission,
    /// Closed-loop scenarios are self-clocking: a query only *exists*
    /// once its predecessor completes, so its effective arrival is the
    /// predecessor's completion, not the nominal stagger offset.
    self_clocked: bool,
    tasks: Vec<String>,
    sim: SocSim,
    states: BTreeMap<String, TaskState>,
    orders_omega: Vec<Vec<Processor>>,
    requests: Vec<RequestOutcome>,
    /// Blobs compiled from scratch for a mid-session adoption
    /// (migration/steal cold path).
    cold_compiles: usize,
    /// Blobs that arrived warm from another shard's pool at adoption.
    warm_loads: usize,
    /// Shard-local fault profile (see [`super::faults`]): crash windows
    /// and degradations re-indexed so shard 0 means *this* session.
    faults: FaultProfile,
    /// Per crash-window flag: rejoin processing already ran.
    rejoined: Vec<bool>,
    /// Crash-window ends still waiting for their first post-rejoin
    /// completion (the recovery-latency measurement in flight).
    pending_recovery: Vec<f64>,
    /// Recovery latencies observed: first completion after each rejoin,
    /// minus the window end.
    recoveries: Vec<f64>,
    /// Structured trace sink: `NoopSink` unless [`ServeOpts::trace`]
    /// (zero events retained, nothing perturbed).
    tsink: Box<dyn TraceSink>,
    /// True fleet shard index stamped on trace events — sessions
    /// otherwise see themselves as shard 0 (see
    /// [`Session::set_trace_shard`]).
    trace_shard: usize,
    /// Monotone per-session batch counter (the trace `batch` argument).
    batch_seq: u64,
}

impl<'s, 'a> Session<'s, 'a> {
    /// Submit one query: admission check, stage-by-stage booking on
    /// the pipeline, SLO feedback, optional real PJRT execution.
    /// Returns (and records) the query's [`RequestOutcome`]. Exactly a
    /// single-query [`Session::submit_batch`].
    pub fn submit(&mut self, q: &Query) -> Result<RequestOutcome> {
        let mut evs = self.submit_batch(std::slice::from_ref(&q))?;
        Ok(evs.pop().expect("one outcome per submitted query"))
    }

    /// Submit a coalesced batch of same-task queries: per-query
    /// admission against the pre-batch backlog, then **one** placement
    /// decision booking each pipeline stage once for the whole batch at
    /// the batch-aware stage occupancy (`LatencyModel::batch_factor`).
    /// Every query of the batch completes when the batch does, so each
    /// admitted query's service latency is the full batch service time —
    /// batching trades per-query latency for throughput. Returns (and
    /// records) one [`RequestOutcome`] per input query, in input order.
    ///
    /// Queries must all target the same task and be in per-task FIFO
    /// order (the [`super::dispatch::Dispatcher`] guarantees both).
    pub fn submit_batch(&mut self, batch: &[&Query]) -> Result<Vec<RequestOutcome>> {
        let Some(first) = batch.first() else {
            bail!("submit_batch needs at least one query");
        };
        let task = &first.task;
        if batch.iter().any(|q| &q.task != task) {
            bail!("batch mixes tasks (dispatcher invariant violated)");
        }
        let coord = &self.server.coord;
        let opts = &self.server.opts;
        let platform = &coord.lm.platform;
        let Some(slo) = self.slos.get(task).copied() else {
            bail!(
                "query {} targets task {:?} with no SLO in this session",
                first.id,
                task
            );
        };
        let self_clocked = self.self_clocked;
        let tz = coord.zoo.task(task)?;

        // Fault lab: lazily apply crash windows whose recovery point has
        // passed by this batch — raise per-task FIFO floors to the
        // window end and, on a cold rejoin, wipe the pool so each task's
        // next batch pays compile + load again. Runs before the fair
        // snapshot so fairness sees the raised floors.
        if !self.faults.crashes.is_empty() {
            let ready = self.states.get(task).map(|st| st.ready_ms).unwrap_or(0.0);
            self.process_rejoins(first.arrival_ms.max(ready));
        }

        // Weighted-fair admission compares this task's backlog against
        // the *other* tasks'; snapshot the cross-task state before taking
        // this task's mutable state. `ready_ms` of other tasks cannot
        // move while this batch books, so the snapshot stays exact.
        // (slack, own weight, Σ other weights, other tasks' ready_ms)
        let fair: Option<(f64, f64, f64, Vec<f64>)> = match &self.admission {
            Admission::Fair { slack, weights } => {
                let w_of = |t: &str| weights.get(t).copied().unwrap_or(1.0);
                let mut sum_w_others = 0.0;
                let mut others = Vec::with_capacity(self.states.len());
                for (name, st) in &self.states {
                    if name != task {
                        sum_w_others += w_of(name);
                        others.push(st.ready_ms);
                    }
                }
                Some((*slack, w_of(task), sum_w_others, others))
            }
            _ => None,
        };

        let Some(st) = self.states.get_mut(task) else {
            bail!(
                "query {} targets task {:?} not in this scenario",
                first.id,
                task
            );
        };

        // No runnable variant at all: nothing to book.
        let Some(comp) = st.comp.clone() else {
            st.dropped += batch.len();
            if self.tsink.enabled() {
                for q in batch {
                    self.tsink.emit(TraceEvent::new(
                        trace::TR_REQ_ARRIVE,
                        self.trace_shard,
                        task,
                        Some(q.id),
                        q.arrival_ms,
                        q.arrival_ms,
                        &[],
                    ));
                    self.tsink.emit(TraceEvent::new(
                        trace::TR_REQ_DROP,
                        self.trace_shard,
                        task,
                        Some(q.id),
                        q.arrival_ms,
                        q.arrival_ms,
                        &[("cause", trace::DROP_CAUSE_NO_VARIANT)],
                    ));
                }
            }
            let evs: Vec<RequestOutcome> =
                batch.iter().map(|q| dropped_event(q, None)).collect();
            if self.server.opts.record_events {
                self.requests.extend(evs.iter().cloned());
            }
            return Ok(evs);
        };

        // --- per-query admission against the pre-batch backlog ----------
        // A closed-loop query only exists once its predecessor finishes
        // (self-clocking), so it can never be "late"; an open-loop query
        // arrives at its nominal time regardless of backlog.
        let mut events: Vec<Option<RequestOutcome>> =
            (0..batch.len()).map(|_| None).collect();
        // (input index, effective arrival) of every admitted query.
        let mut admitted: Vec<(usize, f64)> = Vec::with_capacity(batch.len());
        let mut batch_arrival = f64::NEG_INFINITY;
        for (i, q) in batch.iter().enumerate() {
            let effective_arrival = if self_clocked {
                q.arrival_ms.max(st.ready_ms)
            } else {
                q.arrival_ms
            };
            // Fault lab: the shard is down — queries arriving inside a
            // crash window, or still queued when one opens, die with it.
            if !self.faults.crashes.is_empty()
                && self.faults.swallowed_by(0, effective_arrival, st.ready_ms)
            {
                if self_clocked {
                    // A self-clocked client retries after the rejoin:
                    // advance the loop past the window instead of
                    // freezing it mid-crash forever.
                    for w in &self.faults.crashes {
                        if w.swallows(effective_arrival, st.ready_ms)
                            && st.ready_ms < w.end_ms
                        {
                            st.ready_ms = w.end_ms;
                        }
                    }
                }
                st.dropped += 1;
                events[i] = Some(dropped_event(q, None));
                if self.tsink.enabled() {
                    self.tsink.emit(TraceEvent::new(
                        trace::TR_REQ_ARRIVE,
                        self.trace_shard,
                        task,
                        Some(q.id),
                        effective_arrival,
                        effective_arrival,
                        &[],
                    ));
                    self.tsink.emit(TraceEvent::new(
                        trace::TR_REQ_DROP,
                        self.trace_shard,
                        task,
                        Some(q.id),
                        effective_arrival,
                        effective_arrival,
                        &[("cause", trace::DROP_CAUSE_CRASH)],
                    ));
                }
                continue;
            }
            while st
                .inflight
                .front()
                .map(|&done| done <= effective_arrival)
                .unwrap_or(false)
            {
                st.inflight.pop_front();
            }
            let backlog_ms = (st.ready_ms - effective_arrival).max(0.0);
            // Every arrival feeds the per-task forecasters regardless
            // of policy (deterministic, and the end-of-run SLO
            // forecast wants them on reactive runs too).
            st.rate.observe(effective_arrival);
            st.backlog_trend.observe(effective_arrival, backlog_ms);
            let admit = match &self.admission {
                Admission::Always => true,
                Admission::QueueCap { max_queued } => {
                    st.inflight.len() + admitted.len() <= *max_queued
                }
                Admission::Deadline { slack } => {
                    backlog_ms <= slack * slo.max_latency_ms
                }
                Admission::Fair { .. } => {
                    let (slack, w_self, sum_w_others, others) =
                        fair.as_ref().expect("fair context prepared above");
                    let others_backlog: f64 = others
                        .iter()
                        .map(|&ready| (ready - effective_arrival).max(0.0))
                        .sum();
                    // Deadline floor, plus the share clause: own
                    // per-weight backlog strictly under the margin of
                    // the others' per-weight backlog. With no other
                    // tasks both sides are zero and Fair is exactly
                    // Deadline.
                    backlog_ms <= slack * slo.max_latency_ms
                        || backlog_ms * sum_w_others
                            < FAIR_SHARE_MARGIN * w_self * others_backlog
                }
                Admission::Predictive { horizon_ms, headroom } => {
                    // Shed on the *projected* queueing delay: observed
                    // backlog plus the fitted growth over the horizon.
                    // An empty queue always admits (shedding there
                    // relieves nothing, and closed loops never build
                    // backlog, so they stay lossless); a flat or
                    // draining queue degenerates to Deadline with
                    // slack = headroom.
                    backlog_ms <= 0.0
                        || backlog_ms + st.backlog_trend.projected_growth(*horizon_ms)
                            <= headroom * slo.max_latency_ms
                }
            };
            if self.tsink.enabled() {
                self.tsink.emit(TraceEvent::new(
                    trace::TR_REQ_ARRIVE,
                    self.trace_shard,
                    task,
                    Some(q.id),
                    effective_arrival,
                    effective_arrival,
                    &[],
                ));
                // The decision inputs the verdict was computed from.
                let mut args = vec![("backlog_ms", backlog_ms)];
                match &self.admission {
                    Admission::Always => {}
                    Admission::QueueCap { max_queued } => {
                        args.push(("queued", (st.inflight.len() + admitted.len()) as f64));
                        args.push(("budget", *max_queued as f64));
                    }
                    Admission::Deadline { slack } | Admission::Fair { slack, .. } => {
                        args.push(("budget_ms", slack * slo.max_latency_ms));
                    }
                    Admission::Predictive { horizon_ms, headroom } => {
                        args.push((
                            "projected_ms",
                            backlog_ms + st.backlog_trend.projected_growth(*horizon_ms),
                        ));
                        args.push(("budget_ms", headroom * slo.max_latency_ms));
                    }
                }
                let code = if admit { trace::TR_REQ_ADMIT } else { trace::TR_REQ_SHED };
                self.tsink.emit(TraceEvent::new(
                    code,
                    self.trace_shard,
                    task,
                    Some(q.id),
                    effective_arrival,
                    effective_arrival,
                    &args,
                ));
            }
            if admit {
                admitted.push((i, effective_arrival));
                batch_arrival = batch_arrival.max(effective_arrival);
            } else {
                st.dropped += 1;
                events[i] = Some(dropped_event(q, Some(backlog_ms)));
            }
        }
        if admitted.is_empty() {
            let evs: Vec<RequestOutcome> =
                events.into_iter().map(|e| e.expect("all dropped")).collect();
            if self.server.opts.record_events {
                self.requests.extend(evs.iter().cloned());
            }
            return Ok(evs);
        }

        // --- stage-by-stage booking on the pipeline ---------------------
        // The SLO-judged quantity is the *service* (inference) latency —
        // the sum of stage executions plus any switch cost hitting this
        // batch — matching the paper's per-inference latency SLOs.
        // Queueing delay from arrivals and co-running tasks still shapes
        // the virtual timeline and therefore throughput (Fig. 11) and
        // placement effects (Fig. 13).
        let b = admitted.len();
        let penalty = st.pending_penalty_ms;
        // Consume the penalty split (and the informational link debt)
        // into this batch's trace decomposition, zeroed with the
        // penalty itself.
        let (cold_ms, warm_ms, link_ms) =
            (st.pending_cold_ms, st.pending_warm_ms, st.pending_link_ms);
        st.pending_cold_ms = 0.0;
        st.pending_warm_ms = 0.0;
        st.pending_link_ms = 0.0;
        let issue = batch_arrival.max(st.ready_ms) + penalty;
        let mut service = penalty;
        st.pending_penalty_ms = 0.0;
        let mut stage_ready = issue;
        let mut start_ms = issue;
        let mut supported = true;
        // DVFS stretch this batch's bookings paid (float-exact zero
        // without a throttle curve — the accumulation is gated so
        // fault-free arithmetic is untouched).
        let mut throttle_extra = 0.0;
        for (j, &vi) in comp.0.iter().enumerate() {
            let proc = st.order[j];
            // The batch-aware latency model: stage occupancy for `b`
            // coalesced queries (exactly `subgraph_ms` at b = 1).
            let Some(ms) = coord
                .lm
                .subgraph_batch_ms(tz, vi, j, proc, b)
                .map(|m| m * st.coexec)
            else {
                // Unsupported on this processor: violation-by-
                // construction (infinite latency); stop serving the task.
                st.comp = None;
                supported = false;
                break;
            };
            let hop = if j > 0 { 1.0 + platform.interproc_overhead } else { 1.0 };
            // Fault lab: slow-shard ramps stretch service time by the
            // multiplier in effect when the stage issues (guarded so
            // fault-free runs keep the exact legacy arithmetic).
            let stage_ms = if self.faults.degradations.is_empty() {
                ms * hop
            } else {
                ms * hop * self.faults.degradation_factor(0, stage_ready)
            };
            let (start, end) = self.sim.book(proc, stage_ready, stage_ms);
            if j == 0 {
                start_ms = start;
            }
            if self.faults.throttle.is_some() {
                throttle_extra += (end - start) - stage_ms;
            }
            service += stage_ms;
            stage_ready = end;
        }
        if !supported {
            st.dropped += b;
            for &(i, effective_arrival) in &admitted {
                events[i] = Some(dropped_event(batch[i], None));
                if self.tsink.enabled() {
                    self.tsink.emit(TraceEvent::new(
                        trace::TR_REQ_DROP,
                        self.trace_shard,
                        task,
                        Some(batch[i].id),
                        effective_arrival,
                        effective_arrival,
                        &[("cause", trace::DROP_CAUSE_UNSUPPORTED)],
                    ));
                }
            }
            let evs: Vec<RequestOutcome> =
                events.into_iter().map(|e| e.expect("all dropped")).collect();
            if self.server.opts.record_events {
                self.requests.extend(evs.iter().cloned());
            }
            return Ok(evs);
        }

        // --- per-query completion accounting ----------------------------
        st.ready_ms = stage_ready;
        st.batches += 1;
        st.max_batch = st.max_batch.max(b);
        self.batch_seq += 1;
        let batch_id = self.batch_seq as f64;
        for &(i, effective_arrival) in &admitted {
            // The switch penalty is part of *service* (it delays this
            // query's inference), so it is excluded from queueing:
            // finish − arrival = queueing + service on an idle pipeline.
            let queueing_ms = (start_ms - effective_arrival - penalty).max(0.0);
            st.completed += 1;
            st.lat_sum += service;
            st.lat_max = st.lat_max.max(service);
            st.queue_sum += queueing_ms;
            st.lat_sketch.insert(service);
            if st.recent.len() == FEEDBACK_WINDOW {
                st.recent.pop_front();
            }
            st.recent.push_back(service);
            if service > slo.max_latency_ms {
                st.misses += 1;
            }
            st.inflight.push_back(stage_ready);
            events[i] = Some(RequestOutcome {
                id: batch[i].id,
                task: task.clone(),
                arrival_ms: batch[i].arrival_ms,
                start_ms,
                finish_ms: stage_ready,
                service_ms: service,
                queueing_ms,
                dropped: false,
                slo_ok: Some(service <= slo.max_latency_ms),
            });
            if self.tsink.enabled() {
                self.tsink.emit(TraceEvent::new(
                    trace::TR_REQ_QUEUE,
                    self.trace_shard,
                    task,
                    Some(batch[i].id),
                    effective_arrival,
                    start_ms,
                    &[],
                ));
                self.tsink.emit(TraceEvent::new(
                    trace::TR_REQ_EXEC,
                    self.trace_shard,
                    task,
                    Some(batch[i].id),
                    start_ms,
                    stage_ready,
                    &[
                        ("service_ms", service),
                        ("queueing_ms", queueing_ms),
                        ("cold_ms", cold_ms),
                        ("warm_ms", warm_ms),
                        ("link_ms", link_ms),
                        ("throttle_ms", throttle_extra.max(0.0)),
                        ("batch", batch_id),
                        ("batch_size", b as f64),
                        ("slo_ms", slo.max_latency_ms),
                        (
                            "slo_ok",
                            if service <= slo.max_latency_ms { 1.0 } else { 0.0 },
                        ),
                    ],
                ));
                self.tsink.emit(TraceEvent::new(
                    trace::TR_REQ_DONE,
                    self.trace_shard,
                    task,
                    Some(batch[i].id),
                    stage_ready,
                    stage_ready,
                    &[],
                ));
            }
        }
        // One audit record per batch that actually paid throttle
        // stretch (the 1e-9 floor swallows float noise from the
        // per-stage subtraction).
        if self.tsink.enabled() && throttle_extra > 1e-9 {
            self.tsink.emit(TraceEvent::new(
                trace::TR_CTL_THROTTLE,
                self.trace_shard,
                task,
                None,
                start_ms,
                stage_ready,
                &[("extra_ms", throttle_extra), ("batch", batch_id)],
            ));
        }

        // Fault lab: the first completion after a rejoin closes that
        // window's recovery-latency measurement.
        if !self.pending_recovery.is_empty() {
            let pending = std::mem::take(&mut self.pending_recovery);
            for end in pending {
                if stage_ready >= end {
                    self.recoveries.push(stage_ready - end);
                    if self.tsink.enabled() {
                        self.tsink.emit(TraceEvent::new(
                            trace::TR_CTL_RECOVER,
                            self.trace_shard,
                            task,
                            None,
                            stage_ready,
                            stage_ready,
                            &[("latency_ms", stage_ready - end)],
                        ));
                    }
                } else {
                    self.pending_recovery.push(end);
                }
            }
        }

        // --- SLO feedback: switch variants when violating ---------------
        let served = st.completed;
        if opts.feedback_switching
            && opts.policy == Policy::SparseLoom
            // Trigger whenever this batch crossed a window boundary —
            // for single-query batches this is the classic
            // `served % FEEDBACK_WINDOW == 0` check.
            && served / FEEDBACK_WINDOW > (served - b) / FEEDBACK_WINDOW
        {
            if let Some(p) = coord.profiles.get(task) {
                // The ring holds exactly the trailing window, in the
                // same front→back order the old tail slice had, so the
                // mean is bit-identical to the retained-vector path.
                let recent: Vec<f64> = st.recent.iter().copied().collect();
                let mean = stats::mean(&recent);
                if mean > slo.max_latency_ms {
                    if let Some(new_sel) = coord.switch_variant(
                        p,
                        &slo,
                        &self.prepared.order,
                        &self.orders_omega,
                        mean,
                    ) {
                        let new_comp = p.space.composition(new_sel.stitched_index);
                        // Charge load for blobs not resident.
                        let mut penalty = 0.0;
                        for (j, &vi) in new_comp.0.iter().enumerate() {
                            let id = BlobId::new(task, vi, j);
                            if !self.prepared.pool.touch(&id) {
                                let bytes = tz.variants[vi].subgraphs[j].bytes;
                                penalty += coord.lm.load_ms(bytes, st.order[j]);
                                self.prepared.pool.make_room(bytes);
                                self.prepared.pool.load(id, bytes);
                            }
                        }
                        st.pending_penalty_ms += penalty;
                        st.pending_cold_ms += penalty;
                        st.comp = Some(new_comp);
                        st.accuracy = Some(coord.judged_accuracy(
                            p,
                            new_sel.stitched_index,
                            opts,
                        ));
                        st.switches += 1;
                    }
                }
            }
        }

        // --- optional real execution through PJRT -----------------------
        if let Some(rt) = coord.runtime {
            if !st.ran_real {
                st.ran_real = true;
                let dim = tz.input_dim;
                let input: Vec<f32> =
                    (0..dim).map(|i| (i as f32 * 0.13).cos()).collect();
                let comp_idx = st.comp.as_ref().unwrap_or(&comp).0.clone();
                let _ = rt.run_chain(coord.zoo, task, &comp_idx, 1, &input)?;
            }
        }

        let evs: Vec<RequestOutcome> = events
            .into_iter()
            .map(|e| e.expect("one outcome per query"))
            .collect();
        if self.server.opts.record_events {
            self.requests.extend(evs.iter().cloned());
        }
        Ok(evs)
    }

    /// Submit a whole stream in simulated-time order: at every step the
    /// task whose next query would issue earliest goes first. For open
    /// loops this follows arrival order; for closed loops (all arrivals
    /// at the stagger offset) it reproduces the paper's self-clocking
    /// round-robin. This is [`super::dispatch::Dispatcher::drive`] with
    /// the identity dispatch (one shared replay loop).
    pub fn drive(&mut self, queries: &[Query]) -> Result<()> {
        Dispatcher::new(Dispatch::none()).drive(self, queries)
    }

    /// Events recorded so far (submission order).
    pub fn events(&self) -> &[RequestOutcome] {
        &self.requests
    }

    /// Closed-loop sessions are self-clocking: backlog is zero by
    /// construction, so the dispatcher never batches them.
    pub(crate) fn is_self_clocked(&self) -> bool {
        self.self_clocked
    }

    /// Task iteration order (the scenario's task list).
    pub(crate) fn task_order(&self) -> &[String] {
        &self.tasks
    }

    /// When `task`'s previous query finishes (`None` for unknown tasks).
    pub(crate) fn ready_of(&self, task: &str) -> Option<f64> {
        self.states.get(task).map(|st| st.ready_ms)
    }

    /// Observed mean coalesced batch size for `task` (1.0 before any
    /// batch completed; `None` for unknown tasks).
    pub(crate) fn mean_batch_of(&self, task: &str) -> Option<f64> {
        self.states.get(task).map(|st| {
            if st.batches == 0 {
                1.0
            } else {
                st.completed as f64 / st.batches as f64
            }
        })
    }

    /// Memory-pool budget utilization (used/capacity) of this session's
    /// pool.
    pub fn pool_utilization(&self) -> f64 {
        let cap = self.prepared.pool.capacity();
        if cap == 0 {
            0.0
        } else {
            self.prepared.pool.used() as f64 / cap as f64
        }
    }

    /// Memory-pool capacity (bytes) of this session's pool.
    pub fn pool_capacity(&self) -> u64 {
        self.prepared.pool.capacity()
    }

    /// The committed placement order p⃗* this session serves partitioned
    /// tasks under (migrant re-selection is judged against it).
    pub(crate) fn planned_order(&self) -> &[Processor] {
        &self.prepared.order
    }

    /// Stamp subsequent trace events with the true fleet shard index.
    /// Sessions see themselves as shard 0 (their fault profile is
    /// re-indexed that way); the sharded drives know the real topology
    /// and call this right after opening each session.
    pub(crate) fn set_trace_shard(&mut self, shard: usize) {
        self.trace_shard = shard;
    }

    /// Raise `task`'s per-task FIFO floor: its next query here cannot
    /// issue before `ms`. The stealing drive calls this on every shard
    /// serving a task after each of its batches completes anywhere, so
    /// a task's queries stay FIFO-ordered across the shards serving it.
    pub(crate) fn raise_ready_floor(&mut self, task: &str, ms: f64) {
        if let Some(st) = self.states.get_mut(task) {
            if ms > st.ready_ms {
                st.ready_ms = ms;
            }
        }
    }

    /// Fault lab: lazily apply every crash window whose recovery point
    /// has passed by `now_ms`. The crash already dropped whatever was
    /// queued (the swallow rule in [`Session::submit_batch`]); rejoin
    /// raises every task's FIFO floor to the window end and, for a
    /// [`RejoinMode::Cold`] rejoin, wipes the pool so each task's next
    /// batch pays compile + load again, exactly like a planned cold
    /// start.
    fn process_rejoins(&mut self, now_ms: f64) {
        let coord = &self.server.coord;
        for i in 0..self.faults.crashes.len() {
            if self.rejoined[i] || now_ms < self.faults.crashes[i].end_ms {
                continue;
            }
            self.rejoined[i] = true;
            let w = self.faults.crashes[i].clone();
            for st in self.states.values_mut() {
                if st.ready_ms < w.end_ms {
                    st.ready_ms = w.end_ms;
                }
            }
            if w.rejoin == RejoinMode::Cold {
                let tasks = self.tasks.clone();
                for name in &tasks {
                    // The crash lost device memory: evict, then charge
                    // the task's live composition the full cold path.
                    for (id, _) in self.prepared.pool.task_blobs(name) {
                        self.prepared.pool.evict(&id);
                    }
                    let Some(st) = self.states.get_mut(name) else { continue };
                    let Some(comp) = st.comp.clone() else { continue };
                    let Ok(tz) = coord.zoo.task(name) else { continue };
                    let mut penalty = 0.0;
                    for (j, &vi) in comp.0.iter().enumerate() {
                        let id = BlobId::new(name, vi, j);
                        let bytes = tz.variants[vi].subgraphs[j].bytes;
                        let proc = st.order[j.min(st.order.len() - 1)];
                        penalty += coord.lm.compile_ms(bytes, proc)
                            + coord.lm.load_ms(bytes, proc);
                        self.cold_compiles += 1;
                        self.prepared.pool.make_room(bytes);
                        if self.prepared.pool.load(id.clone(), bytes) {
                            self.prepared.pool.set_active(&id, true);
                        }
                    }
                    st.pending_penalty_ms += penalty;
                    st.pending_cold_ms += penalty;
                }
            }
            self.pending_recovery.push(w.end_ms);
        }
    }

    /// Resident pool entries belonging to `task` (the warm-migration
    /// payload when the task is *copied* — stealing, where the source
    /// keeps serving it too).
    pub(crate) fn pool_task_blobs(&self, task: &str) -> Vec<(BlobId, u64)> {
        self.prepared.pool.task_blobs(task)
    }

    /// Remove and return `task`'s resident pool entries (the
    /// warm-migration payload when the task *leaves* this shard — its
    /// budget share frees up for the remaining tenants).
    pub(crate) fn take_task_blobs(&mut self, task: &str) -> Vec<(BlobId, u64)> {
        let blobs = self.prepared.pool.task_blobs(task);
        for (id, _) in &blobs {
            self.prepared.pool.evict(id);
        }
        blobs
    }

    /// Whether this session could serve `task` warm: it already serves
    /// it (adopted earlier), or its pool holds the complete blob set of
    /// at least one of the task's pure variants.
    pub(crate) fn has_warm_variant(&self, task: &str) -> bool {
        if self.states.contains_key(task) {
            return true;
        }
        let Some(p) = self.server.coord.profiles.get(task) else {
            return false;
        };
        (0..p.space.n_variants).any(|i| {
            let comp = p.space.composition(p.space.pure_index(i));
            comp.0.iter().enumerate().all(|(j, &vi)| {
                self.prepared.pool.contains(&BlobId::new(task, vi, j))
            })
        })
    }

    /// The stitched index this session currently serves `task` with
    /// (`None` for unknown tasks or before a composition commits).
    pub(crate) fn serving_index(&self, task: &str) -> Option<usize> {
        let st = self.states.get(task)?;
        let comp = st.comp.as_ref()?;
        let p = self.server.coord.profiles.get(task)?;
        Some(comp.to_index(p.space.n_variants))
    }

    /// Commit a synthesized (or cache-served) variant switch for
    /// `task` — the online-synthesis twin of the SLO-feedback switch,
    /// with identical booking mechanics: blobs of the new composition
    /// not already resident are charged a **load** against the task's
    /// next batch (evicting colder entries via `make_room`), accuracy
    /// is re-judged under the serve options, and the switch counter
    /// advances. Returns the booked penalty (ms).
    pub(crate) fn resynthesize_task(
        &mut self,
        task: &str,
        selection: crate::optimizer::Selection,
    ) -> Result<f64> {
        let coord = &self.server.coord;
        let opts = &self.server.opts;
        let Some(p) = coord.profiles.get(task) else {
            bail!("resynthesize: no profile for task {task:?}");
        };
        let tz = coord.zoo.task(task)?;
        let Some(st) = self.states.get_mut(task) else {
            bail!("resynthesize: session does not serve task {task:?}");
        };
        let new_comp = p.space.composition(selection.stitched_index);
        // Charge load for blobs not resident (the feedback-switch rule).
        let mut penalty = 0.0;
        for (j, &vi) in new_comp.0.iter().enumerate() {
            let id = BlobId::new(task, vi, j);
            if !self.prepared.pool.touch(&id) {
                let bytes = tz.variants[vi].subgraphs[j].bytes;
                penalty += coord.lm.load_ms(bytes, st.order[j]);
                self.prepared.pool.make_room(bytes);
                self.prepared.pool.load(id, bytes);
            }
        }
        st.pending_penalty_ms += penalty;
        st.pending_cold_ms += penalty;
        st.comp = Some(new_comp);
        st.accuracy =
            Some(coord.judged_accuracy(p, selection.stitched_index, opts));
        st.switches += 1;
        Ok(penalty)
    }

    /// Adopt a migrated (or stolen) task mid-session (the online path
    /// of `super::dispatch`): serve `task` from here on with `selection`
    /// (the planner's re-selection; best-effort pure fallback when
    /// `None`), never starting before `ready_floor_ms` — the source
    /// shard's last completion for the task, which preserves per-task
    /// FIFO order across the migration.
    ///
    /// `warm` is the warm-migration payload: the source shard's
    /// resident pool entries for the task. They are inserted into this
    /// shard's pool — charged against its budget, evicting cold entries
    /// via `make_room` if needed — and any blob of the adopted
    /// composition that arrived warm is charged a cross-shard **load**
    /// (never a compile) on the task's first query here. Blobs the
    /// composition needs that did not arrive warm pay the full cold
    /// compile+load, exactly like a planned cold start.
    pub(crate) fn adopt_task(
        &mut self,
        task: &str,
        slo: Slo,
        selection: Option<crate::optimizer::Selection>,
        ready_floor_ms: f64,
        link_ms: f64,
        warm: Option<Vec<(BlobId, u64)>>,
    ) -> Result<()> {
        if self.states.contains_key(task) {
            bail!("session already serves task {task:?}");
        }
        let coord = &self.server.coord;
        let opts = &self.server.opts;
        let Some(p) = coord.profiles.get(task) else {
            bail!("cannot adopt unknown task {task:?}");
        };
        let s = coord.zoo.subgraphs;
        let order: Vec<Processor> = if opts.policy.is_partitioned() {
            self.prepared.order.clone()
        } else {
            let np = baselines::np_task_processor(coord.profiles, &coord.lm.platform);
            vec![np[task]; s]
        };
        let coexec = if opts.policy.is_partitioned() {
            1.0
        } else {
            // The adopted task joins self.tasks.len() incumbents — and
            // the incumbents now contend with one more co-runner, so
            // their factors are refreshed too (the slowdown is mutual).
            let factor =
                1.0 + coord.lm.platform.coexec_slowdown * self.tasks.len() as f64;
            for st in self.states.values_mut() {
                st.coexec = factor;
            }
            factor
        };
        let planned = selection;
        let sel = planned.or_else(|| best_pure_selection(p, &order));
        let accuracy = match (planned, sel) {
            (Some(_), Some(sel)) => {
                Some(coord.judged_accuracy(p, sel.stitched_index, opts))
            }
            _ => None,
        };
        // The adopted composition's blob ids — known before any pool
        // motion so the warm transfer can prioritize them.
        let comp_ids: BTreeSet<BlobId> = sel
            .map(|sel| {
                p.space
                    .composition(sel.stitched_index)
                    .0
                    .iter()
                    .enumerate()
                    .map(|(j, &vi)| BlobId::new(task, vi, j))
                    .collect()
            })
            .unwrap_or_default();
        // Warm migration: the migrant's pool contents arrive with it,
        // charged against this shard's budget. Composition blobs go
        // first and may evict cold entries (`make_room`, then pinned
        // active); the rest land opportunistically — only if they fit
        // as-is, so extras can never evict what the first query needs.
        // `warm_set` remembers blobs that *actually transferred* so the
        // penalty loop below charges them a cross-shard load, not a
        // compile; payload blobs already resident here (a warm thief)
        // transfer nothing and stay free.
        let mut warm_set: BTreeSet<BlobId> = BTreeSet::new();
        if let Some(blobs) = warm {
            let (needed, extra): (Vec<_>, Vec<_>) = blobs
                .into_iter()
                .partition(|(id, _)| comp_ids.contains(id));
            for (id, bytes) in needed.into_iter().chain(extra) {
                let is_needed = comp_ids.contains(&id);
                if self.prepared.pool.contains(&id) {
                    if is_needed {
                        self.prepared.pool.set_active(&id, true);
                    }
                    continue;
                }
                if is_needed {
                    self.prepared.pool.make_room(bytes);
                }
                if self.prepared.pool.load(id.clone(), bytes) {
                    self.warm_loads += 1;
                    if is_needed {
                        self.prepared.pool.set_active(&id, true);
                    }
                    warm_set.insert(id);
                }
            }
        }
        // Charge the adopted composition's first-query penalty: a
        // cross-shard load for warm-transferred blobs, full cold
        // compile+load for everything else not resident.
        let mut penalty = 0.0;
        // Warm/cold shares of `penalty` — tracked alongside it (never
        // instead: the sum's addition order must stay bit-identical)
        // for the adopted task's first `TR-REQ-EXEC` decomposition.
        let mut warm_share = 0.0;
        let mut cold_share = 0.0;
        if let Some(sel) = &sel {
            let tz = coord.zoo.task(task)?;
            let comp = p.space.composition(sel.stitched_index);
            for (j, &vi) in comp.0.iter().enumerate() {
                let id = BlobId::new(task, vi, j);
                let bytes = tz.variants[vi].subgraphs[j].bytes;
                let proc = order[j.min(order.len() - 1)];
                if warm_set.contains(&id) {
                    self.prepared.pool.touch(&id);
                    penalty += coord.lm.load_ms(bytes, proc);
                    warm_share += coord.lm.load_ms(bytes, proc);
                } else if !self.prepared.pool.touch(&id) {
                    penalty += coord.lm.compile_ms(bytes, proc)
                        + coord.lm.load_ms(bytes, proc);
                    cold_share += coord.lm.compile_ms(bytes, proc)
                        + coord.lm.load_ms(bytes, proc);
                    self.cold_compiles += 1;
                    self.prepared.pool.make_room(bytes);
                    if self.prepared.pool.load(id.clone(), bytes) {
                        self.prepared.pool.set_active(&id, true);
                    }
                }
            }
        }
        self.tasks.push(task.to_string());
        self.slos.insert(task.to_string(), slo);
        self.states.insert(
            task.to_string(),
            TaskState {
                comp: sel.map(|sel| p.space.composition(sel.stitched_index)),
                accuracy,
                ready_ms: ready_floor_ms,
                pending_penalty_ms: penalty,
                pending_cold_ms: cold_share,
                pending_warm_ms: warm_share,
                pending_link_ms: link_ms,
                completed: 0,
                lat_sum: 0.0,
                lat_max: 0.0,
                queue_sum: 0.0,
                lat_sketch: QuantileSketch::default(),
                recent: VecDeque::with_capacity(FEEDBACK_WINDOW),
                switches: 0,
                dropped: 0,
                batches: 0,
                max_batch: 0,
                inflight: VecDeque::new(),
                ran_real: false,
                order,
                coexec,
                misses: 0,
                rate: RateForecaster::default(),
                backlog_trend: TrendTracker::default(),
            },
        );
        Ok(())
    }

    /// Variant switches performed so far (feedback rescheduling).
    pub fn switches(&self) -> usize {
        self.states.values().map(|st| st.switches).sum()
    }

    /// Close the session: judge every task against its SLO and return
    /// the report (per-task percentiles + the full event log), plus
    /// the per-task SLO forecast — the observed violation share scaled
    /// by each task's projected-over-trailing load factor (horizon
    /// from [`Admission::Predictive`] when in effect, else the default
    /// `DEFAULT_FORECAST_HORIZON_MS` of 500 ms).
    pub fn finish(mut self) -> RunReport {
        // Close out the trace: the session-open plan record and the
        // fault profile's crash windows as shard-level spans. Emitted
        // here — after the sharded drives stamped the true shard index —
        // so the events carry the fleet-level shard, then canonicalized
        // per session (stable time sort) before the shard-order merge.
        if self.tsink.enabled() {
            let planned_penalty_ms: f64 =
                self.prepared.switch_penalty_ms.values().sum();
            self.tsink.emit(TraceEvent::new(
                trace::TR_CTL_PLAN,
                self.trace_shard,
                "",
                None,
                0.0,
                0.0,
                &[
                    ("tasks", self.tasks.len() as f64),
                    ("penalty_ms", planned_penalty_ms),
                ],
            ));
            for w in &self.faults.crashes {
                let ev = TraceEvent::new(
                    trace::TR_CTL_CRASH,
                    self.trace_shard,
                    "",
                    None,
                    w.start_ms,
                    w.end_ms,
                    &[(
                        "rejoin_cold",
                        if w.rejoin == RejoinMode::Cold { 1.0 } else { 0.0 },
                    )],
                );
                self.tsink.emit(ev);
            }
        }
        let trace_events = trace::canonical(self.tsink.drain());
        let horizon_ms = match &self.admission {
            Admission::Predictive { horizon_ms, .. } => *horizon_ms,
            _ => DEFAULT_FORECAST_HORIZON_MS,
        };
        let now_ms = self.sim.horizon_ms;
        let mut slo_forecast = std::collections::BTreeMap::new();
        let mut outcomes = Vec::with_capacity(self.tasks.len());
        let mut total_queries = 0usize;
        let mut total_dropped = 0usize;
        let mut total_batches = 0usize;
        let mut slo_miss_count = 0usize;
        for name in &self.tasks {
            let st = &self.states[name];
            let slo = &self.slos[name];
            total_queries += st.completed;
            total_dropped += st.dropped;
            total_batches += st.batches;
            slo_miss_count += st.misses;
            if st.completed > 0 {
                let miss_rate = st.misses as f64 / st.completed as f64;
                slo_forecast.insert(
                    name.clone(),
                    forecast::project_violation_rate(
                        miss_rate,
                        st.rate.load_factor(now_ms, horizon_ms),
                    ),
                );
            }
            let n = st.completed as f64;
            outcomes.push(TaskOutcome {
                task: name.clone(),
                accuracy: st.accuracy,
                mean_latency_ms: if st.completed == 0 { 0.0 } else { st.lat_sum / n },
                max_latency_ms: st.lat_max,
                p50_latency_ms: st.lat_sketch.query(50.0),
                p95_latency_ms: st.lat_sketch.query(95.0),
                p99_latency_ms: st.lat_sketch.query(99.0),
                mean_queueing_ms: if st.completed == 0 { 0.0 } else { st.queue_sum / n },
                queries_completed: st.completed,
                queries_dropped: st.dropped,
                batches: st.batches,
                max_batch: st.max_batch,
                slo_accuracy: slo.min_accuracy,
                slo_misses: st.misses,
                slo_latency_ms: slo.max_latency_ms,
            });
        }
        // Fault lab accounting: downtime is the overlap of each crash
        // window with the realized horizon; throttle debt comes straight
        // off the SoC clock. All three are zero without a profile.
        let downtime_ms: f64 = self
            .faults
            .crashes
            .iter()
            .map(|w| (w.end_ms.min(self.sim.horizon_ms) - w.start_ms).max(0.0))
            .sum();
        RunReport {
            outcomes,
            makespan_ms: self.sim.horizon_ms,
            total_queries,
            total_dropped,
            total_batches,
            cold_compiles: self.cold_compiles,
            warm_loads: self.warm_loads,
            slo_forecast,
            slo_miss_count,
            record_events: self.server.opts.record_events,
            requests: self.requests,
            downtime_ms,
            throttled_ms: self.sim.throttled_ms(),
            recoveries: self.recoveries,
            trace: trace_events,
        }
    }
}

/// The best-effort fallback: minimum-latency *pure* variant supported
/// on `order` (used when planning found no feasible variant, and for
/// migrated tasks whose re-selection came back empty).
fn best_pure_selection(
    p: &TaskProfile,
    order: &[Processor],
) -> Option<crate::optimizer::Selection> {
    let mut best: Option<crate::optimizer::Selection> = None;
    for i in 0..p.space.n_variants {
        let k = p.space.pure_index(i);
        let comp = p.space.composition(k);
        if let Some(l) = p.latency_est(&comp, order) {
            if best.map(|b| l < b.latency_ms).unwrap_or(true) {
                best = Some(crate::optimizer::Selection {
                    stitched_index: k,
                    latency_ms: l,
                    accuracy: p.accuracy(k),
                });
            }
        }
    }
    best
}

fn dropped_event(q: &Query, backlog_ms: Option<f64>) -> RequestOutcome {
    RequestOutcome {
        id: q.id,
        task: q.task.clone(),
        arrival_ms: q.arrival_ms,
        start_ms: q.arrival_ms,
        finish_ms: q.arrival_ms,
        service_ms: 0.0,
        queueing_ms: backlog_ms.unwrap_or(0.0),
        dropped: true,
        slo_ok: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tests::{setup, slos};
    use crate::scenario::Scenario;

    fn tiny_tasks() -> Vec<String> {
        vec!["tiny".to_string()]
    }

    #[test]
    fn closed_loop_scenario_matches_legacy_report_shape() {
        // The legacy `Coordinator::serve` contract for one SLO config:
        // 100 queries served, positive throughput, zero violations
        // under a lax SLO — now expressed as a Scenario through Server.
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let s = slos(0.5, 1e9);
        let uni: Vec<Slo> = s.values().copied().collect();
        let sc = Scenario::closed_loop(&tiny_tasks(), s).with_universe(uni);
        let report = server.run(&sc).unwrap();
        assert_eq!(report.total_queries, 100);
        assert_eq!(report.outcomes.len(), 1);
        assert!(report.throughput_qps() > 0.0);
        assert_eq!(report.violation_rate(), 0.0);
        assert_eq!(report.total_dropped, 0);
        // Event log covers every query with ordered percentiles.
        assert_eq!(report.requests.len(), 100);
        let o = &report.outcomes[0];
        assert!(o.p50_latency_ms <= o.p95_latency_ms + 1e-12);
        assert!(o.p95_latency_ms <= o.p99_latency_ms + 1e-12);
        assert!(o.mean_queueing_ms >= 0.0);
    }

    #[test]
    fn impossible_slo_violates() {
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let sc = Scenario::closed_loop(&tiny_tasks(), slos(2.0, 1e9));
        let report = server.run(&sc).unwrap();
        assert_eq!(report.violation_rate(), 1.0);
    }

    #[test]
    fn smaller_budget_cannot_beat_full_budget() {
        let (zoo, lm, profiles) = setup();
        let s = slos(0.75, 50.0);
        let uni: Vec<Slo> = s.values().copied().collect();
        let sc = Scenario::closed_loop(&tiny_tasks(), s).with_universe(uni);
        let full = Server::builder(&zoo, &lm, &profiles)
            .memory_budget_frac(1.0)
            .build()
            .run(&sc)
            .unwrap();
        let tiny = Server::builder(&zoo, &lm, &profiles)
            .memory_budget_frac(0.05)
            .build()
            .run(&sc)
            .unwrap();
        assert!(tiny.violation_rate() >= full.violation_rate());
    }

    #[test]
    fn all_policies_serve_without_panic() {
        let (zoo, lm, profiles) = setup();
        let sc = Scenario::closed_loop(&tiny_tasks(), slos(0.6, 200.0));
        for policy in Policy::all() {
            let server = Server::builder(&zoo, &lm, &profiles).policy(policy).build();
            let r = server.run(&sc).unwrap();
            assert!(r.total_queries > 0, "{}", policy.name());
        }
    }

    #[test]
    fn poisson_open_loop_serves_and_reports_queueing() {
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        // ~40 qps against a ~18 ms service time: mild overload, queues
        // must form but everything is admitted.
        let sc = Scenario::poisson(&tiny_tasks(), slos(0.5, 1e9), 40.0, 3_000.0)
            .with_seed(5);
        let report = server.run(&sc).unwrap();
        assert!(report.total_queries > 50, "{}", report.total_queries);
        assert_eq!(report.total_dropped, 0);
        assert_eq!(report.requests.len(), report.total_queries);
        let o = &report.outcomes[0];
        assert!(o.mean_queueing_ms > 0.0, "open-loop overload must queue");
        // Arrivals are respected: no request starts before it arrives.
        assert!(report
            .requests
            .iter()
            .all(|r| r.start_ms >= r.arrival_ms - 1e-9));
    }

    #[test]
    fn admission_control_sheds_load_under_overload() {
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let slo = slos(0.5, 50.0);
        let heavy = Scenario::poisson(&tiny_tasks(), slo.clone(), 200.0, 2_000.0)
            .with_seed(7);
        let open = server.run(&heavy).unwrap();
        assert_eq!(open.total_dropped, 0);

        let capped = server
            .run(&heavy.clone().with_admission(Admission::QueueCap { max_queued: 4 }))
            .unwrap();
        assert!(capped.total_dropped > 0, "queue cap must shed load");
        assert!(capped.outcomes[0].mean_queueing_ms < open.outcomes[0].mean_queueing_ms);

        let deadline = server
            .run(&heavy.with_admission(Admission::Deadline { slack: 2.0 }))
            .unwrap();
        assert!(deadline.total_dropped > 0, "deadline admission must shed load");
        // Dropped + completed covers the whole arrival stream.
        assert_eq!(
            deadline.total_queries + deadline.total_dropped,
            deadline.requests.len()
        );
    }

    #[test]
    fn closed_loop_is_self_clocking_under_admission() {
        // A closed-loop query only exists when its predecessor finishes,
        // so admission control must never shed it and (with one task) no
        // queueing delay can accumulate.
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        for admission in [
            Admission::QueueCap { max_queued: 0 },
            Admission::Deadline { slack: 1.0 },
            Admission::Predictive { horizon_ms: 250.0, headroom: 1.0 },
        ] {
            let sc = Scenario::closed_loop(&tiny_tasks(), slos(0.5, 50.0))
                .with_admission(admission.clone());
            let r = server.run(&sc).unwrap();
            assert_eq!(r.total_dropped, 0, "{admission:?}: closed loop never queues");
            assert_eq!(r.total_queries, 100);
            assert!(r.outcomes[0].mean_queueing_ms < 1e-9, "{admission:?}");
        }
    }

    #[test]
    fn predictive_admission_bounds_queueing_and_forecasts() {
        // Sustained overload: predictive admission must shed, and every
        // query it does admit was admitted under the headroom budget —
        // with a single unbatched task, realized queueing equals the
        // backlog the admission decision saw, so no completed query can
        // have waited past headroom × bound. The report carries a
        // per-task SLO forecast in [0, 1].
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let heavy = Scenario::poisson(&tiny_tasks(), slos(0.5, 50.0), 200.0, 2_000.0)
            .with_seed(7);
        let headroom = 2.0;
        let pred = server
            .run(&heavy.clone().with_admission(Admission::Predictive {
                horizon_ms: 250.0,
                headroom,
            }))
            .unwrap();
        assert!(pred.total_dropped > 0, "overload must shed");
        assert_eq!(pred.total_queries + pred.total_dropped, pred.requests.len());
        let budget = headroom * 50.0;
        for r in pred.requests.iter().filter(|r| !r.dropped) {
            assert!(
                r.queueing_ms <= budget + 1e-6,
                "query {} admitted with queueing {} past the {budget} ms budget",
                r.id,
                r.queueing_ms
            );
        }
        assert!(!pred.slo_forecast.is_empty(), "report must carry the forecast");
        assert!(pred
            .slo_forecast
            .values()
            .all(|p| p.is_finite() && (0.0..=1.0).contains(p)));
    }

    #[test]
    fn drive_rejects_unknown_task_queries() {
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let sc = Scenario::trace(
            &tiny_tasks(),
            slos(0.5, 1e9),
            vec![crate::workload::Query {
                task: "ghost".into(),
                arrival_ms: 0.0,
                id: 0,
            }],
        );
        assert!(server.run(&sc).is_err(), "unknown-task trace must error");
        // submit() reports the same condition as an error, not a panic.
        let mut session = server.session(&sc, 0).unwrap();
        let q = crate::workload::Query { task: "ghost".into(), arrival_ms: 0.0, id: 1 };
        assert!(session.submit(&q).is_err());
    }

    #[test]
    fn scheduled_scenario_yields_one_report_per_phase() {
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles)
            .memory_budget_frac(0.2)
            .build();
        let sc = Scenario::closed_loop(&tiny_tasks(), slos(0.5, 1e9))
            .with_queries(25)
            .with_schedule(vec![slos(0.5, 1e9), slos(0.9, 30.0), slos(0.5, 1e9)]);
        let reports = server.run_schedule(&sc).unwrap();
        assert_eq!(reports.len(), 3);
        for r in &reports {
            assert_eq!(r.total_queries, 25);
        }
        // The merged view sums phases.
        let merged = server.run(&sc).unwrap();
        assert_eq!(merged.total_queries, 75);
        assert_eq!(merged.outcomes.len(), 3);
    }

    #[test]
    fn crash_window_drops_mid_window_arrivals_and_recovers() {
        use crate::scenario::{CrashWindow, FaultProfile, RejoinMode};
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let q = |id, t| crate::workload::Query { task: "tiny".into(), arrival_ms: t, id };
        let sc = Scenario::trace(
            &tiny_tasks(),
            slos(0.5, 1e9),
            vec![q(0, 0.0), q(1, 40.0), q(2, 120.0)],
        )
        .with_faults(FaultProfile {
            crashes: vec![CrashWindow {
                shard: 0,
                start_ms: 30.0,
                end_ms: 80.0,
                rejoin: RejoinMode::Cold,
            }],
            ..FaultProfile::default()
        });
        let r = server.run(&sc).unwrap();
        assert_eq!(r.total_dropped, 1, "the mid-window arrival dies with the shard");
        assert_eq!(r.total_queries, 2);
        assert!((r.downtime_ms - 50.0).abs() < 1e-9, "{}", r.downtime_ms);
        assert_eq!(r.recoveries.len(), 1, "one rejoin, one recovery sample");
        assert!(r.recoveries[0] > 0.0);
        assert!(r.cold_compiles > 0, "cold rejoin recompiles the pool");
        let post = r.requests.iter().find(|e| e.id == 2).unwrap();
        assert!(!post.dropped);
        assert!(post.start_ms >= 80.0, "service resumes at the window end");
    }

    #[test]
    fn degradation_ramp_stretches_service_latency() {
        use crate::scenario::{Degradation, FaultProfile};
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let sc = Scenario::closed_loop(&tiny_tasks(), slos(0.5, 1e9)).with_queries(20);
        let base = server.run(&sc).unwrap();
        let degraded = server
            .run(&sc.clone().with_faults(FaultProfile {
                degradations: vec![Degradation {
                    shard: 0,
                    start_ms: 0.0,
                    ramp_ms: 0.0,
                    factor: 2.0,
                }],
                ..FaultProfile::default()
            }))
            .unwrap();
        assert_eq!(degraded.total_queries, base.total_queries);
        assert_eq!(degraded.total_dropped, 0);
        // p50 dodges the one query carrying a switch penalty, so a flat
        // 2x ramp doubles it exactly.
        let b = base.outcomes[0].p50_latency_ms;
        let d = degraded.outcomes[0].p50_latency_ms;
        assert!((d - 2.0 * b).abs() < 1e-6, "flat 2x ramp must double p50: {b} vs {d}");
    }

    #[test]
    fn throttle_curve_surfaces_as_throttled_time() {
        use crate::scenario::{FaultProfile, ThrottleCurve, ThrottleStep};
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let sc = Scenario::closed_loop(&tiny_tasks(), slos(0.5, 1e9)).with_queries(10);
        let base = server.run(&sc).unwrap();
        assert_eq!(base.throttled_ms, 0.0);
        assert_eq!(base.downtime_ms, 0.0);
        assert!(base.recoveries.is_empty());
        let hot = server
            .run(&sc.clone().with_faults(FaultProfile {
                throttle: Some(ThrottleCurve {
                    steps: vec![ThrottleStep { busy_ms: 0.0, factor: 2.0 }],
                }),
                ..FaultProfile::default()
            }))
            .unwrap();
        assert!(hot.throttled_ms > 0.0, "a 2x governor must bank throttle debt");
        assert!(hot.makespan_ms > base.makespan_ms);
    }

    #[test]
    fn session_submit_emits_events() {
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let sc = Scenario::closed_loop(&tiny_tasks(), slos(0.5, 1e9)).with_queries(3);
        let mut session = server.session(&sc, 0).unwrap();
        for q in sc.stream(0) {
            let ev = session.submit(&q).unwrap();
            assert_eq!(ev.task, "tiny");
            assert!(!ev.dropped);
            assert!(ev.finish_ms >= ev.start_ms);
            assert_eq!(ev.slo_ok, Some(true));
        }
        assert_eq!(session.events().len(), 3);
        let report = session.finish();
        assert_eq!(report.total_queries, 3);
    }
}
