# Build-path entry points. The only Python step is the artifact export;
# everything else is `cargo` (see scripts/ci.sh for the tiered gates).

.PHONY: artifacts ci check bench backlog

# Export the L1/L2 model-zoo artifacts the Rust serving system consumes
# (manifest, HLO text, weight blobs, probe/eval tensors, oracles).
artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts

# Both CI tiers: tier 1 (build + test) then tier 2 (benches, rustdoc,
# clippy, fmt, and the hermetic CLI smoke stage).
ci:
	scripts/ci.sh

# Tier 1 only — the fast inner-loop gate (build + test).
check:
	CI_TIER=1 scripts/ci.sh

# The `exp backlog` study with all arms — static / replan / steal /
# steal+warm / predictive — plus the estimated-vs-true arrival-rate
# telemetry table and the per-task SLO forecast.
# Artifact-free: falls back to the synthetic fixture zoo.
backlog:
	cargo bench --bench dispatch_backlog

# All benchmarks: the backlog study plus the Algorithm 1 microbench.
bench: backlog
	cargo bench --bench planner_cost
