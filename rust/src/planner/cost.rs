//! The planner's explicit cost model.
//!
//! Every latency the planner evaluates flows through a [`CostModel`],
//! which scales the paper's additive Eq. 5 estimate by a per-task batch
//! service factor. At the default (batch-1) hints the model is exactly
//! the paper's estimator; with hints from the dispatcher's observed
//! `mean_batch_size` (or the scenario's `Dispatch::max_batch` operating
//! point) Algorithm 1 plans for the occupancy the serving engine will
//! actually book via `LatencyModel::subgraph_batch_ms`.

use std::collections::BTreeMap;

use crate::profiler::TaskProfile;
use crate::soc::{LatencyModel, Processor};
use crate::stitching::Composition;

/// Batch-aware latency evaluation for planning.
///
/// The factor for a task with expected mean batch size `b` is
/// `1 + batch_marginal · (b − 1)` — the continuous extension of
/// `LatencyModel::batch_factor` (identical at integer `b`, and exactly
/// 1.0 at `b = 1`, so the unit model reproduces batch-1 planning
/// bit-for-bit).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// `Platform::batch_marginal` — 0.0 for the unit (batch-1) model.
    batch_marginal: f64,
    /// Expected mean batch size for tasks without a per-task hint.
    default_hint: f64,
    /// Per-task expected mean batch sizes (observed `mean_batch_size`).
    hints: BTreeMap<String, f64>,
}

impl Default for CostModel {
    fn default() -> Self {
        Self { batch_marginal: 0.0, default_hint: 1.0, hints: BTreeMap::new() }
    }
}

impl CostModel {
    /// The identity model: every latency is the plain Eq. 5 estimate
    /// (the paper's batch-1 planning).
    pub fn unit() -> Self {
        Self::default()
    }

    /// Batch-aware model for a platform: `default_hint` is the expected
    /// mean coalesced batch size (clamped to ≥ 1).
    pub fn batch_aware(lm: &LatencyModel, default_hint: f64) -> Self {
        Self {
            batch_marginal: lm.platform.batch_marginal,
            default_hint: default_hint.max(1.0),
            hints: BTreeMap::new(),
        }
    }

    /// Override the expected batch size for one task.
    pub fn with_hint(mut self, task: &str, mean_batch: f64) -> Self {
        self.hints.insert(task.to_string(), mean_batch.max(1.0));
        self
    }

    /// Merge per-task hints (observed mean batch sizes).
    pub fn with_hints(mut self, hints: BTreeMap<String, f64>) -> Self {
        for (task, mean_batch) in hints {
            self.hints.insert(task, mean_batch.max(1.0));
        }
        self
    }

    /// Expected mean batch size for `task` (≥ 1).
    pub fn hint_for(&self, task: &str) -> f64 {
        self.hints
            .get(task)
            .copied()
            .unwrap_or(self.default_hint)
            .max(1.0)
    }

    /// Batch service factor for `task` (1.0 at batch 1).
    pub fn batch_factor(&self, task: &str) -> f64 {
        1.0 + self.batch_marginal * (self.hint_for(task) - 1.0)
    }

    /// Batch-aware Eq. 5 for a composition, via
    /// `TaskProfile::latency_est_batch`. (The hot-loop odometer walk in
    /// `planner::algo` instead folds the factor into its latency
    /// *bound* once per task — same arithmetic, no per-candidate
    /// multiply.)
    pub fn latency(
        &self,
        p: &TaskProfile,
        comp: &Composition,
        order: &[Processor],
    ) -> Option<f64> {
        p.latency_est_batch(comp, order, self.batch_factor(&p.task))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixtures;

    #[test]
    fn unit_model_is_identity() {
        let (_zoo, _lm, profiles) = fixtures::tiny();
        let p = &profiles["tiny"];
        let cost = CostModel::unit();
        assert_eq!(cost.batch_factor("tiny"), 1.0);
        use Processor::*;
        let comp = Composition(vec![0, 0]);
        let order = [Cpu, Gpu];
        assert_eq!(cost.latency(p, &comp, &order), p.latency_est(&comp, &order));
    }

    #[test]
    fn batch_factor_matches_latency_model_at_integers() {
        let (_zoo, lm, profiles) = fixtures::tiny();
        let p = &profiles["tiny"];
        let cost = CostModel::batch_aware(&lm, 4.0);
        assert!((cost.batch_factor("tiny") - lm.batch_factor(4)).abs() < 1e-12);
        use Processor::*;
        let comp = Composition(vec![0, 0]);
        let order = [Cpu, Gpu];
        let base = p.latency_est(&comp, &order).unwrap();
        let batched = cost.latency(p, &comp, &order).unwrap();
        assert!((batched - base * lm.batch_factor(4)).abs() < 1e-9);
    }

    #[test]
    fn hints_override_default_and_clamp_to_one() {
        let (_zoo, lm, _profiles) = fixtures::tiny();
        let cost = CostModel::batch_aware(&lm, 2.0)
            .with_hint("hot", 6.0)
            .with_hint("degenerate", 0.0);
        assert!(cost.batch_factor("hot") > cost.batch_factor("other"));
        assert_eq!(cost.hint_for("degenerate"), 1.0);
        assert_eq!(cost.batch_factor("degenerate"), 1.0);
        let merged = CostModel::unit()
            .with_hints(BTreeMap::from([("a".to_string(), 3.0)]));
        assert_eq!(merged.hint_for("a"), 3.0);
        assert_eq!(merged.hint_for("b"), 1.0);
    }
}
