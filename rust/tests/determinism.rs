//! Determinism guard: the same scenario (including its JSON on-disk
//! form) with the same seed must produce a bit-identical `RunReport`
//! across runs. The forecasting layer (PR 5) sits on every serving
//! path, so this pins it — and every future estimator — to virtual
//! time only: no wall clock, no ambient randomness, no map-iteration
//! nondeterminism may leak into a report.
//!
//! Runs entirely on the synthetic fixture zoo (no artifacts needed).

use sparseloom::coordinator::ServeOpts;
use sparseloom::fixtures;
use sparseloom::metrics::{RunReport, ShardedReport};
use sparseloom::scenario::{
    Admission, Dispatch, PlannerConfig, Scenario, Server, ShardedServer, Sharding,
};

/// Bit-exact report equality: counts, per-request timeline, and the
/// forecast map (f64s compared through `to_bits` — "close" is not
/// deterministic, identical is).
fn assert_identical(a: &RunReport, b: &RunReport) {
    assert_eq!(a.total_queries, b.total_queries);
    assert_eq!(a.total_dropped, b.total_dropped);
    assert_eq!(a.total_batches, b.total_batches);
    assert_eq!(a.cold_compiles, b.cold_compiles);
    assert_eq!(a.warm_loads, b.warm_loads);
    assert_eq!(a.makespan_ms.to_bits(), b.makespan_ms.to_bits());
    assert_eq!(a.requests.len(), b.requests.len());
    for (x, y) in a.requests.iter().zip(&b.requests) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.task, y.task);
        assert_eq!(x.dropped, y.dropped);
        assert_eq!(x.slo_ok, y.slo_ok);
        assert_eq!(x.arrival_ms.to_bits(), y.arrival_ms.to_bits(), "query {}", x.id);
        assert_eq!(x.start_ms.to_bits(), y.start_ms.to_bits(), "query {}", x.id);
        assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits(), "query {}", x.id);
        assert_eq!(x.service_ms.to_bits(), y.service_ms.to_bits(), "query {}", x.id);
        assert_eq!(x.queueing_ms.to_bits(), y.queueing_ms.to_bits(), "query {}", x.id);
    }
    assert_eq!(a.slo_forecast.len(), b.slo_forecast.len());
    for ((ta, pa), (tb, pb)) in a.slo_forecast.iter().zip(&b.slo_forecast) {
        assert_eq!(ta, tb);
        assert_eq!(pa.to_bits(), pb.to_bits(), "forecast for {ta}");
    }
}

fn json_round_trip(sc: &Scenario) -> Scenario {
    let text = sc.to_json().to_string_pretty();
    Scenario::from_json(&sparseloom::json::parse(&text).unwrap()).unwrap()
}

#[test]
fn sharded_online_predictive_run_is_deterministic() {
    // The maximal moving-parts configuration: bursty arrivals, batching,
    // sharding, predictive admission, and the full forecast-triggered
    // online stack (replan + steal + warm migration).
    let (zoo, lm, profiles) = fixtures::quartet();
    let tasks = fixtures::task_names(&zoo);
    let slos = fixtures::slos(&zoo, 0.5, 60.0);
    let sc = Scenario::bursty(&tasks, slos, 4.0, 100.0, 500.0, 3_000.0)
        .with_seed(11)
        .with_admission(Admission::Predictive { horizon_ms: 100.0, headroom: 2.0 })
        .with_dispatch(Dispatch::batched(4))
        .with_sharding(Sharding::hash(2))
        .with_planner(PlannerConfig { max_migrations: 2, ..PlannerConfig::predictive() });

    let run = |s: &Scenario| -> ShardedReport {
        let opts = ServeOpts { batch_hint: 4.0, ..Default::default() };
        ShardedServer::build(&zoo, &lm, &profiles, opts, s.sharding.clone())
            .unwrap()
            .run(s)
            .unwrap()
    };
    let a = run(&sc);
    let b = run(&sc);
    let c = run(&json_round_trip(&sc));

    for other in [&b, &c] {
        assert_eq!(a.replans, other.replans);
        assert_eq!(a.migrations, other.migrations);
        assert_eq!(a.steals, other.steals);
        assert_identical(&a.aggregate, &other.aggregate);
        assert_eq!(a.per_shard.len(), other.per_shard.len());
        for (x, y) in a.per_shard.iter().zip(&other.per_shard) {
            assert_identical(x, y);
        }
        assert_eq!(a.arrival_est_qps.len(), other.arrival_est_qps.len());
        for ((ta, qa), (tb, qb)) in
            a.arrival_est_qps.iter().zip(&other.arrival_est_qps)
        {
            assert_eq!(ta, tb);
            assert_eq!(qa.to_bits(), qb.to_bits(), "rate estimate for {ta}");
        }
    }
}

#[test]
fn single_server_predictive_run_is_deterministic() {
    let (zoo, lm, profiles) = fixtures::trio();
    let tasks = fixtures::task_names(&zoo);
    let sc = Scenario::poisson(&tasks, fixtures::slos(&zoo, 0.5, 50.0), 60.0, 2_500.0)
        .with_seed(7)
        .with_admission(Admission::Predictive { horizon_ms: 250.0, headroom: 1.5 })
        .with_dispatch(Dispatch::batched(4));
    let server = Server::builder(&zoo, &lm, &profiles).build();
    let a = server.run(&sc).unwrap();
    let b = server.run(&sc).unwrap();
    let c = Server::builder(&zoo, &lm, &profiles)
        .build()
        .run(&json_round_trip(&sc))
        .unwrap();
    assert_identical(&a, &b);
    assert_identical(&a, &c);
    assert!(a.total_queries > 0, "the run must actually serve something");
}
