//! Quickstart: load the artifact zoo, run one stitched variant through
//! the real PJRT runtime, and let the optimizer pick variants + a
//! placement order for a mid-grid SLO.
//!
//! Run after `make artifacts && cargo build --release`:
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use std::collections::BTreeMap;

use sparseloom::baselines::Policy;
use sparseloom::experiments::Ctx;
use sparseloom::profiler::ProfilerConfig;
use sparseloom::runtime::Runtime;
use sparseloom::scenario::{Scenario, Server};
use sparseloom::soc::{order_label, Platform};
use sparseloom::stitching::Composition;
use sparseloom::workload::{slo_grid, TaskRanges};

fn main() -> anyhow::Result<()> {
    // --- 1. artifacts + platform model --------------------------------
    let ctx = Ctx::load("artifacts", false)?;
    let platform = Platform::desktop();
    let lm = ctx.lm(platform.clone());
    println!("zoo: {} tasks × {} variants × {} subgraphs",
             ctx.zoo.tasks.len(), ctx.zoo.n_variants(), ctx.zoo.subgraphs);

    // --- 2. run one stitched variant through PJRT (when available) -----
    let rt = match Runtime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            println!("\n(skipping real PJRT execution: {e:#})");
            None
        }
    };
    if let Some(rt) = &rt {
        let task = "imgcls";
        let tz = ctx.zoo.task(task)?;
        // dense → int8 → struct50: one subgraph per compression family.
        let comp = Composition(vec![
            tz.variant_by_name("dense").unwrap().0,
            tz.variant_by_name("int8").unwrap().0,
            tz.variant_by_name("struct50").unwrap().0,
        ]);
        let input: Vec<f32> = (0..tz.input_dim).map(|i| (i as f32 * 0.1).sin()).collect();
        let (logits, timing) = rt.run_chain(&ctx.zoo, task, &comp.0, 1, &input)?;
        println!(
            "\nstitched {} on {task}: logits {:?}",
            comp.name(tz),
            &logits.iter().map(|x| (x * 100.0).round() / 100.0).collect::<Vec<_>>()
        );
        println!("real PJRT stage times: {:?} ms (total {:.3} ms)",
                 timing.stage_ms.iter().map(|x| (x * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
                 timing.total_ms);
    }

    // --- 3. profile + optimize for a mid-grid SLO ----------------------
    let profiles = ctx.profiles(&lm, &ProfilerConfig::default())?;
    let mut slos = BTreeMap::new();
    let mut universe = Vec::new();
    for (name, tz) in &ctx.zoo.tasks {
        let grid = slo_grid(&TaskRanges::measure(tz, &lm));
        universe.extend(grid.iter().copied());
        slos.insert(name.clone(), grid[12]);
    }
    let mut builder = Server::builder(&ctx.zoo, &lm, &profiles).policy(Policy::SparseLoom);
    if let Some(rt) = &rt {
        builder = builder.runtime(rt);
    }
    let server = builder.build();
    let tasks: Vec<String> = profiles.keys().cloned().collect();
    let scenario = Scenario::closed_loop(&tasks, slos.clone())
        .with_queries(50)
        .with_universe(universe.clone());
    let report = server.run(&scenario)?;

    println!("\nSparseLoom plan on {}:", platform.name);
    let prepared = server.prepare(&slos, &universe)?;
    println!("  placement order p* = {}", order_label(&prepared.order));
    for (name, sel) in &prepared.selections {
        if let Some(sel) = sel {
            let p = &profiles[name];
            println!(
                "  {:<10} → {} (est. acc {:.3}, est. lat {:.3} ms)",
                name,
                p.space.composition(sel.stitched_index).name(ctx.zoo.task(name)?),
                sel.accuracy,
                sel.latency_ms,
            );
        } else {
            println!("  {:<10} → no feasible variant (will violate)", name);
        }
    }
    println!(
        "\nserved {} queries: violation rate {:.1} %, throughput {:.0} q/s",
        report.total_queries,
        100.0 * report.violation_rate(),
        report.throughput_qps(),
    );
    Ok(())
}
