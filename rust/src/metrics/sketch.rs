//! Greenwald–Khanna streaming quantile sketch (GK01) — the constant-
//! memory percentile estimator behind streaming-mode `TaskOutcome`
//! latency stats.
//!
//! # Guarantee
//!
//! For a stream of `n` finite values and error parameter `ε`, a query
//! for rank `r` returns a value whose true rank lies in
//! `[r − εn, r + εn]`. The sketch maintains a sorted list of tuples
//! `(vᵢ, gᵢ, Δᵢ)` where `gᵢ` is the gap between the minimum rank of
//! `vᵢ` and of `vᵢ₋₁`, and `Δᵢ` bounds the rank uncertainty of `vᵢ`:
//!
//! * `rmin(i) = Σ_{j≤i} gⱼ` and `rmax(i) = rmin(i) + Δᵢ` bracket the
//!   true rank of `vᵢ`;
//! * the **GK invariant** `gᵢ + Δᵢ ≤ max(1, ⌊2εn⌋)` holds after every
//!   insert and compress, so consecutive tuples bracket every possible
//!   rank with a gap of at most `⌊2εn⌋` — which is exactly what makes
//!   the `εn` query bound provable (Greenwald & Khanna, SIGMOD '01,
//!   Proposition 1).
//!
//! Inserts place a tuple `(v, 1, ⌊2εn⌋ − 1)` at its sorted position
//! (`Δ = 0` at either end, keeping the minimum and maximum exact) and
//! a periodic compress pass merges adjacent tuples whose combined span
//! still fits the invariant, bounding live tuples at
//! `O((1/ε)·log(εn))`.
//!
//! # Merging
//!
//! [`QuantileSketch::merge`] concatenates two tuple lists in value
//! order and sums the counts. Absolute rank errors add under this
//! merge: a sketch with error `ε·n₁` merged with one of error `ε·n₂`
//! answers queries within `ε·(n₁+n₂)` of the true rank, so one level
//! of shard → aggregate (or phase → report) folding preserves the
//! bound without re-compressing. Merge does **not** compress (which
//! would add another `⌊2εn⌋` of slack); fleet-scale fan-in is a few
//! dozen sketches, so the size cost is negligible.
//!
//! # Determinism
//!
//! No randomness anywhere: identical insert sequences produce
//! identical tuple lists, and [`QuantileSketch::merge`] breaks value
//! ties in favor of `self`, so merging per-shard sketches in stable
//! shard-index order is reproducible bit-for-bit. Non-finite inserts
//! are ignored (never poison a percentile with NaN), and querying an
//! empty sketch returns 0.0 — the same convention as
//! [`crate::util::stats::percentile`].
//!
//! ```
//! use sparseloom::metrics::sketch::QuantileSketch;
//!
//! let mut sk = QuantileSketch::new(0.01);
//! for i in 0..10_000 {
//!     sk.insert(i as f64);
//! }
//! let p50 = sk.query(50.0);
//! assert!((p50 - 5_000.0).abs() <= 0.01 * 10_000.0 + 1.0, "{p50}");
//! ```

/// One GK tuple: a sample value `v` covering `g` ranks with rank
/// uncertainty `delta`.
#[derive(Clone, Copy, Debug)]
struct Tuple {
    v: f64,
    g: u64,
    delta: u64,
}

/// Streaming quantile sketch with a proven `εn` rank-error bound. See
/// the module docs for the invariant and merge semantics.
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    eps: f64,
    n: u64,
    tuples: Vec<Tuple>,
    /// Inserts between compress passes (`⌈1/(2ε)⌉`).
    period: u64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new(DEFAULT_EPS)
    }
}

/// Default rank-error parameter: p50/p99 within 1 % of the true rank.
pub const DEFAULT_EPS: f64 = 0.01;

impl QuantileSketch {
    /// A sketch answering rank queries within `±eps·n`. `eps` is
    /// clamped into `[1e-4, 0.5]`.
    pub fn new(eps: f64) -> QuantileSketch {
        let eps = if eps.is_finite() { eps.clamp(1e-4, 0.5) } else { DEFAULT_EPS };
        QuantileSketch {
            eps,
            n: 0,
            tuples: Vec::new(),
            period: (1.0 / (2.0 * eps)).ceil() as u64,
        }
    }

    /// Observed stream length (finite values only).
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Live tuples — the sketch's memory footprint, `O((1/ε)·log(εn))`.
    pub fn tuple_count(&self) -> usize {
        self.tuples.len()
    }

    /// The error parameter queries are answered under.
    pub fn eps(&self) -> f64 {
        self.eps
    }

    /// `max(1, ⌊2εn⌋)` — the invariant's per-tuple span budget.
    fn cap(&self) -> u64 {
        ((2.0 * self.eps * self.n as f64).floor() as u64).max(1)
    }

    /// Insert one value. Non-finite values are ignored.
    pub fn insert(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.n += 1;
        // First index whose value exceeds v — insertion keeps the list
        // sorted and puts equal values after their existing run (ties
        // resolve deterministically).
        let pos = self.tuples.partition_point(|t| t.v <= v);
        let delta = if pos == 0 || pos == self.tuples.len() {
            // New minimum or maximum: its rank is exact.
            0
        } else {
            self.cap() - 1
        };
        self.tuples.insert(pos, Tuple { v, g: 1, delta });
        if self.n % self.period == 0 {
            self.compress();
        }
    }

    /// Merge adjacent tuples whose combined span still satisfies the
    /// invariant. The first and last tuples are never merged away, so
    /// the observed minimum and maximum stay exact.
    fn compress(&mut self) {
        if self.tuples.len() < 3 {
            return;
        }
        let cap = self.cap();
        let mut i = self.tuples.len() - 2;
        while i >= 1 {
            let merged = self.tuples[i].g + self.tuples[i + 1].g + self.tuples[i + 1].delta;
            if merged <= cap {
                self.tuples[i + 1].g += self.tuples[i].g;
                self.tuples.remove(i);
            }
            i -= 1;
        }
    }

    /// The value at percentile `q` (0–100): a value whose rank is
    /// within `±εn` of `⌈q/100·n⌉`. 0.0 on an empty sketch.
    pub fn query(&self, q: f64) -> f64 {
        if self.n == 0 || self.tuples.is_empty() {
            return 0.0;
        }
        let q = if q.is_finite() { q.clamp(0.0, 100.0) } else { 50.0 };
        let rank = ((q / 100.0) * self.n as f64).ceil().max(1.0).min(self.n as f64);
        let target = rank + self.eps * self.n as f64;
        // Return the first tuple i whose successor would overshoot
        // `rank + εn` (GK01 §3: then rmax(i) ≤ rank + εn, and the
        // invariant gives rmin(i) ≥ rank − εn).
        let mut rmin: u64 = 0;
        for i in 0..self.tuples.len() - 1 {
            rmin += self.tuples[i].g;
            let next = &self.tuples[i + 1];
            if (rmin + next.g + next.delta) as f64 > target {
                return self.tuples[i].v;
            }
        }
        self.tuples[self.tuples.len() - 1].v
    }

    /// Exact observed minimum (`None` on an empty sketch).
    pub fn min(&self) -> Option<f64> {
        self.tuples.first().map(|t| t.v)
    }

    /// Exact observed maximum (`None` on an empty sketch).
    pub fn max(&self) -> Option<f64> {
        self.tuples.last().map(|t| t.v)
    }

    /// Fold `other` into `self`: tuple lists interleave in value order
    /// (ties keep `self`'s tuples first), counts sum, and the error
    /// parameter takes the looser of the two. Absolute rank errors add,
    /// so the merged sketch answers within `ε·(n₁+n₂)`.
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let mut merged = Vec::with_capacity(self.tuples.len() + other.tuples.len());
        let (mut a, mut b) = (self.tuples.iter().peekable(), other.tuples.iter().peekable());
        loop {
            match (a.peek(), b.peek()) {
                (Some(x), Some(y)) => {
                    if x.v <= y.v {
                        merged.push(**x);
                        a.next();
                    } else {
                        merged.push(**y);
                        b.next();
                    }
                }
                (Some(x), None) => {
                    merged.push(**x);
                    a.next();
                }
                (None, Some(y)) => {
                    merged.push(**y);
                    b.next();
                }
                (None, None) => break,
            }
        }
        self.tuples = merged;
        self.n += other.n;
        self.eps = self.eps.max(other.eps);
        self.period = (1.0 / (2.0 * self.eps)).ceil() as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;
    use crate::util::Rng;

    /// True-rank window check: the sketch's answer for percentile `q`
    /// must lie between the exact order statistics `±⌈εn⌉` around the
    /// queried rank.
    fn assert_within_rank_error(sorted: &[f64], sk: &QuantileSketch, q: f64) {
        let n = sorted.len();
        assert_eq!(sk.count() as usize, n);
        let got = sk.query(q);
        assert!(got.is_finite(), "sketch must never return NaN (q={q})");
        let rank = ((q / 100.0) * n as f64).ceil().max(1.0) as usize;
        let slack = (sk.eps() * n as f64).ceil() as usize + 1;
        let lo = sorted[rank.saturating_sub(slack + 1).min(n - 1)];
        let hi = sorted[(rank + slack - 1).min(n - 1)];
        assert!(
            (lo..=hi).contains(&got),
            "q={q}: {got} outside rank-error window [{lo}, {hi}] (n={n})"
        );
    }

    fn check_stream(values: Vec<f64>) {
        let mut sk = QuantileSketch::new(0.01);
        for &v in &values {
            sk.insert(v);
        }
        let mut sorted = values;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [1.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            assert_within_rank_error(&sorted, &sk, q);
        }
        // Exact extremes survive compression.
        assert_eq!(sk.min().unwrap(), sorted[0]);
        assert_eq!(sk.max().unwrap(), *sorted.last().unwrap());
    }

    #[test]
    fn accurate_on_random_streams() {
        let mut rng = Rng::new(42);
        for n in [100usize, 1_000, 20_000] {
            let values: Vec<f64> =
                (0..n).map(|_| 1.0 + 99.0 * rng.f64()).collect();
            check_stream(values);
        }
    }

    #[test]
    fn accurate_on_adversarial_streams() {
        // Sorted ascending: the worst case for naive reservoir schemes.
        check_stream((0..10_000).map(|i| i as f64).collect());
        // Sorted descending: every insert lands at the front.
        check_stream((0..10_000).rev().map(|i| i as f64).collect());
        // Heavy ties: only 3 distinct values.
        check_stream((0..9_000).map(|i| (i % 3) as f64).collect());
        // Sawtooth with outliers.
        check_stream(
            (0..12_000)
                .map(|i| if i % 997 == 0 { 1e6 } else { (i % 50) as f64 })
                .collect(),
        );
    }

    #[test]
    fn memory_stays_sublinear() {
        let mut sk = QuantileSketch::new(0.01);
        for i in 0..200_000 {
            sk.insert((i % 1_000) as f64);
        }
        // ε = 0.01 ⇒ a couple hundred tuples suffice for 200k inserts;
        // the bound is O((1/ε)·log(εn)) but assert a generous absolute
        // ceiling so a compress regression (linear growth) fails loudly.
        assert!(
            sk.tuple_count() < 2_000,
            "sketch grew to {} tuples over 200k inserts",
            sk.tuple_count()
        );
    }

    #[test]
    fn non_finite_inserts_are_ignored_and_empty_queries_are_zero() {
        let mut sk = QuantileSketch::new(0.01);
        assert_eq!(sk.query(50.0), 0.0);
        sk.insert(f64::NAN);
        sk.insert(f64::INFINITY);
        sk.insert(f64::NEG_INFINITY);
        assert_eq!(sk.count(), 0);
        assert_eq!(sk.query(99.0), 0.0);
        sk.insert(7.0);
        assert_eq!(sk.query(0.0), 7.0);
        assert_eq!(sk.query(100.0), 7.0);
        assert!(sk.query(f64::NAN).is_finite(), "NaN query must not poison");
    }

    #[test]
    fn queries_are_monotone_in_q() {
        let mut rng = Rng::new(7);
        let mut sk = QuantileSketch::new(0.02);
        for _ in 0..5_000 {
            sk.insert(rng.f64() * 1_000.0);
        }
        let mut last = f64::NEG_INFINITY;
        for q in 0..=100 {
            let v = sk.query(q as f64);
            assert!(v >= last, "p{q} = {v} < p{} = {last}", q - 1);
            last = v;
        }
    }

    #[test]
    fn merge_preserves_the_rank_error_bound() {
        let mut rng = Rng::new(3);
        let mut all = Vec::new();
        let mut merged = QuantileSketch::new(0.01);
        // 4 shards with different distributions, merged in index order.
        for shard in 0..4 {
            let mut sk = QuantileSketch::new(0.01);
            for _ in 0..5_000 {
                let v = (shard + 1) as f64 * 10.0 + rng.f64() * 25.0;
                sk.insert(v);
                all.push(v);
            }
            merged.merge(&sk);
        }
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [1.0, 50.0, 99.0] {
            assert_within_rank_error(&all, &merged, q);
        }
        // Merging an empty sketch is a no-op, merging into empty clones.
        let snapshot = merged.query(50.0);
        merged.merge(&QuantileSketch::new(0.01));
        assert_eq!(merged.query(50.0).to_bits(), snapshot.to_bits());
        let mut fresh = QuantileSketch::new(0.01);
        fresh.merge(&merged);
        assert_eq!(fresh.count(), merged.count());
    }

    #[test]
    fn deterministic_across_identical_runs() {
        let build = || {
            let mut rng = Rng::new(11);
            let mut sk = QuantileSketch::new(0.01);
            for _ in 0..10_000 {
                sk.insert(rng.f64() * 123.0);
            }
            sk
        };
        let (a, b) = (build(), build());
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(a.query(q).to_bits(), b.query(q).to_bits());
        }
        assert_eq!(a.tuple_count(), b.tuple_count());
    }

    #[test]
    fn tracks_exact_percentiles_closely_on_small_streams() {
        // Below 1/(2ε) inserts nothing has been compressed: every value
        // is retained and queries are exact order statistics.
        let mut sk = QuantileSketch::new(0.01);
        let values = [5.0, 1.0, 9.0, 3.0, 7.0];
        for v in values {
            sk.insert(v);
        }
        assert_eq!(sk.query(0.0), 1.0);
        assert_eq!(sk.query(100.0), 9.0);
        let p50 = sk.query(50.0);
        let exact = stats::median(&values);
        assert!((p50 - exact).abs() <= 2.0, "{p50} vs exact {exact}");
    }
}
