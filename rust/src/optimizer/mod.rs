//! Sparsity-Aware Optimizer (paper §3.3, Algorithm 1) — legacy façade.
//!
//! The algorithm itself lives in `crate::planner::algo` (batch-aware,
//! pruned, with an explicit `CostModel`); this module keeps the plan
//! *types* plus thin deprecated shims of the original free functions at
//! the unit (batch-1) cost model, so external callers keep compiling.
//! The Algorithm 1 math notes moved to DESIGN.md §"Algorithm 1".

use std::collections::BTreeMap;

use crate::planner::{algo, CostModel};
use crate::profiler::TaskProfile;
use crate::soc::Processor;
use crate::stitching::Composition;
use crate::workload::Slo;

/// The filtered candidate set Θᵗ for one task.
#[derive(Clone, Debug, Default)]
pub struct CandidateSet {
    /// Stitched indices satisfying the SLO (accuracy via the estimator,
    /// latency achievable under at least one order in Ω).
    pub indices: Vec<usize>,
}

impl CandidateSet {
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }
}

/// Step 1 of Alg. 1: compute Θᵗ.
#[deprecated(
    note = "use planner::algo::feasible_set with a CostModel (pruned, batch-aware)"
)]
pub fn feasible_set(
    profile: &TaskProfile,
    slo: &Slo,
    orders: &[Vec<Processor>],
) -> CandidateSet {
    algo::feasible_set(&CostModel::unit(), profile, slo, orders)
}

/// The optimizer's decision for a whole SLO configuration.
#[derive(Clone, Debug)]
pub struct Plan {
    /// p⃗* — the global placement order.
    pub order: Vec<Processor>,
    /// Per task: chosen stitched index and its estimated latency, or
    /// `None` when Θᵗ was empty (an unavoidable SLO violation).
    pub selections: BTreeMap<String, Option<Selection>>,
    /// L(p⃗*) — mean best latency across tasks (selected ones).
    pub mean_latency_ms: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct Selection {
    pub stitched_index: usize,
    pub latency_ms: f64,
    pub accuracy: f64,
}

impl Plan {
    pub fn composition_for(&self, profile: &TaskProfile) -> Option<Composition> {
        self.selections
            .get(&profile.task)
            .and_then(|s| s.as_ref())
            .map(|s| profile.space.composition(s.stitched_index))
    }

    /// Number of tasks with no feasible variant.
    pub fn infeasible_tasks(&self) -> usize {
        self.selections.values().filter(|s| s.is_none()).count()
    }
}

/// Algorithm 1, complete: joint placement-order + variant selection.
///
/// `profiles` and `slos` are keyed by task name; `orders` is Ω.
/// Planning is SLO-driven: profiles without an SLO entry are left
/// unplanned (historically this indexed `slos` by every profile and
/// panicked on shard-filtered SLO maps).
#[deprecated(note = "use planner::algo::optimize with a CostModel (batch-aware)")]
pub fn optimize(
    profiles: &BTreeMap<String, TaskProfile>,
    slos: &BTreeMap<String, Slo>,
    orders: &[Vec<Processor>],
) -> Plan {
    algo::optimize(&CostModel::unit(), profiles, slos, orders)
}

/// Restricted optimizer used by the no-stitching baselines: only pure
/// compositions are considered (classic adaptive-variant selection).
#[deprecated(note = "use planner::algo::optimize_pure_only with a CostModel")]
pub fn optimize_pure_only(
    profiles: &BTreeMap<String, TaskProfile>,
    slos: &BTreeMap<String, Slo>,
    orders: &[Vec<Processor>],
) -> Plan {
    algo::optimize_pure_only(&CostModel::unit(), profiles, slos, orders)
}

// The shim tests double as behavioral pins for the canonical
// `planner::algo` implementation the shims delegate to.
#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::profiler::{profile_task, ProfilerConfig};
    use crate::soc::latency::tests::tiny_taskzoo;
    use crate::soc::{BaseLatencies, LatencyModel, Platform};
    use crate::stitching::StitchSpace;
    use crate::zoo::KernelPath;
    use Processor::*;

    fn setup() -> BTreeMap<String, TaskProfile> {
        let tz = tiny_taskzoo();
        let mut b = BaseLatencies::new();
        for sg in 0..2 {
            b.set("tiny", sg, KernelPath::Dense, 10.0);
            b.set("tiny", sg, KernelPath::BlockSparse, 8.0);
        }
        let lm = LatencyModel::new(Platform::desktop(), b);
        let space = StitchSpace::for_task(&tz);
        let oracle: Vec<f64> = space
            .iter()
            .map(|c| c.0.iter().map(|&i| tz.variants[i].accuracy).sum::<f64>() / 2.0)
            .collect();
        let cfg = ProfilerConfig {
            train_samples: 4,
            gbdt: crate::gbdt::GbdtParams {
                n_trees: 200,
                max_depth: 3,
                eta: 0.2,
                min_leaf: 1,
                subsample: 1.0,
                seed: 1,
            },
            seed: 23,
        };
        let p = profile_task(&tz, &lm, &oracle, &cfg, true);
        BTreeMap::from([("tiny".to_string(), p)])
    }

    fn orders2() -> Vec<Vec<Processor>> {
        vec![vec![Cpu, Gpu], vec![Gpu, Cpu], vec![Gpu, Npu], vec![Npu, Gpu]]
    }

    #[test]
    fn feasible_set_respects_both_constraints() {
        let profiles = setup();
        let p = &profiles["tiny"];
        let lax = Slo { min_accuracy: 0.0, max_latency_ms: 1e9 };
        assert_eq!(feasible_set(p, &lax, &orders2()).len(), p.space.len());
        let impossible = Slo { min_accuracy: 2.0, max_latency_ms: 1e9 };
        assert!(feasible_set(p, &impossible, &orders2()).is_empty());
        let tight_lat = Slo { min_accuracy: 0.0, max_latency_ms: 0.0001 };
        assert!(feasible_set(p, &tight_lat, &orders2()).is_empty());
    }

    #[test]
    fn optimizer_picks_feasible_and_order_in_omega() {
        let profiles = setup();
        let slos = BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.6, max_latency_ms: 100.0 },
        )]);
        let orders = orders2();
        let plan = optimize(&profiles, &slos, &orders);
        assert!(orders.contains(&plan.order));
        let sel = plan.selections["tiny"].expect("feasible");
        assert!(sel.accuracy >= 0.6);
        assert!(sel.latency_ms <= 100.0);
        assert_eq!(plan.infeasible_tasks(), 0);
    }

    #[test]
    fn optimizer_reports_infeasible() {
        let profiles = setup();
        let slos = BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.99, max_latency_ms: 0.001 },
        )]);
        let plan = optimize(&profiles, &slos, &orders2());
        assert_eq!(plan.infeasible_tasks(), 1);
    }

    #[test]
    fn chosen_variant_is_latency_minimal_under_order() {
        let profiles = setup();
        let slos = BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.0, max_latency_ms: 1e9 },
        )]);
        let plan = optimize(&profiles, &slos, &orders2());
        let p = &profiles["tiny"];
        let sel = plan.selections["tiny"].unwrap();
        for k in 0..p.space.len() {
            if let Some(l) = p.latency_est(&p.space.composition(k), &plan.order) {
                assert!(sel.latency_ms <= l + 1e-12);
            }
        }
    }

    #[test]
    fn pure_only_selects_pure() {
        let profiles = setup();
        let slos = BTreeMap::from([(
            "tiny".to_string(),
            Slo { min_accuracy: 0.5, max_latency_ms: 1e9 },
        )]);
        let plan = optimize_pure_only(&profiles, &slos, &orders2());
        let p = &profiles["tiny"];
        let sel = plan.selections["tiny"].unwrap();
        assert!(p.space.composition(sel.stitched_index).is_pure());
    }

    #[test]
    fn stitching_beats_pure_under_tight_slo() {
        // The paper's core claim (Fig. 3): stitched variants satisfy
        // SLOs that pure variants cannot. Construct an SLO between the
        // pure variants' (acc, lat) points.
        let profiles = setup();
        let p = &profiles["tiny"];
        // accuracy above struct50's 0.7 but latency below what pure
        // dense can reach on the fastest order:
        let pure_dense_lat = {
            let comp = p.space.composition(p.space.pure_index(0));
            orders2()
                .iter()
                .filter_map(|o| p.latency_est(&comp, o))
                .fold(f64::INFINITY, f64::min)
        };
        let slo = Slo { min_accuracy: 0.75, max_latency_ms: pure_dense_lat * 0.98 };
        let slos = BTreeMap::from([("tiny".to_string(), slo)]);
        let stitched = optimize(&profiles, &slos, &orders2());
        let pure = optimize_pure_only(&profiles, &slos, &orders2());
        assert!(pure.infeasible_tasks() >= stitched.infeasible_tasks());
    }
}
