//! sparselint integration: the lint → serve contract.
//!
//! Three properties, all on the synthetic fixture zoo (no artifacts):
//!
//! 1. Any generated `Scenario` that round-trips JSON and passes
//!    `lint_scenario` with no errors serves without panicking (and
//!    without an engine error — the session gate is a strict subset of
//!    the lint, so a clean lint means a clean open).
//! 2. A corrupted-scenario corpus — structural corruptions applied to a
//!    clean scenario, plus byte-level mutations of its JSON text —
//!    always yields diagnostics (or a typed load error), never a panic.
//! 3. A real run's event stream satisfies every `SL-INV-*` invariant
//!    (the `serve --verify` path), and the fail-fast gates reject the
//!    configurations the analyzer calls errors.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};

use sparseloom::analysis::{invariants, lint_scenario};
use sparseloom::coordinator::ServeOpts;
use sparseloom::fixtures;
use sparseloom::propcheck::{check, choice, usize_in, vec_of};
use sparseloom::scenario::{
    Admission, CrashWindow, Degradation, Dispatch, Expect, FaultProfile, LinkMatrix,
    PlannerConfig, RejoinMode, Scenario, Server, ShardAssignment, ShardedServer, Sharding,
    ThrottleCurve, ThrottleStep,
};
use sparseloom::workload::Query;

fn round_trip(sc: &Scenario) -> Scenario {
    let text = sc.to_json().to_string_pretty();
    Scenario::from_json(&sparseloom::json::parse(&text).unwrap()).unwrap()
}

/// Decode an 8-digit parameter vector into a scenario over the trio
/// fixture. Intentionally spans footgun territory (max_batch 0, shard
/// counts above the task count, every admission kind, every planner
/// flag combination) — the property filters on the lint verdict.
fn scenario_from(params: &[usize], tasks: &[String]) -> Scenario {
    let slos = |acc: f64, lat: f64| {
        tasks
            .iter()
            .map(|t| {
                (t.clone(), sparseloom::workload::Slo { min_accuracy: acc, max_latency_ms: lat })
            })
            .collect::<BTreeMap<_, _>>()
    };
    let base = match params[0] % 3 {
        0 => Scenario::closed_loop(tasks, slos(0.5, 1e9))
            .with_queries(params[1] % 5)
            .with_stagger_ms(params[2] as f64 * 0.5),
        1 => Scenario::poisson(
            tasks,
            slos(0.5, 1e9),
            (params[1] + 1) as f64 * 2.0,
            300.0,
        ),
        _ => Scenario::bursty(
            tasks,
            slos(0.5, 60.0),
            params[1] as f64,
            (params[2] + 1) as f64 * 10.0,
            100.0,
            400.0,
        ),
    };
    let admission = match params[3] % 5 {
        0 => Admission::Always,
        1 => Admission::QueueCap { max_queued: params[4] },
        2 => Admission::Deadline { slack: params[4] as f64 * 0.5 + 0.5 },
        3 => Admission::Fair { slack: 2.0, weights: BTreeMap::new() },
        _ => Admission::Predictive { horizon_ms: 50.0, headroom: 1.5 },
    };
    let flags = params[7];
    base.with_admission(admission)
        .with_dispatch(Dispatch { max_batch: params[5] % 4, min_queue: params[6] % 3 })
        .with_sharding(Sharding::hash(params[4] % 3 + 1))
        .with_planner(PlannerConfig {
            batch_aware: flags & 1 != 0,
            replan: flags & 2 != 0,
            steal: flags & 4 != 0,
            warm_migrate: flags & 8 != 0,
            predictive: flags & 16 != 0,
            ..PlannerConfig::default()
        })
        .with_seed(params[0] as u64)
}

/// Decode a parameter vector into a fault profile. Mostly well-formed
/// by construction (sorted throttle steps, symmetric links, positive
/// factors) but shard indices deliberately range past a 2-shard
/// deployment so the gate path gets exercised too.
fn fault_profile_from(params: &[usize]) -> FaultProfile {
    let mut fp = FaultProfile::default();
    for i in 0..params[0] % 3 {
        let start = ((params[1] + i * 7) % 40) as f64 * 10.0;
        fp.crashes.push(CrashWindow {
            shard: (params[2] + i) % 3,
            start_ms: start,
            end_ms: start + 20.0 + (params[3] % 5) as f64 * 30.0,
            rejoin: if (params[4] + i) % 2 == 0 { RejoinMode::Cold } else { RejoinMode::Warm },
        });
    }
    if params[5] % 2 == 0 {
        fp.degradations.push(Degradation {
            shard: params[5] % 3,
            start_ms: (params[6] % 10) as f64 * 25.0,
            ramp_ms: (params[7] % 4) as f64 * 100.0,
            factor: 1.0 + (params[6] % 6) as f64 * 0.25,
        });
    }
    if params[6] % 3 == 0 {
        fp.throttle = Some(ThrottleCurve {
            steps: (0..1 + params[7] % 3)
                .map(|i| ThrottleStep {
                    busy_ms: (i as f64 + 1.0) * 50.0,
                    factor: 1.0 + (i as f64 + 1.0) * 0.25,
                })
                .collect(),
        });
    }
    if params[7] % 2 == 0 {
        let c = (params[0] % 5) as f64;
        fp.links = Some(LinkMatrix { transfer_ms: vec![vec![0.0, c], vec![c, 0.0]] });
    }
    match params[3] % 3 {
        0 => fp.expects.push(Expect::MinCompleted { task: None, at_least: params[0] }),
        1 => fp.expects.push(Expect::MaxViolationRate { at_most: 0.5 }),
        _ => fp.expects.push(Expect::RecoveryWithin { shard: params[2] % 3, ms: 250.0 }),
    }
    fp
}

#[test]
fn generated_fault_profiles_round_trip_json() {
    let (zoo, _lm, _profiles) = fixtures::trio();
    let tasks = fixtures::task_names(&zoo);
    let gen = vec_of(usize_in(0, 9), 8);
    check("fault profiles round-trip JSON", &gen, 80, 13, |params| {
        let fp = fault_profile_from(params);
        // Standalone profile round trip.
        let text = fp.to_json().to_string_pretty();
        let v = sparseloom::json::parse(&text)
            .map_err(|e| format!("profile JSON does not re-parse: {e:#}"))?;
        let back = FaultProfile::from_json(&v)
            .map_err(|e| format!("profile JSON does not re-load: {e:#}"))?;
        if back != fp {
            return Err(format!("profile changed across round trip: {fp:?} vs {back:?}"));
        }
        // And embedded in a scenario.
        let sc = Scenario::closed_loop(&tasks, fixtures::slos(&zoo, 0.5, 1e9))
            .with_sharding(Sharding::hash(2))
            .with_faults(fp.clone());
        if round_trip(&sc).faults != fp {
            return Err("scenario embedding dropped fault fields".to_string());
        }
        Ok(())
    });
}

#[test]
fn generated_fault_scenarios_never_panic_the_server() {
    let (zoo, lm, profiles) = fixtures::trio();
    let tasks = fixtures::task_names(&zoo);
    let gen = vec_of(usize_in(0, 9), 8);
    check("fault scenarios never panic", &gen, 40, 99, |params| {
        let sc = Scenario::poisson(&tasks, {
            tasks
                .iter()
                .map(|t| {
                    (
                        t.clone(),
                        sparseloom::workload::Slo { min_accuracy: 0.5, max_latency_ms: 60.0 },
                    )
                })
                .collect::<BTreeMap<_, _>>()
        }, 30.0, 400.0)
            .with_seed(params[0] as u64)
            .with_dispatch(Dispatch::batched(2))
            .with_sharding(Sharding::hash(2))
            .with_planner(PlannerConfig::online())
            .with_faults(fault_profile_from(params));
        // Profiles naming shard 2 of 2 must be *refused* (typed error),
        // valid ones must run — neither may panic.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            ShardedServer::build(&zoo, &lm, &profiles, ServeOpts::default(), sc.sharding.clone())
                .and_then(|s| s.run(&sc))
                .map(|_| ())
        }));
        match outcome {
            Err(_) => Err(format!("serving panicked on a generated fault scenario: {params:?}")),
            Ok(_) => Ok(()),
        }
    });
}

#[test]
fn lint_clean_round_tripped_scenarios_serve_without_panicking() {
    let (zoo, lm, profiles) = fixtures::trio();
    let tasks = fixtures::task_names(&zoo);
    let gen = vec_of(usize_in(0, 11), 8);
    check("lint-clean scenarios serve", &gen, 60, 42, |params| {
        let sc = round_trip(&scenario_from(params, &tasks));
        if lint_scenario(&sc).has_errors() {
            return Ok(()); // the property only covers lint-clean inputs
        }
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if sc.sharding.shards > 1 {
                ShardedServer::build(&zoo, &lm, &profiles, ServeOpts::default(), sc.sharding.clone())
                    .and_then(|s| s.run(&sc))
                    .map(|_| ())
            } else {
                Server::builder(&zoo, &lm, &profiles).build().run(&sc).map(|_| ())
            }
        }));
        match outcome {
            Err(_) => Err(format!("serving panicked on a lint-clean scenario: {params:?}")),
            Ok(Err(e)) => Err(format!("lint-clean scenario rejected at serve time: {e:#}")),
            Ok(Ok(())) => Ok(()),
        }
    });
}

/// Structural corruptions of a clean scenario. Every one of these must
/// surface as diagnostics from the analyzer — and serving the corrupted
/// scenario must fail with an error (or run degraded), never panic.
#[test]
fn corrupted_corpus_yields_diagnostics_never_panics() {
    let (zoo, lm, profiles) = fixtures::trio();
    let tasks = fixtures::task_names(&zoo);
    let clean = Scenario::closed_loop(
        &tasks,
        fixtures::slos(&zoo, 0.5, 1e9),
    )
    .with_queries(3);
    assert!(lint_scenario(&clean).is_empty(), "baseline must be clean");

    let corruptions: Vec<(&str, fn(&mut Scenario))> = vec![
        ("duplicate task", |sc| {
            let t = sc.tasks[0].clone();
            sc.tasks.push(t);
        }),
        ("empty task list", |sc| sc.tasks.clear()),
        ("empty schedule", |sc| sc.schedule.clear()),
        ("missing phase SLO", |sc| {
            let t = sc.tasks[0].clone();
            sc.schedule[0].remove(&t);
        }),
        ("NaN SLO bound", |sc| {
            let t = sc.tasks[0].clone();
            sc.schedule[0].get_mut(&t).unwrap().min_accuracy = f64::NAN;
        }),
        ("universe misses a served SLO", |sc| {
            sc.universe =
                vec![sparseloom::workload::Slo { min_accuracy: 0.9, max_latency_ms: 1.0 }];
        }),
        ("negative trace arrival", |sc| {
            let t = sc.tasks[0].clone();
            sc.arrival = sparseloom::scenario::Arrival::Trace(vec![Query {
                task: t,
                arrival_ms: -5.0,
                id: 0,
            }]);
        }),
        ("trace targets unknown task", |sc| {
            sc.arrival = sparseloom::scenario::Arrival::Trace(vec![Query {
                task: "ghost".into(),
                arrival_ms: 1.0,
                id: 0,
            }]);
        }),
        ("nonpositive admission slack", |sc| {
            sc.admission = Admission::Deadline { slack: 0.0 };
        }),
        ("sharding map ghost task", |sc| {
            sc.sharding =
                Sharding::explicit(BTreeMap::from([("ghost".to_string(), 0)]), 2);
        }),
        ("sharding map out of range", |sc| {
            let t = sc.tasks[0].clone();
            sc.sharding = Sharding::explicit(BTreeMap::from([(t, 9)]), 2);
        }),
        ("predictive planner without horizon", |sc| {
            sc.planner = PlannerConfig { horizon_ms: 0.0, ..PlannerConfig::predictive() };
            sc.sharding = Sharding::hash(2);
        }),
        ("online planner with zero slack", |sc| {
            sc.planner =
                PlannerConfig { saturation_slack: 0.0, ..PlannerConfig::replanning() };
            sc.sharding = Sharding::hash(2);
        }),
        ("empty crash window", |sc| {
            sc.faults.crashes.push(CrashWindow {
                shard: 0,
                start_ms: 50.0,
                end_ms: 50.0,
                rejoin: RejoinMode::Cold,
            });
        }),
        ("crash window on ghost shard", |sc| {
            sc.faults.crashes.push(CrashWindow {
                shard: 7,
                start_ms: 0.0,
                end_ms: 10.0,
                rejoin: RejoinMode::Warm,
            });
        }),
        ("nonpositive throttle factor", |sc| {
            sc.faults.throttle = Some(ThrottleCurve {
                steps: vec![ThrottleStep { busy_ms: 0.0, factor: -1.0 }],
            });
        }),
        ("asymmetric link matrix with a self-loop", |sc| {
            sc.sharding = Sharding::hash(2);
            sc.faults.links = Some(LinkMatrix {
                transfer_ms: vec![vec![0.0, 1.0], vec![2.0, 3.0]],
            });
        }),
    ];

    for (what, corrupt) in &corruptions {
        let mut sc = clean.clone();
        corrupt(&mut sc);
        let report = lint_scenario(&sc);
        assert!(!report.is_empty(), "{what}: the analyzer must say something");
        let ran = catch_unwind(AssertUnwindSafe(|| {
            if sc.sharding.shards > 1 {
                ShardedServer::build(&zoo, &lm, &profiles, ServeOpts::default(), sc.sharding.clone())
                    .and_then(|s| s.run(&sc))
                    .map(|_| ())
            } else {
                Server::builder(&zoo, &lm, &profiles).build().run(&sc).map(|_| ())
            }
        }));
        assert!(ran.is_ok(), "{what}: serving a corrupted scenario must not panic");
        if report.has_errors() && !matches!(sc.sharding.assignment, ShardAssignment::Hash) {
            // Build-gate errors reject before any session opens.
            assert!(ran.unwrap().is_err(), "{what}: the build gate must refuse");
        }
    }
}

/// Byte-level corruption: truncation or character substitution anywhere
/// in the JSON text loads as a typed error or a (lintable) scenario —
/// the loader and analyzer never panic on garbage.
#[test]
fn mutated_json_text_never_panics() {
    let (zoo, _lm, _profiles) = fixtures::trio();
    let clean = Scenario::closed_loop(&fixtures::task_names(&zoo), fixtures::slos(&zoo, 0.5, 1e9));
    let text = clean.to_json().to_string_pretty();
    let len = text.len();
    let gen = vec_of(usize_in(0, len - 1), 2);
    let junk = choice(vec!['}', '"', ':', 'x', '-']);
    let junk_pool: Vec<char> = {
        let mut rng = sparseloom::util::Rng::new(9);
        (0..64).map(|_| junk.sample(&mut rng)).collect()
    };
    check("mutated scenario JSON loads or errors", &gen, 120, 7, |pos| {
        let (cut, sub) = (pos[0], pos[1]);
        let truncated: String = text.chars().take(cut).collect();
        let mut swapped: Vec<char> = text.chars().collect();
        swapped[sub] = junk_pool[(cut + sub) % junk_pool.len()];
        let swapped: String = swapped.into_iter().collect();
        for candidate in [truncated, swapped] {
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                sparseloom::json::parse(&candidate)
                    .ok()
                    .and_then(|v| Scenario::from_json(&v).ok())
                    .map(|sc| lint_scenario(&sc).summary())
            }));
            if outcome.is_err() {
                return Err(format!("panic on mutated JSON (cut {cut}, sub {sub})"));
            }
        }
        Ok(())
    });
}

#[test]
fn real_runs_pass_the_invariant_verifier() {
    // Single server, closed loop (the `serve --verify` default path).
    let (zoo, lm, profiles) = fixtures::trio();
    let sc = Scenario::closed_loop(&fixtures::task_names(&zoo), fixtures::slos(&zoo, 0.5, 1e9))
        .with_queries(20);
    let report = Server::builder(&zoo, &lm, &profiles).build().run(&sc).unwrap();
    let inv = invariants::verify_report(&report);
    assert!(inv.is_empty(), "{}", inv.render_text());

    // The maximal sharded online configuration under backlog.
    let (zoo, lm, profiles) = fixtures::quartet();
    let sc = Scenario::bursty(
        &fixtures::task_names(&zoo),
        fixtures::slos(&zoo, 0.5, 60.0),
        4.0,
        100.0,
        500.0,
        3_000.0,
    )
    .with_seed(11)
    .with_admission(Admission::Predictive { horizon_ms: 100.0, headroom: 2.0 })
    .with_dispatch(Dispatch::batched(4))
    .with_sharding(Sharding::hash(2))
    .with_planner(PlannerConfig { max_migrations: 2, ..PlannerConfig::predictive() });
    let opts = ServeOpts { batch_hint: 4.0, ..Default::default() };
    let report = ShardedServer::build(&zoo, &lm, &profiles, opts, sc.sharding.clone())
        .unwrap()
        .run(&sc)
        .unwrap();
    let inv = invariants::verify_sharded(&report);
    assert!(inv.is_empty(), "{}", inv.render_text());
}

#[test]
fn fail_fast_gates_reject_what_the_analyzer_rejects() {
    let (zoo, lm, profiles) = fixtures::trio();
    let tasks = fixtures::task_names(&zoo);

    // Session gate: a duplicated task is refused with its reason code.
    let mut dup = Scenario::closed_loop(&tasks, fixtures::slos(&zoo, 0.5, 1e9));
    dup.tasks.push(tasks[0].clone());
    let err = Server::builder(&zoo, &lm, &profiles)
        .build()
        .run(&dup)
        .unwrap_err()
        .to_string();
    assert!(err.contains("SL-SCN-002"), "{err}");

    // Build gate: an out-of-range explicit map is refused at build.
    let bad = Sharding::explicit(BTreeMap::from([(tasks[0].clone(), 9)]), 2);
    let err = ShardedServer::build(&zoo, &lm, &profiles, ServeOpts::default(), bad)
        .unwrap_err()
        .to_string();
    assert!(err.contains("SL-SCN-009"), "{err}");

    // Run gate: a fault profile naming a shard the deployment does not
    // have is refused before any session opens.
    let ghost = Scenario::closed_loop(&tasks, fixtures::slos(&zoo, 0.5, 1e9))
        .with_sharding(Sharding::hash(2))
        .with_faults(FaultProfile {
            crashes: vec![CrashWindow {
                shard: 9,
                start_ms: 0.0,
                end_ms: 10.0,
                rejoin: RejoinMode::Cold,
            }],
            ..FaultProfile::default()
        });
    let err = ShardedServer::build(&zoo, &lm, &profiles, ServeOpts::default(), ghost.sharding.clone())
        .unwrap()
        .run(&ghost)
        .unwrap_err()
        .to_string();
    assert!(err.contains("SL-SCN-017"), "{err}");

    // Example scenario files shipped in-repo stay lint-clean (what the
    // CI tier-2 `sparseloom lint` stage enforces, minus the zoo probe).
    for file in [
        "closed_loop.json",
        "bursty_sharded.json",
        "predictive_phases.json",
        "crash_recover.json",
        "thermal_throttle.json",
    ] {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/scenarios/");
        let sc = Scenario::load(format!("{path}{file}")).unwrap();
        let r = lint_scenario(&sc);
        assert!(!r.has_errors(), "{file}:\n{}", r.render_text());
    }
}
