//! Determinism guard: the same scenario (including its JSON on-disk
//! form) with the same seed must produce a bit-identical `RunReport`
//! across runs. The forecasting layer (PR 5) sits on every serving
//! path, so this pins it — and every future estimator — to virtual
//! time only: no wall clock, no ambient randomness, no map-iteration
//! nondeterminism may leak into a report.
//!
//! Runs entirely on the synthetic fixture zoo (no artifacts needed).

use std::collections::BTreeMap;

use sparseloom::coordinator::ServeOpts;
use sparseloom::fixtures;
use sparseloom::metrics::{RunReport, ShardedReport};
use sparseloom::profiler::TaskProfile;
use sparseloom::scenario::{
    Admission, CrashWindow, Degradation, Dispatch, Expect, FaultProfile, LinkMatrix,
    PlannerConfig, RejoinMode, Scenario, Server, ShardedServer, Sharding, ThrottleCurve,
    ThrottleStep,
};
use sparseloom::soc::{LatencyModel, Processor};
use sparseloom::trace;
use sparseloom::zoo::Zoo;

/// Bit-exact report equality: counts, per-request timeline, and the
/// forecast map (f64s compared through `to_bits` — "close" is not
/// deterministic, identical is).
fn assert_identical(a: &RunReport, b: &RunReport) {
    assert_eq!(a.total_queries, b.total_queries);
    assert_eq!(a.total_dropped, b.total_dropped);
    assert_eq!(a.total_batches, b.total_batches);
    assert_eq!(a.cold_compiles, b.cold_compiles);
    assert_eq!(a.warm_loads, b.warm_loads);
    assert_eq!(a.makespan_ms.to_bits(), b.makespan_ms.to_bits());
    assert_eq!(a.requests.len(), b.requests.len());
    for (x, y) in a.requests.iter().zip(&b.requests) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.task, y.task);
        assert_eq!(x.dropped, y.dropped);
        assert_eq!(x.slo_ok, y.slo_ok);
        assert_eq!(x.arrival_ms.to_bits(), y.arrival_ms.to_bits(), "query {}", x.id);
        assert_eq!(x.start_ms.to_bits(), y.start_ms.to_bits(), "query {}", x.id);
        assert_eq!(x.finish_ms.to_bits(), y.finish_ms.to_bits(), "query {}", x.id);
        assert_eq!(x.service_ms.to_bits(), y.service_ms.to_bits(), "query {}", x.id);
        assert_eq!(x.queueing_ms.to_bits(), y.queueing_ms.to_bits(), "query {}", x.id);
    }
    assert_eq!(a.slo_forecast.len(), b.slo_forecast.len());
    for ((ta, pa), (tb, pb)) in a.slo_forecast.iter().zip(&b.slo_forecast) {
        assert_eq!(ta, tb);
        assert_eq!(pa.to_bits(), pb.to_bits(), "forecast for {ta}");
    }
    assert_eq!(a.downtime_ms.to_bits(), b.downtime_ms.to_bits());
    assert_eq!(a.throttled_ms.to_bits(), b.throttled_ms.to_bits());
    assert_eq!(a.recoveries.len(), b.recoveries.len());
    for (x, y) in a.recoveries.iter().zip(&b.recoveries) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

fn json_round_trip(sc: &Scenario) -> Scenario {
    let text = sc.to_json().to_string_pretty();
    Scenario::from_json(&sparseloom::json::parse(&text).unwrap()).unwrap()
}

#[test]
fn sharded_online_predictive_run_is_deterministic() {
    // The maximal moving-parts configuration: bursty arrivals, batching,
    // sharding, predictive admission, and the full forecast-triggered
    // online stack (replan + steal + warm migration).
    let (zoo, lm, profiles) = fixtures::quartet();
    let tasks = fixtures::task_names(&zoo);
    let slos = fixtures::slos(&zoo, 0.5, 60.0);
    let sc = Scenario::bursty(&tasks, slos, 4.0, 100.0, 500.0, 3_000.0)
        .with_seed(11)
        .with_admission(Admission::Predictive { horizon_ms: 100.0, headroom: 2.0 })
        .with_dispatch(Dispatch::batched(4))
        .with_sharding(Sharding::hash(2))
        .with_planner(PlannerConfig { max_migrations: 2, ..PlannerConfig::predictive() });

    let run = |s: &Scenario| -> ShardedReport {
        let opts = ServeOpts { batch_hint: 4.0, ..Default::default() };
        ShardedServer::build(&zoo, &lm, &profiles, opts, s.sharding.clone())
            .unwrap()
            .run(s)
            .unwrap()
    };
    let a = run(&sc);
    let b = run(&sc);
    let c = run(&json_round_trip(&sc));

    for other in [&b, &c] {
        assert_eq!(a.replans, other.replans);
        assert_eq!(a.migrations, other.migrations);
        assert_eq!(a.steals, other.steals);
        assert_identical(&a.aggregate, &other.aggregate);
        assert_eq!(a.per_shard.len(), other.per_shard.len());
        for (x, y) in a.per_shard.iter().zip(&other.per_shard) {
            assert_identical(x, y);
        }
        assert_eq!(a.arrival_est_qps.len(), other.arrival_est_qps.len());
        for ((ta, qa), (tb, qb)) in
            a.arrival_est_qps.iter().zip(&other.arrival_est_qps)
        {
            assert_eq!(ta, tb);
            assert_eq!(qa.to_bits(), qb.to_bits(), "rate estimate for {ta}");
        }
    }
}

#[test]
fn fault_lab_crash_and_throttle_run_is_deterministic() {
    // Crash-mid-phase on the loaded shard, a degradation ramp on the
    // other, a thermal throttle curve, and priced cross-shard links —
    // the full fault lab, riding the online steal/warm-migrate stack.
    // No fault mechanism may introduce ambient randomness.
    let (zoo, lm, profiles) = fixtures::quartet();
    let tasks = fixtures::task_names(&zoo);
    let slos = fixtures::slos(&zoo, 0.5, 60.0);
    let map = BTreeMap::from([
        ("alpha".to_string(), 0),
        ("beta".to_string(), 0),
        ("delta".to_string(), 0),
        ("gamma".to_string(), 1),
    ]);
    let faults = FaultProfile {
        crashes: vec![CrashWindow {
            shard: 0,
            start_ms: 400.0,
            end_ms: 900.0,
            rejoin: RejoinMode::Warm,
        }],
        degradations: vec![Degradation {
            shard: 1,
            start_ms: 200.0,
            ramp_ms: 400.0,
            factor: 1.5,
        }],
        throttle: Some(ThrottleCurve {
            steps: vec![ThrottleStep { busy_ms: 100.0, factor: 1.3 }],
        }),
        links: Some(LinkMatrix { transfer_ms: vec![vec![0.0, 2.0], vec![2.0, 0.0]] }),
        expects: vec![Expect::MinCompleted { task: None, at_least: 1 }],
    };
    let sc = Scenario::bursty(&tasks, slos, 4.0, 100.0, 500.0, 3_000.0)
        .with_seed(11)
        .with_admission(Admission::Deadline { slack: 2.0 })
        .with_dispatch(Dispatch::batched(4))
        .with_sharding(Sharding::explicit(map, 2))
        .with_planner(PlannerConfig { max_migrations: 2, ..PlannerConfig::online() })
        .with_faults(faults);

    let run = |s: &Scenario| -> ShardedReport {
        let opts = ServeOpts { batch_hint: 4.0, ..Default::default() };
        ShardedServer::build(&zoo, &lm, &profiles, opts, s.sharding.clone())
            .unwrap()
            .run(s)
            .unwrap()
    };
    let a = run(&sc);
    let b = run(&sc);
    let c = run(&json_round_trip(&sc));

    for other in [&b, &c] {
        assert_eq!(a.replans, other.replans);
        assert_eq!(a.migrations, other.migrations);
        assert_eq!(a.steals, other.steals);
        assert_eq!(a.link_cost_ms.to_bits(), other.link_cost_ms.to_bits());
        assert_identical(&a.aggregate, &other.aggregate);
        assert_eq!(a.per_shard.len(), other.per_shard.len());
        for (x, y) in a.per_shard.iter().zip(&other.per_shard) {
            assert_identical(x, y);
        }
    }
    // The faults actually fired: the run booked downtime and throttle
    // debt, and still served work.
    assert!(a.aggregate.total_queries > 0, "the run must actually serve something");
    assert!(a.aggregate.downtime_ms > 0.0, "the crash window never opened");
    assert!(a.aggregate.throttled_ms > 0.0, "the throttle curve never bit");
}

#[test]
fn threaded_static_shards_match_sequential_bit_for_bit() {
    // The static sharded drive runs each shard on its own OS thread by
    // default (`ServeOpts::parallel`). Shards are fully independent
    // there, so the threaded run must be bit-identical to the
    // sequential loop — not "close", identical.
    let (zoo, lm, profiles, sharding) = fixtures::fleet(4, 8);
    let tasks = fixtures::task_names(&zoo);
    let slos = fixtures::slos(&zoo, 0.5, 80.0);
    let sc = Scenario::poisson(&tasks, slos, 30.0, 1_500.0)
        .with_seed(5)
        .with_dispatch(Dispatch::batched(4))
        .with_sharding(sharding);
    let run = |parallel: bool| -> ShardedReport {
        let opts = ServeOpts { parallel, ..Default::default() };
        ShardedServer::build(&zoo, &lm, &profiles, opts, sc.sharding.clone())
            .unwrap()
            .run(&sc)
            .unwrap()
    };
    let threaded = run(true);
    let sequential = run(false);
    assert_identical(&threaded.aggregate, &sequential.aggregate);
    assert_eq!(threaded.per_shard.len(), sequential.per_shard.len());
    for (x, y) in threaded.per_shard.iter().zip(&sequential.per_shard) {
        assert_identical(x, y);
    }
    for (x, y) in threaded
        .budget_utilization
        .iter()
        .zip(&sequential.budget_utilization)
    {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    assert!(threaded.aggregate.total_queries > 0, "the run must actually serve");
    // And the threaded drive is stable run-to-run.
    let again = run(true);
    assert_identical(&threaded.aggregate, &again.aggregate);
}

#[test]
fn epoch_barrier_drive_matches_sequential_under_faults() {
    // The epoch-barrier online drive (`PlannerConfig::epoch_ms`) keeps
    // all cross-shard decisions at barriers, so the threaded window
    // execution must replay bit-identically against the sequential
    // fallback — including under a crash window, a throttle curve, and
    // priced links, and across a JSON round-trip of the scenario.
    let (zoo, lm, profiles) = fixtures::quartet();
    let tasks = fixtures::task_names(&zoo);
    let slos = fixtures::slos(&zoo, 0.5, 60.0);
    let map = BTreeMap::from([
        ("alpha".to_string(), 0),
        ("beta".to_string(), 0),
        ("delta".to_string(), 0),
        ("gamma".to_string(), 1),
    ]);
    let faults = FaultProfile {
        crashes: vec![CrashWindow {
            shard: 0,
            start_ms: 400.0,
            end_ms: 900.0,
            rejoin: RejoinMode::Warm,
        }],
        degradations: vec![Degradation {
            shard: 1,
            start_ms: 200.0,
            ramp_ms: 400.0,
            factor: 1.5,
        }],
        throttle: Some(ThrottleCurve {
            steps: vec![ThrottleStep { busy_ms: 100.0, factor: 1.3 }],
        }),
        links: Some(LinkMatrix { transfer_ms: vec![vec![0.0, 2.0], vec![2.0, 0.0]] }),
        expects: vec![Expect::MinCompleted { task: None, at_least: 1 }],
    };
    let sc = Scenario::bursty(&tasks, slos, 4.0, 100.0, 500.0, 3_000.0)
        .with_seed(11)
        .with_admission(Admission::Deadline { slack: 2.0 })
        .with_dispatch(Dispatch::batched(4))
        .with_sharding(Sharding::explicit(map, 2))
        .with_planner(PlannerConfig {
            epoch_ms: 25.0,
            max_migrations: 2,
            ..PlannerConfig::online()
        })
        .with_faults(faults);
    let run = |parallel: bool, s: &Scenario| -> ShardedReport {
        let opts = ServeOpts { batch_hint: 4.0, parallel, ..Default::default() };
        ShardedServer::build(&zoo, &lm, &profiles, opts, s.sharding.clone())
            .unwrap()
            .run(s)
            .unwrap()
    };
    let threaded = run(true, &sc);
    let sequential = run(false, &sc);
    let round_trip = run(true, &json_round_trip(&sc));
    for other in [&sequential, &round_trip] {
        assert_eq!(threaded.replans, other.replans);
        assert_eq!(threaded.migrations, other.migrations);
        assert_eq!(threaded.steals, other.steals);
        assert_eq!(threaded.link_cost_ms.to_bits(), other.link_cost_ms.to_bits());
        assert_identical(&threaded.aggregate, &other.aggregate);
        assert_eq!(threaded.per_shard.len(), other.per_shard.len());
        for (x, y) in threaded.per_shard.iter().zip(&other.per_shard) {
            assert_identical(x, y);
        }
    }
    assert!(threaded.aggregate.total_queries > 0, "the run must actually serve");
}

#[test]
fn streaming_metrics_match_retained_run_without_event_log() {
    // With `record_events` off the run keeps no per-request events
    // (retention is O(1) in request count), yet every aggregate the
    // report exposes — counters, means, maxima, sketch percentiles,
    // SLO-miss counts — is bit-identical to the retained run.
    let (zoo, lm, profiles, sharding) = fixtures::fleet(2, 4);
    let tasks = fixtures::task_names(&zoo);
    let sc = Scenario::poisson(&tasks, fixtures::slos(&zoo, 0.5, 40.0), 40.0, 1_500.0)
        .with_seed(3)
        .with_dispatch(Dispatch::batched(4))
        .with_sharding(sharding);
    let run = |record_events: bool| -> ShardedReport {
        let opts = ServeOpts { record_events, ..Default::default() };
        ShardedServer::build(&zoo, &lm, &profiles, opts, sc.sharding.clone())
            .unwrap()
            .run(&sc)
            .unwrap()
    };
    let retained = run(true);
    let streaming = run(false);
    assert!(retained.aggregate.total_queries > 0, "the run must actually serve");
    assert!(!retained.aggregate.requests.is_empty());
    assert!(retained.aggregate.record_events);
    assert!(streaming.aggregate.requests.is_empty());
    assert!(!streaming.aggregate.record_events);
    for p in &streaming.per_shard {
        assert!(p.requests.is_empty(), "streaming shard retained events");
    }
    assert_eq!(retained.aggregate.total_queries, streaming.aggregate.total_queries);
    assert_eq!(retained.aggregate.total_dropped, streaming.aggregate.total_dropped);
    assert_eq!(retained.aggregate.total_batches, streaming.aggregate.total_batches);
    assert_eq!(
        retained.aggregate.slo_miss_count,
        streaming.aggregate.slo_miss_count
    );
    assert_eq!(
        retained.aggregate.makespan_ms.to_bits(),
        streaming.aggregate.makespan_ms.to_bits()
    );
    assert_eq!(retained.aggregate.outcomes.len(), streaming.aggregate.outcomes.len());
    for (x, y) in retained
        .aggregate
        .outcomes
        .iter()
        .zip(&streaming.aggregate.outcomes)
    {
        assert_eq!(x.task, y.task);
        assert_eq!(x.queries_completed, y.queries_completed);
        assert_eq!(x.queries_dropped, y.queries_dropped);
        assert_eq!(x.slo_misses, y.slo_misses);
        assert_eq!(x.mean_latency_ms.to_bits(), y.mean_latency_ms.to_bits(), "{}", x.task);
        assert_eq!(x.max_latency_ms.to_bits(), y.max_latency_ms.to_bits(), "{}", x.task);
        assert_eq!(x.p50_latency_ms.to_bits(), y.p50_latency_ms.to_bits(), "{}", x.task);
        assert_eq!(x.p99_latency_ms.to_bits(), y.p99_latency_ms.to_bits(), "{}", x.task);
        assert_eq!(
            x.mean_queueing_ms.to_bits(),
            y.mean_queueing_ms.to_bits(),
            "{}",
            x.task
        );
    }
}

/// The full fault lab on the quartet fixture (crash + degradation +
/// throttle + priced links), riding the online stack — `epoch_ms > 0`
/// selects the epoch-barrier drive, `0.0` the classic one.
fn fault_lab_scenario(
    epoch_ms: f64,
) -> (Zoo, LatencyModel, BTreeMap<String, TaskProfile>, Scenario) {
    let (zoo, lm, profiles) = fixtures::quartet();
    let tasks = fixtures::task_names(&zoo);
    let slos = fixtures::slos(&zoo, 0.5, 60.0);
    let map = BTreeMap::from([
        ("alpha".to_string(), 0),
        ("beta".to_string(), 0),
        ("delta".to_string(), 0),
        ("gamma".to_string(), 1),
    ]);
    let faults = FaultProfile {
        crashes: vec![CrashWindow {
            shard: 0,
            start_ms: 400.0,
            end_ms: 900.0,
            rejoin: RejoinMode::Warm,
        }],
        degradations: vec![Degradation {
            shard: 1,
            start_ms: 200.0,
            ramp_ms: 400.0,
            factor: 1.5,
        }],
        throttle: Some(ThrottleCurve {
            steps: vec![ThrottleStep { busy_ms: 100.0, factor: 1.3 }],
        }),
        links: Some(LinkMatrix { transfer_ms: vec![vec![0.0, 2.0], vec![2.0, 0.0]] }),
        expects: vec![Expect::MinCompleted { task: None, at_least: 1 }],
    };
    let sc = Scenario::bursty(&tasks, slos, 4.0, 100.0, 500.0, 3_000.0)
        .with_seed(11)
        .with_admission(Admission::Deadline { slack: 2.0 })
        .with_dispatch(Dispatch::batched(4))
        .with_sharding(Sharding::explicit(map, 2))
        .with_planner(PlannerConfig {
            epoch_ms,
            max_migrations: 2,
            ..PlannerConfig::online()
        })
        .with_faults(faults);
    (zoo, lm, profiles, sc)
}

#[test]
fn traced_jsonl_is_byte_identical_across_drive_modes() {
    // The determinism contract `explain` and the CI smoke ride on: the
    // canonical JSONL trace — request spans and control-plane audit
    // events — must come out byte-for-byte identical from the threaded
    // and sequential drives, for the classic and epoch-barrier online
    // stacks alike, under the full fault lab.
    for epoch_ms in [0.0, 25.0] {
        let (zoo, lm, profiles, sc) = fault_lab_scenario(epoch_ms);
        let run = |parallel: bool| -> ShardedReport {
            let opts = ServeOpts {
                batch_hint: 4.0,
                parallel,
                trace: true,
                ..Default::default()
            };
            ShardedServer::build(&zoo, &lm, &profiles, opts, sc.sharding.clone())
                .unwrap()
                .run(&sc)
                .unwrap()
        };
        let threaded = run(true);
        let sequential = run(false);
        let a = trace::to_jsonl(&threaded.canonical_trace());
        let b = trace::to_jsonl(&sequential.canonical_trace());
        assert!(!a.is_empty(), "epoch_ms={epoch_ms}: traced run produced no events");
        assert_eq!(a, b, "epoch_ms={epoch_ms}: drives disagree on trace bytes");
        let again = trace::to_jsonl(&run(true).canonical_trace());
        assert_eq!(a, again, "epoch_ms={epoch_ms}: threaded drive unstable");
        // The fault lab actually left audit records behind.
        for code in ["TR-REQ-EXEC", "TR-CTL-CRASH", "TR-CTL-THROTTLE"] {
            assert!(a.contains(code), "epoch_ms={epoch_ms}: no {code} in trace");
        }
        // The file replays through the importer without diagnostics,
        // and the attribution totals reconcile with the report.
        let (events, lint) = trace::parse_jsonl(&a);
        assert!(!lint.has_errors(), "{}", lint.render_text());
        let att = trace::explain::attribute(&events);
        assert_eq!(att.done, threaded.aggregate.total_queries);
        assert_eq!(att.misses, threaded.aggregate.slo_miss_count);
        let totals = att.totals();
        assert_eq!(
            totals.iter().take(6).sum::<usize>(),
            att.misses,
            "every SLO miss lands in exactly one cause bucket"
        );
        assert_eq!(totals[6], threaded.aggregate.total_dropped);
    }
}

#[test]
fn traced_static_shards_match_sequential_bit_for_bit() {
    // Same contract on the static sharded drive, where every shard
    // thread writes request spans concurrently.
    let (zoo, lm, profiles, sharding) = fixtures::fleet(4, 8);
    let tasks = fixtures::task_names(&zoo);
    let sc = Scenario::poisson(&tasks, fixtures::slos(&zoo, 0.5, 80.0), 30.0, 1_500.0)
        .with_seed(5)
        .with_dispatch(Dispatch::batched(4))
        .with_sharding(sharding);
    let run = |parallel: bool| -> ShardedReport {
        let opts = ServeOpts { parallel, trace: true, ..Default::default() };
        ShardedServer::build(&zoo, &lm, &profiles, opts, sc.sharding.clone())
            .unwrap()
            .run(&sc)
            .unwrap()
    };
    let threaded = run(true);
    let sequential = run(false);
    let a = trace::to_jsonl(&threaded.canonical_trace());
    let b = trace::to_jsonl(&sequential.canonical_trace());
    assert!(!a.is_empty(), "traced run produced no events");
    assert_eq!(a, b, "static drives disagree on trace bytes");
}

#[test]
fn disabled_tracing_retains_nothing_and_perturbs_nothing() {
    // The no-op sink contract: with `trace` off no events are retained
    // anywhere, and turning tracing on changes nothing outside the
    // trace itself — virtual time never observes the observer.
    let (zoo, lm, profiles, sc) = fault_lab_scenario(25.0);
    let run = |traced: bool| -> ShardedReport {
        let opts = ServeOpts { batch_hint: 4.0, trace: traced, ..Default::default() };
        ShardedServer::build(&zoo, &lm, &profiles, opts, sc.sharding.clone())
            .unwrap()
            .run(&sc)
            .unwrap()
    };
    let untraced = run(false);
    assert!(untraced.canonical_trace().is_empty());
    assert!(untraced.aggregate.trace.is_empty());
    for shard in &untraced.per_shard {
        assert!(shard.trace.is_empty(), "no-op sink retained events");
    }
    let traced = run(true);
    assert!(!traced.canonical_trace().is_empty());
    assert_eq!(traced.replans, untraced.replans);
    assert_eq!(traced.migrations, untraced.migrations);
    assert_eq!(traced.steals, untraced.steals);
    assert_eq!(traced.link_cost_ms.to_bits(), untraced.link_cost_ms.to_bits());
    assert_identical(&traced.aggregate, &untraced.aggregate);
    for (x, y) in traced.per_shard.iter().zip(&untraced.per_shard) {
        assert_identical(x, y);
    }
}

#[test]
fn single_server_predictive_run_is_deterministic() {
    let (zoo, lm, profiles) = fixtures::trio();
    let tasks = fixtures::task_names(&zoo);
    let sc = Scenario::poisson(&tasks, fixtures::slos(&zoo, 0.5, 50.0), 60.0, 2_500.0)
        .with_seed(7)
        .with_admission(Admission::Predictive { horizon_ms: 250.0, headroom: 1.5 })
        .with_dispatch(Dispatch::batched(4));
    let server = Server::builder(&zoo, &lm, &profiles).build();
    let a = server.run(&sc).unwrap();
    let b = server.run(&sc).unwrap();
    let c = Server::builder(&zoo, &lm, &profiles)
        .build()
        .run(&json_round_trip(&sc))
        .unwrap();
    assert_identical(&a, &b);
    assert_identical(&a, &c);
    assert!(a.total_queries > 0, "the run must actually serve something");
}

#[test]
fn synthesis_run_is_deterministic_across_drive_modes() {
    // The online synthesis action must stay bit-identical across the
    // threaded and sequential drives, in both the classic (epoch_ms=0)
    // and the epoch-barrier protocols, with its TR-CTL-SYNTH audit
    // events byte-identical through the JSONL export — and the
    // `synthesize` planner knob must survive the scenario JSON round
    // trip on the way.
    let (zoo, lm, profiles) = fixtures::stitchable(&[
        ("cam0", 0.92, 20.0),
        ("cam1", 0.90, 20.0),
        ("lidar", 0.88, 20.0),
        ("radar", 0.91, 20.0),
    ]);
    let map: BTreeMap<String, usize> =
        [("cam0", 0), ("cam1", 0), ("lidar", 1), ("radar", 1)]
            .into_iter()
            .map(|(t, s)| (t.to_string(), s))
            .collect();
    let sharding = Sharding::explicit(map, 2);
    let tasks = fixtures::task_names(&zoo);
    for epoch_ms in [0.0, 25.0] {
        let sc = Scenario::bursty(&tasks, fixtures::slos(&zoo, 0.25, 14.8), 2.0, 80.0, 500.0, 2_000.0)
            .with_admission(Admission::Always)
            .with_sharding(sharding.clone())
            .with_planner(PlannerConfig {
                batch_aware: true,
                saturation_slack: 1.5,
                synthesize: true,
                epoch_ms,
                ..PlannerConfig::default()
            })
            .with_seed(7);
        let sc = json_round_trip(&sc);
        assert!(sc.planner.synthesize, "synthesize must survive the JSON round trip");
        let run = |parallel: bool| {
            let opts = ServeOpts {
                batch_hint: 4.0,
                memory_budget_frac: 0.6,
                feedback_switching: false,
                force_order: Some(vec![Processor::Cpu, Processor::Gpu]),
                parallel,
                trace: true,
                ..ServeOpts::default()
            };
            ShardedServer::build(&zoo, &lm, &profiles, opts, sharding.clone())
                .unwrap()
                .run(&sc)
                .unwrap()
        };
        let threaded = run(true);
        let sequential = run(false);
        assert_eq!(threaded.synths, sequential.synths, "epoch_ms={epoch_ms}");
        assert!(
            threaded.synths >= 1,
            "epoch_ms={epoch_ms}: the stitchable fixture must trigger synthesis"
        );
        assert_identical(&threaded.aggregate, &sequential.aggregate);
        for (x, y) in threaded.per_shard.iter().zip(&sequential.per_shard) {
            assert_identical(x, y);
        }
        let a = trace::to_jsonl(&threaded.canonical_trace());
        let b = trace::to_jsonl(&sequential.canonical_trace());
        assert_eq!(a, b, "epoch_ms={epoch_ms}: traced JSONL must be byte-identical");
        assert!(
            a.contains(trace::TR_CTL_SYNTH),
            "epoch_ms={epoch_ms}: synthesis run left no TR-CTL-SYNTH events"
        );
    }
}
