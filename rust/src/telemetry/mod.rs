//! Online telemetry control plane: per-task arrival-rate estimation,
//! hotness tracking, and per-shard load accounting.
//!
//! Until this module, the planner was blind to the traffic it served:
//! `PlanContext::arrival_hint` had to be supplied by hand, and the
//! replan drive scored migration victims on memory hotness alone. The
//! [`Telemetry`] handle closes that loop. It ingests
//! [`RequestOutcome`] events as the server runs and maintains, per
//! task:
//!
//! * an **EWMA arrival-rate** estimate — a bias-corrected
//!   exponentially weighted moving average of inter-arrival gaps
//!   (`m ← α·gap + (1−α)·m`, estimate `m / (1 − (1−α)ᵏ)` after `k`
//!   gaps — the Adam-style correction makes early estimates behave
//!   like a running mean instead of anchoring on the first gap),
//!   reported as `1000/ĝ` qps. The stationary relative error on a
//!   Poisson stream is `√(α/(2−α))` of the true gap (≈ 5 % at the
//!   default α = 0.005), comfortably inside the 25 % band the backlog
//!   study asserts;
//! * a **sliding-window rate** — arrivals inside the trailing
//!   [`TelemetryConfig::window_ms`] over the window length — the fast,
//!   bursty-phase signal the EWMA deliberately smooths over;
//! * **hotness** — the task's share of all observed arrivals, the
//!   traffic weight that multiplies Eq. 7 memory hotness in budget
//!   splits and victim scoring;
//!
//! and per shard: latest queueing backlog, cumulative busy time
//! (occupancy), completion/drop counts, and stolen batches.
//!
//! Consumers:
//!
//! * the `ShardedServer` online drive reads shard backlog/warmness to
//!   trigger query-level work stealing, and hands
//!   [`Telemetry::arrival_hint`] to `Planner::replan` via
//!   `ShardObservation::arrival_qps` on every saturation event, so
//!   victim scoring and the migrant's budget share follow observed
//!   traffic;
//! * [`Telemetry::plan_context`] builds a [`PlanContext`] whose
//!   `arrival_hint` is the live EWMA estimates — the front door for
//!   re-running a *full* `Planner::plan` from observed traffic instead
//!   of hand-supplied hints (startup plans have no traffic to observe
//!   yet and stay unweighted).
//!
//! On top of the trailing estimators sits the [`forecast`] layer
//! (PR 5): every task additionally feeds a
//! [`forecast::RateForecaster`] (Holt trend over the windowed rate +
//! burst detector) and every shard a [`forecast::TrendTracker`] over
//! its observed backlog, so consumers can ask for *projected* state —
//! [`Telemetry::projected_rate_qps`] /
//! [`Telemetry::projected_arrival_hint`] (the predictive
//! `PlanContext::arrival_hint`), [`Telemetry::forecast_shard_backlog_ms`]
//! (the forecast replan trigger), and [`Telemetry::slo_forecast`]
//! (projected per-task violation rates). See DESIGN.md §Forecasting.
//!
//! ```
//! use sparseloom::telemetry::Telemetry;
//! use sparseloom::util::Rng;
//! use sparseloom::workload::poisson_stream;
//!
//! let mut t = Telemetry::new(2);
//! let stream = poisson_stream(&["a".to_string()], 50.0, 60_000.0, &mut Rng::new(1));
//! for q in &stream {
//!     t.observe_arrival(&q.task, q.arrival_ms);
//! }
//! let est = t.rate_qps("a").unwrap();
//! assert!((est - 50.0).abs() / 50.0 < 0.25, "EWMA within 25 %: {est}");
//! ```

pub mod forecast;

use std::collections::BTreeMap;

use crate::metrics::RequestOutcome;
use crate::planner::PlanContext;
use crate::workload::Slo;

use self::forecast::{RateForecaster, TrendTracker};

/// Estimator knobs. The defaults favor stability: the EWMA averages
/// over an effective `2/α − 1 ≈ 399` recent gaps (the bias correction
/// makes it a plain running mean until that many have been seen), and
/// the window spans one second of virtual time.
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryConfig {
    /// EWMA smoothing factor for inter-arrival gaps (0 < α ≤ 1).
    pub ewma_alpha: f64,
    /// Sliding-window length (virtual ms) for the windowed rate.
    pub window_ms: f64,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        Self { ewma_alpha: 0.005, window_ms: 1_000.0 }
    }
}

/// Per-task online estimator state.
#[derive(Clone, Debug, Default)]
struct TaskStats {
    arrivals: u64,
    completed: u64,
    dropped: u64,
    /// Uncorrected EWMA accumulator of inter-arrival gaps (ms),
    /// initialized at 0 — `rate_qps` applies the `1 − (1−α)ᵏ` bias
    /// correction.
    ewma_gap_ms: f64,
    /// Gaps observed so far (k of the bias correction).
    gaps: u64,
    last_arrival_ms: Option<f64>,
    /// Cumulative service time of completed requests (ms) and how many
    /// of them missed their per-request latency SLO — the observed
    /// violation share [`Telemetry::slo_forecast`] projects forward.
    service_sum_ms: f64,
    slo_misses: u64,
    /// Sliding arrival window + Holt trend + burst detector. The one
    /// owner of the window timestamps: `window_rate_qps` reads through
    /// it, and it is built over [`TelemetryConfig::window_ms`].
    forecast: RateForecaster,
}

impl TaskStats {
    /// Fresh stats whose forecaster windows over `window_ms` (the
    /// telemetry config's window, not the forecast default).
    fn with_window(window_ms: f64) -> TaskStats {
        TaskStats {
            forecast: RateForecaster::new(forecast::ForecastConfig {
                window_ms,
                ..forecast::ForecastConfig::default()
            }),
            ..TaskStats::default()
        }
    }
}

/// Per-shard load accounting.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Latest observed total queueing backlog (ms).
    pub backlog_ms: f64,
    /// Holt trend over the observed backlog series — the projection
    /// behind [`Telemetry::forecast_shard_backlog_ms`].
    pub backlog_trend: TrendTracker,
    /// Cumulative booked service time (ms) — the occupancy numerator.
    pub busy_ms: f64,
    pub completed: u64,
    pub dropped: u64,
    /// Batches this shard served for tasks homed on another shard.
    pub stolen_batches: u64,
}

impl ShardStats {
    /// Fold another accounting fragment into this one: the cumulative
    /// counters (busy time, completions, drops, stolen batches) sum.
    /// The backlog gauge and its trend are point-in-time *observations*
    /// owned by whoever calls [`Telemetry::observe_backlog`] — a
    /// counter fragment carries none, so they are left untouched.
    ///
    /// This is what makes shard accounting mergeable: the threaded
    /// sharded drive hands each shard thread its own scratch
    /// [`Telemetry`] part and folds the parts back at every epoch
    /// barrier ([`Telemetry::merge`]) in shard-index order.
    pub fn absorb(&mut self, other: &ShardStats) {
        self.busy_ms += other.busy_ms;
        self.completed += other.completed;
        self.dropped += other.dropped;
        self.stolen_batches += other.stolen_batches;
    }
}

/// The telemetry handle: feed it [`RequestOutcome`]s (or raw arrivals)
/// and read rate/hotness/load estimates back. All state is windowed or
/// exponentially discounted — memory is O(tasks + shards + window).
#[derive(Clone, Debug)]
pub struct Telemetry {
    cfg: TelemetryConfig,
    tasks: BTreeMap<String, TaskStats>,
    shards: Vec<ShardStats>,
}

impl Telemetry {
    /// Telemetry over `n_shards` shards with default estimator knobs
    /// (use 1 for a single server).
    pub fn new(n_shards: usize) -> Telemetry {
        Self::with_config(n_shards, TelemetryConfig::default())
    }

    pub fn with_config(n_shards: usize, cfg: TelemetryConfig) -> Telemetry {
        Telemetry {
            cfg,
            tasks: BTreeMap::new(),
            shards: vec![ShardStats::default(); n_shards.max(1)],
        }
    }

    pub fn config(&self) -> &TelemetryConfig {
        &self.cfg
    }

    /// Ingest one arrival. Arrivals of one task must be fed in
    /// non-decreasing time order (per-task FIFO dispatch order, which
    /// every drive loop already guarantees).
    pub fn observe_arrival(&mut self, task: &str, arrival_ms: f64) {
        let alpha = self.cfg.ewma_alpha.clamp(1e-6, 1.0);
        let window = self.cfg.window_ms.max(1e-9);
        let st = self
            .tasks
            .entry(task.to_string())
            .or_insert_with(|| TaskStats::with_window(window));
        st.arrivals += 1;
        if let Some(last) = st.last_arrival_ms {
            let gap = (arrival_ms - last).max(0.0);
            st.ewma_gap_ms = alpha * gap + (1.0 - alpha) * st.ewma_gap_ms;
            st.gaps += 1;
        }
        st.last_arrival_ms = Some(arrival_ms);
        // The forecaster owns the sliding window (one copy of the
        // timestamps): it trims it and samples the windowed rate here.
        st.forecast.observe(arrival_ms);
    }

    /// Ingest one request outcome served (or dropped) by `shard`:
    /// updates the task's arrival estimators and the shard's
    /// completion/occupancy counters.
    pub fn observe_outcome(&mut self, shard: usize, ev: &RequestOutcome) {
        self.observe_task_outcome(ev);
        self.observe_shard_outcome(shard, ev);
    }

    /// The task-estimator half of [`Telemetry::observe_outcome`]: feeds
    /// the arrival EWMAs/forecaster and the task's completion counters
    /// without touching any shard's counters. The epoch-barrier drive
    /// calls this centrally — per worker, in shard-index order — at
    /// every barrier, because EWMA estimators depend on feed order and
    /// therefore stay coordinator-owned (they cannot merge).
    pub fn observe_task_outcome(&mut self, ev: &RequestOutcome) {
        self.observe_arrival(&ev.task, ev.arrival_ms);
        if ev.dropped {
            if let Some(st) = self.tasks.get_mut(&ev.task) {
                st.dropped += 1;
            }
        } else if let Some(st) = self.tasks.get_mut(&ev.task) {
            st.completed += 1;
            st.service_sum_ms += ev.service_ms;
            if ev.slo_ok == Some(false) {
                st.slo_misses += 1;
            }
        }
    }

    /// The shard-counter half of [`Telemetry::observe_outcome`]:
    /// updates only `shard`'s completion/drop/occupancy counters,
    /// leaving the per-task arrival estimators alone. Shard threads in
    /// the epoch-barrier drive call this on their scratch telemetry
    /// part (counters merge; EWMA estimators do not), and the
    /// coordinator feeds the task half centrally at the barrier.
    pub fn observe_shard_outcome(&mut self, shard: usize, ev: &RequestOutcome) {
        if let Some(sh) = self.shards.get_mut(shard) {
            if ev.dropped {
                sh.dropped += 1;
            } else {
                sh.completed += 1;
                sh.busy_ms += ev.service_ms;
            }
        }
    }

    /// Fold a scratch telemetry `part` (shard counters accumulated by
    /// one worker between barriers) into this instance. Only the
    /// per-shard counters merge — see [`ShardStats::absorb`]. Task
    /// estimators are EWMAs over a global arrival order and cannot be
    /// merged pairwise, so the coordinator owns them exclusively.
    pub fn merge(&mut self, part: &Telemetry) {
        for (mine, theirs) in self.shards.iter_mut().zip(part.shards.iter()) {
            mine.absorb(theirs);
        }
    }

    /// Record the latest observed queueing backlog of `shard` at
    /// virtual time `now_ms` (the timestamp feeds the backlog trend
    /// behind [`Telemetry::forecast_shard_backlog_ms`]).
    pub fn observe_backlog(&mut self, shard: usize, backlog_ms: f64, now_ms: f64) {
        if let Some(sh) = self.shards.get_mut(shard) {
            sh.backlog_ms = backlog_ms.max(0.0);
            sh.backlog_trend.observe(now_ms, backlog_ms.max(0.0));
        }
    }

    /// Record one stolen batch served by `shard`.
    pub fn note_steal(&mut self, shard: usize) {
        if let Some(sh) = self.shards.get_mut(shard) {
            sh.stolen_batches += 1;
        }
    }

    /// EWMA arrival-rate estimate for `task` (qps), bias-corrected so
    /// early values behave like a running mean of the gaps seen so
    /// far. `None` before two arrivals (a single point has no gap), or
    /// when every observed gap was ~0 (a degenerate burst has no
    /// finite rate).
    pub fn rate_qps(&self, task: &str) -> Option<f64> {
        let st = self.tasks.get(task)?;
        if st.gaps == 0 {
            return None;
        }
        let alpha = self.cfg.ewma_alpha.clamp(1e-6, 1.0);
        let correction = 1.0 - (1.0 - alpha).powf(st.gaps as f64);
        let gap = st.ewma_gap_ms / correction.max(1e-12);
        if gap <= 1e-9 {
            return None;
        }
        Some(1_000.0 / gap)
    }

    /// Sliding-window arrival rate for `task` (qps) looking back
    /// [`TelemetryConfig::window_ms`] from `now_ms` — the fast signal
    /// for burst detection. `None` for unobserved tasks.
    pub fn window_rate_qps(&self, task: &str, now_ms: f64) -> Option<f64> {
        let st = self.tasks.get(task)?;
        Some(st.forecast.window_rate_qps(now_ms))
    }

    /// Projected arrival rate for `task` (qps) `horizon_ms` past
    /// `now_ms`: the Holt trend fit over the windowed rate, floored at
    /// the raw windowed rate during a detected burst. Falls back to
    /// the trailing EWMA before the forecaster has a sample; `None`
    /// for unobserved tasks.
    pub fn projected_rate_qps(
        &self,
        task: &str,
        now_ms: f64,
        horizon_ms: f64,
    ) -> Option<f64> {
        let st = self.tasks.get(task)?;
        if st.forecast.samples() == 0 {
            return self.rate_qps(task);
        }
        Some(st.forecast.projected_qps(now_ms, horizon_ms))
    }

    /// Whether `task`'s latest rate sample flagged a burst (rate
    /// acceleration above the detector threshold).
    pub fn is_burst(&self, task: &str) -> bool {
        self.tasks
            .get(task)
            .map(|st| st.forecast.is_burst())
            .unwrap_or(false)
    }

    /// The *predictive* arrival-hint map: per task, the projected
    /// rather than trailing rate (qps). Tasks whose projection is zero
    /// or unavailable are omitted and keep the planner's default
    /// weight — the forecast counterpart of [`Telemetry::arrival_hint`].
    pub fn projected_arrival_hint(
        &self,
        now_ms: f64,
        horizon_ms: f64,
    ) -> BTreeMap<String, f64> {
        self.tasks
            .keys()
            .filter_map(|t| {
                self.projected_rate_qps(t, now_ms, horizon_ms)
                    .filter(|q| q.is_finite() && *q > 0.0)
                    .map(|q| (t.clone(), q))
            })
            .collect()
    }

    /// Projected queueing backlog of `shard` (ms) `horizon_ms` past
    /// `now_ms` — the level + trend fit over the observed backlog
    /// series, clamped at 0. 0.0 for unknown shards or before any
    /// observation. The forecast replan trigger compares
    /// `max(observed, forecast)` against the saturation threshold, so
    /// a falling trend can never *suppress* a crossing the observed
    /// backlog already made.
    pub fn forecast_shard_backlog_ms(
        &self,
        shard: usize,
        now_ms: f64,
        horizon_ms: f64,
    ) -> f64 {
        self.shards
            .get(shard)
            .map(|sh| sh.backlog_trend.forecast(now_ms, horizon_ms))
            .unwrap_or(0.0)
    }

    /// Projected per-task SLO violation rates over the next
    /// `horizon_ms`: the observed per-request violation share scaled
    /// by the forecast load factor (projected / fitted current rate),
    /// clamped into [0, 1]. Only tasks in `slos` with at least one
    /// completion appear — a task that has not served anything has no
    /// violation share to project.
    ///
    /// Same formula ([`forecast::project_violation_rate`]) as the
    /// per-session `RunReport::slo_forecast` that `Session::finish`
    /// fills from its own counters — this is the telemetry-side view
    /// for callers driving servers through raw outcomes (the session
    /// cannot be asked mid-run, telemetry can).
    pub fn slo_forecast(
        &self,
        slos: &BTreeMap<String, Slo>,
        now_ms: f64,
        horizon_ms: f64,
    ) -> BTreeMap<String, f64> {
        self.tasks
            .iter()
            .filter(|(name, st)| slos.contains_key(*name) && st.completed > 0)
            .map(|(name, st)| {
                let miss_rate = st.slo_misses as f64 / st.completed as f64;
                let factor = st.forecast.load_factor(now_ms, horizon_ms);
                (
                    name.clone(),
                    forecast::project_violation_rate(miss_rate, factor),
                )
            })
            .collect()
    }

    /// Mean service latency of `task`'s completed requests (ms) —
    /// `None` before the first completion.
    pub fn mean_service_ms(&self, task: &str) -> Option<f64> {
        let st = self.tasks.get(task)?;
        if st.completed == 0 {
            return None;
        }
        Some(st.service_sum_ms / st.completed as f64)
    }

    /// `task`'s share of all observed arrivals (0..1; 0.0 for
    /// unobserved tasks) — the traffic-hotness weight.
    pub fn hotness(&self, task: &str) -> f64 {
        let total: u64 = self.tasks.values().map(|st| st.arrivals).sum();
        if total == 0 {
            return 0.0;
        }
        self.tasks
            .get(task)
            .map(|st| st.arrivals as f64 / total as f64)
            .unwrap_or(0.0)
    }

    /// Every task with an EWMA estimate, as the planner's arrival-hint
    /// map (qps).
    pub fn arrival_hint(&self) -> BTreeMap<String, f64> {
        self.tasks
            .keys()
            .filter_map(|t| self.rate_qps(t).map(|q| (t.clone(), q)))
            .collect()
    }

    /// Alias of [`Telemetry::arrival_hint`] for report surfaces.
    pub fn rates(&self) -> BTreeMap<String, f64> {
        self.arrival_hint()
    }

    /// Per-shard load accounting.
    pub fn shards(&self) -> &[ShardStats] {
        &self.shards
    }

    /// Fraction of `[0, now_ms]` shard `shard` spent booked (0.0 when
    /// nothing elapsed). Can exceed 1.0 when batching overlaps stages.
    pub fn occupancy(&self, shard: usize, now_ms: f64) -> f64 {
        if now_ms <= 0.0 {
            return 0.0;
        }
        self.shards
            .get(shard)
            .map(|sh| sh.busy_ms / now_ms)
            .unwrap_or(0.0)
    }

    /// Total stolen batches across shards.
    pub fn steals(&self) -> u64 {
        self.shards.iter().map(|sh| sh.stolen_batches).sum()
    }

    /// Build a [`PlanContext`] whose `arrival_hint` is the live EWMA
    /// estimates — the automatic replacement for hand-supplied hints.
    /// Tasks without an estimate yet keep the planner's 1.0 default
    /// weight.
    pub fn plan_context(
        &self,
        slos: BTreeMap<String, Slo>,
        universe: Vec<Slo>,
        memory_budget: u64,
    ) -> PlanContext {
        PlanContext::new(slos, memory_budget)
            .with_universe(universe)
            .with_arrival_hint(self.arrival_hint())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::poisson_stream;

    fn feed_poisson(rate_qps: f64, horizon_ms: f64, seed: u64) -> Telemetry {
        let mut t = Telemetry::new(2);
        let tasks = vec!["a".to_string()];
        for q in poisson_stream(&tasks, rate_qps, horizon_ms, &mut Rng::new(seed)) {
            t.observe_arrival(&q.task, q.arrival_ms);
        }
        t
    }

    #[test]
    fn ewma_rate_within_25pct_of_poisson_ground_truth() {
        // The acceptance bound of the backlog study: on the Poisson
        // fixture the EWMA estimate lands within 25 % of the true rate
        // (stationary relative error √(α/(2−α)) ≈ 5 % at α = 0.005 —
        // the 25 % band sits ~4σ out).
        for (rate, seed) in [(50.0, 1u64), (20.0, 7)] {
            let t = feed_poisson(rate, 60_000.0, seed);
            let est = t.rate_qps("a").expect("estimate after thousands of arrivals");
            let err = (est - rate).abs() / rate;
            assert!(
                err < 0.25,
                "EWMA {est:.2} qps vs true {rate} qps (err {:.0} %)",
                100.0 * err
            );
        }
    }

    #[test]
    fn estimators_start_empty_and_need_two_arrivals() {
        let mut t = Telemetry::new(1);
        assert!(t.rate_qps("a").is_none());
        assert!(t.window_rate_qps("a", 0.0).is_none());
        assert_eq!(t.hotness("a"), 0.0);
        t.observe_arrival("a", 10.0);
        assert!(t.rate_qps("a").is_none(), "one arrival has no gap");
        t.observe_arrival("a", 30.0);
        // A single 20 ms gap ⇒ 50 qps exactly.
        let est = t.rate_qps("a").unwrap();
        assert!((est - 50.0).abs() < 1e-9, "{est}");
        assert_eq!(t.arrival_hint().len(), 1);
    }

    #[test]
    fn window_rate_tracks_the_recent_burst_only() {
        let mut t = Telemetry::with_config(
            1,
            TelemetryConfig { ewma_alpha: 0.02, window_ms: 100.0 },
        );
        // A sparse prefix, then a 10-query burst in the last 100 ms.
        for i in 0..5 {
            t.observe_arrival("a", 1_000.0 * i as f64);
        }
        for i in 0..10 {
            t.observe_arrival("a", 4_900.0 + 10.0 * i as f64);
        }
        let w = t.window_rate_qps("a", 5_000.0).unwrap();
        // 10-11 arrivals inside [4900, 5000] ⇒ ~100 qps; the EWMA still
        // remembers the sparse prefix and sits far lower.
        assert!(w >= 90.0, "window rate must see the burst: {w}");
        let ewma = t.rate_qps("a").unwrap();
        assert!(ewma < w, "EWMA smooths over the burst: {ewma} vs {w}");
    }

    #[test]
    fn hotness_is_arrival_share() {
        let mut t = Telemetry::new(1);
        for i in 0..30 {
            t.observe_arrival("hot", i as f64);
        }
        for i in 0..10 {
            t.observe_arrival("cold", i as f64);
        }
        assert!((t.hotness("hot") - 0.75).abs() < 1e-12);
        assert!((t.hotness("cold") - 0.25).abs() < 1e-12);
        assert_eq!(t.hotness("absent"), 0.0);
    }

    #[test]
    fn outcomes_update_shard_accounting() {
        use crate::metrics::RequestOutcome;
        let mut t = Telemetry::new(2);
        let ev = |id: u64, arrival: f64, dropped: bool| RequestOutcome {
            id,
            task: "a".into(),
            arrival_ms: arrival,
            start_ms: arrival,
            finish_ms: arrival + 5.0,
            service_ms: 5.0,
            queueing_ms: 0.0,
            dropped,
            slo_ok: if dropped { None } else { Some(true) },
        };
        t.observe_outcome(0, &ev(0, 0.0, false));
        t.observe_outcome(0, &ev(1, 10.0, false));
        t.observe_outcome(1, &ev(2, 20.0, true));
        t.observe_backlog(0, 42.0, 20.0);
        t.note_steal(1);
        let sh = t.shards();
        assert_eq!(sh[0].completed, 2);
        assert!((sh[0].busy_ms - 10.0).abs() < 1e-12);
        assert!((sh[0].backlog_ms - 42.0).abs() < 1e-12);
        assert_eq!(sh[1].dropped, 1);
        assert_eq!(sh[1].stolen_batches, 1);
        assert_eq!(t.steals(), 1);
        assert!(t.occupancy(0, 20.0) > 0.0);
        assert_eq!(t.occupancy(0, 0.0), 0.0);
        // Mean service over completions only (drops contribute nothing).
        assert!((t.mean_service_ms("a").unwrap() - 5.0).abs() < 1e-12);
        assert!(t.mean_service_ms("ghost").is_none());
        // Out-of-range shards are ignored, not a panic.
        t.observe_outcome(9, &ev(3, 30.0, false));
        t.observe_backlog(9, 1.0, 30.0);
        t.note_steal(9);
    }

    #[test]
    fn merge_folds_shard_counters_and_keeps_own_gauges() {
        use crate::metrics::RequestOutcome;
        let ev = |id: u64, arrival: f64, dropped: bool| RequestOutcome {
            id,
            task: "a".into(),
            arrival_ms: arrival,
            start_ms: arrival,
            finish_ms: arrival + 4.0,
            service_ms: 4.0,
            queueing_ms: 0.0,
            dropped,
            slo_ok: if dropped { None } else { Some(true) },
        };
        let mut coord = Telemetry::new(2);
        coord.observe_backlog(0, 17.0, 100.0);
        coord.note_steal(0);
        // A worker part: shard-half only, as the threaded drive does.
        let mut part = Telemetry::new(2);
        part.observe_shard_outcome(0, &ev(0, 0.0, false));
        part.observe_shard_outcome(0, &ev(1, 5.0, false));
        part.observe_shard_outcome(1, &ev(2, 9.0, true));
        part.note_steal(1);
        // The shard half never touches the task estimators.
        assert!(part.rate_qps("a").is_none());
        assert!(part.mean_service_ms("a").is_none());
        coord.merge(&part);
        let sh = coord.shards();
        assert_eq!(sh[0].completed, 2);
        assert!((sh[0].busy_ms - 8.0).abs() < 1e-12);
        assert_eq!(sh[0].stolen_batches, 1);
        assert_eq!(sh[1].dropped, 1);
        assert_eq!(sh[1].stolen_batches, 1);
        // Gauges belong to the coordinator and survive the merge.
        assert!((sh[0].backlog_ms - 17.0).abs() < 1e-12);
        // Merging twice doubles counters (merge is additive).
        coord.merge(&part);
        assert_eq!(coord.shards()[0].completed, 4);
        // Mismatched widths fold the common prefix rather than panic.
        coord.merge(&Telemetry::new(5));
        assert_eq!(coord.shards().len(), 2);
    }

    #[test]
    fn shard_backlog_forecast_tracks_the_trend() {
        let mut t = Telemetry::new(2);
        // Shard 0: backlog climbing 1 ms per ms; shard 1: flat.
        for i in 0..20 {
            let now = 100.0 * i as f64;
            t.observe_backlog(0, now, now);
            t.observe_backlog(1, 30.0, now);
        }
        let now = 1_900.0;
        let f0 = t.forecast_shard_backlog_ms(0, now, 500.0);
        assert!(
            f0 > t.shards()[0].backlog_ms,
            "a rising backlog must project above the last observation: {f0}"
        );
        let f1 = t.forecast_shard_backlog_ms(1, now, 500.0);
        assert!((f1 - 30.0).abs() < 1.0, "flat backlog projects flat: {f1}");
        // Unknown shards and cold trackers are total.
        assert_eq!(t.forecast_shard_backlog_ms(9, now, 500.0), 0.0);
        assert_eq!(Telemetry::new(1).forecast_shard_backlog_ms(0, 0.0, 500.0), 0.0);
    }

    #[test]
    fn projected_hint_follows_burst_faster_than_ewma() {
        let mut t = Telemetry::new(1);
        // 10 qps for 10 s, then a 200 qps burst for 600 ms.
        let mut now = 0.0;
        while now < 10_000.0 {
            t.observe_arrival("a", now);
            now += 100.0;
        }
        while now < 10_600.0 {
            t.observe_arrival("a", now);
            now += 5.0;
        }
        let trailing = t.rate_qps("a").unwrap();
        let projected = t.projected_rate_qps("a", now, 250.0).unwrap();
        assert!(
            projected > 2.0 * trailing,
            "projection must see the burst the EWMA smooths over: \
             {projected} vs {trailing}"
        );
        assert!(t.is_burst("a"), "the rate edge must flag a burst");
        let hint = t.projected_arrival_hint(now, 250.0);
        assert!((hint["a"] - projected).abs() < 1e-9);
        // Unobserved tasks stay absent (planner default weight).
        assert!(t.projected_rate_qps("ghost", now, 250.0).is_none());
        assert!(!t.is_burst("ghost"));
    }

    #[test]
    fn slo_forecast_scales_observed_misses_by_projected_load() {
        use crate::metrics::RequestOutcome;
        let mut t = Telemetry::new(1);
        let ev = |id: u64, arrival: f64, ok: bool| RequestOutcome {
            id,
            task: "a".into(),
            arrival_ms: arrival,
            start_ms: arrival,
            finish_ms: arrival + 10.0,
            service_ms: 10.0,
            queueing_ms: 0.0,
            dropped: false,
            slo_ok: Some(ok),
        };
        // Steady 20 qps; half the completions violate.
        for i in 0..100u64 {
            t.observe_outcome(0, &ev(i, 50.0 * i as f64, i % 2 == 0));
        }
        let slos = BTreeMap::from([(
            "a".to_string(),
            Slo { min_accuracy: 0.5, max_latency_ms: 5.0 },
        )]);
        let now = 5_000.0;
        let f = t.slo_forecast(&slos, now, 500.0);
        let p = f["a"];
        assert!((0.0..=1.0).contains(&p), "forecast is a probability: {p}");
        // Flat load ⇒ the projection stays near the observed 50 %.
        assert!((p - 0.5).abs() < 0.2, "flat load keeps the miss share: {p}");
        // Tasks outside the SLO map (or never completed) are absent.
        assert!(t.slo_forecast(&BTreeMap::new(), now, 500.0).is_empty());
    }

    #[test]
    fn plan_context_carries_live_estimates() {
        use crate::workload::Slo;
        let t = feed_poisson(40.0, 30_000.0, 3);
        let slos = BTreeMap::from([(
            "a".to_string(),
            Slo { min_accuracy: 0.5, max_latency_ms: 100.0 },
        )]);
        let ctx = t.plan_context(slos, Vec::new(), 10_000);
        let hint = ctx.arrival_hint.get("a").copied().expect("hint filled");
        assert!((hint - 40.0).abs() / 40.0 < 0.25, "{hint}");
        assert_eq!(ctx.memory_budget, 10_000);
    }
}
