//! Memory planning: Algorithm 2 preloading and hotness-driven budget
//! splits.
//!
//! The canonical home of the greedy hotness-ordered preloader (the old
//! `crate::preloader::preload` shim is gone — call [`preload`]
//! directly), plus the budget-split machinery the replan path uses:
//! a shard's pool budget is divided across its tasks **proportionally
//! to hotness mass** instead of evenly, so a task whose subgraphs cover
//! many SLO configurations keeps more resident working set.

use std::collections::BTreeMap;

use crate::preloader::{Hotness, PreloadPlan};
use crate::soc::BlobId;
use crate::zoo::TaskZoo;

fn blob_bytes(tz: &TaskZoo, variant: usize, sg: usize) -> u64 {
    tz.variants[variant].subgraphs[sg].bytes
}

/// Algorithm 2: greedy hotness-ordered preloading under a global budget.
///
/// Iterates hotness *ranks* in the outer loop (rank 0 of every
/// task/position first), not tasks — a task-sequential walk (Alg. 2 as
/// literally written) lets early tasks exhaust the budget before later
/// tasks load even their hottest subgraph. Rank-interleaving keeps the
/// greedy invariant (never load a colder blob while a hotter one at the
/// same position would fit) and is task-fair; DESIGN.md notes the
/// refinement.
pub fn preload(tasks: &[(&TaskZoo, &Hotness)], budget_bytes: u64) -> PreloadPlan {
    let mut plan = PreloadPlan { budget_bytes, ..Default::default() };
    let mut used = 0u64;
    let max_rank = tasks
        .iter()
        .map(|(_, h)| h.scores.first().map(|r| r.len()).unwrap_or(0))
        .max()
        .unwrap_or(0);
    for rank in 0..max_rank {
        for (tz, hot) in tasks {
            let s = hot.scores.len();
            for j in 0..s {
                let ranked = hot.ranked_at(j);
                let Some(&(i, score)) = ranked.get(rank) else { continue };
                if score <= 0.0 {
                    continue; // never feasible anywhere — skip cold blobs
                }
                let id = BlobId::new(&tz.name, i, j);
                if plan.contains(&id) {
                    continue;
                }
                let bytes = blob_bytes(tz, i, j);
                if used + bytes > budget_bytes {
                    continue;
                }
                used += bytes;
                plan.blobs.push(id);
            }
        }
    }
    plan.total_bytes = used;
    plan
}

/// Total hotness mass of one task: Σ over positions and variants of the
/// Eq. 7 scores. Proportional to how often the task's subgraphs appear
/// in SLO-feasible variant sets across Ψ.
pub fn hotness_mass(h: &Hotness) -> f64 {
    h.scores.iter().map(|row| row.iter().sum::<f64>()).sum()
}

/// Split `budget_bytes` across tasks proportionally to hotness mass
/// (an all-cold task set splits evenly). The shares sum to exactly
/// `budget_bytes`: fractional shares floor and the remainder goes to
/// the last task in slice order.
pub fn split_budget_by_hotness(
    tasks: &[(&TaskZoo, &Hotness)],
    budget_bytes: u64,
) -> BTreeMap<String, u64> {
    split_budget_by_hotness_weighted(tasks, budget_bytes, &BTreeMap::new())
}

/// [`split_budget_by_hotness`] with per-task traffic weights (e.g. the
/// telemetry arrival-rate estimates): each task's effective mass is its
/// Eq. 7 hotness mass × its weight, so budgets follow *served heat* —
/// a memory-hot task that receives no traffic cedes budget to one that
/// does. Missing weights default to 1.0; an empty map reproduces the
/// unweighted split exactly.
pub fn split_budget_by_hotness_weighted(
    tasks: &[(&TaskZoo, &Hotness)],
    budget_bytes: u64,
    traffic: &BTreeMap<String, f64>,
) -> BTreeMap<String, u64> {
    let mut out = BTreeMap::new();
    let n = tasks.len();
    if n == 0 {
        return out;
    }
    let masses: Vec<f64> = tasks
        .iter()
        .map(|(tz, h)| {
            let w = traffic.get(&tz.name).copied().unwrap_or(1.0).max(0.0);
            hotness_mass(h) * w
        })
        .collect();
    let total: f64 = masses.iter().sum();
    let weights: Vec<f64> = if total <= 0.0 {
        vec![1.0 / n as f64; n]
    } else {
        masses.iter().map(|m| m / total).collect()
    };
    let mut assigned = 0u64;
    for (i, (tz, _)) in tasks.iter().enumerate() {
        let share = if i + 1 == n {
            budget_bytes.saturating_sub(assigned)
        } else {
            (budget_bytes as f64 * weights[i]).floor() as u64
        };
        assigned = assigned.saturating_add(share);
        out.insert(tz.name.clone(), share);
    }
    out
}

/// Per-task budgeted preload: rank-greedy within each task under its
/// own share from [`split_budget_by_hotness`]. Unlike the
/// global-budget [`preload`], one task's bulk cannot crowd out another
/// task's hot set — the per-shard memory-budget mode. Exactly
/// [`preload`] applied per task at its own budget.
pub fn preload_split(
    tasks: &[(&TaskZoo, &Hotness)],
    budgets: &BTreeMap<String, u64>,
) -> PreloadPlan {
    let mut plan = PreloadPlan::default();
    for (tz, hot) in tasks {
        let budget = budgets.get(&tz.name).copied().unwrap_or(0);
        let part = preload(&[(*tz, *hot)], budget);
        plan.blobs.extend(part.blobs);
        plan.total_bytes += part.total_bytes;
        plan.budget_bytes += part.budget_bytes;
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixtures;
    use crate::preloader::full_preload_bytes;
    use crate::workload::{placement_orders, Slo};

    fn trio_hotness() -> (crate::zoo::Zoo, Vec<(String, Hotness)>) {
        let (zoo, lm, profiles) = fixtures::trio();
        let orders = placement_orders(&lm.platform, zoo.subgraphs);
        let universe = vec![
            Slo { min_accuracy: 0.0, max_latency_ms: 1e9 },
            Slo { min_accuracy: 0.8, max_latency_ms: 1e9 },
            Slo { min_accuracy: 0.88, max_latency_ms: 1e9 },
        ];
        let hot: Vec<(String, Hotness)> = profiles
            .iter()
            .map(|(name, p)| (name.clone(), Hotness::compute(p, &universe, &orders)))
            .collect();
        (zoo, hot)
    }

    fn pairs<'a>(
        zoo: &'a crate::zoo::Zoo,
        hot: &'a [(String, Hotness)],
    ) -> Vec<(&'a crate::zoo::TaskZoo, &'a Hotness)> {
        hot.iter()
            .map(|(name, h)| (zoo.task(name).unwrap(), h))
            .collect()
    }

    #[test]
    fn split_shares_sum_to_budget_and_track_mass() {
        let (zoo, hot) = trio_hotness();
        let refs = pairs(&zoo, &hot);
        for budget in [0u64, 999, 12_345] {
            let split = split_budget_by_hotness(&refs, budget);
            assert_eq!(split.len(), 3);
            assert_eq!(split.values().sum::<u64>(), budget);
        }
        // Higher mass ⇒ no smaller share (up to rounding).
        let split = split_budget_by_hotness(&refs, 1_000_000);
        for (a, ha) in &hot {
            for (b, hb) in &hot {
                if hotness_mass(ha) > hotness_mass(hb) + 1e-9 {
                    assert!(split[a] + 2 >= split[b], "{a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn weighted_split_follows_traffic() {
        let (zoo, hot) = trio_hotness();
        let refs = pairs(&zoo, &hot);
        let budget = 1_000_000u64;
        // Empty weights reproduce the unweighted split exactly.
        let plain = split_budget_by_hotness(&refs, budget);
        let empty = split_budget_by_hotness_weighted(&refs, budget, &BTreeMap::new());
        assert_eq!(plain, empty);
        // Skewing all traffic onto alpha must grow alpha's share and
        // shrink the others', while shares still sum to the budget.
        let traffic = BTreeMap::from([
            ("alpha".to_string(), 50.0),
            ("beta".to_string(), 1.0),
            ("gamma".to_string(), 1.0),
        ]);
        let skewed = split_budget_by_hotness_weighted(&refs, budget, &traffic);
        assert_eq!(skewed.values().sum::<u64>(), budget);
        assert!(
            skewed["alpha"] > plain["alpha"],
            "{} vs {}",
            skewed["alpha"],
            plain["alpha"]
        );
        assert!(skewed["beta"] < plain["beta"]);
        // All-zero weights degrade to the even split, never divide by
        // zero.
        let zeros: BTreeMap<String, f64> =
            ["alpha", "beta", "gamma"].iter().map(|t| (t.to_string(), 0.0)).collect();
        let even = split_budget_by_hotness_weighted(&refs, budget, &zeros);
        assert_eq!(even.values().sum::<u64>(), budget);
        for share in even.values() {
            assert!((*share as i64 - (budget / 3) as i64).abs() <= 1);
        }
    }

    #[test]
    fn split_preload_respects_per_task_shares() {
        let (zoo, hot) = trio_hotness();
        let refs = pairs(&zoo, &hot);
        let full = full_preload_bytes(&refs.iter().map(|(tz, _)| *tz).collect::<Vec<_>>());
        let budgets = split_budget_by_hotness(&refs, full / 3);
        let plan = preload_split(&refs, &budgets);
        assert!(plan.total_bytes <= full / 3);
        // Per-task bytes stay within each task's own share.
        for (tz, _) in &refs {
            let bytes: u64 = plan
                .blobs
                .iter()
                .filter(|b| b.task == tz.name)
                .map(|b| tz.variants[b.variant].subgraphs[b.subgraph].bytes)
                .sum();
            assert!(
                bytes <= budgets[&tz.name],
                "{}: {bytes} > {}",
                tz.name,
                budgets[&tz.name]
            );
        }
        // Under a generous split every task loads its hottest blob.
        let budgets = split_budget_by_hotness(&refs, full);
        let plan = preload_split(&refs, &budgets);
        for (tz, h) in &refs {
            let ranked = h.ranked_at(0);
            assert!(plan.contains(&BlobId::new(&tz.name, ranked[0].0, 0)));
        }
    }

    // --- single-task Alg. 2 pins --------------------------------------
    // Folded in from the removed `preloader::preload` shim's test
    // suite: the same assertions, stated against the canonical
    // [`preload`] on the two-position tiny fixture.

    fn tiny_setup() -> (crate::zoo::TaskZoo, Hotness) {
        use crate::profiler::{profile_task, ProfilerConfig};
        use crate::soc::{BaseLatencies, LatencyModel, Platform};
        use crate::zoo::KernelPath;
        let tz = crate::soc::latency::tests::tiny_taskzoo();
        let mut b = BaseLatencies::new();
        for sg in 0..2 {
            b.set("tiny", sg, KernelPath::Dense, 10.0);
            b.set("tiny", sg, KernelPath::BlockSparse, 8.0);
        }
        let plat = Platform::desktop();
        let orders = placement_orders(&plat, 2);
        let lm = LatencyModel::new(plat, b);
        let space = crate::stitching::StitchSpace::for_task(&tz);
        let oracle: Vec<f64> = space
            .iter()
            .map(|c| c.0.iter().map(|&i| tz.variants[i].accuracy).sum::<f64>() / 2.0)
            .collect();
        let cfg = ProfilerConfig {
            train_samples: 4,
            gbdt: crate::gbdt::GbdtParams {
                n_trees: 200,
                max_depth: 3,
                eta: 0.2,
                min_leaf: 1,
                subsample: 1.0,
                seed: 1,
            },
            seed: 23,
        };
        let p = profile_task(&tz, &lm, &oracle, &cfg, true);
        let universe = vec![
            Slo { min_accuracy: 0.0, max_latency_ms: 1e9 },
            Slo { min_accuracy: 0.75, max_latency_ms: 1e9 },
            Slo { min_accuracy: 0.85, max_latency_ms: 1e9 },
        ];
        let h = Hotness::compute(&p, &universe, &orders);
        (tz, h)
    }

    #[test]
    fn preload_respects_budget() {
        let (tz, h) = tiny_setup();
        let full = full_preload_bytes(&[&tz]);
        for frac in [0.1, 0.3, 0.55, 1.0] {
            let budget = (full as f64 * frac) as u64;
            let plan = preload(&[(&tz, &h)], budget);
            assert!(plan.total_bytes <= budget, "{} > {budget}", plan.total_bytes);
        }
    }

    #[test]
    fn full_budget_loads_all_hot_blobs() {
        let (tz, h) = tiny_setup();
        let plan = preload(&[(&tz, &h)], u64::MAX);
        // Every (variant, position) with positive hotness is loaded.
        let hot_count: usize = h
            .scores
            .iter()
            .map(|row| row.iter().filter(|&&x| x > 0.0).count())
            .sum();
        assert_eq!(plan.blobs.len(), hot_count);
    }

    #[test]
    fn greedy_prefers_hotter_variants() {
        let (tz, h) = tiny_setup();
        // Budget for exactly one (dense) blob: the greedy must spend it
        // on the hottest candidate at position 0 first.
        let plan = preload(&[(&tz, &h)], tz.variants[0].subgraphs[0].bytes);
        assert_eq!(plan.blobs.first(), Some(&BlobId::new("tiny", 0, 0)));
        // Alg. 2 walks positions in order and back-fills whatever still
        // fits, so a colder-but-smaller blob may follow — but never
        // *instead of* a hotter one at the same position.
        let full = full_preload_bytes(&[&tz]);
        let plan = preload(&[(&tz, &h)], full);
        for j in 0..2 {
            let ranked = h.ranked_at(j);
            assert!(plan.contains(&BlobId::new("tiny", ranked[0].0, j)));
        }
    }
}
