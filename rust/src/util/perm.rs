//! Permutations of processor sets — the Ω = P! placement-order space.

/// All permutations of `items`, in lexicographic order of indices
/// (Heap's algorithm would be faster but order-stability matters for
/// reproducible experiment tables).
pub fn permutations<T: Clone>(items: &[T]) -> Vec<Vec<T>> {
    let n = items.len();
    if n == 0 {
        return vec![vec![]];
    }
    let mut out = Vec::with_capacity(factorial(n));
    let mut idx: Vec<usize> = (0..n).collect();
    loop {
        out.push(idx.iter().map(|&i| items[i].clone()).collect());
        // next lexicographic permutation
        let Some(i) = (0..n - 1).rev().find(|&i| idx[i] < idx[i + 1]) else {
            break;
        };
        let j = (i + 1..n).rev().find(|&j| idx[j] > idx[i]).unwrap();
        idx.swap(i, j);
        idx[i + 1..].reverse();
    }
    out
}

pub fn factorial(n: usize) -> usize {
    (1..=n).product::<usize>().max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_factorial() {
        for n in 0..6 {
            let items: Vec<usize> = (0..n).collect();
            assert_eq!(permutations(&items).len(), factorial(n));
        }
    }

    #[test]
    fn three_items_lexicographic() {
        let p = permutations(&['a', 'b', 'c']);
        assert_eq!(p[0], vec!['a', 'b', 'c']);
        assert_eq!(p[5], vec!['c', 'b', 'a']);
        assert_eq!(p.len(), 6);
    }

    #[test]
    fn all_unique() {
        let p = permutations(&[0, 1, 2, 3]);
        let mut seen = std::collections::HashSet::new();
        for perm in &p {
            assert!(seen.insert(perm.clone()));
        }
    }

    #[test]
    fn factorial_values() {
        assert_eq!(factorial(0), 1);
        assert_eq!(factorial(1), 1);
        assert_eq!(factorial(3), 6);
        assert_eq!(factorial(5), 120);
    }
}
