//! Pass group 3: plan/stitch feasibility against a zoo (`SL-FEA-*`).
//!
//! Structural checks first — every task resolvable, every profile's
//! V^S space aligned with the zoo's interface (subgraph count, variant
//! alphabet, predictor table length), the space itself representable —
//! then, only when the structure is sound, a *probe*: run the real
//! planning + preloading pipeline per declared shard per phase and
//! check what comes back (selection indices in-bounds, per-task budgets
//! within the shard pool, preload sets that fit). The probe uses the
//! same `Coordinator::prepare` / `SparsityAwarePlanner::plan` code the
//! server runs at session open, so `lint` rejects exactly the plans
//! that would fail (or worse, panic) at serve time.

use std::collections::BTreeMap;

use crate::coordinator::{Coordinator, ServeOpts};
use crate::planner::{PlanContext, Planner, SparsityAwarePlanner};
use crate::profiler::TaskProfile;
use crate::scenario::Scenario;
use crate::soc::LatencyModel;
use crate::workload::Slo;
use crate::zoo::Zoo;

use super::{Diagnostic, Report};

/// Lint a scenario's plan/stitch feasibility against a concrete zoo +
/// latency model + profile set. Never panics: the zoo probe only runs
/// once the structural pass is clean.
pub fn lint_feasibility(
    sc: &Scenario,
    zoo: &Zoo,
    lm: &LatencyModel,
    profiles: &BTreeMap<String, TaskProfile>,
    opts: &ServeOpts,
) -> Report {
    let mut r = Report::new();
    for name in &sc.tasks {
        lint_task_structure(name, zoo, profiles, &mut r);
    }
    if r.has_errors() {
        r.push(Diagnostic::info(
            "SL-FEA-008",
            "probe",
            "zoo probe skipped: structural errors above would make planning unreliable",
        ));
        return r;
    }
    probe(sc, zoo, lm, profiles, opts, &mut r);
    lint_warm_migrate_links(sc, zoo, lm, &mut r);
    r
}

/// `SL-XLY-009` — warm migration only pays off when carrying a compiled
/// blob across the shard link is cheaper than rebuilding it cold. When
/// even the cheapest link is priced above the most expensive cold
/// rebuild (compile + load) anywhere in the scenario's zoo slice, every
/// migration the planner attempts is strictly worse than recompiling.
fn lint_warm_migrate_links(sc: &Scenario, zoo: &Zoo, lm: &LatencyModel, r: &mut Report) {
    if !sc.planner.warm_migrate {
        return;
    }
    let Some(links) = &sc.faults.links else { return };
    let Some(cheapest_link) = links.min_transfer_ms() else { return };
    let procs = lm.platform.processor_list();
    let mut worst_rebuild = 0.0f64;
    for name in &sc.tasks {
        let Some(tz) = zoo.tasks.get(name) else { continue };
        for v in &tz.variants {
            for sg in &v.subgraphs {
                for &proc in &procs {
                    let c = lm.compile_ms(sg.bytes, proc) + lm.load_ms(sg.bytes, proc);
                    if c > worst_rebuild {
                        worst_rebuild = c;
                    }
                }
            }
        }
    }
    if worst_rebuild > 0.0 && cheapest_link > worst_rebuild {
        r.push(Diagnostic::warn(
            "SL-XLY-009",
            "planner.warm_migrate",
            format!(
                "cheapest link transfer ({cheapest_link} ms) exceeds the most expensive \
                 cold rebuild in the zoo ({worst_rebuild:.3} ms): warm migration is \
                 strictly worse than recompiling on the destination"
            ),
        ));
    }
}

/// Structural alignment of one task across zoo, profile, and V^S space.
fn lint_task_structure(
    name: &str,
    zoo: &Zoo,
    profiles: &BTreeMap<String, TaskProfile>,
    r: &mut Report,
) {
    let at = format!("task {name:?}");
    let (Some(tz), Some(p)) = (zoo.tasks.get(name), profiles.get(name)) else {
        r.push(Diagnostic::error(
            "SL-FEA-001",
            at,
            "task unknown to the zoo or has no profile",
        ));
        return;
    };
    let mut aligned = true;
    let mut misalign = |what: String| {
        r.push(Diagnostic::error("SL-FEA-003", format!("task {name:?}"), what));
    };
    if tz.iface.len() != zoo.subgraphs + 1 {
        aligned = false;
        misalign(format!(
            "interface has {} boundaries, want S+1 = {}",
            tz.iface.len(),
            zoo.subgraphs + 1
        ));
    }
    if p.space.n_subgraphs != zoo.subgraphs {
        aligned = false;
        misalign(format!(
            "profile space spans {} subgraph position(s), zoo pipelines have {}",
            p.space.n_subgraphs, zoo.subgraphs
        ));
    }
    if p.space.n_variants != tz.variants.len() {
        aligned = false;
        misalign(format!(
            "profile space has a {}-variant alphabet, zoo ships {} variant(s)",
            p.space.n_variants,
            tz.variants.len()
        ));
    }
    for (i, v) in tz.variants.iter().enumerate() {
        if v.subgraphs.len() != zoo.subgraphs {
            aligned = false;
            misalign(format!(
                "variant {} ({:?}) has {} subgraph(s), want {}",
                i,
                v.spec.name,
                v.subgraphs.len(),
                zoo.subgraphs
            ));
        }
    }
    match p.space.try_len() {
        Err(e) => r.push(Diagnostic::error(
            "SL-FEA-006",
            format!("task {name:?}"),
            format!("stitched space is not representable: {e}"),
        )),
        Ok(n) if aligned && p.acc_pred.len() != n => {
            r.push(Diagnostic::error(
                "SL-FEA-003",
                format!("task {name:?}"),
                format!(
                    "accuracy predictor covers {} composition(s), V^S = {n}",
                    p.acc_pred.len()
                ),
            ));
        }
        Ok(_) => {}
    }
}

/// Run the real planning pipeline per declared shard per phase and
/// check the resulting selections, budgets, and preload sets.
fn probe(
    sc: &Scenario,
    zoo: &Zoo,
    lm: &LatencyModel,
    profiles: &BTreeMap<String, TaskProfile>,
    opts: &ServeOpts,
    r: &mut Report,
) {
    let universe = sc.slo_universe();
    let shards = sc.sharding.shards.max(1);
    let coord = Coordinator::new(zoo, lm, profiles);
    let planner = SparsityAwarePlanner::new(zoo, lm, profiles);
    for (phase, cfg) in sc.schedule.iter().enumerate() {
        for shard in 0..shards {
            let slos: BTreeMap<String, Slo> = sc
                .tasks
                .iter()
                .filter(|t| sc.sharding.shard_of(t) == shard)
                .filter_map(|t| cfg.get(t).map(|&slo| (t.clone(), slo)))
                .collect();
            if slos.is_empty() {
                continue;
            }
            let at = if shards > 1 {
                format!("phase {phase}, shard {shard}")
            } else {
                format!("phase {phase}")
            };
            let prepared = match coord.prepare(&slos, &universe, opts) {
                Ok(p) => p,
                Err(e) => {
                    r.push(Diagnostic::error(
                        "SL-FEA-008",
                        at,
                        format!("server preparation failed: {e}"),
                    ));
                    continue;
                }
            };
            for (task, sel) in &prepared.selections {
                match sel {
                    None => r.push(Diagnostic::warn(
                        "SL-FEA-007",
                        format!("{at}, task {task:?}"),
                        "no SLO-feasible stitched variant: the engine will serve the \
                         best pure variant and judge it as violating",
                    )),
                    Some(sel) => {
                        let len = profiles[task].space.try_len().unwrap_or(0);
                        if sel.stitched_index >= len {
                            r.push(Diagnostic::error(
                                "SL-FEA-002",
                                format!("{at}, task {task:?}"),
                                format!(
                                    "selected composition index {} out of bounds for \
                                     V^S = {len}",
                                    sel.stitched_index
                                ),
                            ));
                        }
                    }
                }
            }
            let plan = &prepared.preload_plan;
            if plan.total_bytes > plan.budget_bytes {
                r.push(Diagnostic::error(
                    "SL-FEA-005",
                    at.clone(),
                    format!(
                        "preload set ({} B) exceeds its budget ({} B)",
                        plan.total_bytes, plan.budget_bytes
                    ),
                ));
            }
            if prepared.pool.used() > prepared.pool.capacity() {
                r.push(Diagnostic::error(
                    "SL-FEA-005",
                    at.clone(),
                    format!(
                        "memory pool oversubscribed: {} B resident in a {} B pool",
                        prepared.pool.used(),
                        prepared.pool.capacity()
                    ),
                ));
            }
            let ctx = PlanContext::new(slos, prepared.pool.capacity())
                .with_universe(universe.clone());
            match planner.plan(&ctx) {
                Err(e) => r.push(Diagnostic::error(
                    "SL-FEA-008",
                    at,
                    format!("planner failed: {e}"),
                )),
                Ok(plan) => {
                    let total: u64 = plan.task_budgets.values().sum();
                    if total > ctx.memory_budget {
                        r.push(Diagnostic::error(
                            "SL-FEA-004",
                            at,
                            format!(
                                "per-task budgets sum to {} B, over the {} B shard pool",
                                total, ctx.memory_budget
                            ),
                        ));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixtures;
    use crate::scenario::Sharding;
    use crate::stitching::StitchSpace;

    fn codes(r: &Report) -> Vec<&'static str> {
        r.diagnostics.iter().map(|d| d.code).collect()
    }

    #[test]
    fn clean_fixture_scenario_is_feasible() {
        let (zoo, lm, profiles) = fixtures::quartet();
        let sc = crate::scenario::Scenario::closed_loop(
            &fixtures::task_names(&zoo),
            fixtures::slos(&zoo, 0.5, 1e9),
        );
        let r = lint_feasibility(&sc, &zoo, &lm, &profiles, &ServeOpts::default());
        assert!(!r.has_errors(), "{}", r.render_text());
    }

    #[test]
    fn sharded_scenario_probes_each_partition() {
        let (zoo, lm, profiles) = fixtures::quartet();
        let sc = crate::scenario::Scenario::poisson(
            &fixtures::task_names(&zoo),
            fixtures::slos(&zoo, 0.5, 1e9),
            20.0,
            500.0,
        )
        .with_sharding(Sharding::hash(2));
        let r = lint_feasibility(&sc, &zoo, &lm, &profiles, &ServeOpts::default());
        assert!(!r.has_errors(), "{}", r.render_text());
    }

    #[test]
    fn unknown_task_is_a_structural_error() {
        let (zoo, lm, profiles) = fixtures::tiny();
        let sc = crate::scenario::Scenario::closed_loop(
            &["tiny".to_string(), "ghost".to_string()],
            fixtures::slos(&zoo, 0.5, 1e9),
        );
        let r = lint_feasibility(&sc, &zoo, &lm, &profiles, &ServeOpts::default());
        assert!(codes(&r).contains(&"SL-FEA-001"), "{}", r.render_text());
        // Structural errors fence off the probe.
        assert!(codes(&r).contains(&"SL-FEA-008"), "{}", r.render_text());
    }

    #[test]
    fn misaligned_profile_is_rejected_without_probing() {
        let (zoo, lm, mut profiles) = fixtures::tiny();
        profiles.get_mut("tiny").unwrap().acc_pred.pop();
        let sc = crate::scenario::Scenario::closed_loop(
            &["tiny".to_string()],
            fixtures::slos(&zoo, 0.5, 1e9),
        );
        let r = lint_feasibility(&sc, &zoo, &lm, &profiles, &ServeOpts::default());
        assert!(codes(&r).contains(&"SL-FEA-003"), "{}", r.render_text());
    }

    #[test]
    fn unrepresentable_space_is_typed() {
        let (zoo, lm, mut profiles) = fixtures::tiny();
        profiles.get_mut("tiny").unwrap().space =
            StitchSpace { n_variants: 3, n_subgraphs: usize::BITS as usize };
        let sc = crate::scenario::Scenario::closed_loop(
            &["tiny".to_string()],
            fixtures::slos(&zoo, 0.5, 1e9),
        );
        let r = lint_feasibility(&sc, &zoo, &lm, &profiles, &ServeOpts::default());
        assert!(codes(&r).contains(&"SL-FEA-006"), "{}", r.render_text());
    }

    #[test]
    fn warm_migrate_priced_out_by_links_warns() {
        use crate::scenario::{FaultProfile, LinkMatrix, PlannerConfig};
        let (zoo, lm, profiles) = fixtures::quartet();
        let sc = crate::scenario::Scenario::poisson(
            &fixtures::task_names(&zoo),
            fixtures::slos(&zoo, 0.5, 1e9),
            20.0,
            500.0,
        )
        .with_sharding(Sharding::hash(2))
        .with_planner(PlannerConfig::online())
        .with_faults(FaultProfile {
            links: Some(LinkMatrix {
                transfer_ms: vec![vec![0.0, 1e6], vec![1e6, 0.0]],
            }),
            ..FaultProfile::default()
        });
        let r = lint_feasibility(&sc, &zoo, &lm, &profiles, &ServeOpts::default());
        assert!(codes(&r).contains(&"SL-XLY-009"), "{}", r.render_text());
        assert!(!r.has_errors(), "{}", r.render_text());

        // Cheap links don't warn: migration can genuinely win.
        let cheap = crate::scenario::Scenario::poisson(
            &fixtures::task_names(&zoo),
            fixtures::slos(&zoo, 0.5, 1e9),
            20.0,
            500.0,
        )
        .with_sharding(Sharding::hash(2))
        .with_planner(PlannerConfig::online())
        .with_faults(FaultProfile {
            links: Some(LinkMatrix {
                transfer_ms: vec![vec![0.0, 0.01], vec![0.01, 0.0]],
            }),
            ..FaultProfile::default()
        });
        let r = lint_feasibility(&cheap, &zoo, &lm, &profiles, &ServeOpts::default());
        assert!(!codes(&r).contains(&"SL-XLY-009"), "{}", r.render_text());
    }

    #[test]
    fn infeasible_slo_warns_but_does_not_block() {
        let (zoo, lm, profiles) = fixtures::tiny();
        let sc = crate::scenario::Scenario::closed_loop(
            &["tiny".to_string()],
            fixtures::slos(&zoo, 0.999, 1e9),
        );
        let r = lint_feasibility(&sc, &zoo, &lm, &profiles, &ServeOpts::default());
        assert!(codes(&r).contains(&"SL-FEA-007"), "{}", r.render_text());
        assert!(!r.has_errors(), "{}", r.render_text());
    }
}
