//! SLO-violation attribution: replay a trace and charge every
//! violation to exactly one dominant cause bucket.
//!
//! Buckets (fixed order; deterministic first-max tie-break):
//!
//! | bucket         | component of the miss                             |
//! |----------------|---------------------------------------------------|
//! | `queueing`     | `queueing_ms` — waiting before the first stage    |
//! | `execution`    | `service_ms` minus all switch penalties — the     |
//! |                | variant's own inference time                      |
//! | `cold-compile` | cold switch penalty (compile + load) in service   |
//! | `migration`    | warm-migration load penalty in service            |
//! | `link`         | cross-shard transfer cost delaying the first      |
//! |                | post-adoption batch                               |
//! | `throttle`     | DVFS stretch delaying this batch's completion     |
//! | `shed`         | the request never ran (admission shed, crash      |
//! |                | swallow, no runnable variant)                     |
//!
//! A completed request misses when its `TR-REQ-EXEC` span says
//! `slo_ok == 0`; the dominant (largest) component above wins. Every
//! dropped request (`TR-REQ-SHED` / `TR-REQ-DROP`) lands in `shed`.
//! Counts therefore reconcile exactly with `RunReport`:
//! Σ misses = `slo_miss_count`, Σ shed = `total_dropped` — the
//! trace-consistency invariant pass (`SL-INV-*`) enforces this.

use std::collections::BTreeMap;

use crate::metrics::render_table;

use super::{TraceEvent, TR_REQ_DONE, TR_REQ_DROP, TR_REQ_EXEC, TR_REQ_SHED};

/// Attribution bucket labels, in dominance tie-break order.
pub const BUCKETS: [&str; 7] = [
    "queueing",
    "execution",
    "cold-compile",
    "migration",
    "link",
    "throttle",
    "shed",
];

/// Per-task and total violation attribution over one trace.
#[derive(Clone, Debug, Default)]
pub struct Attribution {
    /// Bucket counts per task, indexed like [`BUCKETS`].
    pub per_task: BTreeMap<String, [usize; 7]>,
    /// Completed requests observed (`TR-REQ-DONE` count).
    pub done: usize,
    /// SLO misses attributed (each to exactly one bucket).
    pub misses: usize,
    /// Dropped requests attributed to `shed`.
    pub sheds: usize,
}

impl Attribution {
    /// Bucket totals across tasks, indexed like [`BUCKETS`].
    pub fn totals(&self) -> [usize; 7] {
        let mut t = [0usize; 7];
        for counts in self.per_task.values() {
            for (i, c) in counts.iter().enumerate() {
                t[i] += c;
            }
        }
        t
    }
}

/// Attribute every SLO violation in `events` to a dominant bucket.
pub fn attribute(events: &[TraceEvent]) -> Attribution {
    let mut a = Attribution::default();
    for ev in events {
        match ev.code.as_str() {
            TR_REQ_DONE => a.done += 1,
            TR_REQ_SHED | TR_REQ_DROP => {
                a.per_task.entry(ev.task.clone()).or_default()[6] += 1;
                a.sheds += 1;
            }
            TR_REQ_EXEC => {
                if ev.arg("slo_ok").unwrap_or(1.0) != 0.0 {
                    continue;
                }
                let service = ev.arg("service_ms").unwrap_or(0.0);
                let cold = ev.arg("cold_ms").unwrap_or(0.0);
                let warm = ev.arg("warm_ms").unwrap_or(0.0);
                let components = [
                    ev.arg("queueing_ms").unwrap_or(0.0),
                    (service - cold - warm).max(0.0),
                    cold,
                    warm,
                    ev.arg("link_ms").unwrap_or(0.0),
                    ev.arg("throttle_ms").unwrap_or(0.0),
                ];
                // First strict max wins — ties break toward the earlier
                // bucket, deterministically.
                let mut best = 0usize;
                for (i, &c) in components.iter().enumerate() {
                    if c > components[best] {
                        best = i;
                    }
                }
                a.per_task.entry(ev.task.clone()).or_default()[best] += 1;
                a.misses += 1;
            }
            _ => {}
        }
    }
    a
}

/// Render the per-task attribution table plus reconciliation lines.
pub fn render(a: &Attribution) -> String {
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (task, counts) in &a.per_task {
        let mut row = vec![task.clone()];
        row.push((counts.iter().take(6).sum::<usize>()).to_string());
        row.extend(counts.iter().map(|c| c.to_string()));
        rows.push(row);
    }
    let totals = a.totals();
    let mut total_row = vec!["TOTAL".to_string(), a.misses.to_string()];
    total_row.extend(totals.iter().map(|c| c.to_string()));
    rows.push(total_row);
    let headers = [
        "task", "misses", "queueing", "execution", "cold", "migration", "link",
        "throttle", "shed",
    ];
    let mut out = String::from("SLO-violation attribution (dominant cause per request)\n\n");
    out.push_str(&render_table(&headers, &rows));
    out.push('\n');
    let attributed: usize = totals.iter().take(6).sum();
    out.push_str(&format!(
        "attributed {attributed}/{} misses and {}/{} drops ({} requests completed)\n",
        a.misses, totals[6], a.sheds, a.done
    ));
    let named: Vec<String> = BUCKETS
        .iter()
        .zip(totals.iter())
        .map(|(b, c)| format!("{b}={c}"))
        .collect();
    out.push_str(&format!("buckets: {}\n", named.join(" ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{TR_REQ_DONE, TR_REQ_DROP, TR_REQ_EXEC, TR_REQ_SHED};

    fn exec(task: &str, slo_ok: f64, args: &[(&str, f64)]) -> TraceEvent {
        let mut all = vec![("slo_ok", slo_ok)];
        all.extend_from_slice(args);
        TraceEvent::new(TR_REQ_EXEC, 0, task, Some(1), 0.0, 1.0, &all)
    }

    #[test]
    fn dominant_component_wins_and_every_miss_gets_one_bucket() {
        let events = vec![
            // Queueing dominates.
            exec("a", 0.0, &[("service_ms", 5.0), ("queueing_ms", 40.0)]),
            // Cold compile dominates (service = 30 of which cold = 25).
            exec("a", 0.0, &[
                ("service_ms", 30.0),
                ("cold_ms", 25.0),
                ("queueing_ms", 2.0),
            ]),
            // Throttle dominates.
            exec("b", 0.0, &[("service_ms", 3.0), ("throttle_ms", 50.0)]),
            // SLO met: not attributed.
            exec("b", 1.0, &[("service_ms", 3.0), ("queueing_ms", 99.0)]),
            TraceEvent::new(TR_REQ_DONE, 0, "a", Some(1), 1.0, 1.0, &[]),
            TraceEvent::new(TR_REQ_SHED, 0, "b", Some(2), 2.0, 2.0, &[]),
            TraceEvent::new(TR_REQ_DROP, 0, "b", Some(3), 3.0, 3.0, &[]),
        ];
        let a = attribute(&events);
        assert_eq!(a.misses, 3);
        assert_eq!(a.sheds, 2);
        assert_eq!(a.done, 1);
        let t = a.totals();
        assert_eq!(t[0], 1, "queueing");
        assert_eq!(t[2], 1, "cold-compile");
        assert_eq!(t[5], 1, "throttle");
        assert_eq!(t[6], 2, "shed");
        // Exactly one bucket per violation.
        assert_eq!(t.iter().sum::<usize>(), a.misses + a.sheds);
        let text = render(&a);
        assert!(text.contains("TOTAL"));
        assert!(text.contains("attributed 3/3 misses"));
        assert!(text.contains("shed=2"));
    }

    #[test]
    fn execution_component_excludes_penalties() {
        // service 20 = 12 exec + 8 cold: execution dominates.
        let a = attribute(&[exec("a", 0.0, &[
            ("service_ms", 20.0),
            ("cold_ms", 8.0),
        ])]);
        assert_eq!(a.totals()[1], 1);
        // service 20 = 6 exec + 14 warm-migration: migration dominates.
        let b = attribute(&[exec("a", 0.0, &[
            ("service_ms", 20.0),
            ("warm_ms", 14.0),
        ])]);
        assert_eq!(b.totals()[3], 1);
    }
}
