//! Tiny declarative CLI parser (offline substrate for `clap`).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean
//! `--switch`, positional args, defaults, and auto-generated help.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

/// One option specification.
#[derive(Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// A parsed invocation: values for options plus positional args.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError(format!("--{name} expects an integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError(format!("--{name} expects a number, got {v:?}"))),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.get(name) == Some("true")
    }
}

/// A subcommand with its options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str,
               default: Option<&'static str>) -> Self {
        self.opts.push(Opt { name, help, default, is_switch: false });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_switch: true });
        self
    }

    /// Parse this command's arguments (everything after the subcommand).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for opt in &self.opts {
            if let Some(d) = opt.default {
                args.values.insert(opt.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError(format!(
                        "unknown option --{name} for `{}`", self.name)))?;
                let value = if opt.is_switch {
                    inline.unwrap_or_else(|| "true".into())
                } else if let Some(v) = inline {
                    v
                } else {
                    it.next()
                        .cloned()
                        .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                };
                args.values.insert(name.to_string(), value);
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn help(&self) -> String {
        let mut s = format!("  {:<12} {}\n", self.name, self.about);
        for o in &self.opts {
            let d = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("      --{:<18} {}{}\n", o.name, o.help, d));
        }
        s
    }
}

/// The top-level application: a set of subcommands.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE: {} <command> [options]\n\nCOMMANDS:\n",
                            self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&c.help());
        }
        s
    }

    /// Dispatch: returns (command name, parsed args) or help text error.
    pub fn dispatch(&self, argv: &[String]) -> Result<(&Command, Args), CliError> {
        let Some(cmd_name) = argv.first() else {
            return Err(CliError(self.help()));
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(CliError(self.help()));
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| CliError(format!(
                "unknown command {cmd_name:?}\n\n{}", self.help())))?;
        let args = cmd.parse(&argv[1..])?;
        Ok((cmd, args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("serve", "run the coordinator")
            .opt("platform", "platform profile", Some("desktop"))
            .opt("queries", "queries per task", Some("100"))
            .switch("verbose", "chatty output")
    }

    fn s(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&[]).unwrap();
        assert_eq!(a.get("platform"), Some("desktop"));
        assert_eq!(a.get_usize("queries").unwrap(), Some(100));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd().parse(&s(&["--platform", "laptop"])).unwrap();
        assert_eq!(a.get("platform"), Some("laptop"));
        let b = cmd().parse(&s(&["--platform=orin"])).unwrap();
        assert_eq!(b.get("platform"), Some("orin"));
    }

    #[test]
    fn switches_and_positionals() {
        let a = cmd().parse(&s(&["--verbose", "extra1", "extra2"])).unwrap();
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&s(&["--nope", "x"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&s(&["--platform"])).is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let a = cmd().parse(&s(&["--queries", "abc"])).unwrap();
        assert!(a.get_usize("queries").is_err());
    }

    #[test]
    fn app_dispatch() {
        let app = App {
            name: "sparseloom",
            about: "test",
            commands: vec![cmd()],
        };
        let (c, a) = app.dispatch(&s(&["serve", "--queries", "7"])).unwrap();
        assert_eq!(c.name, "serve");
        assert_eq!(a.get_usize("queries").unwrap(), Some(7));
        assert!(app.dispatch(&s(&["bogus"])).is_err());
        assert!(app.dispatch(&[]).is_err());
    }
}
