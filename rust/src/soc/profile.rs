//! Platform profiles: the three evaluation SoCs of paper Table 3.
//!
//! Substitution note (DESIGN.md §Substitutions): the real devices are
//! hardware-gated, so each platform is a *calibrated performance model*
//! over the measured PJRT-CPU subgraph latencies. The scale factors
//! encode the qualitative structure the paper's Table 2 / Fig. 13 rest
//! on — NPUs love INT8 and structured sparsity but can't accelerate
//! unstructured pruning; GPUs are the dense-FP throughput kings;
//! CPU sparse engines (DeepSparse-style) reward unstructured pruning —
//! so the *shape* of every downstream result (best order varies by
//! variant mix, placement matters up to 2×) is preserved.

use anyhow::{bail, Result};

use crate::zoo::{VariantSpec, VariantType};

/// A processor class on an edge SoC.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Processor {
    Cpu,
    Gpu,
    Npu,
}

impl Processor {
    pub fn name(&self) -> &'static str {
        match self {
            Self::Cpu => "CPU",
            Self::Gpu => "GPU",
            Self::Npu => "NPU",
        }
    }

    /// Dense index (CPU=0, GPU=1, NPU=2) for table-backed lookups.
    #[inline]
    pub fn idx(&self) -> usize {
        match self {
            Self::Cpu => 0,
            Self::Gpu => 1,
            Self::Npu => 2,
        }
    }

    /// One-letter tag for paper-style order labels ("C-G-N").
    pub fn tag(&self) -> char {
        match self {
            Self::Cpu => 'C',
            Self::Gpu => 'G',
            Self::Npu => 'N',
        }
    }
}

/// Format a placement order as the paper does: "C-G-N".
pub fn order_label(order: &[Processor]) -> String {
    order
        .iter()
        .map(|p| p.tag().to_string())
        .collect::<Vec<_>>()
        .join("-")
}

/// Per-processor cost coefficients.
#[derive(Clone, Debug)]
pub struct ProcessorModel {
    pub proc: Processor,
    /// Dense-FP32 latency multiplier vs the measured PJRT-CPU baseline.
    pub dense_scale: f64,
    /// Additional multiplier for FP16 weights.
    pub fp16_factor: f64,
    /// Additional multiplier for INT8 (quant path).
    pub int8_factor: f64,
    /// Unstructured (masked) support: `None` = unsupported on this
    /// processor; `Some(gain)` = latency × (1 − gain·sparsity).
    pub unstructured_gain: Option<f64>,
    /// Structured (block-sparse) channel-skip gain: × (1 − gain·sparsity).
    pub structured_gain: f64,
    /// Model compile cost per MiB of weights (ms) — paper Fig. 5a says
    /// compilation ≈ 23.7× inference.
    pub compile_ms_per_mib: f64,
    /// Weight load (disk → device pool) cost per MiB (ms) — ≈ 3× infer.
    pub load_ms_per_mib: f64,
}

impl ProcessorModel {
    /// Latency multiplier for a variant on this processor.
    /// Returns `None` if the variant type is unsupported here.
    pub fn scale_for(&self, spec: &VariantSpec) -> Option<f64> {
        let base = self.dense_scale;
        Some(match spec.vtype {
            VariantType::Dense => base,
            VariantType::Fp16 => base * self.fp16_factor,
            VariantType::Int8 => base * self.int8_factor,
            VariantType::Unstructured => {
                let gain = self.unstructured_gain?;
                base * (1.0 - gain * spec.sparsity).max(0.05)
            }
            VariantType::Structured => {
                base * (1.0 - self.structured_gain * spec.sparsity).max(0.05)
            }
        })
    }
}

/// An evaluation platform (paper Table 3).
#[derive(Clone, Debug)]
pub struct Platform {
    pub name: &'static str,
    pub description: &'static str,
    pub processors: Vec<ProcessorModel>,
    /// Device memory pool available to model weights (unified memory).
    pub memory_bytes: u64,
    /// Fraction of per-hop latency added for inter-processor activation
    /// transfer + format conversion (paper §5.4 measures ≈ 5 % total).
    pub interproc_overhead: f64,
    /// DVFS frequency multiplier (1.0 = nominal; > 1 = throttled).
    pub dvfs_slowdown: f64,
    /// Co-execution slowdown coefficient κ: running N DNNs *concurrently*
    /// on one processor (the NP systems' mode) costs ×(1 + κ·(N−1)) per
    /// inference — memory-bandwidth and scheduler contention, the effect
    /// Hetero²Pipe [45] measures and the paper's §1 cites. Pipelined
    /// subgraph execution time-multiplexes exclusively and does not pay it.
    pub coexec_slowdown: f64,
    /// Marginal cost of growing a batch: a coalesced batch of `b`
    /// same-task queries costs `1 + batch_marginal·(b−1)` single-query
    /// latencies per stage (weights and dispatch amortize across the
    /// batch; activation compute still scales). Values < 1 are what make
    /// batching under backlog profitable (`LatencyModel::batch_factor`).
    pub batch_marginal: f64,
}

impl Platform {
    pub fn processor_list(&self) -> Vec<Processor> {
        self.processors.iter().map(|m| m.proc).collect()
    }

    pub fn model(&self, p: Processor) -> Option<&ProcessorModel> {
        self.processors.iter().find(|m| m.proc == p)
    }

    pub fn n_processors(&self) -> usize {
        self.processors.len()
    }

    /// Desktop: Intel Core Ultra 7 265K — 20-core CPU, 4-Xe GPU, AI Boost NPU.
    pub fn desktop() -> Platform {
        Platform {
            name: "desktop",
            description: "Intel Core Ultra 7 265K (x86 20-core CPU, 4-Xe GPU, AI Boost NPU)",
            processors: vec![
                ProcessorModel {
                    proc: Processor::Cpu,
                    dense_scale: 1.0,
                    fp16_factor: 0.95,
                    int8_factor: 0.72,
                    // DeepSparse-style sparse engine on CPU.
                    unstructured_gain: Some(0.75),
                    structured_gain: 0.55,
                    compile_ms_per_mib: 12.0,
                    load_ms_per_mib: 1.5,
                },
                ProcessorModel {
                    proc: Processor::Gpu,
                    dense_scale: 0.48,
                    fp16_factor: 0.62,
                    int8_factor: 0.80,
                    // GPUs gain little from zero-masking.
                    unstructured_gain: Some(0.10),
                    structured_gain: 0.60,
                    compile_ms_per_mib: 17.0,
                    load_ms_per_mib: 2.0,
                },
                ProcessorModel {
                    proc: Processor::Npu,
                    dense_scale: 0.85,
                    fp16_factor: 0.55,
                    int8_factor: 0.45,
                    // Intel AI Boost runs masked models but w/o gain.
                    unstructured_gain: Some(0.0),
                    structured_gain: 0.65,
                    compile_ms_per_mib: 21.0,
                    load_ms_per_mib: 2.5,
                },
            ],
            memory_bytes: 8 * 1024 * 1024 * 1024,
            interproc_overhead: 0.075,
            dvfs_slowdown: 1.0,
            coexec_slowdown: 0.30,
            batch_marginal: 0.32,
        }
    }

    /// Laptop: Intel Core Ultra 5 135U — 12-core CPU, 4-Xe GPU, AI Boost NPU.
    pub fn laptop() -> Platform {
        Platform {
            name: "laptop",
            description: "Intel Core Ultra 5 135U (x86 12-core CPU, 4-Xe GPU, AI Boost NPU)",
            processors: vec![
                ProcessorModel {
                    proc: Processor::Cpu,
                    dense_scale: 1.55,
                    fp16_factor: 0.95,
                    int8_factor: 0.74,
                    unstructured_gain: Some(0.72),
                    structured_gain: 0.55,
                    compile_ms_per_mib: 16.0,
                    load_ms_per_mib: 2.0,
                },
                ProcessorModel {
                    proc: Processor::Gpu,
                    dense_scale: 0.66,
                    fp16_factor: 0.62,
                    int8_factor: 0.82,
                    unstructured_gain: Some(0.10),
                    structured_gain: 0.60,
                    compile_ms_per_mib: 22.0,
                    load_ms_per_mib: 2.7,
                },
                ProcessorModel {
                    proc: Processor::Npu,
                    dense_scale: 1.05,
                    fp16_factor: 0.56,
                    int8_factor: 0.47,
                    unstructured_gain: Some(0.0),
                    structured_gain: 0.65,
                    compile_ms_per_mib: 27.0,
                    load_ms_per_mib: 3.2,
                },
            ],
            memory_bytes: 4 * 1024 * 1024 * 1024,
            interproc_overhead: 0.080,
            dvfs_slowdown: 1.0,
            coexec_slowdown: 0.35,
            batch_marginal: 0.38,
        }
    }

    /// NVIDIA Jetson AGX Orin (MAXN): 12-core ARM CPU + Ampere GPU, no NPU.
    /// Its zoo (Table 5) also has no unstructured variants.
    pub fn orin() -> Platform {
        Platform {
            name: "orin",
            description: "NVIDIA Jetson AGX Orin MAXN (ARM 12-core CPU, 2048-core Ampere GPU)",
            processors: vec![
                ProcessorModel {
                    proc: Processor::Cpu,
                    dense_scale: 1.25,
                    fp16_factor: 0.97,
                    int8_factor: 0.80,
                    // No sparse-engine runtime for ARM in this stack.
                    unstructured_gain: None,
                    structured_gain: 0.50,
                    compile_ms_per_mib: 20.0,
                    load_ms_per_mib: 2.3,
                },
                ProcessorModel {
                    proc: Processor::Gpu,
                    dense_scale: 0.55,
                    fp16_factor: 0.55,
                    int8_factor: 0.62,
                    unstructured_gain: None,
                    structured_gain: 0.62,
                    compile_ms_per_mib: 28.0,
                    load_ms_per_mib: 1.7,
                },
            ],
            memory_bytes: 32 * 1024 * 1024 * 1024,
            interproc_overhead: 0.070,
            dvfs_slowdown: 1.0,
            coexec_slowdown: 0.40,
            batch_marginal: 0.30,
        }
    }

    pub fn by_name(name: &str) -> Result<Platform> {
        Ok(match name {
            "desktop" => Self::desktop(),
            "laptop" => Self::laptop(),
            "orin" => Self::orin(),
            other => bail!("unknown platform {other:?} (desktop|laptop|orin)"),
        })
    }

    pub fn all() -> Vec<Platform> {
        vec![Self::desktop(), Self::laptop(), Self::orin()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::zoo::{KernelPath, Precision};

    fn spec(vtype: VariantType, sparsity: f64) -> VariantSpec {
        VariantSpec {
            name: "t".into(),
            vtype,
            sparsity,
            kernel_path: KernelPath::Dense,
            precision: Precision::Fp32,
        }
    }

    #[test]
    fn table3_processor_counts() {
        assert_eq!(Platform::desktop().n_processors(), 3);
        assert_eq!(Platform::laptop().n_processors(), 3);
        assert_eq!(Platform::orin().n_processors(), 2); // no NPU
    }

    #[test]
    fn npu_loves_int8() {
        let d = Platform::desktop();
        let npu = d.model(Processor::Npu).unwrap();
        let int8 = npu.scale_for(&spec(VariantType::Int8, 0.0)).unwrap();
        let dense = npu.scale_for(&spec(VariantType::Dense, 0.0)).unwrap();
        assert!(int8 < 0.5 * dense, "NPU INT8 should be ≥2× dense speed");
    }

    #[test]
    fn gpu_fastest_dense() {
        let d = Platform::desktop();
        let g = d.model(Processor::Gpu).unwrap().scale_for(&spec(VariantType::Dense, 0.0)).unwrap();
        let c = d.model(Processor::Cpu).unwrap().scale_for(&spec(VariantType::Dense, 0.0)).unwrap();
        let n = d.model(Processor::Npu).unwrap().scale_for(&spec(VariantType::Dense, 0.0)).unwrap();
        assert!(g < c && g < n);
    }

    #[test]
    fn cpu_rewards_unstructured_sparsity() {
        let d = Platform::desktop();
        let cpu = d.model(Processor::Cpu).unwrap();
        let s90 = cpu.scale_for(&spec(VariantType::Unstructured, 0.9)).unwrap();
        let s65 = cpu.scale_for(&spec(VariantType::Unstructured, 0.65)).unwrap();
        let dense = cpu.scale_for(&spec(VariantType::Dense, 0.0)).unwrap();
        assert!(s90 < s65 && s65 < dense);
    }

    #[test]
    fn orin_rejects_unstructured() {
        let o = Platform::orin();
        for m in &o.processors {
            assert!(m.scale_for(&spec(VariantType::Unstructured, 0.8)).is_none());
        }
    }

    #[test]
    fn structured_monotone_in_sparsity() {
        let d = Platform::laptop();
        for m in &d.processors {
            let lo = m.scale_for(&spec(VariantType::Structured, 0.2)).unwrap();
            let hi = m.scale_for(&spec(VariantType::Structured, 0.55)).unwrap();
            assert!(hi < lo);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for p in Platform::all() {
            assert_eq!(Platform::by_name(p.name).unwrap().name, p.name);
        }
        assert!(Platform::by_name("phone").is_err());
    }

    #[test]
    fn order_labels() {
        use Processor::*;
        assert_eq!(order_label(&[Npu, Gpu, Cpu]), "N-G-C");
        assert_eq!(order_label(&[Gpu, Cpu]), "G-C");
    }
}
