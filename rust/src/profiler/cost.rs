//! Profiling-cost accounting (paper Table 1, Eq. 6, Figs. 8 & 12).
//!
//! A "profiling run" measures one variant's accuracy *or* one latency
//! configuration. Exhaustive profiling of the stitched space needs
//! `T·V^S` accuracy runs and `T·V^S·P!` latency runs; SparseLoom's
//! estimators need `T·V` accuracy runs and `T·S·V·P` subgraph-latency
//! runs.

use crate::util::factorial;

/// Problem-size parameters (paper notation).
#[derive(Clone, Copy, Debug)]
pub struct CostParams {
    /// T — number of tasks.
    pub tasks: usize,
    /// V — variants per task.
    pub variants: usize,
    /// S — subgraphs per variant.
    pub subgraphs: usize,
    /// P — processors.
    pub processors: usize,
}

impl CostParams {
    /// Placement orders |Ω| = P!.
    pub fn orders(&self) -> usize {
        factorial(self.processors)
    }

    /// Total stitched variants per task: V^S.
    pub fn stitched_per_task(&self) -> usize {
        self.variants.pow(self.subgraphs as u32)
    }

    // ---- Table 1: without stitching --------------------------------

    pub fn no_stitch_accuracy_runs(&self) -> usize {
        self.tasks * self.variants
    }

    pub fn no_stitch_latency_runs(&self) -> usize {
        self.tasks * self.variants * self.orders()
    }

    pub fn no_stitch_total_runs(&self) -> usize {
        self.tasks * self.variants * (self.orders() + 1)
    }

    // ---- Table 1: with stitching, exhaustive ------------------------

    pub fn exhaustive_accuracy_runs(&self) -> usize {
        self.tasks * self.stitched_per_task()
    }

    pub fn exhaustive_latency_runs(&self) -> usize {
        self.tasks * self.stitched_per_task() * self.orders()
    }

    pub fn exhaustive_total_runs(&self) -> usize {
        self.tasks * self.stitched_per_task() * (self.orders() + 1)
    }

    // ---- Eq. 6: SparseLoom with estimators --------------------------

    pub fn sparseloom_accuracy_runs(&self) -> usize {
        self.tasks * self.variants
    }

    pub fn sparseloom_latency_runs(&self) -> usize {
        self.tasks * self.subgraphs * self.variants * self.processors
    }

    pub fn sparseloom_total_runs(&self) -> usize {
        self.sparseloom_accuracy_runs() + self.sparseloom_latency_runs()
    }

    /// Cost reduction of SparseLoom vs exhaustive (fraction in [0,1]).
    pub fn reduction(&self) -> f64 {
        1.0 - self.sparseloom_total_runs() as f64 / self.exhaustive_total_runs() as f64
    }
}

/// Estimated wall-clock profiling time (Fig. 12), given the mean cost of
/// one accuracy run and one latency run on a platform.
#[derive(Clone, Copy, Debug)]
pub struct RunCosts {
    pub accuracy_run_ms: f64,
    pub latency_run_ms: f64,
}

impl CostParams {
    pub fn exhaustive_minutes(&self, rc: &RunCosts) -> f64 {
        (self.exhaustive_accuracy_runs() as f64 * rc.accuracy_run_ms
            + self.exhaustive_latency_runs() as f64 * rc.latency_run_ms)
            / 60_000.0
    }

    pub fn sparseloom_minutes(&self, rc: &RunCosts) -> f64 {
        (self.sparseloom_accuracy_runs() as f64 * rc.accuracy_run_ms
            + self.sparseloom_latency_runs() as f64 * rc.latency_run_ms)
            / 60_000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper() -> CostParams {
        CostParams { tasks: 4, variants: 10, subgraphs: 3, processors: 3 }
    }

    #[test]
    fn table1_formulas() {
        let c = paper();
        assert_eq!(c.orders(), 6);
        assert_eq!(c.no_stitch_total_runs(), 4 * 10 * 7);
        assert_eq!(c.exhaustive_accuracy_runs(), 4 * 1000);
        assert_eq!(c.exhaustive_latency_runs(), 4 * 1000 * 6);
        assert_eq!(c.exhaustive_total_runs(), 4 * 1000 * 7);
    }

    #[test]
    fn eq6_formula() {
        let c = paper();
        assert_eq!(c.sparseloom_accuracy_runs(), 40);
        assert_eq!(c.sparseloom_latency_runs(), 4 * 3 * 10 * 3);
        assert_eq!(c.sparseloom_total_runs(), 40 + 360);
    }

    #[test]
    fn reduction_exceeds_98_percent_at_paper_scale() {
        // Fig. 8b: "up to 98% cost reductions" as V grows.
        let c = paper();
        assert!(c.reduction() > 0.98, "reduction {}", c.reduction());
    }

    #[test]
    fn estimator_cost_linear_in_v() {
        // Fig. 8b's key property: SparseLoom scales linearly with V.
        let base = paper();
        let c2 = CostParams { variants: 20, ..base };
        assert_eq!(c2.sparseloom_total_runs(), 2 * base.sparseloom_total_runs());
        // …while exhaustive scales with V^S (8× for V doubling, S=3).
        assert_eq!(c2.exhaustive_total_runs(), 8 * base.exhaustive_total_runs());
    }

    #[test]
    fn minutes_scale_with_run_costs() {
        let c = paper();
        let rc = RunCosts { accuracy_run_ms: 6000.0, latency_run_ms: 50.0 };
        let ex = c.exhaustive_minutes(&rc);
        let sl = c.sparseloom_minutes(&rc);
        assert!(sl < ex / 20.0, "exhaustive {ex:.1} min vs sparseloom {sl:.1} min");
    }
}
