# Build-path entry points. The only Python step is the artifact export;
# everything else is `cargo` (see scripts/ci.sh for the tier-1 gate).

.PHONY: artifacts ci bench

# Export the L1/L2 model-zoo artifacts the Rust serving system consumes
# (manifest, HLO text, weight blobs, probe/eval tensors, oracles).
artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts

ci:
	scripts/ci.sh

# Dispatch + planner benchmarks (artifact-free: both fall back to the
# synthetic fixture zoo when artifacts/ is absent).
bench:
	cargo bench --bench dispatch_backlog
	cargo bench --bench planner_cost
