//! The [`VariantProvider`] API: one contract for answering "which
//! stitched variant should serve this task right now?".
//!
//! Before this module the answer was an implicit convention — a
//! selection *index into the pre-enumerated zoo* threaded through
//! `coordinator`, `planner::{algo,memory,replan}`, `scenario::server`,
//! and `analysis::feasibility`. The provider makes the contract
//! explicit and adds a second answer mode: **online synthesis**, the
//! paper's §3.1 recombination run at serving time instead of as a
//! static preprocessing step.
//!
//! * [`EnumeratedProvider`] reproduces the existing behavior exactly:
//!   Θᵗ via `algo::feasible_set` over the query's order set, scored by
//!   the batch-aware [`CostModel`] at the query's operating point,
//!   preferring the fastest candidate whose weights fit the task's
//!   pool share (the `reselect` contract) — and, under a commit
//!   order, Algorithm 1 step 3 bit-for-bit.
//! * [`SynthesizingProvider`] delegates to the enumerated path for
//!   ordinary queries and switches to a **bounded best-first search**
//!   over [`StitchSpace`] recombinations when the query carries a
//!   [`PressureSignal`] (red `slo_forecast` or pool over budget). The
//!   search is a pure function of the query — no clocks, no RNG — so
//!   threaded and sequential drives stay bit-identical. Results are
//!   cached per `(task, phase, quantized batch, pool share)` and
//!   invalidated on phase/telemetry shifts via
//!   [`VariantProvider::invalidate`].
//!
//! Search bounds, the cache key, and the invalidation rules are
//! documented in DESIGN.md §Stitching.

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

use crate::optimizer::Selection;
use crate::profiler::TaskProfile;
use crate::soc::{LatencyModel, Processor};
use crate::workload::Slo;
use crate::zoo::Zoo;

use super::algo;
use super::cost::CostModel;

/// Hard cap on best-first node expansions per synthesis query. Each
/// expansion scores at most `S · (V − 1)` neighbors, so the search
/// touches `O(64 · S · V)` candidates — a sliver of the `V^S` space —
/// before committing to the best seen.
pub const SYNTH_MAX_EXPANSIONS: usize = 64;

/// Quantization step for the batch dimension of the synthesis cache
/// key: operating points within 1/8 of a query of each other share a
/// cache line.
const BATCH_QUANTUM: f64 = 8.0;

/// Where a [`VariantDecision`] came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VariantSource {
    /// Selected from the pre-enumerated feasible set (Algorithm 1).
    Enumerated,
    /// Synthesized online by the bounded best-first search.
    Synthesized,
    /// Served from the synthesis cache without a new search.
    Cached,
}

/// Search accounting attached to every decision (audit-span fodder for
/// `TR-CTL-SYNTH`).
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchStats {
    /// Best-first nodes expanded (0 for enumerated answers).
    pub expanded: usize,
    /// Candidates scored against the cost model.
    pub evaluated: usize,
    /// Whether the answer came straight from the synthesis cache.
    pub cache_hit: bool,
}

/// Why the caller is under pressure — the trigger that flips a
/// [`SynthesizingProvider`] from delegation into search mode.
#[derive(Clone, Copy, Debug)]
pub struct PressureSignal {
    /// Observed-or-forecast backlog (ms) on the task's home shard.
    pub forecast_ms: f64,
    /// The saturation threshold the backlog crossed.
    pub threshold_ms: f64,
    /// The home shard's pool utilization (used / capacity).
    pub pool_utilization: f64,
}

/// Everything a provider needs to answer one variant question.
#[derive(Clone, Debug)]
pub struct VariantQuery {
    /// The task being (re)selected.
    pub task: String,
    /// The SLO in force — `min_accuracy` is a hard floor for synthesis.
    pub slo: Slo,
    /// Orders Θᵗ feasibility is judged over; empty ⇒ the provider's
    /// full Ω.
    pub feasible_orders: Vec<Vec<Processor>>,
    /// When set, candidates are scored (and will be served) under
    /// exactly this committed placement order.
    pub commit_order: Option<Vec<Processor>>,
    /// Expected mean coalesced batch size — the operating point.
    pub batch: f64,
    /// The task's byte share of its pool (candidates that fit are
    /// preferred; 0 disables the preference, `u64::MAX` makes every
    /// candidate "fit").
    pub pool_share: u64,
    /// Scenario phase index (part of the synthesis cache key).
    pub phase: usize,
    /// Present when the caller is under SLO/budget pressure — the
    /// synthesis trigger. `None` keeps even a synthesizing provider on
    /// the enumerated path.
    pub pressure: Option<PressureSignal>,
}

/// A provider's answer: the selection plus provenance and search
/// accounting.
#[derive(Clone, Copy, Debug)]
pub struct VariantDecision {
    pub selection: Selection,
    pub source: VariantSource,
    pub stats: SearchStats,
}

/// The unified variant contract consumed by `Planner::plan`, `replan`,
/// the steal/warm-migrate adoption path, and the online synthesis
/// action.
pub trait VariantProvider {
    /// Answer a variant query, or `None` when nothing is feasible.
    fn provide(&self, q: &VariantQuery) -> Option<VariantDecision>;

    /// Score one specific stitched index at the query's operating
    /// point (used to price an incumbent before replacing it).
    fn score(&self, q: &VariantQuery, index: usize) -> Option<Selection>;

    /// Drop any cached decisions (phase boundary, pool reshuffle, or
    /// telemetry shift — see DESIGN.md §Stitching for the rules).
    fn invalidate(&self);

    /// Stable name for audit output ("enumerated" | "synthesized").
    fn kind(&self) -> &'static str;
}

/// Weights footprint of a composition on its task zoo.
fn composition_bytes(tz: &crate::zoo::TaskZoo, comp: &crate::stitching::Composition) -> u64 {
    comp.0
        .iter()
        .enumerate()
        .map(|(j, &vi)| tz.variants[vi].subgraphs[j].bytes)
        .sum()
}

/// Min latency of `comp` over `orders` under `cost`; `None` when no
/// order can run it.
fn best_latency(
    cost: &CostModel,
    p: &TaskProfile,
    comp: &crate::stitching::Composition,
    orders: &[Vec<Processor>],
) -> Option<f64> {
    let lat = orders
        .iter()
        .filter_map(|o| cost.latency(p, comp, o))
        .fold(f64::INFINITY, f64::min);
    lat.is_finite().then_some(lat)
}

/// The pre-enumerated answer mode: Θᵗ from `algo::feasible_set`, the
/// fastest in-share candidate preferred (fallback: fastest feasible).
pub struct EnumeratedProvider<'a> {
    zoo: &'a Zoo,
    lm: &'a LatencyModel,
    profiles: &'a BTreeMap<String, TaskProfile>,
    orders: Vec<Vec<Processor>>,
}

impl<'a> EnumeratedProvider<'a> {
    pub fn new(
        zoo: &'a Zoo,
        lm: &'a LatencyModel,
        profiles: &'a BTreeMap<String, TaskProfile>,
        orders: Vec<Vec<Processor>>,
    ) -> EnumeratedProvider<'a> {
        EnumeratedProvider { zoo, lm, profiles, orders }
    }

    /// The cost model at the query's operating point. Only the queried
    /// task's batch factor is ever read, so a single hint suffices.
    fn cost_at(&self, q: &VariantQuery) -> CostModel {
        CostModel::batch_aware(self.lm, 1.0).with_hint(&q.task, q.batch)
    }

    fn feasible<'q>(&'q self, q: &'q VariantQuery) -> &'q [Vec<Processor>] {
        if q.feasible_orders.is_empty() { &self.orders } else { &q.feasible_orders }
    }
}

impl VariantProvider for EnumeratedProvider<'_> {
    fn provide(&self, q: &VariantQuery) -> Option<VariantDecision> {
        let p = self.profiles.get(&q.task)?;
        let tz = self.zoo.task(&q.task).ok()?;
        let cost = self.cost_at(q);
        let feasible = self.feasible(q);
        let theta = algo::feasible_set(&cost, p, &q.slo, feasible);
        let score_orders: &[Vec<Processor>] = match &q.commit_order {
            Some(o) => std::slice::from_ref(o),
            None => feasible,
        };
        let mut within_share: Option<Selection> = None;
        let mut any: Option<Selection> = None;
        let mut evaluated = 0usize;
        for &k in &theta.indices {
            let comp = p.space.composition(k);
            evaluated += 1;
            let Some(lat) = best_latency(&cost, p, &comp, score_orders) else {
                continue;
            };
            let sel = Selection {
                stitched_index: k,
                latency_ms: lat,
                accuracy: p.accuracy(k),
            };
            if any.map(|b| lat < b.latency_ms).unwrap_or(true) {
                any = Some(sel);
            }
            let bytes = composition_bytes(tz, &comp);
            if bytes <= q.pool_share
                && within_share.map(|b| lat < b.latency_ms).unwrap_or(true)
            {
                within_share = Some(sel);
            }
        }
        let selection = within_share.or(any)?;
        Some(VariantDecision {
            selection,
            source: VariantSource::Enumerated,
            stats: SearchStats { expanded: 0, evaluated, cache_hit: false },
        })
    }

    fn score(&self, q: &VariantQuery, index: usize) -> Option<Selection> {
        let p = self.profiles.get(&q.task)?;
        if index >= p.space.len() {
            return None;
        }
        let cost = self.cost_at(q);
        let comp = p.space.composition(index);
        let score_orders: &[Vec<Processor>] = match &q.commit_order {
            Some(o) => std::slice::from_ref(o),
            None => self.feasible(q),
        };
        let lat = best_latency(&cost, p, &comp, score_orders)?;
        Some(Selection {
            stitched_index: index,
            latency_ms: lat,
            accuracy: p.accuracy(index),
        })
    }

    fn invalidate(&self) {}

    fn kind(&self) -> &'static str {
        "enumerated"
    }
}

/// Synthesis cache key: one line per `(task, phase, quantized batch,
/// pool share)` operating point.
type CacheKey = (String, usize, u64, u64);

/// The online answer mode: enumerated for ordinary queries, bounded
/// best-first synthesis under pressure, with a per-operating-point
/// decision cache.
pub struct SynthesizingProvider<'a> {
    inner: EnumeratedProvider<'a>,
    cache: RefCell<BTreeMap<CacheKey, VariantDecision>>,
}

impl<'a> SynthesizingProvider<'a> {
    pub fn new(
        zoo: &'a Zoo,
        lm: &'a LatencyModel,
        profiles: &'a BTreeMap<String, TaskProfile>,
        orders: Vec<Vec<Processor>>,
    ) -> SynthesizingProvider<'a> {
        SynthesizingProvider {
            inner: EnumeratedProvider::new(zoo, lm, profiles, orders),
            cache: RefCell::new(BTreeMap::new()),
        }
    }

    fn cache_key(q: &VariantQuery) -> CacheKey {
        let qbatch = (q.batch.max(1.0) * BATCH_QUANTUM).round() as u64;
        (q.task.clone(), q.phase, qbatch, q.pool_share)
    }

    /// Bounded best-first search over the stitch space: seed with the
    /// V pure compositions, expand one subgraph digit at a time in
    /// ascending-latency order, keep the fastest candidate meeting the
    /// SLO accuracy floor (in-share preferred). Pure function of the
    /// query — ties break on the canonical stitched index, latencies
    /// compare via `to_bits` (positive finite floats order like
    /// integers), and the expansion budget is a constant.
    fn synthesize(&self, q: &VariantQuery) -> Option<VariantDecision> {
        let p = self.inner.profiles.get(&q.task)?;
        let tz = self.inner.zoo.task(&q.task).ok()?;
        let cost = self.inner.cost_at(q);
        let score_orders: Vec<Vec<Processor>> = match &q.commit_order {
            Some(o) => vec![o.clone()],
            None => self.inner.feasible(q).to_vec(),
        };
        let space = &p.space;
        let (v, s) = (space.n_variants, space.n_subgraphs);

        let mut frontier: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        let mut seen: BTreeSet<usize> = BTreeSet::new();
        let mut best_any: Option<Selection> = None;
        let mut best_within: Option<Selection> = None;
        let mut evaluated = 0usize;

        // Scoring a node: admissible candidates must clear the SLO
        // accuracy floor; every runnable node stays expandable (a
        // low-accuracy composition can still bridge to a good one).
        let mut admit = |k: usize,
                         frontier: &mut BinaryHeap<Reverse<(u64, usize)>>,
                         best_any: &mut Option<Selection>,
                         best_within: &mut Option<Selection>| {
            let comp = space.composition(k);
            evaluated += 1;
            let Some(lat) = best_latency(&cost, p, &comp, &score_orders) else {
                return;
            };
            frontier.push(Reverse((lat.to_bits(), k)));
            if p.accuracy(k) < q.slo.min_accuracy {
                return;
            }
            let sel = Selection {
                stitched_index: k,
                latency_ms: lat,
                accuracy: p.accuracy(k),
            };
            if best_any.map(|b| lat < b.latency_ms).unwrap_or(true) {
                *best_any = Some(sel);
            }
            if composition_bytes(tz, &comp) <= q.pool_share
                && best_within.map(|b| lat < b.latency_ms).unwrap_or(true)
            {
                *best_within = Some(sel);
            }
        };

        for i in 0..v {
            let k = space.pure_index(i);
            if seen.insert(k) {
                admit(k, &mut frontier, &mut best_any, &mut best_within);
            }
        }

        let mut expanded = 0usize;
        while expanded < SYNTH_MAX_EXPANSIONS {
            let Some(Reverse((_, k))) = frontier.pop() else { break };
            expanded += 1;
            let comp = space.composition(k);
            for j in 0..s {
                for vi in 0..v {
                    if vi == comp.0[j] {
                        continue;
                    }
                    let mut digits = comp.0.clone();
                    digits[j] = vi;
                    let neighbor = crate::stitching::Composition(digits);
                    let nk = neighbor.to_index(v);
                    if seen.insert(nk) {
                        admit(nk, &mut frontier, &mut best_any, &mut best_within);
                    }
                }
            }
        }

        let selection = best_within.or(best_any)?;
        Some(VariantDecision {
            selection,
            source: VariantSource::Synthesized,
            stats: SearchStats { expanded, evaluated, cache_hit: false },
        })
    }
}

impl VariantProvider for SynthesizingProvider<'_> {
    fn provide(&self, q: &VariantQuery) -> Option<VariantDecision> {
        if q.pressure.is_none() {
            // No pressure ⇒ planning-time query: stay bit-identical to
            // the enumerated planner.
            return self.inner.provide(q);
        }
        let key = Self::cache_key(q);
        if let Some(hit) = self.cache.borrow().get(&key) {
            let mut dec = *hit;
            dec.source = VariantSource::Cached;
            dec.stats.cache_hit = true;
            return Some(dec);
        }
        let dec = self.synthesize(q)?;
        self.cache.borrow_mut().insert(key, dec);
        Some(dec)
    }

    fn score(&self, q: &VariantQuery, index: usize) -> Option<Selection> {
        self.inner.score(q, index)
    }

    fn invalidate(&self) {
        self.cache.borrow_mut().clear();
    }

    fn kind(&self) -> &'static str {
        "synthesized"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixtures;
    use crate::workload::placement_orders;

    fn providers() -> (Zoo, LatencyModel, BTreeMap<String, TaskProfile>) {
        fixtures::trio()
    }

    fn base_query(task: &str) -> VariantQuery {
        VariantQuery {
            task: task.to_string(),
            slo: Slo { min_accuracy: 0.5, max_latency_ms: 1e9 },
            feasible_orders: Vec::new(),
            commit_order: None,
            batch: 1.0,
            pool_share: u64::MAX,
            phase: 0,
            pressure: None,
        }
    }

    fn pressured(task: &str) -> VariantQuery {
        VariantQuery {
            pressure: Some(PressureSignal {
                forecast_ms: 100.0,
                threshold_ms: 10.0,
                pool_utilization: 1.0,
            }),
            ..base_query(task)
        }
    }

    #[test]
    fn enumerated_matches_algorithm_one_step_three() {
        let (zoo, lm, profiles) = providers();
        let orders = placement_orders(&lm.platform, zoo.subgraphs);
        let slos: BTreeMap<String, Slo> = profiles
            .keys()
            .map(|n| (n.clone(), Slo { min_accuracy: 0.5, max_latency_ms: 1e9 }))
            .collect();
        let cost = CostModel::unit();
        let plan = algo::optimize(&cost, &profiles, &slos, &orders);
        let provider = EnumeratedProvider::new(&zoo, &lm, &profiles, orders.clone());
        for (name, sel) in &plan.selections {
            let q = VariantQuery {
                commit_order: Some(plan.order.clone()),
                ..base_query(name)
            };
            let dec = provider.provide(&q).expect("feasible");
            let sel = sel.expect("step 3 chose");
            assert_eq!(dec.selection.stitched_index, sel.stitched_index, "{name}");
            assert_eq!(dec.selection.latency_ms.to_bits(), sel.latency_ms.to_bits());
            assert_eq!(dec.selection.accuracy.to_bits(), sel.accuracy.to_bits());
            assert_eq!(dec.source, VariantSource::Enumerated);
        }
    }

    #[test]
    fn synthesis_delegates_without_pressure() {
        let (zoo, lm, profiles) = providers();
        let orders = placement_orders(&lm.platform, zoo.subgraphs);
        let enumerated = EnumeratedProvider::new(&zoo, &lm, &profiles, orders.clone());
        let synth = SynthesizingProvider::new(&zoo, &lm, &profiles, orders);
        let q = base_query("alpha");
        let a = enumerated.provide(&q).unwrap();
        let b = synth.provide(&q).unwrap();
        assert_eq!(a.selection.stitched_index, b.selection.stitched_index);
        assert_eq!(b.source, VariantSource::Enumerated);
    }

    #[test]
    fn synthesis_finds_fastest_admissible_composition() {
        let (zoo, lm, profiles) = providers();
        let orders = placement_orders(&lm.platform, zoo.subgraphs);
        let synth = SynthesizingProvider::new(&zoo, &lm, &profiles, orders.clone());
        let q = pressured("alpha");
        let dec = synth.provide(&q).expect("synthesis must find a variant");
        assert_eq!(dec.source, VariantSource::Synthesized);
        assert!(dec.stats.expanded > 0);
        // Exhaustive reference: the trio space (9 compositions) fits
        // well inside the expansion budget, so the search must return
        // the global fastest accuracy-admissible composition.
        let p = &profiles["alpha"];
        let cost = CostModel::batch_aware(&lm, 1.0).with_hint("alpha", 1.0);
        let mut best: Option<(f64, usize)> = None;
        for k in 0..p.space.len() {
            if p.accuracy(k) < q.slo.min_accuracy {
                continue;
            }
            let comp = p.space.composition(k);
            let Some(lat) = best_latency(&cost, p, &comp, &orders) else { continue };
            if best.map(|(b, _)| lat < b).unwrap_or(true) {
                best = Some((lat, k));
            }
        }
        let (lat, k) = best.unwrap();
        assert_eq!(dec.selection.stitched_index, k);
        assert_eq!(dec.selection.latency_ms.to_bits(), lat.to_bits());
        assert!(dec.selection.accuracy >= q.slo.min_accuracy);
    }

    #[test]
    fn synthesis_respects_the_accuracy_floor() {
        let (zoo, lm, profiles) = providers();
        let orders = placement_orders(&lm.platform, zoo.subgraphs);
        let synth = SynthesizingProvider::new(&zoo, &lm, &profiles, orders);
        // alpha's dense top accuracy is 0.92; demand nearly that much
        // so every sparse-heavy recombination is inadmissible.
        let q = VariantQuery {
            slo: Slo { min_accuracy: 0.91, max_latency_ms: 1e9 },
            ..pressured("alpha")
        };
        let dec = synth.provide(&q).expect("dense variant is admissible");
        assert!(dec.selection.accuracy >= 0.91);
    }

    #[test]
    fn cache_hits_and_invalidation() {
        let (zoo, lm, profiles) = providers();
        let orders = placement_orders(&lm.platform, zoo.subgraphs);
        let synth = SynthesizingProvider::new(&zoo, &lm, &profiles, orders);
        let q = pressured("beta");
        let first = synth.provide(&q).unwrap();
        assert_eq!(first.source, VariantSource::Synthesized);
        assert!(!first.stats.cache_hit);
        let second = synth.provide(&q).unwrap();
        assert_eq!(second.source, VariantSource::Cached);
        assert!(second.stats.cache_hit);
        assert_eq!(second.selection.stitched_index, first.selection.stitched_index);
        // A different operating point is a different cache line.
        let other = VariantQuery { batch: 4.0, ..q.clone() };
        let third = synth.provide(&other).unwrap();
        assert_eq!(third.source, VariantSource::Synthesized);
        // Invalidation forces a re-search.
        synth.invalidate();
        let fourth = synth.provide(&q).unwrap();
        assert_eq!(fourth.source, VariantSource::Synthesized);
        assert_eq!(fourth.selection.stitched_index, first.selection.stitched_index);
    }

    #[test]
    fn synthesized_indices_stay_in_bounds() {
        let (zoo, lm, profiles) = providers();
        let orders = placement_orders(&lm.platform, zoo.subgraphs);
        let synth = SynthesizingProvider::new(&zoo, &lm, &profiles, orders);
        for task in ["alpha", "beta", "gamma"] {
            for batch in [1.0, 2.0, 4.0] {
                let q = VariantQuery { batch, ..pressured(task) };
                let dec = synth.provide(&q).unwrap();
                let p = &profiles[task];
                assert!(dec.selection.stitched_index < p.space.len());
                let comp = p.space.composition(dec.selection.stitched_index);
                assert_eq!(comp.to_index(p.space.n_variants), dec.selection.stitched_index);
            }
        }
    }

    #[test]
    fn pool_share_prefers_fitting_candidates() {
        let (zoo, lm, profiles) = providers();
        let orders = placement_orders(&lm.platform, zoo.subgraphs);
        let synth = SynthesizingProvider::new(&zoo, &lm, &profiles, orders);
        // A share only the smallest (int8) blobs fit: 2 × 400 bytes.
        let q = VariantQuery { pool_share: 800, ..pressured("alpha") };
        let dec = synth.provide(&q).unwrap();
        let p = &profiles["alpha"];
        let tz = zoo.task("alpha").unwrap();
        let comp = p.space.composition(dec.selection.stitched_index);
        assert!(composition_bytes(tz, &comp) <= 800);
    }
}
