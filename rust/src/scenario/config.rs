//! `ServeConfig` — the one construction API for serving runs.
//!
//! The `serve` subcommand accreted ~15 loose flags (`--steal --replan
//! --warm-migrate --predictive --synthesize --max-batch ...`), each
//! with its own coupling rules (warm migration without an online path
//! is a silent no-op; predictive triggers need replan or steal; the
//! synthesizing provider wants batch-aware costs). Those rules used to
//! live inline in `main.rs`, where neither tests nor scenario files
//! could reach them. `ServeConfig` centralizes them: the CLI parses
//! flags into a builder, tests construct the builder directly, and a
//! loaded Scenario JSON file round-trips through it via
//! [`ServeConfig::from_scenario`] — all three paths produce the same
//! `planner` / `dispatch` / `sharding` blocks by construction.
//!
//! The legacy flags survive as thin aliases over the builder (their
//! `--help` text says so); nothing in the JSON schema changed.

use std::collections::BTreeMap;

use crate::workload::Slo;

use super::{Admission, Arrival, Dispatch, PlannerConfig, Scenario, Sharding};

/// The arrival process a run is built around — mirrors [`Arrival`]
/// minus the trace-replay case (a replay carries its own queries, so
/// it only arrives via a scenario file).
#[derive(Clone, Debug, PartialEq)]
pub enum Workload {
    /// Closed loop: `queries` back-to-back requests per task, task slot
    /// k starting at `k × stagger_ms`.
    Closed { queries: usize, stagger_ms: f64 },
    /// Poisson open loop at `rate_qps` per task for `horizon_ms`.
    Poisson { rate_qps: f64, horizon_ms: f64 },
    /// Square-wave open loop: half of each `period_ms` at `base_qps`,
    /// half at `burst_qps`.
    Bursty {
        base_qps: f64,
        burst_qps: f64,
        period_ms: f64,
        horizon_ms: f64,
    },
}

impl Default for Workload {
    fn default() -> Self {
        Workload::Closed { queries: 100, stagger_ms: 0.0 }
    }
}

/// Builder for serving runs. Defaults match `serve` with no flags:
/// closed loop, admit-all, no batching, one shard, the frozen PR 2
/// planner. Toggle methods encode the flag-coupling rules in one
/// place — see each method's doc.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeConfig {
    pub workload: Workload,
    pub admission: Admission,
    pub dispatch: Dispatch,
    pub sharding: Sharding,
    pub planner: PlannerConfig,
    pub seed: u64,
}

impl ServeConfig {
    /// Start from the all-defaults run.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the arrival process.
    pub fn workload(mut self, w: Workload) -> Self {
        self.workload = w;
        self
    }

    /// Set the admission policy.
    pub fn admission(mut self, a: Admission) -> Self {
        self.admission = a;
        self
    }

    /// Batch up to `max_batch` queries once `min_queue` are waiting.
    pub fn batching(mut self, max_batch: usize, min_queue: usize) -> Self {
        self.dispatch = Dispatch { max_batch: max_batch.max(1), min_queue };
        self
    }

    /// Hash-partition tasks across `shards` servers.
    pub fn shards(mut self, shards: usize) -> Self {
        self.sharding = Sharding::hash(shards);
        self
    }

    /// Seed for the open-loop arrival generators.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// `--replan`: online re-planning. Implies batch-aware planning
    /// (the replanner scores migrations at the dispatch operating
    /// point).
    pub fn replan(mut self) -> Self {
        self.planner.replan = true;
        self.planner.batch_aware = true;
        self
    }

    /// `--steal`: telemetry-driven work stealing. Implies batch-aware
    /// planning.
    pub fn steal(mut self) -> Self {
        self.planner.steal = true;
        self.planner.batch_aware = true;
        self
    }

    /// `--warm-migrate`: carry a migrant's pool across shards. Warm
    /// migration only acts on the online adoption paths, so without
    /// `--replan` or `--steal` it would be a silent no-op — it implies
    /// `--replan` when neither is set.
    pub fn warm_migrate(mut self) -> Self {
        self.planner.warm_migrate = true;
        if !self.planner.replan && !self.planner.steal {
            return self.replan();
        }
        self
    }

    /// `--predictive`: forecast-triggered adaptation. Forecast triggers
    /// only act on the online paths — implies `--replan` when neither
    /// `--replan` nor `--steal` is set.
    pub fn predictive(mut self) -> Self {
        self.planner.predictive = true;
        if !self.planner.replan && !self.planner.steal {
            return self.replan();
        }
        self
    }

    /// `--synthesize`: online stitched-variant synthesis under
    /// pressure. Implies batch-aware planning — the synthesizing
    /// provider scores candidates at the live batch operating point,
    /// and a batch-1 cost model would price them against a different
    /// objective than the serving plan (`SL-STI-001`).
    pub fn synthesize(mut self) -> Self {
        self.planner.synthesize = true;
        self.planner.batch_aware = true;
        self
    }

    /// Epoch length for the threaded online drive (`0` keeps the
    /// classic per-batch drive).
    pub fn epoch_ms(mut self, ms: f64) -> Self {
        self.planner.epoch_ms = ms.max(0.0);
        self
    }

    /// Extract the run configuration from a scenario (e.g. one loaded
    /// from JSON), so file-driven and flag-driven runs flow through the
    /// same type. Trace-replay arrivals keep their queries on the
    /// scenario; the config maps them to the default closed loop only
    /// as a placeholder — use [`ServeConfig::apply`] on the *same*
    /// scenario to preserve them.
    pub fn from_scenario(s: &Scenario) -> Self {
        let workload = match &s.arrival {
            Arrival::ClosedLoop { queries, stagger_ms } => {
                Workload::Closed { queries: *queries, stagger_ms: *stagger_ms }
            }
            Arrival::PoissonOpenLoop { rate_qps, horizon_ms } => {
                Workload::Poisson { rate_qps: *rate_qps, horizon_ms: *horizon_ms }
            }
            Arrival::Bursty { base_qps, burst_qps, period_ms, horizon_ms } => {
                Workload::Bursty {
                    base_qps: *base_qps,
                    burst_qps: *burst_qps,
                    period_ms: *period_ms,
                    horizon_ms: *horizon_ms,
                }
            }
            Arrival::Trace(_) => Workload::default(),
        };
        Self {
            workload,
            admission: s.admission.clone(),
            dispatch: s.dispatch.clone(),
            sharding: s.sharding.clone(),
            planner: s.planner.clone(),
            seed: s.seed,
        }
    }

    /// Overwrite a scenario's run-configuration blocks with this
    /// config's, leaving tasks / SLO schedule / faults / arrival
    /// queries untouched. `from_scenario` ∘ `apply` is the identity on
    /// the `planner` / `dispatch` / `sharding` / `admission` / `seed`
    /// blocks.
    pub fn apply(&self, mut s: Scenario) -> Scenario {
        s.admission = self.admission.clone();
        s.dispatch = self.dispatch.clone();
        s.sharding = self.sharding.clone();
        s.planner = self.planner.clone();
        s.seed = self.seed;
        s
    }

    /// Build the full scenario for `tasks` under `slos` — the one
    /// construction path behind `serve`'s workload flags.
    pub fn build(&self, tasks: &[String], slos: BTreeMap<String, Slo>) -> Scenario {
        let base = match self.workload {
            Workload::Closed { queries, stagger_ms } => {
                Scenario::closed_loop(tasks, slos)
                    .with_queries(queries)
                    .with_stagger_ms(stagger_ms)
            }
            Workload::Poisson { rate_qps, horizon_ms } => {
                Scenario::poisson(tasks, slos, rate_qps, horizon_ms)
            }
            Workload::Bursty { base_qps, burst_qps, period_ms, horizon_ms } => {
                Scenario::bursty(tasks, slos, base_qps, burst_qps, period_ms, horizon_ms)
            }
        };
        self.apply(base)
    }
}

impl Default for Admission {
    fn default() -> Self {
        Admission::Always
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Slo;

    fn tasks() -> Vec<String> {
        vec!["alpha".into(), "beta".into()]
    }

    fn slos() -> BTreeMap<String, Slo> {
        tasks()
            .into_iter()
            .map(|t| (t, Slo { min_accuracy: 0.5, max_latency_ms: 100.0 }))
            .collect()
    }

    #[test]
    fn toggles_encode_the_flag_coupling_rules() {
        // Warm migration alone would be a no-op: it pulls in replan.
        let c = ServeConfig::new().warm_migrate();
        assert!(c.planner.warm_migrate && c.planner.replan && c.planner.batch_aware);
        // ... but not when stealing already gives it an adoption path.
        let c = ServeConfig::new().steal().warm_migrate();
        assert!(c.planner.steal && c.planner.warm_migrate && !c.planner.replan);
        // Predictive triggers need an online path too.
        let c = ServeConfig::new().predictive();
        assert!(c.planner.predictive && c.planner.replan);
        // Synthesis prices at the batch operating point (SL-STI-001).
        let c = ServeConfig::new().synthesize();
        assert!(c.planner.synthesize && c.planner.batch_aware);
        assert!(!c.planner.replan, "synthesis alone does not migrate");
    }

    #[test]
    fn builder_blocks_survive_the_scenario_json_round_trip() {
        let cfg = ServeConfig::new()
            .workload(Workload::Bursty {
                base_qps: 20.0,
                burst_qps: 80.0,
                period_ms: 500.0,
                horizon_ms: 2_000.0,
            })
            .admission(Admission::Deadline { slack: 2.0 })
            .batching(4, 2)
            .shards(2)
            .seed(7)
            .steal()
            .synthesize()
            .epoch_ms(25.0);
        let scenario = cfg.build(&tasks(), slos());
        let text = scenario.to_json().to_string();
        let back = Scenario::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(ServeConfig::from_scenario(&back), cfg);
    }

    #[test]
    fn apply_after_from_scenario_is_identity_on_run_blocks() {
        let s = Scenario::closed_loop(&tasks(), slos())
            .with_planner(PlannerConfig::online())
            .with_dispatch(Dispatch::batched(8))
            .with_seed(3);
        let round = ServeConfig::from_scenario(&s).apply(s.clone());
        assert_eq!(round.planner, s.planner);
        assert_eq!(round.dispatch, s.dispatch);
        assert_eq!(round.admission, s.admission);
        assert_eq!(round.seed, s.seed);
    }
}
