//! The dispatch subsystem: adaptive batching under backlog and
//! multi-server sharding.
//!
//! `scenario::Server` places one query at a time on one simulated SoC,
//! which is exactly right for the paper's closed-loop protocol and
//! degrades exactly where it shouldn't under bursty open-loop traffic:
//! backlog piles up per task while every stage still pays full
//! single-query occupancy, and every task contends for one set of
//! processors. This module adds the two scale mechanisms ROADMAP names:
//!
//! * **Adaptive batching** — a [`Dispatcher`] sits between the arrival
//!   stream and [`Session::submit`]. When a task's queue exceeds
//!   [`Dispatch::min_queue`], it coalesces up to [`Dispatch::max_batch`]
//!   consecutive same-task queries into one
//!   [`Session::submit_batch`] call: one placement decision, one booking
//!   per stage at the batch-aware occupancy
//!   (`LatencyModel::batch_factor`), which drains backlog strictly
//!   faster than dispatching queries alone. Batches are FIFO prefixes of
//!   the task queue, so requests are never reordered within a task.
//! * **Sharding** — a [`ShardedServer`] partitions the task set across N
//!   independent [`Server`]s ([`Sharding`]: hash or explicit map), each
//!   with its own planning cache, memory pool, and simulated SoC.
//!   Arrival streams are generated once per scenario (identical per-task
//!   arrivals to the unsharded run) and routed per query; the result is
//!   one `RunReport` per shard plus a cross-shard aggregate
//!   ([`crate::metrics::ShardedReport`]).
//!
//! Cross-task *admission fairness* rides along in
//! [`Admission::Fair`](super::Admission::Fair), judged per shard inside
//! the session.
//!
//! ```
//! use sparseloom::coordinator::ServeOpts;
//! use sparseloom::fixtures;
//! use sparseloom::scenario::{Dispatch, Scenario, ShardedServer, Sharding};
//!
//! let (zoo, lm, profiles) = fixtures::trio();
//! let scenario = Scenario::bursty(&fixtures::task_names(&zoo),
//!                                 fixtures::slos(&zoo, 0.5, 1e9),
//!                                 5.0, 60.0, 500.0, 2_000.0)
//!     .with_seed(7)
//!     .with_dispatch(Dispatch::batched(4))
//!     .with_sharding(Sharding::hash(2));
//!
//! let sharded = ShardedServer::build(&zoo, &lm, &profiles,
//!                                    ServeOpts::default(),
//!                                    scenario.sharding.clone());
//! let report = sharded.run(&scenario).unwrap();
//! assert_eq!(report.per_shard.len(), 2);
//! // Every arrival is accounted for: completed + dropped = events.
//! assert_eq!(report.aggregate.total_queries + report.aggregate.total_dropped,
//!            report.aggregate.requests.len());
//! ```

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Result};

use crate::coordinator::ServeOpts;
use crate::metrics::{RunReport, ShardedReport};
use crate::planner::{Planner, ShardObservation, ShardPlan, SparsityAwarePlanner};
use crate::profiler::TaskProfile;
use crate::soc::{LatencyModel, Processor};
use crate::workload::{shard_of_task, Query, Slo};
use crate::zoo::Zoo;

use super::server::{Server, Session};
use super::{Arrival, Scenario};

/// Adaptive-batching configuration: when and how hard to coalesce.
///
/// The default is the *identity* dispatch (`max_batch = 1`): every query
/// is placed alone and serving behaves exactly as if this module did not
/// exist. Batching only changes anything for open-loop scenarios —
/// closed loops are self-clocking and never build backlog.
#[derive(Clone, Debug, PartialEq)]
pub struct Dispatch {
    /// Largest number of same-task queries coalesced into one placement
    /// decision. `1` disables batching.
    pub max_batch: usize,
    /// Backlog threshold: coalescing starts only once at least this
    /// many queries of one task are already waiting at dispatch time.
    /// Below the threshold queries dispatch alone, keeping per-query
    /// latency untouched when the system is keeping up.
    pub min_queue: usize,
}

impl Default for Dispatch {
    fn default() -> Self {
        Self { max_batch: 1, min_queue: 2 }
    }
}

impl Dispatch {
    /// The identity dispatch: no batching (same as `Default`).
    pub fn none() -> Self {
        Self::default()
    }

    /// Batch up to `max_batch` queries with the default backlog
    /// threshold.
    pub fn batched(max_batch: usize) -> Self {
        Self { max_batch: max_batch.max(1), ..Self::default() }
    }

    /// Whether this configuration can ever coalesce.
    pub fn is_batching(&self) -> bool {
        self.max_batch > 1
    }

    /// How many of `waiting` already-arrived same-task queries one
    /// dispatch decision takes: the FIFO prefix up to `max_batch` once
    /// at least `min_queue` wait; 1 when `batching` is off or the
    /// threshold is not met. The single coalescing rule shared by
    /// [`Dispatcher::drive`] and the replan drive — change it here and
    /// both paths stay comparable.
    pub fn take(&self, waiting: usize, batching: bool) -> usize {
        if batching && waiting >= self.min_queue.max(1) {
            waiting.min(self.max_batch)
        } else {
            1
        }
    }
}

/// How tasks map to shards.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardAssignment {
    /// FNV-1a hash of the task name modulo the shard count
    /// ([`crate::workload::shard_of_task`]) — deterministic across runs
    /// and processes.
    Hash,
    /// Explicit task → shard map. Out-of-range indices wrap modulo the
    /// shard count; tasks absent from the map fall back to the hash
    /// rule.
    Explicit(BTreeMap<String, usize>),
}

/// Multi-server sharding configuration: how many servers, and which
/// tasks each one owns.
#[derive(Clone, Debug, PartialEq)]
pub struct Sharding {
    /// Number of independent servers. `1` (the default) means no
    /// sharding.
    pub shards: usize,
    /// Task → shard rule.
    pub assignment: ShardAssignment,
}

impl Default for Sharding {
    fn default() -> Self {
        Self { shards: 1, assignment: ShardAssignment::Hash }
    }
}

impl Sharding {
    /// Hash-partition tasks across `shards` servers.
    pub fn hash(shards: usize) -> Self {
        Self { shards: shards.max(1), assignment: ShardAssignment::Hash }
    }

    /// Explicitly map tasks to `shards` servers (unlisted tasks hash).
    pub fn explicit(map: BTreeMap<String, usize>, shards: usize) -> Self {
        Self { shards: shards.max(1), assignment: ShardAssignment::Explicit(map) }
    }

    /// Which shard serves `task`.
    pub fn shard_of(&self, task: &str) -> usize {
        let n = self.shards.max(1);
        match &self.assignment {
            ShardAssignment::Hash => shard_of_task(task, n),
            ShardAssignment::Explicit(map) => match map.get(task) {
                Some(&shard) => shard % n,
                None => shard_of_task(task, n),
            },
        }
    }
}

/// Replays an arrival stream into a [`Session`], coalescing same-task
/// FIFO runs into batches when backlog builds.
///
/// At every step the dispatcher issues for the task whose next query
/// would start earliest (exactly like [`Session::drive`]); if at least
/// [`Dispatch::min_queue`] queries of that task are already waiting at
/// that instant, the waiting FIFO prefix — never more than
/// [`Dispatch::max_batch`] — is submitted as one batch. Queries that
/// have not yet arrived at issue time are never pulled into a batch, so
/// batching cannot reorder a task's queries or violate causality.
pub struct Dispatcher {
    cfg: Dispatch,
}

impl Dispatcher {
    /// A dispatcher for one batching configuration.
    pub fn new(cfg: Dispatch) -> Self {
        Self { cfg }
    }

    /// The batching configuration this dispatcher applies.
    pub fn config(&self) -> &Dispatch {
        &self.cfg
    }

    /// Drive a whole stream through `session` in simulated-time order —
    /// the one replay loop behind both [`Session::drive`] (which
    /// delegates here with the identity dispatch) and batched serving.
    ///
    /// With the identity dispatch — or a self-clocking (closed-loop)
    /// session, which cannot build backlog — every query dispatches
    /// alone.
    pub fn drive(&self, session: &mut Session, queries: &[Query]) -> Result<()> {
        let batching = self.cfg.is_batching() && !session.is_self_clocked();
        let order: Vec<String> = session.task_order().to_vec();
        let mut pending: BTreeMap<&str, VecDeque<&Query>> = BTreeMap::new();
        for q in queries {
            if session.ready_of(&q.task).is_none() {
                bail!(
                    "query {} targets task {:?} not in this scenario",
                    q.id,
                    q.task
                );
            }
            pending.entry(q.task.as_str()).or_default().push_back(q);
        }
        loop {
            // Earliest-issue task first (arrival vs per-task FIFO ready).
            let mut next: Option<(&str, f64)> = None;
            for name in &order {
                let Some(queue) = pending.get(name.as_str()) else { continue };
                let Some(q) = queue.front() else { continue };
                let ready = session.ready_of(name).unwrap_or(0.0);
                let issue = q.arrival_ms.max(ready);
                if next.map(|(_, t)| issue < t).unwrap_or(true) {
                    next = Some((name.as_str(), issue));
                }
            }
            let Some((task, issue)) = next else { break };
            let queue = pending.get_mut(task).unwrap();
            // The FIFO prefix already waiting at issue time; the head
            // always qualifies (issue ≥ its arrival by construction).
            let waiting = queue.iter().take_while(|q| q.arrival_ms <= issue).count();
            let take = self.cfg.take(waiting, batching);
            let batch: Vec<&Query> =
                (0..take).map(|_| queue.pop_front().unwrap()).collect();
            session.submit_batch(&batch)?;
        }
        Ok(())
    }
}

/// N independent [`Server`]s — each with its own planning cache, memory
/// pool, and simulated SoC — serving a partition of the task set.
///
/// Sharding models scaling *out*: shards run in parallel on separate
/// (simulated) hardware, so the aggregate report takes the maximum
/// makespan across shards while summing query counts. Per-task arrival
/// streams are identical to the unsharded run (streams are generated
/// from the scenario, then routed), which makes single-server and
/// sharded runs directly comparable.
///
/// The sharded path is simulation-only: attach a PJRT runtime to a plain
/// [`Server`] instead when real execution is needed.
pub struct ShardedServer<'a> {
    shards: Vec<Server<'a>>,
    sharding: Sharding,
}

impl<'a> ShardedServer<'a> {
    /// Build `sharding.shards` servers over the shared zoo, latency
    /// model, and profiles, all with the same serving options.
    pub fn build(
        zoo: &'a Zoo,
        lm: &'a LatencyModel,
        profiles: &'a BTreeMap<String, TaskProfile>,
        opts: ServeOpts,
        sharding: Sharding,
    ) -> ShardedServer<'a> {
        let n = sharding.shards.max(1);
        let shards = (0..n)
            .map(|_| Server::builder(zoo, lm, profiles).opts(opts.clone()).build())
            .collect();
        ShardedServer { shards, sharding: Sharding { shards: n, ..sharding } }
    }

    /// Number of shards (≥ 1).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard serves `task` under this server's assignment.
    pub fn shard_of(&self, task: &str) -> usize {
        self.sharding.shard_of(task)
    }

    /// The shard servers themselves (e.g. to inspect per-shard plans).
    pub fn servers(&self) -> &[Server<'a>] {
        &self.shards
    }

    /// Run a whole scenario across the shards: generate each phase's
    /// stream once, route queries to their task's shard — routing
    /// follows this server's **build-time** [`Sharding`], so build from
    /// `scenario.sharding` (as the CLI does) when the scenario declares
    /// one — and drive every shard's session through the scenario's
    /// [`Dispatch`] config. Each
    /// shard plans against the scenario restricted to its own partition
    /// (task list *and* SLO schedule filtered; an explicit `universe` is
    /// kept as-is, an empty one derives per shard), so a shard's
    /// budgeted selections cover only tasks it actually serves.
    ///
    /// Multi-phase schedules are merged per shard with the same
    /// summation [`Server::run`] applies, but each phase plans against a
    /// freshly budgeted pool — the persistent cross-phase pool of
    /// `Server::run_schedule` (§3.4 switch-cost dynamics) is not modeled
    /// on the sharded path.
    pub fn run(&self, scenario: &Scenario) -> Result<ShardedReport> {
        // The online re-planning path (scenario.planner.replan) drives
        // all shards through one interleaved loop so it can observe
        // cross-shard backlog and migrate tasks mid-phase. Closed loops
        // are self-clocking (no backlog) and never saturate.
        if scenario.planner.replan
            && self.shards.len() > 1
            && !matches!(scenario.arrival, Arrival::ClosedLoop { .. })
        {
            return self.run_replan(scenario);
        }
        let n = self.shards.len();
        let mut shard_tasks: Vec<Vec<String>> = vec![Vec::new(); n];
        for task in &scenario.tasks {
            shard_tasks[self.shard_of(task)].push(task.clone());
        }
        let dispatcher = Dispatcher::new(scenario.dispatch.clone());
        let mut per_shard: Vec<RunReport> = vec![RunReport::default(); n];
        let mut budget_utilization = vec![0.0f64; n];
        for phase in 0..scenario.phases() {
            let mut parts: Vec<Vec<Query>> = vec![Vec::new(); n];
            for q in scenario.stream(phase) {
                let shard = self.shard_of(&q.task);
                parts[shard].push(q);
            }
            for (i, server) in self.shards.iter().enumerate() {
                if shard_tasks[i].is_empty() {
                    continue;
                }
                let sub = sub_scenario(scenario, &shard_tasks[i]);
                let mut session = server.session(&sub, phase)?;
                dispatcher.drive(&mut session, &parts[i])?;
                budget_utilization[i] = session.pool_utilization();
                // Phases of one shard are sequential, like Server::run.
                per_shard[i].merge_sequential(session.finish());
            }
        }
        let mut aggregate = RunReport::default();
        for report in &per_shard {
            // Shards are parallel SoCs: wall-clock is the slowest shard.
            aggregate.merge_parallel(report.clone());
        }
        Ok(ShardedReport {
            per_shard,
            aggregate,
            replans: 0,
            migrations: 0,
            budget_utilization,
        })
    }

    /// The online re-planning drive: every shard gets a session (empty
    /// shards included — they are migration targets), queries are
    /// issued in global simulated-time order, and after each booking
    /// the just-served shard's backlog is checked against its
    /// saturation threshold (`PlannerConfig::saturation_slack ×` the
    /// mean SLO latency bound of its tasks). On saturation,
    /// `Planner::replan` proposes one bounded migration: the hottest
    /// still-queued task moves to the least-loaded shard, its variant
    /// re-selected batch-aware under its hotness share of the target
    /// pool budget, and its first query there floored at the source
    /// shard's last completion (per-task FIFO is never reordered).
    fn run_replan(&self, scenario: &Scenario) -> Result<ShardedReport> {
        let n = self.shards.len();
        let coord = self.shards[0].coordinator();
        let planner = SparsityAwarePlanner::new(coord.zoo, coord.lm, coord.profiles);
        let universe = scenario.slo_universe();
        let mut assignment: BTreeMap<String, usize> = scenario
            .tasks
            .iter()
            .map(|t| (t.clone(), self.shard_of(t)))
            .collect();
        let mut per_shard: Vec<RunReport> = vec![RunReport::default(); n];
        let mut budget_utilization = vec![0.0f64; n];
        let mut replans = 0usize;
        let mut migrations = 0usize;
        for phase in 0..scenario.phases() {
            let slos = &scenario.schedule[phase];
            let mut sessions = Vec::with_capacity(n);
            for (i, server) in self.shards.iter().enumerate() {
                let tasks_i: Vec<String> = scenario
                    .tasks
                    .iter()
                    .filter(|t| assignment[*t] == i)
                    .cloned()
                    .collect();
                sessions.push(server.session(&sub_scenario(scenario, &tasks_i), phase)?);
            }
            // Committed placement orders + pool capacities per shard:
            // the planner re-selects a migrant against the target's.
            let shard_orders: Vec<Vec<Processor>> = sessions
                .iter()
                .map(|s| s.planned_order().to_vec())
                .collect();
            let shard_pool_bytes: Vec<u64> =
                sessions.iter().map(|s| s.pool_capacity()).collect();
            let mut pending: BTreeMap<String, VecDeque<Query>> = BTreeMap::new();
            for q in scenario.stream(phase) {
                if !assignment.contains_key(&q.task) {
                    bail!(
                        "query {} targets task {:?} not in this scenario",
                        q.id,
                        q.task
                    );
                }
                pending.entry(q.task.clone()).or_default().push_back(q);
            }
            let batching = scenario.dispatch.is_batching();
            let mut budget_left = scenario.planner.max_migrations;
            loop {
                // Globally earliest-issue task first, across all shards.
                let mut next: Option<(&String, f64)> = None;
                for task in &scenario.tasks {
                    let Some(queue) = pending.get(task) else { continue };
                    let Some(q) = queue.front() else { continue };
                    let ready = sessions[assignment[task]]
                        .ready_of(task)
                        .unwrap_or(0.0);
                    let issue = q.arrival_ms.max(ready);
                    if next.map(|(_, t)| issue < t).unwrap_or(true) {
                        next = Some((task, issue));
                    }
                }
                let Some((task, issue)) = next else { break };
                let task = task.clone();
                let shard = assignment[&task];
                let queue = pending.get_mut(&task).unwrap();
                // Same coalescing rule as Dispatcher::drive.
                let waiting =
                    queue.iter().take_while(|q| q.arrival_ms <= issue).count();
                let take = scenario.dispatch.take(waiting, batching);
                let batch: Vec<Query> =
                    (0..take).map(|_| queue.pop_front().unwrap()).collect();
                let refs: Vec<&Query> = batch.iter().collect();
                sessions[shard].submit_batch(&refs)?;

                if budget_left == 0 {
                    continue;
                }
                // --- saturation check -------------------------------------
                // Backlog as admission sees it: per task, the queueing
                // delay its *next pending* query is headed for
                // (ready − arrival), summed per shard. Tasks with no
                // queued work contribute nothing.
                let mut shard_backlog = vec![0.0f64; n];
                for (t, &si) in &assignment {
                    let Some(front) = pending.get(t).and_then(|q| q.front()) else {
                        continue;
                    };
                    let ready = sessions[si].ready_of(t).unwrap_or(0.0);
                    shard_backlog[si] += (ready - front.arrival_ms).max(0.0);
                }
                let mut slo_sum = 0.0;
                let mut slo_n = 0usize;
                for (t, &si) in &assignment {
                    if si == shard {
                        if let Some(slo) = slos.get(t) {
                            slo_sum += slo.max_latency_ms;
                            slo_n += 1;
                        }
                    }
                }
                if slo_n == 0 {
                    continue;
                }
                let threshold =
                    scenario.planner.saturation_slack * slo_sum / slo_n as f64;
                if shard_backlog[shard] <= threshold {
                    continue;
                }
                // Cheap pre-checks before invoking the planner (the
                // hotness scan is the expensive part): a strictly
                // less-loaded target must exist, and some task on the
                // saturated shard must still have queued work AND not
                // have been served by another shard this phase (a
                // second adoption would break FIFO floors).
                let has_target = shard_backlog
                    .iter()
                    .enumerate()
                    .any(|(i2, &b)| i2 != shard && b < shard_backlog[shard]);
                let movable: Vec<String> = scenario
                    .tasks
                    .iter()
                    .filter(|t| assignment[*t] == shard)
                    .filter(|t| {
                        pending.get(*t).map(|q| !q.is_empty()).unwrap_or(false)
                    })
                    .filter(|t| {
                        !sessions.iter().enumerate().any(|(i2, s)| {
                            i2 != shard && s.ready_of(t).is_some()
                        })
                    })
                    .cloned()
                    .collect();
                if !has_target || movable.is_empty() {
                    continue;
                }
                replans += 1;
                let mut mean_batch = BTreeMap::new();
                for t in &scenario.tasks {
                    if let Some(mb) = sessions[assignment[t]].mean_batch_of(t) {
                        mean_batch.insert(t.clone(), mb);
                    }
                }
                let prior = ShardPlan {
                    assignment: assignment.clone(),
                    shards: n,
                    slos: slos.clone(),
                    universe: universe.clone(),
                };
                let observed = ShardObservation {
                    saturated: shard,
                    shard_backlog_ms: shard_backlog,
                    shard_orders: shard_orders.clone(),
                    shard_pool_bytes: shard_pool_bytes.clone(),
                    movable,
                    mean_batch,
                };
                let Some(mig) = planner.replan(&prior, &observed) else {
                    continue;
                };
                debug_assert!(sessions[mig.to].ready_of(&mig.task).is_none());
                let Some(slo) = slos.get(&mig.task).copied() else { continue };
                let floor = sessions[mig.from].ready_of(&mig.task).unwrap_or(0.0);
                sessions[mig.to].adopt_task(&mig.task, slo, mig.selection, floor)?;
                assignment.insert(mig.task.clone(), mig.to);
                migrations += 1;
                budget_left -= 1;
            }
            for (i, session) in sessions.into_iter().enumerate() {
                budget_utilization[i] = session.pool_utilization();
                per_shard[i].merge_sequential(session.finish());
            }
        }
        let mut aggregate = RunReport::default();
        for report in &per_shard {
            aggregate.merge_parallel(report.clone());
        }
        Ok(ShardedReport {
            per_shard,
            aggregate,
            replans,
            migrations,
            budget_utilization,
        })
    }
}

/// Restrict a scenario to one shard's partition: the task list and
/// every schedule entry. SLOs of foreign tasks would otherwise leak
/// into the shard's planning and (budget < 1) preloading.
fn sub_scenario(scenario: &Scenario, tasks: &[String]) -> Scenario {
    let schedule: Vec<BTreeMap<String, Slo>> = scenario
        .schedule
        .iter()
        .map(|cfg| {
            cfg.iter()
                .filter(|&(t, _)| tasks.contains(t))
                .map(|(t, slo)| (t.clone(), *slo))
                .collect()
        })
        .collect();
    scenario.clone().with_tasks(tasks).with_schedule(schedule)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tests::{setup, slos};
    use crate::fixtures;
    use crate::scenario::{Admission, PlannerConfig};
    use crate::workload::Slo;

    fn tiny_tasks() -> Vec<String> {
        vec!["tiny".to_string()]
    }

    /// A dense same-task arrival ramp that must build backlog.
    fn ramp(task: &str, n: usize, gap_ms: f64) -> Vec<Query> {
        (0..n)
            .map(|i| Query {
                task: task.to_string(),
                arrival_ms: i as f64 * gap_ms,
                id: i as u64,
            })
            .collect()
    }

    #[test]
    fn batching_never_reorders_requests_within_a_task() {
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        // ~17 ms service vs 1 ms inter-arrival: heavy backlog.
        let sc = Scenario::trace(&tiny_tasks(), slos(0.5, 1e9), ramp("tiny", 40, 1.0))
            .with_dispatch(Dispatch { max_batch: 4, min_queue: 2 });
        let report = server.run(&sc).unwrap();
        assert_eq!(report.total_queries, 40);
        assert!(
            report.total_batches < 40,
            "backlog must trigger coalescing ({} batches)",
            report.total_batches
        );
        assert!(report.mean_batch_size() > 1.0);
        assert!(report.outcomes[0].max_batch > 1);
        assert!(report.outcomes[0].max_batch <= 4);
        // FIFO within the task: ids in arrival order, times monotone.
        let ids: Vec<u64> = report.requests.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted, "batching must not reorder a task's queries");
        for w in report.requests.windows(2) {
            assert!(w[1].start_ms >= w[0].start_ms - 1e-9);
            assert!(w[1].finish_ms >= w[0].finish_ms - 1e-9);
        }
    }

    #[test]
    fn below_threshold_dispatch_matches_unbatched_run() {
        // A batching dispatcher whose threshold is never reached must
        // reproduce the unbatched run event-for-event.
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let base = Scenario::poisson(&tiny_tasks(), slos(0.5, 1e9), 30.0, 3_000.0)
            .with_seed(5);
        let plain = server.run(&base).unwrap();
        let gated = server
            .run(
                &base
                    .clone()
                    .with_dispatch(Dispatch { max_batch: 8, min_queue: usize::MAX }),
            )
            .unwrap();
        assert_eq!(plain.total_queries, gated.total_queries);
        assert_eq!(plain.total_batches, gated.total_batches);
        assert!((plain.makespan_ms - gated.makespan_ms).abs() < 1e-6);
        for (a, b) in plain.requests.iter().zip(&gated.requests) {
            assert_eq!(a.id, b.id);
            assert!((a.start_ms - b.start_ms).abs() < 1e-9);
            assert!((a.finish_ms - b.finish_ms).abs() < 1e-9);
        }
    }

    #[test]
    fn batching_drains_backlog_faster() {
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let sc = Scenario::trace(&tiny_tasks(), slos(0.5, 1e9), ramp("tiny", 60, 1.0));
        let alone = server.run(&sc).unwrap();
        let batched = server
            .run(&sc.clone().with_dispatch(Dispatch::batched(4)))
            .unwrap();
        assert_eq!(alone.total_queries, batched.total_queries);
        assert!(
            batched.makespan_ms < alone.makespan_ms,
            "batch 4 must drain faster: {} vs {} ms",
            batched.makespan_ms,
            alone.makespan_ms
        );
        // Sub-linear batch cost ⇒ strictly higher throughput.
        assert!(batched.throughput_qps() > alone.throughput_qps());
    }

    #[test]
    fn sharding_partitions_tasks_and_aggregates_reports() {
        let (zoo, lm, profiles) = fixtures::trio();
        let tasks = fixtures::task_names(&zoo);
        let slo_map = fixtures::slos(&zoo, 0.5, 1e9);
        let sc = Scenario::poisson(&tasks, slo_map, 10.0, 2_000.0).with_seed(3);

        let single = Server::builder(&zoo, &lm, &profiles).build().run(&sc).unwrap();
        let sharded = ShardedServer::build(
            &zoo,
            &lm,
            &profiles,
            ServeOpts::default(),
            Sharding::hash(2),
        );
        let report = sharded.run(&sc).unwrap();

        assert_eq!(report.per_shard.len(), 2);
        // Every task is served by exactly one shard.
        let served: usize = report.per_shard.iter().map(|r| r.outcomes.len()).sum();
        assert_eq!(served, tasks.len());
        // Aggregate counts are the per-shard sums; makespan is the max.
        assert_eq!(
            report.aggregate.total_queries,
            report.per_shard.iter().map(|r| r.total_queries).sum::<usize>()
        );
        let max_ms = report
            .per_shard
            .iter()
            .map(|r| r.makespan_ms)
            .fold(0.0f64, f64::max);
        assert!((report.aggregate.makespan_ms - max_ms).abs() < 1e-9);
        // Same arrivals, everything admitted: identical completed counts.
        assert_eq!(report.aggregate.total_queries, single.total_queries);
        assert_eq!(report.aggregate.total_dropped, 0);
        // Less contention can only finish no later than the single SoC.
        assert!(report.aggregate.makespan_ms <= single.makespan_ms + 1e-6);
    }

    #[test]
    fn explicit_assignment_and_fallbacks() {
        let sharding = Sharding::explicit(
            BTreeMap::from([("alpha".to_string(), 1), ("beta".to_string(), 5)]),
            2,
        );
        assert_eq!(sharding.shard_of("alpha"), 1);
        // Out-of-range indices wrap instead of panicking.
        assert_eq!(sharding.shard_of("beta"), 1);
        // Unlisted tasks fall back to the hash rule.
        assert_eq!(
            sharding.shard_of("gamma"),
            crate::workload::shard_of_task("gamma", 2)
        );
        // Degenerate configs are clamped.
        assert_eq!(Sharding::hash(0).shards, 1);
        assert_eq!(Dispatch::batched(0).max_batch, 1);
        assert!(!Dispatch::none().is_batching());
    }

    #[test]
    fn sharded_batched_beats_single_server_under_backlog() {
        // The headline property: a bursty overload scenario completes
        // strictly more requests with 2 shards × batch-4 dispatch than
        // the single-server unbatched baseline under the same deadline
        // admission (see `experiments::endtoend::backlog_comparison`).
        let (zoo, lm, profiles) = fixtures::trio();
        let tasks = fixtures::task_names(&zoo);
        let slo_map = fixtures::slos(&zoo, 0.5, 60.0);
        let sc = Scenario::bursty(&tasks, slo_map, 4.0, 120.0, 500.0, 4_000.0)
            .with_seed(11)
            .with_admission(Admission::Deadline { slack: 2.0 });

        let single = Server::builder(&zoo, &lm, &profiles).build().run(&sc).unwrap();
        assert!(single.total_dropped > 0, "baseline must actually be overloaded");

        let scaled = ShardedServer::build(
            &zoo,
            &lm,
            &profiles,
            ServeOpts::default(),
            Sharding::hash(2),
        )
        .run(&sc.clone().with_dispatch(Dispatch::batched(4)))
        .unwrap();

        assert!(
            scaled.aggregate.total_queries > single.total_queries,
            "2 shards × batch 4 must complete strictly more: {} vs {}",
            scaled.aggregate.total_queries,
            single.total_queries
        );
        assert!(scaled.aggregate.total_dropped < single.total_dropped);
    }

    #[test]
    fn replan_beats_static_sharding_under_backlog() {
        // The acceptance property: under bursty overload with a skewed
        // static partition (three flooded tasks share shard 0, one
        // idles on shard 1), the batch-aware plan with online
        // re-planning completes at least as many requests with fewer
        // SLO-shed drops than the PR 2 static sharded baseline — and
        // never reorders queries within a task.
        let (zoo, lm, profiles) = fixtures::build(&[
            ("alpha", 0.92, 8.0),
            ("beta", 0.88, 12.0),
            ("delta", 0.90, 10.0),
            ("gamma", 0.85, 16.0),
        ]);
        let tasks = fixtures::task_names(&zoo);
        let slo_map = fixtures::slos(&zoo, 0.5, 60.0);
        let sharding = Sharding::explicit(
            BTreeMap::from([
                ("alpha".to_string(), 0),
                ("beta".to_string(), 0),
                ("delta".to_string(), 0),
                ("gamma".to_string(), 1),
            ]),
            2,
        );
        let sc = Scenario::bursty(&tasks, slo_map, 4.0, 100.0, 500.0, 4_000.0)
            .with_seed(11)
            .with_admission(Admission::Deadline { slack: 2.0 })
            .with_dispatch(Dispatch::batched(4))
            .with_sharding(sharding.clone());

        let static_run = ShardedServer::build(
            &zoo,
            &lm,
            &profiles,
            ServeOpts::default(),
            sharding.clone(),
        )
        .run(&sc)
        .unwrap();
        assert!(
            static_run.aggregate.total_dropped > 0,
            "the static partition must actually be overloaded"
        );
        assert_eq!(static_run.migrations, 0, "static path never migrates");

        let replan_sc = sc
            .clone()
            .with_planner(PlannerConfig { max_migrations: 2, ..PlannerConfig::replanning() });
        // Batch-aware Algorithm 1 at the dispatch operating point.
        let opts = ServeOpts { batch_hint: 4.0, ..Default::default() };
        let replanned = ShardedServer::build(&zoo, &lm, &profiles, opts, sharding)
            .run(&replan_sc)
            .unwrap();

        assert!(replanned.migrations >= 1, "saturation must trigger a migration");
        assert!(replanned.replans >= replanned.migrations);
        assert!(
            replanned.aggregate.total_queries >= static_run.aggregate.total_queries,
            "replan must complete at least as many: {} vs {}",
            replanned.aggregate.total_queries,
            static_run.aggregate.total_queries
        );
        assert!(
            replanned.aggregate.total_dropped < static_run.aggregate.total_dropped,
            "replan must shed less: {} vs {}",
            replanned.aggregate.total_dropped,
            static_run.aggregate.total_dropped
        );
        // Per-shard budget utilization is reported for every shard.
        assert_eq!(replanned.budget_utilization.len(), 2);
        assert!(replanned.budget_utilization.iter().all(|&u| (0.0..=1.0).contains(&u)));
        // Planner::replan never reorders queries within a task: in
        // id (= per-task arrival) order, completions stay monotone
        // even across the migration boundary.
        for task in ["alpha", "beta", "delta", "gamma"] {
            let mut reqs: Vec<_> = replanned
                .aggregate
                .requests
                .iter()
                .filter(|r| r.task == task && !r.dropped)
                .collect();
            reqs.sort_by_key(|r| r.id);
            for w in reqs.windows(2) {
                assert!(
                    w[1].start_ms >= w[0].start_ms - 1e-9,
                    "{task}: query {} started before query {}",
                    w[1].id,
                    w[0].id
                );
                assert!(w[1].finish_ms >= w[0].finish_ms - 1e-9, "{task}");
            }
        }
    }

    #[test]
    fn replan_noop_without_saturation_or_on_closed_loops() {
        // A replan-enabled run that never saturates must match the
        // static path's outcome counts; closed loops take the static
        // path outright (self-clocking ⇒ no backlog to observe).
        let (zoo, lm, profiles) = fixtures::trio();
        let tasks = fixtures::task_names(&zoo);
        let light = Scenario::poisson(&tasks, fixtures::slos(&zoo, 0.5, 1e9), 2.0, 2_000.0)
            .with_seed(3);
        let build = || {
            ShardedServer::build(
                &zoo,
                &lm,
                &profiles,
                ServeOpts::default(),
                Sharding::hash(2),
            )
        };
        let plain = build().run(&light).unwrap();
        let replan = build()
            .run(&light.clone().with_planner(PlannerConfig::replanning()))
            .unwrap();
        assert_eq!(replan.migrations, 0, "no saturation ⇒ no migration");
        assert_eq!(replan.aggregate.total_queries, plain.aggregate.total_queries);
        assert_eq!(replan.aggregate.total_dropped, plain.aggregate.total_dropped);

        let closed = Scenario::closed_loop(&tasks, fixtures::slos(&zoo, 0.5, 1e9))
            .with_queries(5)
            .with_planner(PlannerConfig::replanning());
        let r = build().run(&closed).unwrap();
        assert_eq!(r.migrations, 0);
        assert_eq!(r.aggregate.total_queries, 15);
    }

    #[test]
    fn fair_with_single_task_equals_deadline() {
        // With no other tasks the share clause can never fire (both
        // sides of the strict comparison are zero), so Fair must shed
        // exactly like Deadline — a single-task shard keeps admission
        // control.
        let (zoo, lm, profiles) = setup();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let heavy = Scenario::poisson(&tiny_tasks(), slos(0.5, 50.0), 200.0, 2_000.0)
            .with_seed(7);
        let deadline = server
            .run(&heavy.clone().with_admission(Admission::Deadline { slack: 2.0 }))
            .unwrap();
        let fair = server
            .run(&heavy.with_admission(Admission::Fair {
                slack: 2.0,
                weights: BTreeMap::new(),
            }))
            .unwrap();
        assert!(deadline.total_dropped > 0, "overload must shed");
        assert_eq!(fair.total_dropped, deadline.total_dropped);
        assert_eq!(fair.total_queries, deadline.total_queries);
        assert!((fair.makespan_ms - deadline.makespan_ms).abs() < 1e-9);
        // Asserted, not assumed: the two runs agree event-for-event.
        assert_eq!(fair.requests.len(), deadline.requests.len());
        for (f, d) in fair.requests.iter().zip(&deadline.requests) {
            assert_eq!(f.id, d.id);
            assert_eq!(f.dropped, d.dropped);
            assert!((f.start_ms - d.start_ms).abs() < 1e-9);
            assert!((f.finish_ms - d.finish_ms).abs() < 1e-9);
        }
    }

    #[test]
    fn fair_admission_degenerate_weights_never_divide_by_zero() {
        // Explicit zero weights must be inert, not a division hazard:
        // with every weight zero the share clause compares 0 < 0 and
        // Fair degrades to exactly Deadline — finite outcomes, no NaN
        // timestamps, identical event logs.
        let (zoo, lm, profiles) = fixtures::trio();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        let tasks = fixtures::task_names(&zoo);
        let heavy = Scenario::poisson(&tasks, fixtures::slos(&zoo, 0.5, 40.0), 120.0, 2_000.0)
            .with_seed(9);
        let deadline = server
            .run(&heavy.clone().with_admission(Admission::Deadline { slack: 1.5 }))
            .unwrap();
        assert!(deadline.total_dropped > 0, "overload must shed");
        let zero_weights: BTreeMap<String, f64> =
            tasks.iter().map(|t| (t.clone(), 0.0)).collect();
        let fair = server
            .run(&heavy.clone().with_admission(Admission::Fair {
                slack: 1.5,
                weights: zero_weights,
            }))
            .unwrap();
        assert_eq!(fair.total_dropped, deadline.total_dropped);
        assert_eq!(fair.total_queries, deadline.total_queries);
        assert_eq!(fair.requests.len(), deadline.requests.len());
        for (f, d) in fair.requests.iter().zip(&deadline.requests) {
            assert_eq!((f.id, f.dropped), (d.id, d.dropped));
            assert!(f.start_ms.is_finite() && f.finish_ms.is_finite());
            assert!((f.finish_ms - d.finish_ms).abs() < 1e-9);
        }
        // A single zero-weighted task among weighted floods loses only
        // its share-clause bonus — it still keeps the Deadline floor,
        // so every outcome stays finite and accounted.
        let one_zero = server
            .run(&heavy.with_admission(Admission::Fair {
                slack: 1.5,
                weights: BTreeMap::from([("alpha".to_string(), 0.0)]),
            }))
            .unwrap();
        assert_eq!(
            one_zero.total_queries + one_zero.total_dropped,
            one_zero.requests.len()
        );
        assert!(one_zero.requests.iter().all(|r| r.finish_ms.is_finite()));
        let f = one_zero.fairness_index();
        assert!(f.is_finite() && f > 0.0);
    }

    #[test]
    fn fair_admission_protects_weighted_task_burst() {
        let (zoo, lm, profiles) = fixtures::trio();
        let server = Server::builder(&zoo, &lm, &profiles).build();
        // alpha and beta flood (1 query/ms each); deadline admission
        // throttles them at their own generous budget (2 × 100 ms), so
        // by t ≈ 400 ms both hold ≈ 200 ms of standing backlog. Then
        // gamma — the latency-critical tenant with a tight 2 × 30 ms
        // budget — takes a 20-query burst at t = 600 ms. Under plain
        // `Deadline` the burst's own queue blows gamma's small budget
        // after a handful of queries and the tail is shed; under
        // weighted-fair admission gamma's per-weight backlog (8× weight)
        // stays well under the floods' standing per-weight backlog, so
        // the whole burst is admitted.
        let mut queries = ramp("alpha", 1_500, 1.0);
        for (k, q) in ramp("beta", 1_500, 1.0).into_iter().enumerate() {
            queries.push(Query { id: 5_000 + k as u64, ..q });
        }
        for i in 0..20u64 {
            queries.push(Query {
                task: "gamma".to_string(),
                arrival_ms: 600.0 + 0.1 * i as f64,
                id: 10_000 + i,
            });
        }
        let tasks: Vec<String> =
            ["alpha", "beta", "gamma"].iter().map(|s| s.to_string()).collect();
        let mut slo_map = BTreeMap::new();
        for flood in ["alpha", "beta"] {
            slo_map
                .insert(flood.to_string(), Slo { min_accuracy: 0.5, max_latency_ms: 100.0 });
        }
        slo_map.insert("gamma".to_string(), Slo { min_accuracy: 0.5, max_latency_ms: 30.0 });
        let base = Scenario::trace(&tasks, slo_map, queries);

        let deadline = server
            .run(&base.clone().with_admission(Admission::Deadline { slack: 2.0 }))
            .unwrap();
        let fair = server
            .run(&base.with_admission(Admission::Fair {
                slack: 2.0,
                weights: BTreeMap::from([("gamma".to_string(), 8.0)]),
            }))
            .unwrap();

        let completed = |r: &RunReport, task: &str| {
            r.outcomes
                .iter()
                .find(|o| o.task == task)
                .map(|o| o.queries_completed)
                .unwrap()
        };
        // Plain deadline admission sheds most of the burst…
        assert!(deadline.outcomes.iter().any(|o| o.queries_dropped > 0));
        assert!(
            completed(&deadline, "gamma") < 10,
            "deadline admission must shed the burst tail (completed {})",
            completed(&deadline, "gamma")
        );
        // …while weighted-fair admission keeps the weighted task whole.
        assert_eq!(
            completed(&fair, "gamma"),
            20,
            "fair admission must keep the weighted task's burst whole"
        );
        // The floods are still shed at their own deadline budget.
        assert!(
            fair.outcomes.iter().find(|o| o.task == "alpha").unwrap().queries_dropped > 0,
            "fair admission must still throttle the flood"
        );
        // The index stays within Jain bounds on both runs.
        for r in [&deadline, &fair] {
            let f = r.fairness_index();
            assert!((1.0 / 3.0..=1.0).contains(&f), "Jain bounds: {f}");
        }
    }
}
