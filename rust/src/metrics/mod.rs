//! Serving metrics: SLO violation rate, throughput, latency/memory
//! breakdowns — the quantities every figure in §5 reports.

pub mod sketch;

use std::collections::BTreeMap;

use crate::json::Json;
use crate::trace::{self, TraceEvent};
use crate::util::stats;

pub use sketch::QuantileSketch;

/// One request's life cycle through the serving engine — emitted per
/// query by `scenario::Session::submit` (arrival → queueing → placement
/// → completion → SLO verdict).
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub id: u64,
    pub task: String,
    /// When the query entered the system (virtual ms).
    pub arrival_ms: f64,
    /// When its first subgraph stage started executing.
    pub start_ms: f64,
    /// When its last stage completed.
    pub finish_ms: f64,
    /// Inference (service) latency — the SLO-judged quantity: stage
    /// executions plus any switch penalty charged to this query.
    pub service_ms: f64,
    /// Time spent waiting before the first stage started.
    pub queueing_ms: f64,
    /// Rejected by admission control (or had no runnable variant):
    /// nothing was booked for it.
    pub dropped: bool,
    /// Per-request latency verdict against the task's SLO at submit
    /// time (`None` when dropped).
    pub slo_ok: Option<bool>,
}

/// Outcome of serving one task under one SLO configuration.
#[derive(Clone, Debug)]
pub struct TaskOutcome {
    pub task: String,
    /// Accuracy of the variant that served the task (estimated at plan
    /// time, oracle-checked in experiments), if any was selected.
    pub accuracy: Option<f64>,
    /// Mean per-query end-to-end latency (virtual ms).
    pub mean_latency_ms: f64,
    /// Worst single-query latency (virtual ms; 0.0 when nothing
    /// completed).
    pub max_latency_ms: f64,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Mean time queries spent queued before their first stage ran.
    pub mean_queueing_ms: f64,
    pub queries_completed: usize,
    /// Queries rejected by admission control (open-loop overload).
    pub queries_dropped: usize,
    /// Dispatch batches that served this task (a lone query counts as
    /// one batch; equals `queries_completed` when batching is off).
    pub batches: usize,
    /// Largest coalesced batch dispatched for this task.
    pub max_batch: usize,
    /// Completed queries whose per-request latency verdict failed
    /// (`service_ms > slo_latency_ms`) — the streaming violation
    /// counter; sums to `RunReport::slo_miss_count`.
    pub slo_misses: usize,
    /// SLO bounds it was judged against.
    pub slo_accuracy: f64,
    pub slo_latency_ms: f64,
}

impl TaskOutcome {
    /// The paper's violation predicate: fails accuracy OR latency (or
    /// had no feasible variant at all).
    pub fn violated(&self) -> bool {
        match self.accuracy {
            None => true,
            Some(acc) => {
                acc < self.slo_accuracy || self.mean_latency_ms > self.slo_latency_ms
            }
        }
    }

    /// Structured JSON view (for `serve --json` / `exp ... --json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task", Json::Str(self.task.clone())),
            (
                "accuracy",
                self.accuracy.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("mean_latency_ms", Json::Num(self.mean_latency_ms)),
            ("max_latency_ms", Json::Num(self.max_latency_ms)),
            ("p50_latency_ms", Json::Num(self.p50_latency_ms)),
            ("p95_latency_ms", Json::Num(self.p95_latency_ms)),
            ("p99_latency_ms", Json::Num(self.p99_latency_ms)),
            ("mean_queueing_ms", Json::Num(self.mean_queueing_ms)),
            ("queries_completed", Json::Num(self.queries_completed as f64)),
            ("queries_dropped", Json::Num(self.queries_dropped as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("slo_misses", Json::Num(self.slo_misses as f64)),
            ("slo_accuracy", Json::Num(self.slo_accuracy)),
            ("slo_latency_ms", Json::Num(self.slo_latency_ms)),
            ("violated", Json::Bool(self.violated())),
        ])
    }
}

/// One serving run: all tasks, one SLO config, one arrival order.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub outcomes: Vec<TaskOutcome>,
    /// Total virtual time to drain all queries (ms).
    pub makespan_ms: f64,
    pub total_queries: usize,
    /// Queries rejected by admission control across all tasks.
    pub total_dropped: usize,
    /// Dispatch batches across all tasks (= `total_queries` when the
    /// dispatcher never coalesces).
    pub total_batches: usize,
    /// Blobs compiled from scratch for a mid-run adoption (migration or
    /// steal) — the cold path warm migration exists to avoid.
    pub cold_compiles: usize,
    /// Blobs that arrived warm from another shard's pool (load, never
    /// compile) during adoption.
    pub warm_loads: usize,
    /// Per-task projected SLO violation rate over the forecast horizon
    /// (observed violation share × forecast load factor, in [0, 1]) —
    /// filled by `Session::finish`; empty for legacy aggregate-only
    /// callers. Merges take the per-task maximum (a task served by
    /// several shards is as at-risk as its worst fragment).
    pub slo_forecast: BTreeMap<String, f64>,
    /// Completed requests whose per-request latency verdict failed —
    /// the streaming counter behind [`RunReport::slo_misses`]. Kept in
    /// both retention modes (the event-log scan it replaced only
    /// worked with `record_events` on).
    pub slo_miss_count: usize,
    /// Whether this run retained its full per-request event log in
    /// `requests`. Streaming-mode runs (the fleet-scale default for
    /// `bench` and `serve` without `--verify`) set this false and keep
    /// `requests` empty; memory is then O(tasks), not O(requests).
    pub record_events: bool,
    /// Per-request event log (arrival/queueing/placement/completion),
    /// in submission order. Empty for legacy aggregate-only callers
    /// and for streaming-mode (`record_events == false`) runs.
    pub requests: Vec<RequestOutcome>,
    /// Virtual time this session's shard spent inside crash windows
    /// (fault lab; 0 without a fault profile).
    pub downtime_ms: f64,
    /// Extra virtual time bookings paid to DVFS-style thermal
    /// throttling on the session's SoC clock (fault lab; 0 without a
    /// throttle curve).
    pub throttled_ms: f64,
    /// Recovery latencies, one per crash window the session rejoined
    /// from: the gap between the window end and the first completion
    /// that finished after it (fault lab; empty without crashes).
    pub recoveries: Vec<f64>,
    /// Structured trace events drained from the session's sink
    /// (`ServeOpts::trace`; empty when tracing is off). Merges
    /// concatenate in fold order — shard-index order on the sharded
    /// paths — which is what makes the canonical trace bit-identical
    /// across threaded and sequential drives.
    pub trace: Vec<TraceEvent>,
}

impl Default for RunReport {
    fn default() -> Self {
        RunReport {
            outcomes: Vec::new(),
            makespan_ms: 0.0,
            total_queries: 0,
            total_dropped: 0,
            total_batches: 0,
            cold_compiles: 0,
            warm_loads: 0,
            slo_forecast: BTreeMap::new(),
            slo_miss_count: 0,
            // Default true so an empty aggregate merges neutrally: the
            // first folded fragment decides the mode (see fold_counts).
            record_events: true,
            requests: Vec::new(),
            downtime_ms: 0.0,
            throttled_ms: 0.0,
            recoveries: Vec::new(),
            trace: Vec::new(),
        }
    }
}

impl RunReport {
    /// Fraction of tasks that violated their SLO.
    pub fn violation_rate(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().filter(|o| o.violated()).count() as f64
            / self.outcomes.len() as f64
    }

    /// Queries per second over the virtual makespan.
    pub fn throughput_qps(&self) -> f64 {
        if self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.total_queries as f64 / (self.makespan_ms / 1000.0)
    }

    /// Completed requests whose per-request latency verdict failed
    /// (`slo_ok == Some(false)`) — the per-request violation count the
    /// predictive-admission study compares across arms. Dropped
    /// requests carry no verdict and are not misses. Served by the
    /// streaming `slo_miss_count` counter, so it works identically
    /// with event retention off.
    pub fn slo_misses(&self) -> usize {
        self.slo_miss_count
    }

    /// Mean coalesced batch size (1.0 when batching never kicked in;
    /// 0.0 when nothing completed).
    pub fn mean_batch_size(&self) -> f64 {
        if self.total_batches == 0 {
            return 0.0;
        }
        self.total_queries as f64 / self.total_batches as f64
    }

    /// Jain fairness index over per-task completion *ratios*
    /// (completed / offered): 1.0 when every task gets the same share of
    /// its offered load served, → 1/T when one task monopolizes
    /// admission. Scale-free, so tasks with different arrival rates
    /// compare fairly. Tasks that were offered no queries are excluded —
    /// an idle task is neither fairly nor unfairly served, and counting
    /// it would dilute real starvation. Outcomes are grouped by task
    /// name first: a task served by several shards (work stealing
    /// splits one task's queries across sessions) contributes a single
    /// ratio over its combined counts, not one ratio per fragment.
    /// Degenerate inputs are vacuously fair (1.0, never NaN): an empty
    /// task set, an all-idle task set, and the all-zero ratio vector
    /// (everything offered was dropped) all have no service shares to
    /// be unequal about.
    pub fn fairness_index(&self) -> f64 {
        let mut by_task: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
        for o in &self.outcomes {
            let e = by_task.entry(o.task.as_str()).or_insert((0, 0));
            e.0 += o.queries_completed;
            e.1 += o.queries_dropped;
        }
        let xs: Vec<f64> = by_task
            .values()
            .filter(|&&(completed, dropped)| completed + dropped > 0)
            .map(|&(completed, dropped)| {
                completed as f64 / (completed + dropped) as f64
            })
            .collect();
        if xs.is_empty() {
            return 1.0;
        }
        let sum: f64 = xs.iter().sum();
        let sq: f64 = xs.iter().map(|x| x * x).sum();
        if sq <= 0.0 {
            return 1.0;
        }
        (sum * sum) / (xs.len() as f64 * sq)
    }

    /// Fold `other` into `self` as a *sequential* continuation (phases
    /// of a schedule): makespans sum, query/batch counts sum, outcomes
    /// and event logs concatenate.
    pub fn merge_sequential(&mut self, other: RunReport) {
        self.makespan_ms += other.makespan_ms;
        self.fold_counts(other);
    }

    /// Fold `other` into `self` as a *parallel* sibling (shards on
    /// separate hardware): wall-clock is the slower of the two, counts
    /// sum, outcomes and event logs concatenate.
    pub fn merge_parallel(&mut self, other: RunReport) {
        self.makespan_ms = self.makespan_ms.max(other.makespan_ms);
        self.fold_counts(other);
    }

    fn fold_counts(&mut self, other: RunReport) {
        self.total_queries += other.total_queries;
        self.total_dropped += other.total_dropped;
        self.total_batches += other.total_batches;
        self.cold_compiles += other.cold_compiles;
        self.warm_loads += other.warm_loads;
        self.downtime_ms += other.downtime_ms;
        self.throttled_ms += other.throttled_ms;
        self.recoveries.extend(other.recoveries);
        for (task, p) in other.slo_forecast {
            let e = self.slo_forecast.entry(task).or_insert(0.0);
            if p > *e {
                *e = p;
            }
        }
        self.slo_miss_count += other.slo_miss_count;
        self.outcomes.extend(other.outcomes);
        // Trace events concatenate unconditionally: they are empty
        // unless tracing was opted into, and (unlike the request log)
        // a partial trace is still a valid, attributable trace.
        self.trace.extend(other.trace);
        // Event logs concatenate only when *both* sides retained them:
        // folding in a streaming-mode fragment means the combined log
        // would be partial, so it is dropped and the merged report
        // carries streaming aggregates only. This is what bounds
        // `ShardedReport` memory at O(tasks) under `record_events ==
        // false` — the logs used to concatenate unconditionally.
        if self.record_events && other.record_events {
            self.requests.extend(other.requests);
        } else {
            self.record_events = false;
            self.requests = Vec::new();
        }
    }

    /// Structured JSON view: all counters plus the derived rates, but
    /// not the per-request log or trace bodies (those have their own
    /// sinks — `--verify` and `--trace` respectively); their sizes are
    /// reported so consumers can tell what was retained.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "outcomes",
                Json::Arr(self.outcomes.iter().map(TaskOutcome::to_json).collect()),
            ),
            ("makespan_ms", Json::Num(self.makespan_ms)),
            ("total_queries", Json::Num(self.total_queries as f64)),
            ("total_dropped", Json::Num(self.total_dropped as f64)),
            ("total_batches", Json::Num(self.total_batches as f64)),
            ("cold_compiles", Json::Num(self.cold_compiles as f64)),
            ("warm_loads", Json::Num(self.warm_loads as f64)),
            (
                "slo_forecast",
                Json::Obj(
                    self.slo_forecast
                        .iter()
                        .map(|(t, p)| (t.clone(), Json::Num(*p)))
                        .collect(),
                ),
            ),
            ("slo_miss_count", Json::Num(self.slo_miss_count as f64)),
            ("record_events", Json::Bool(self.record_events)),
            ("requests_retained", Json::Num(self.requests.len() as f64)),
            ("downtime_ms", Json::Num(self.downtime_ms)),
            ("throttled_ms", Json::Num(self.throttled_ms)),
            (
                "recoveries_ms",
                Json::Arr(self.recoveries.iter().map(|r| Json::Num(*r)).collect()),
            ),
            ("trace_events", Json::Num(self.trace.len() as f64)),
            ("violation_rate", Json::Num(self.violation_rate())),
            ("throughput_qps", Json::Num(self.throughput_qps())),
            ("mean_batch_size", Json::Num(self.mean_batch_size())),
            ("fairness_index", Json::Num(self.fairness_index())),
        ])
    }
}

/// A sharded run: one report per shard plus the cross-shard aggregate.
/// Shards are independent simulated SoCs running in parallel, so the
/// aggregate's makespan is the *maximum* over shards while query counts,
/// outcomes, and event logs are summed/concatenated.
#[derive(Clone, Debug, Default)]
pub struct ShardedReport {
    /// Per-shard reports, indexed by shard id (empty shards yield
    /// default reports).
    pub per_shard: Vec<RunReport>,
    /// The cross-shard roll-up (max makespan, summed counts).
    pub aggregate: RunReport,
    /// Replan evaluations triggered by shard saturation (0 on the
    /// static path).
    pub replans: usize,
    /// Task migrations actually applied (bounded re-sharding).
    pub migrations: usize,
    /// Batches served by a shard other than the task's home shard
    /// (query-granularity work stealing; 0 on the static path).
    pub steals: usize,
    /// Synthesized-variant switches committed by the online synthesis
    /// action (0 unless `PlannerConfig::synthesize` is set).
    pub synths: usize,
    /// Per-shard memory-pool budget utilization (used/capacity) at the
    /// end of the last served phase.
    pub budget_utilization: Vec<f64>,
    /// Telemetry's per-task EWMA arrival-rate estimates (qps) at the
    /// end of the run (empty on the static path, which runs no
    /// telemetry).
    pub arrival_est_qps: BTreeMap<String, f64>,
    /// Total cross-shard link cost (virtual ms) steal/warm-migrate
    /// adoptions paid under a fault-lab link matrix (0 without one).
    pub link_cost_ms: f64,
    /// Control-plane audit events (`TR-CTL-*`) emitted by the
    /// coordinator drive loops — steal/replan/redirect decisions happen
    /// outside any one session, so they land here rather than in a
    /// shard's `RunReport::trace`. Empty when tracing is off.
    pub control_trace: Vec<TraceEvent>,
}

impl ShardedReport {
    /// Violation rate of the aggregate report.
    pub fn violation_rate(&self) -> f64 {
        self.aggregate.violation_rate()
    }

    /// Cross-shard SLO forecast: per task, the worst projected
    /// violation rate over the shards that served it (the aggregate's
    /// max-merged map).
    pub fn slo_forecast(&self) -> &BTreeMap<String, f64> {
        &self.aggregate.slo_forecast
    }

    /// Combined throughput: total queries over the slowest shard's
    /// makespan (shards run in parallel).
    pub fn throughput_qps(&self) -> f64 {
        self.aggregate.throughput_qps()
    }

    /// The run's canonical trace: request-lifecycle events (already
    /// merged into the aggregate in shard-index order) plus the
    /// control-plane audit events, stable-sorted by begin time. Both
    /// inputs are deterministic under `--parallel`, so the canonical
    /// trace is byte-identical across threaded and sequential drives.
    pub fn canonical_trace(&self) -> Vec<TraceEvent> {
        let mut events = self.aggregate.trace.clone();
        events.extend(self.control_trace.iter().cloned());
        trace::canonical(events)
    }

    /// Structured JSON view of the whole sharded run (`serve --json`):
    /// the aggregate, every per-shard report, and the coordinator
    /// counters. Trace bodies are excluded — `--trace` writes those.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "per_shard",
                Json::Arr(self.per_shard.iter().map(RunReport::to_json).collect()),
            ),
            ("aggregate", self.aggregate.to_json()),
            ("replans", Json::Num(self.replans as f64)),
            ("migrations", Json::Num(self.migrations as f64)),
            ("steals", Json::Num(self.steals as f64)),
            ("synths", Json::Num(self.synths as f64)),
            (
                "budget_utilization",
                Json::Arr(
                    self.budget_utilization.iter().map(|u| Json::Num(*u)).collect(),
                ),
            ),
            (
                "arrival_est_qps",
                Json::Obj(
                    self.arrival_est_qps
                        .iter()
                        .map(|(t, q)| (t.clone(), Json::Num(*q)))
                        .collect(),
                ),
            ),
            ("link_cost_ms", Json::Num(self.link_cost_ms)),
            (
                "control_trace_events",
                Json::Num(self.control_trace.len() as f64),
            ),
        ])
    }
}

/// Aggregation over many runs (SLO configs × arrival orders).
#[derive(Clone, Debug, Default)]
pub struct Aggregate {
    pub violation_rates: Vec<f64>,
    pub throughputs: Vec<f64>,
}

impl Aggregate {
    pub fn push(&mut self, r: &RunReport) {
        self.violation_rates.push(r.violation_rate());
        self.throughputs.push(r.throughput_qps());
    }

    pub fn mean_violation_pct(&self) -> f64 {
        100.0 * stats::mean(&self.violation_rates)
    }

    pub fn mean_throughput(&self) -> f64 {
        stats::mean(&self.throughputs)
    }
}

/// Latency breakdown of adding a new variant (paper Fig. 5a).
#[derive(Clone, Copy, Debug, Default)]
pub struct SwitchBreakdown {
    pub compile_ms: f64,
    pub load_ms: f64,
    pub inference_ms: f64,
}

impl SwitchBreakdown {
    pub fn total(&self) -> f64 {
        self.compile_ms + self.load_ms + self.inference_ms
    }

    /// Fraction of the total spent loading (the paper reports ≤ 96.4 %
    /// for compile+load combined, with compile 23.7× and load 3× infer).
    pub fn load_fraction(&self) -> f64 {
        if self.total() <= 0.0 {
            return 0.0;
        }
        (self.compile_ms + self.load_ms) / self.total()
    }
}

/// Render an aligned text table (experiment output).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Per-platform experiment results keyed by method name — the common
/// shape of Figs. 10, 11, 15, 16.
pub type MethodResults = BTreeMap<String, f64>;

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(acc: Option<f64>, lat: f64) -> TaskOutcome {
        TaskOutcome {
            task: "t".into(),
            accuracy: acc,
            mean_latency_ms: lat,
            max_latency_ms: lat,
            p50_latency_ms: lat,
            p95_latency_ms: lat,
            p99_latency_ms: lat,
            mean_queueing_ms: 0.0,
            queries_completed: 100,
            queries_dropped: 0,
            batches: 100,
            max_batch: 1,
            slo_misses: 0,
            slo_accuracy: 0.8,
            slo_latency_ms: 50.0,
        }
    }

    fn outcome_served(completed: usize, dropped: usize) -> TaskOutcome {
        TaskOutcome {
            queries_completed: completed,
            queries_dropped: dropped,
            batches: completed,
            ..outcome(Some(0.9), 40.0)
        }
    }

    fn outcome_named(name: &str, completed: usize, dropped: usize) -> TaskOutcome {
        TaskOutcome { task: name.into(), ..outcome_served(completed, dropped) }
    }

    #[test]
    fn violation_predicate() {
        assert!(!outcome(Some(0.9), 40.0).violated());
        assert!(outcome(Some(0.7), 40.0).violated(), "accuracy miss");
        assert!(outcome(Some(0.9), 60.0).violated(), "latency miss");
        assert!(outcome(None, 0.0).violated(), "no variant");
    }

    #[test]
    fn rates_and_throughput() {
        let r = RunReport {
            outcomes: vec![outcome(Some(0.9), 40.0), outcome(Some(0.7), 40.0)],
            makespan_ms: 2000.0,
            total_queries: 400,
            ..Default::default()
        };
        assert!((r.violation_rate() - 0.5).abs() < 1e-12);
        assert!((r.throughput_qps() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn aggregate_means() {
        let mut agg = Aggregate::default();
        agg.push(&RunReport {
            outcomes: vec![outcome(Some(0.9), 40.0)],
            makespan_ms: 1000.0,
            total_queries: 100,
            ..Default::default()
        });
        agg.push(&RunReport {
            outcomes: vec![outcome(None, 0.0)],
            makespan_ms: 1000.0,
            total_queries: 50,
            ..Default::default()
        });
        assert!((agg.mean_violation_pct() - 50.0).abs() < 1e-9);
        assert!((agg.mean_throughput() - 75.0).abs() < 1e-9);
    }

    #[test]
    fn fairness_index_even_vs_starved() {
        let even = RunReport {
            outcomes: vec![outcome_named("a", 80, 20), outcome_named("b", 40, 10)],
            ..Default::default()
        };
        assert!((even.fairness_index() - 1.0).abs() < 1e-12, "equal ratios");
        let starved = RunReport {
            outcomes: vec![outcome_named("a", 100, 0), outcome_named("b", 5, 95)],
            ..Default::default()
        };
        let f = starved.fairness_index();
        assert!(f < 0.7, "one starved task must drag the index down: {f}");
        assert!(f >= 0.5, "Jain index is bounded below by 1/T: {f}");
        // Idle tasks (zero offered) are excluded, not counted as fair.
        let with_idle = RunReport {
            outcomes: vec![
                outcome_named("a", 100, 0),
                outcome_named("b", 5, 95),
                outcome_named("c", 0, 0),
            ],
            ..Default::default()
        };
        assert!(
            (with_idle.fairness_index() - f).abs() < 1e-12,
            "an idle task must not dilute starvation"
        );
        // Empty report is vacuously fair.
        assert_eq!(RunReport::default().fairness_index(), 1.0);
    }

    #[test]
    fn fairness_index_merges_multi_shard_fragments() {
        // Work stealing splits one task's queries across sessions, so a
        // sharded aggregate holds several TaskOutcome fragments for the
        // same task: the index must judge the task's *combined* ratio,
        // not one ratio per fragment.
        let split = RunReport {
            outcomes: vec![
                outcome_named("a", 60, 40), // home shard: all the drops…
                outcome_named("a", 40, 0),  // …thief shard: clean
                outcome_named("b", 80, 20),
            ],
            ..Default::default()
        };
        // Combined: a = 100/140, b = 80/100 — nearly equal shares.
        let merged = RunReport {
            outcomes: vec![outcome_named("a", 100, 40), outcome_named("b", 80, 20)],
            ..Default::default()
        };
        assert!(
            (split.fairness_index() - merged.fairness_index()).abs() < 1e-12,
            "fragments of one task must merge before the Jain computation"
        );
        assert!(split.fairness_index() > 0.99);
    }

    #[test]
    fn fairness_index_degenerate_inputs_never_nan() {
        // Empty task set: vacuously fair, not NaN.
        let empty = RunReport::default();
        let f = empty.fairness_index();
        assert!(f.is_finite());
        assert_eq!(f, 1.0, "empty task set is vacuously fair");
        // All-idle task set (nothing offered anywhere).
        let idle = RunReport {
            outcomes: vec![outcome_named("a", 0, 0), outcome_named("b", 0, 0)],
            ..Default::default()
        };
        assert_eq!(idle.fairness_index(), 1.0, "idle tasks are excluded");
        // Everything offered was dropped: the all-zero ratio vector has
        // no service shares to be unequal about.
        let starved = RunReport {
            outcomes: vec![outcome_named("a", 0, 10), outcome_named("b", 0, 3)],
            ..Default::default()
        };
        let f = starved.fairness_index();
        assert!(f.is_finite(), "all-dropped must not divide 0/0");
        assert_eq!(f, 1.0);
    }

    #[test]
    fn slo_misses_reads_the_streaming_counter() {
        let r = RunReport { slo_miss_count: 2, ..Default::default() };
        assert_eq!(r.slo_misses(), 2);
        assert_eq!(RunReport::default().slo_misses(), 0);
        // Counters sum across folds regardless of event retention.
        let mut a = RunReport {
            slo_miss_count: 2,
            record_events: false,
            ..Default::default()
        };
        a.merge_parallel(RunReport { slo_miss_count: 3, ..Default::default() });
        assert_eq!(a.slo_misses(), 5);
    }

    #[test]
    fn merge_concatenates_events_only_when_both_sides_retained_them() {
        let req = |id: u64| RequestOutcome {
            id,
            task: "t".into(),
            arrival_ms: 0.0,
            start_ms: 0.0,
            finish_ms: 1.0,
            service_ms: 1.0,
            queueing_ms: 0.0,
            dropped: false,
            slo_ok: Some(true),
        };
        // Both sides recording: logs concatenate.
        let mut both = RunReport { requests: vec![req(0)], ..Default::default() };
        both.merge_parallel(RunReport { requests: vec![req(1)], ..Default::default() });
        assert!(both.record_events);
        assert_eq!(both.requests.len(), 2);
        // A streaming-mode side poisons retention: the partial log is
        // dropped rather than shipped, and the flag sticks through
        // further folds (this is the unbounded-growth fix).
        let mut mixed = RunReport { requests: vec![req(0)], ..Default::default() };
        mixed.merge_parallel(RunReport {
            record_events: false,
            total_queries: 5,
            ..Default::default()
        });
        assert!(!mixed.record_events);
        assert!(mixed.requests.is_empty());
        assert_eq!(mixed.total_queries, 5);
        mixed.merge_sequential(RunReport { requests: vec![req(2)], ..Default::default() });
        assert!(!mixed.record_events, "streaming mode is sticky");
        assert!(mixed.requests.is_empty());
    }

    #[test]
    fn merge_takes_worst_slo_forecast_per_task() {
        let part = |entries: Vec<(&str, f64)>| RunReport {
            slo_forecast: entries
                .into_iter()
                .map(|(t, p)| (t.to_string(), p))
                .collect(),
            ..Default::default()
        };
        let mut a = part(vec![("x", 0.2), ("y", 0.9)]);
        a.merge_parallel(part(vec![("x", 0.6), ("z", 0.1)]));
        assert_eq!(a.slo_forecast["x"], 0.6, "worst fragment wins");
        assert_eq!(a.slo_forecast["y"], 0.9);
        assert_eq!(a.slo_forecast["z"], 0.1);
        // ShardedReport exposes the aggregate map.
        let sr = ShardedReport { aggregate: a.clone(), ..Default::default() };
        assert_eq!(sr.slo_forecast()["x"], 0.6);
    }

    #[test]
    fn merge_folds_sequential_and_parallel() {
        let part = |q: usize, ms: f64| RunReport {
            total_queries: q,
            total_batches: q,
            cold_compiles: 1,
            warm_loads: 2,
            makespan_ms: ms,
            ..Default::default()
        };
        let mut seq = part(10, 100.0);
        seq.merge_sequential(part(5, 50.0));
        assert_eq!(seq.total_queries, 15);
        assert_eq!(seq.total_batches, 15);
        assert_eq!(seq.cold_compiles, 2, "adoption counters sum");
        assert_eq!(seq.warm_loads, 4);
        assert!((seq.makespan_ms - 150.0).abs() < 1e-12, "phases sum");
        let mut par = part(10, 100.0);
        par.merge_parallel(part(5, 50.0));
        assert_eq!(par.total_queries, 15);
        assert_eq!(par.cold_compiles, 2);
        assert!((par.makespan_ms - 100.0).abs() < 1e-12, "shards take the max");
    }

    #[test]
    fn mean_batch_size_and_defaults() {
        let r = RunReport {
            total_queries: 60,
            total_batches: 20,
            ..Default::default()
        };
        assert!((r.mean_batch_size() - 3.0).abs() < 1e-12);
        assert_eq!(RunReport::default().mean_batch_size(), 0.0);
    }

    #[test]
    fn sharded_report_delegates_to_aggregate() {
        let sr = ShardedReport {
            per_shard: vec![RunReport::default(), RunReport::default()],
            aggregate: RunReport {
                outcomes: vec![outcome(Some(0.9), 40.0), outcome(None, 0.0)],
                makespan_ms: 1000.0,
                total_queries: 100,
                ..Default::default()
            },
            ..Default::default()
        };
        assert!((sr.violation_rate() - 0.5).abs() < 1e-12);
        assert!((sr.throughput_qps() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn switch_breakdown_fractions() {
        // Paper Fig. 5a: compile 23.7× infer, load 3× infer.
        let b = SwitchBreakdown { compile_ms: 23.7, load_ms: 3.0, inference_ms: 1.0 };
        assert!(b.load_fraction() > 0.96);
    }

    #[test]
    fn json_view_round_trips_and_excludes_bulky_logs() {
        let sr = ShardedReport {
            per_shard: vec![RunReport::default()],
            aggregate: RunReport {
                outcomes: vec![outcome(Some(0.9), 40.0)],
                makespan_ms: 1000.0,
                total_queries: 100,
                ..Default::default()
            },
            steals: 3,
            ..Default::default()
        };
        let text = sr.to_json().to_string();
        let parsed = crate::json::parse(&text).expect("serve --json parses");
        assert_eq!(parsed.get("steals").unwrap().as_f64().unwrap(), 3.0);
        let agg = parsed.get("aggregate").unwrap();
        assert_eq!(agg.get("total_queries").unwrap().as_f64().unwrap(), 100.0);
        assert_eq!(agg.get("trace_events").unwrap().as_f64().unwrap(), 0.0);
        assert!(agg.get("requests").is_none(), "logs stay out of --json");
        assert!(
            agg.get("outcomes").unwrap().as_arr().unwrap()[0]
                .get("violated")
                .is_some()
        );
    }

    #[test]
    fn table_rendering_aligns() {
        let t = render_table(
            &["method", "value"],
            &[
                vec!["SparseLoom".into(), "1.0".into()],
                vec!["SV-AO-P".into(), "22.5".into()],
            ],
        );
        assert!(t.contains("SparseLoom"));
        assert!(t.lines().count() == 4);
    }
}
